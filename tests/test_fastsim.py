"""Fast-backend parity suite: the trace-compiled numpy/jax simulators must
reproduce the reference ``PipelineSimulator`` -- cycles, WL skips, and
bandwidth-stall cycles -- on arbitrary instruction streams, across all eight
designs and both load-model families (idealized ports and epoch token
buckets), plus the chip-level epoch-arbiter fixed point end to end."""

import dataclasses
import math
import random

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (DESIGNS, GemmSpec, Instr, Op, TABLE_I, get_design,
                        simulate, sweep_designs, sweep_workload)
from repro.core import fastsim
from repro.core.fastsim import (StreamModelParams, _run_numpy_params,
                                run_cores, run_trace_numpy, sweep_trace)
from repro.core.simulator import _simulate_cached
from repro.core.tiling import ALG1_POLICY, lower_gemm, lowered_stream
from repro.core.timing import LoadStreamModel, PipelineSimulator
from repro.core.trace import compile_stream, compiled_trace, gemm_trace
from repro.multicore import ChipConfig, simulate_chip
from repro.multicore.chip import EpochBandwidthLoadModel

needs_jax = pytest.mark.skipif(not fastsim.has_jax(),
                               reason="jax not importable")

SMALL = GemmSpec("small", 128, 256, 256)
REL = 1e-6          # the acceptance bound; numpy is in fact bit-exact


def random_stream(rng: random.Random, n: int) -> list[Instr]:
    """Random but well-defined stream: all registers TL-defined up front,
    then a mix of loads, stores and MMs (including reuse runs, C-chains,
    and MMs whose destination aliases their B register)."""
    stream = [Instr(Op.TL, dst=r, addr=("B", 0, r)) for r in range(8)]
    for _ in range(n):
        x = rng.random()
        if x < 0.3:
            stream.append(Instr(
                Op.TL, dst=rng.randrange(8),
                addr=(rng.choice("ABC"), rng.randrange(4), rng.randrange(4)),
                tm=rng.choice((1, 7, 16)), tk=rng.choice((8, 32)),
                tn=rng.choice((3, 16))))
        elif x < 0.45:
            stream.append(Instr(
                Op.TS, src1=rng.randrange(8),
                addr=("C", rng.randrange(4), 0),
                tm=rng.choice((1, 16)), tn=rng.choice((3, 16))))
        else:
            b = rng.randrange(8)
            # bias toward repeating B registers so WLBP reuse fires
            if rng.random() < 0.5 and stream[-1].op is Op.MM:
                b = stream[-1].src2
            stream.append(Instr(
                Op.MM, dst=rng.randrange(8), src1=rng.randrange(8),
                src2=b, tm=rng.choice((1, 8, 16))))
    return stream


def make_models():
    """(name, model factory, params) for both load-model families."""
    shares = (8.0, 16.0, 48.0)
    return [
        ("port",
         lambda cfg: LoadStreamModel(cfg.load_ports),
         lambda cfg: StreamModelParams(cfg.load_ports)),
        ("epoch",
         lambda cfg: EpochBandwidthLoadModel(
             cfg.load_ports, shares, 256.0, tail_share=64.0,
             burst_bytes=2048.0, store_ports=1, charge_store_bytes=True),
         lambda cfg: StreamModelParams(
             cfg.load_ports, 1, shares, 256.0, 64.0, 2048.0, True)),
        ("static",
         lambda cfg: EpochBandwidthLoadModel(
             cfg.load_ports, (), math.inf, tail_share=12.0,
             burst_bytes=1024.0, store_ports=1, charge_store_bytes=True),
         lambda cfg: StreamModelParams(
             cfg.load_ports, 1, (), math.inf, 12.0, 1024.0, True)),
    ]


def assert_matches(ref, fast, tag=""):
    assert fast.cycles == pytest.approx(ref.cycles, rel=REL), tag
    assert fast.wl_skips == ref.wl_skips, tag
    assert fast.bw_stall_cycles == pytest.approx(
        ref.bw_stall_cycles, rel=REL, abs=1e-6), tag
    assert (fast.n_mm, fast.n_tl, fast.n_ts) == (ref.n_mm, ref.n_tl,
                                                 ref.n_ts), tag
    assert fast.useful_macs == pytest.approx(ref.useful_macs), tag


def _check_stream(stream, designs=None, jax_too=False):
    trace = compile_stream(stream)
    for design in (designs or sorted(DESIGNS)):
        cfg = get_design(design)
        for name, mk_model, mk_params in make_models():
            ref = PipelineSimulator(cfg, load_model=mk_model(cfg)).run(stream)
            tag = f"{design}/{name}"
            # numpy over live model objects (bit-exact by construction)
            fast = run_trace_numpy(trace, cfg, mk_model(cfg))
            assert fast.cycles == ref.cycles, tag
            assert_matches(ref, fast, tag)
            # numpy with inlined stream-model arithmetic
            inl, _ = _run_numpy_params(trace, cfg, mk_params(cfg))
            assert_matches(ref, inl, tag + "/inline")
            if jax_too:
                jx = sweep_trace(trace, [cfg], mk_params(cfg),
                                 backend="jax")[0]
                assert_matches(ref, jx, tag + "/jax")


# ----------------------------------------------------------- fixed streams
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_numpy_parity_random_streams(seed):
    """All 8 designs x all load models on seeded random streams (numpy)."""
    _check_stream(random_stream(random.Random(seed), 120))


@needs_jax
@pytest.mark.parametrize("seed", [0, 5])
def test_jax_parity_random_streams(seed):
    """jax scan parity on random streams (two designs to bound compiles)."""
    _check_stream(random_stream(random.Random(seed), 90),
                  designs=["RASA-WLBP", "RASA-DMDB-WLS"], jax_too=True)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 9), st.integers(1, 200),
       st.sampled_from(sorted(DESIGNS)))
def test_parity_property(seed, n, design):
    """Hypothesis: fast == reference on arbitrary streams and designs."""
    _check_stream(random_stream(random.Random(seed), n), designs=[design])


def test_static_reuse_bits_match_dirty_bit_tracking():
    """The trace's precompiled WLBP reuse bits equal the runtime dirty-bit
    decisions, including when an MM's destination aliases its B register."""
    stream = [
        Instr(Op.TL, dst=7, addr=("B", 0, 0)),
        Instr(Op.TL, dst=4, addr=("A", 0, 0)),
        Instr(Op.MM, dst=0, src1=4, src2=7, tm=16),
        Instr(Op.MM, dst=1, src1=4, src2=7, tm=16),   # reuse
        Instr(Op.MM, dst=7, src1=4, src2=7, tm=16),   # C aliases B
        Instr(Op.MM, dst=1, src1=4, src2=7, tm=16),   # still reusable
        Instr(Op.TL, dst=7, addr=("B", 0, 1)),        # overwrite weights
        Instr(Op.MM, dst=2, src1=4, src2=7, tm=16),   # must reload
    ]
    trace = compile_stream(stream)
    mm_bits = [bool(b) for o, b in zip(trace.opcode, trace.reusable)
               if o == 2]
    assert mm_bits == [False, True, True, True, False]
    cfg = get_design("RASA-WLBP")
    ref = PipelineSimulator(cfg).run(stream)
    assert ref.wl_skips == sum(mm_bits)
    assert run_trace_numpy(trace, cfg).wl_skips == ref.wl_skips


# ------------------------------------------------------------ GEMM parity
@pytest.mark.parametrize("backend", ["numpy"] +
                         (["jax"] if fastsim.has_jax() else []))
def test_simulate_backend_parity(backend):
    ref = simulate(SMALL, "RASA-DMDB-WLS")
    fast = simulate(SMALL, "RASA-DMDB-WLS", backend=backend)
    assert fast.cycles == pytest.approx(ref.cycles, rel=REL)
    assert fast.wl_skips == ref.wl_skips
    assert fast.utilization == pytest.approx(ref.utilization, rel=REL)


@pytest.mark.parametrize("backend", ["numpy"] +
                         (["jax"] if fastsim.has_jax() else []))
def test_sweep_designs_backend_parity(backend):
    ref = sweep_designs(SMALL)
    fast = sweep_designs(SMALL, backend=backend)
    assert set(ref) == set(fast)
    for k in ref:
        assert fast[k].cycles == pytest.approx(ref[k].cycles, rel=REL), k
        assert fast[k].wl_skips == ref[k].wl_skips, k


@needs_jax
def test_sweep_workload_grid_parity():
    wl = [SMALL, TABLE_I["DLRM-2"], GemmSpec("odd", 200, 96, 150)]
    ref = sweep_workload(wl)
    fast = sweep_workload(wl, backend="jax")
    for r, f in zip(ref, fast):
        for k in r:
            assert f[k].cycles == pytest.approx(r[k].cycles, rel=REL), k
            assert f[k].wl_skips == r[k].wl_skips, k


def test_simulate_custom_load_model_falls_back_to_reference():
    """A load model the fast backends cannot express must still be honored
    (silent fallback to the reference loop), not ignored."""
    class Throttled(LoadStreamModel):
        def acquire(self, t_request, n_bytes):
            start, stall = super().acquire(t_request, n_bytes)
            return start + 100.0, stall

    ref = simulate(SMALL, "RASA-WLBP", load_model=Throttled(2))
    fast = simulate(SMALL, "RASA-WLBP", load_model=Throttled(2),
                    backend="fast")
    assert fast.cycles == ref.cycles
    assert fast.cycles > simulate(SMALL, "RASA-WLBP").cycles


# ----------------------------------------------------- caching satellites
def test_simulate_cached_accepts_frozen_engine_config():
    import dataclasses
    cfg = dataclasses.replace(get_design("RASA-WLBP"), name="probe",
                              load_latency=11)
    _simulate_cached.cache_clear()
    a = _simulate_cached(SMALL, cfg, ALG1_POLICY)
    before = _simulate_cached.cache_info().hits
    b = _simulate_cached(SMALL, cfg, ALG1_POLICY)
    assert a is b
    assert _simulate_cached.cache_info().hits == before + 1


def test_lowered_stream_memoized():
    s1 = lowered_stream(SMALL, ALG1_POLICY)
    s2 = lowered_stream(SMALL, ALG1_POLICY)
    assert s1 is s2
    assert list(s1) == list(lower_gemm(SMALL, ALG1_POLICY))


def test_compiled_trace_cached_and_consistent():
    t1 = compiled_trace((SMALL,), ALG1_POLICY)
    t2 = gemm_trace(SMALL, ALG1_POLICY)
    assert t1 is t2
    assert t1.n_mm + t1.n_tl + t1.n_ts == len(t1)
    assert t1.n_mm == sum(1 for i in lowered_stream(SMALL, ALG1_POLICY)
                          if i.op is Op.MM)


# ------------------------------------------------- chip-level arbiter parity
def _skewed():
    return [TABLE_I["DLRM-2"], SMALL, SMALL, SMALL, SMALL, SMALL]


@pytest.mark.parametrize("arbitration", ["static", "epoch"])
@pytest.mark.parametrize("backend", ["numpy"] +
                         (["jax"] if fastsim.has_jax() else []))
def test_chip_backend_parity(arbitration, backend):
    """run_streams fixed point: fast backends match the reference chip
    simulation -- makespan, stalls, arbiter trace -- under a binding
    budget."""
    mk = lambda be: simulate_chip(
        _skewed(), ChipConfig(n_cores=2, design="RASA-WLBP",
                              bw_bytes_per_cycle=24.0,
                              arbitration=arbitration, backend=be),
        scheduler="work_queue")
    ref, fast = mk("reference"), mk(backend)
    assert fast.cycles == pytest.approx(ref.cycles, rel=REL)
    assert fast.bw_stall_cycles == pytest.approx(ref.bw_stall_cycles,
                                                 rel=REL, abs=1e-6)
    assert fast.wl_skips == ref.wl_skips
    assert fast.n_mm == ref.n_mm
    assert fast.arb_rounds == ref.arb_rounds
    assert fast.share_trace == pytest.approx(ref.share_trace)
    assert fast.active_trace == ref.active_trace


def test_run_cores_epoch_parity_with_last_grant():
    """Batched run_cores reproduces per-core reference runs of the epoch
    bucket exactly, including the activity horizon (last_grant)."""
    cfg = get_design("RASA-WLBP")
    shares = (8.0, 12.0, 24.0)
    specs = [SMALL, GemmSpec("odd", 200, 96, 150)]
    streams = [lowered_stream(s, ALG1_POLICY) for s in specs]
    traces = [compiled_trace((s,), ALG1_POLICY) for s in specs]
    tails = (24.0, 48.0)
    params = [StreamModelParams(cfg.load_ports, 1, shares, 1024.0, t,
                                2048.0, True) for t in tails]
    refs = []
    for s, t in zip(streams, tails):
        m = EpochBandwidthLoadModel(cfg.load_ports, shares, 1024.0, t,
                                    2048.0, 1, True)
        r = PipelineSimulator(cfg, load_model=m).run(s)
        refs.append((r, m.last_grant))
    backends = ["numpy"] + (["jax"] if fastsim.has_jax() else [])
    for be in backends:
        for (rr, rlg), (fr, flg) in zip(
                refs, run_cores(traces, cfg, params, backend=be)):
            assert fr.cycles == pytest.approx(rr.cycles, rel=REL), be
            assert fr.wl_skips == rr.wl_skips, be
            assert flg == pytest.approx(rlg, rel=REL), be


# ------------------------------------------------- arbiter short-circuit
def test_arbiter_records_skipped_rounds():
    """The epoch relaxation skips cores whose visible share schedule is
    unchanged, records them per round, and still converges to the same
    fixed point as the skip-free reference backend."""
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0)
    wl = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"], TABLE_I["DLRM-2"],
          TABLE_I["BERT-1"], TABLE_I["DLRM-2"], TABLE_I["DLRM-2"]]
    fast = simulate_chip(wl, chip, scheduler="lpt")
    ref = simulate_chip(wl, dataclasses.replace(chip, backend="reference"),
                        scheduler="lpt")
    assert fast.cycles == pytest.approx(ref.cycles, rel=REL)
    assert len(fast.arb_skipped) == fast.arb_rounds
    assert fast.arb_skipped[0] == 0           # round 1 simulates everyone
    assert sum(fast.arb_skipped) > 0          # later rounds skip someone
    # the reference path never skips (it is the oracle)
    assert ref.arb_skipped == (0,) * ref.arb_rounds


def test_single_core_fast_equals_reference_chip():
    """n=1 chip reduction holds on every backend."""
    ref = simulate(SMALL, "RASA-DMDB-WLS")
    for be in ("reference", "numpy", "fast"):
        rep = simulate_chip(SMALL, ChipConfig(n_cores=1,
                                              design="RASA-DMDB-WLS",
                                              backend=be))
        assert rep.cycles == pytest.approx(ref.cycles, rel=REL), be
        assert rep.bw_stall_cycles == 0.0, be


# ------------------------------------------------ resumable segment runner
def test_run_segment_resume_parity():
    """Resuming the inlined numpy recurrence from a snapshot is bit-exact:
    under the unchanged schedule from any snapshot, and under a schedule
    whose shares changed only past the snapshot's horizon -- the invariant
    the online chip model's re-simulation path rests on."""
    from repro.core.fastsim import run_segment
    cfg = get_design("RASA-WLBP")
    E = 2048.0
    stream = random_stream(random.Random(11), 1500)
    trace = compile_stream(stream)
    shares_a = tuple([6.0, 9.0, 12.0, 18.0, 24.0, 32.0] * 6)
    pa = StreamModelParams(cfg.load_ports, 1, shares_a, E, 64.0,
                           2048.0, True)
    ra, lga, snaps = run_segment(trace, cfg, pa, snap_stride=128)
    assert snaps
    for s1, s2 in zip(snaps, snaps[1:]):
        assert s2.i > s1.i and s2.horizon >= s1.horizon
    for s in snaps[::4]:
        r2, lg2, _ = run_segment(trace, cfg, pa, carry=s)
        assert (r2.cycles, lg2, r2.wl_skips, r2.bw_stall_cycles) == \
            (ra.cycles, lga, ra.wl_skips, ra.bw_stall_cycles), s.i
    resumed_any = False
    for x in (8, 16, 24):
        shares_b = shares_a[:x] + tuple(v * 0.5 for v in shares_a[x:])
        pb = StreamModelParams(cfg.load_ports, 1, shares_b, E, 48.0,
                               2048.0, True)
        rb, lgb, _ = run_segment(trace, cfg, pb)
        model = EpochBandwidthLoadModel(cfg.load_ports, shares_b, E, 48.0,
                                        2048.0, 1, True)
        ref = PipelineSimulator(cfg, load_model=model).run(stream)
        assert rb.cycles == ref.cycles and lgb == model.last_grant, x
        usable = [s for s in snaps if s.horizon <= x * E]
        if not usable:
            continue
        resumed_any = True
        r2, lg2, _ = run_segment(trace, cfg, pb, carry=usable[-1])
        assert (r2.cycles, lg2, r2.wl_skips, r2.bw_stall_cycles) == \
            (rb.cycles, lgb, rb.wl_skips, rb.bw_stall_cycles), x
    assert resumed_any          # the scenario must actually exercise resume


# ------------------------------------------------ online chip parity
def _online_scenario(backend):
    """Staggered arrivals + a queued mid-run injection on a tight budget."""
    from repro.multicore import OnlineChip
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=24.0, backend=backend)
    oc = OnlineChip(chip, snap_stride=512)
    segs = [oc.submit(0, [TABLE_I["DLRM-2"]])]
    oc.advance_to(2)
    segs.append(oc.submit(1, [SMALL]))               # arrival mid-run
    oc.advance_to(4)
    segs.append(oc.submit(0, [GemmSpec("odd", 200, 96, 150)]))  # queued
    segs.append(oc.submit(1, [SMALL]))
    oc.drain()
    return oc, segs


def test_online_chip_backend_parity():
    """Every arrival/departure of the online scenario lands identically on
    the reference, numpy and fast backends: per-segment finish times,
    makespan, and the converged share/active traces."""
    ref, rsegs = _online_scenario("reference")
    for be in ["numpy", "fast"]:
        oc, segs = _online_scenario(be)
        assert oc.makespan == pytest.approx(ref.makespan, rel=REL), be
        for s, rs in zip(segs, rsegs):
            assert oc.finish_time(s) == pytest.approx(
                ref.finish_time(rs), rel=REL), (be, s.sid)
            assert (s.start, s.end) == (rs.start, rs.end), (be, s.sid)
        assert oc.share_trace == pytest.approx(ref.share_trace), be
        assert oc.active_trace == ref.active_trace, be
        # the fast path must actually resume from snapshots, not replay
        assert oc.stats["sims_resumed"] > 0, be
        assert oc.stats["instrs_resumed_past"] > 0, be
