"""Multi-core chip model tests: partition coverage, single-core reduction,
scaling monotonicity, bandwidth contention (static + epoch-dynamic
arbitration, conservation), store-traffic accounting, and workload
scheduling including gang splits."""

import dataclasses
import math
from collections import defaultdict

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import DESIGNS, GemmSpec, TABLE_I, simulate
from repro.core.designs import get_design
from repro.core.engine import simulate_chip as core_simulate_chip
from repro.core.timing import LoadStreamModel, PipelineSimulator
from repro.multicore import (ChipConfig, EpochBandwidthLoadModel,
                             SharedBandwidthLoadModel, partition_gemm,
                             simulate_chip, split_ways)
from repro.multicore.chip import CoreCluster, _lower_many
from repro.multicore.partition import PARTITIONERS, _best_grid
from repro.multicore.scheduler import assign

SMALL = GemmSpec("small", 128, 256, 256)
ODD = GemmSpec("odd", 200, 96, 150)       # edge tiles in M and N
TILE_BYTES = 1024                         # largest single tile transfer


def _skewed_workload():
    return [TABLE_I["DLRM-2"], SMALL, SMALL, SMALL, SMALL, SMALL]


# ------------------------------------------------------------- partitioners
@pytest.mark.parametrize("strategy", PARTITIONERS)
@pytest.mark.parametrize("spec", [SMALL, ODD], ids=lambda s: s.name)
@pytest.mark.parametrize("n_cores", [1, 2, 3, 4, 8, 16])
def test_partition_conserves_macs(strategy, spec, n_cores):
    """Sharding conserves MACs: per-core MACs must sum to the GEMM's MACs
    (a K-split's ReduceSpec contributes zero -- a reduction multiplies
    nothing)."""
    shards = partition_gemm(spec, n_cores, strategy)
    assert len(shards) == n_cores
    total = sum(s.macs for shard in shards for s in shard)
    assert total == spec.macs
    gemms = [s for shard in shards for s in shard
             if isinstance(s, GemmSpec)]
    if strategy == "k_split":
        assert all(s.M == spec.M and s.N == spec.N for s in gemms)
        assert sum(s.K for s in gemms) == spec.K
    else:
        for s in gemms:
            assert s.K == spec.K        # output-space: K is never split


@pytest.mark.parametrize("n_cores", [2, 3, 4, 8])
def test_k_split_emits_one_reduction(n_cores):
    """A live K-split carries exactly one ReduceSpec, hosted by core 0,
    with one way per live K-chunk."""
    from repro.core.tiling import ReduceSpec
    shards = partition_gemm(SMALL, n_cores, "k_split")
    reduces = [s for shard in shards for s in shard
               if isinstance(s, ReduceSpec)]
    live = sum(1 for shard in shards
               if any(isinstance(s, GemmSpec) for s in shard))
    if live > 1:
        assert len(reduces) == 1
        assert reduces[0].ways == live
        assert reduces[0].M == SMALL.M and reduces[0].N == SMALL.N
        assert isinstance(shards[0][-1], ReduceSpec)
    else:
        assert not reduces


def test_k_split_n1_is_the_unsplit_gemm():
    """n_cores=1: one shard, same dims, no reduction."""
    [shard] = partition_gemm(SMALL, 1, "k_split")
    [only] = shard
    assert (only.M, only.K, only.N) == (SMALL.M, SMALL.K, SMALL.N)


def test_partition_more_cores_than_tiles():
    tiny = GemmSpec("tiny", 16, 32, 16)     # a single tile
    shards = partition_gemm(tiny, 8, "m_split")
    occupied = [s for s in shards if s]
    assert len(occupied) == 1
    assert occupied[0][0].M == 16


def test_split_ways_drops_empty_shards():
    assert split_ways(SMALL, 1, "m_split") == [SMALL]   # identity at w=1
    tiny = GemmSpec("tiny", 16, 32, 16)
    shards = split_ways(tiny, 8, "m_split")
    assert len(shards) == 1 and shards[0].M == 16
    four = split_ways(SMALL, 4, "m_split")
    assert len(four) == 4
    assert sum(s.macs for s in four) == SMALL.macs


def test_best_grid_prefers_square():
    assert _best_grid(16, 64, 64) == (4, 4)
    assert sorted(_best_grid(8, 64, 64)) == [2, 4]


def test_partition_rejects_unknown_strategy():
    with pytest.raises(ValueError):
        partition_gemm(SMALL, 4, "kn_split")


def test_split_ways_rejects_k_split():
    """Gangs place one shard per core; a K-split's reduction must ride its
    host shard, so split_ways refuses the strategy explicitly."""
    with pytest.raises(ValueError):
        split_ways(SMALL, 2, "k_split")


# ------------------------------------------------------ k_split cost model
def test_k_split_reduction_charges_shared_budget():
    """The reduction's partial traffic is real: tightening the chip budget
    must lengthen a K-split run (the merge bytes queue behind the same
    arbiter as tile loads), and a K-split is never reported cheaper than
    the work it does -- dynamic arbitration stays <= static throughout."""
    spec = GemmSpec("dec", 8, 4096, 512)        # decode shape: 1 tile row
    mk = lambda bw, arb: simulate_chip(
        spec, ChipConfig(n_cores=4, design="RASA-DMDB-WLS",
                         bw_bytes_per_cycle=bw, arbitration=arb),
        partition="k_split")
    loose = mk(math.inf, "epoch")
    tight = mk(32.0, "epoch")
    assert tight.cycles > loose.cycles
    assert tight.bw_stall_cycles > 0.0
    # the merge traffic flows through the span arbiter like any tile load:
    # the dynamic-share schedule must still dominate the frozen shares
    for bw in (32.0, 64.0, 256.0):
        assert mk(bw, "epoch").cycles <= mk(bw, "static").cycles, f"bw={bw}"


def test_k_split_scales_small_m_where_m_split_cannot():
    """The point of the partitioner: a decode GEMM with a single tile row
    cannot occupy more than one core under m_split, but K-split spreads it
    -- and still pays for its reduction (speedup strictly below linear)."""
    spec = GemmSpec("dec", 8, 4096, 512)
    chip = ChipConfig(n_cores=4, design="RASA-DMDB-WLS")
    m = simulate_chip(spec, chip, partition="m_split")
    k = simulate_chip(spec, chip, partition="k_split")
    assert sum(1 for c in m.per_core_cycles if c > 0) == 1
    assert m.speedup == pytest.approx(1.0)
    assert sum(1 for c in k.per_core_cycles if c > 0) == 4
    assert 1.0 < k.speedup < 4.0
    assert k.macs == m.macs == spec.macs


@pytest.mark.parametrize("backend", ["reference", "numpy", "jax"])
def test_k_split_backend_parity(backend):
    """Cross-backend parity on a K-split decode workload: the reduce
    stream (pure TL/TS, no rasa_mm) must time identically on the oracle
    loop and both fast backends."""
    if backend == "jax":
        pytest.importorskip("jax")
    spec = GemmSpec("dec", 8, 1024, 256)
    rep = simulate_chip(spec, ChipConfig(n_cores=4, design="RASA-WLBP",
                                         bw_bytes_per_cycle=64.0,
                                         backend=backend),
                        partition="k_split")
    ref = simulate_chip(spec, ChipConfig(n_cores=4, design="RASA-WLBP",
                                         bw_bytes_per_cycle=64.0,
                                         backend="reference"),
                        partition="k_split")
    assert rep.cycles == ref.cycles
    assert rep.per_core_cycles == ref.per_core_cycles
    assert rep.bw_stall_cycles == pytest.approx(ref.bw_stall_cycles)


# ----------------------------------------------- single-core exact reduction
@pytest.mark.parametrize("design", ["BASE", "RASA-WLBP", "RASA-DMDB-WLS"])
@pytest.mark.parametrize("strategy", PARTITIONERS)
def test_n1_reduces_to_single_core_simreport(design, strategy):
    """At n_cores=1 the chip model must reproduce the single-core simulator
    exactly: the default budget does not bind for one engine."""
    ref = simulate(SMALL, design)
    rep = simulate_chip(SMALL, ChipConfig(n_cores=1, design=design),
                        partition=strategy)
    assert rep.cycles == ref.cycles
    assert rep.speedup == 1.0 and rep.efficiency == 1.0
    assert rep.bw_stall_cycles == 0.0
    assert rep.utilization == pytest.approx(ref.utilization)


@pytest.mark.parametrize("arbitration", ["epoch", "static"])
@pytest.mark.parametrize("scheduler", ["work_queue", "gang"])
def test_n1_scheduler_reduces_to_single_core(scheduler, arbitration):
    """At n_cores=1 the scheduler entry point (submission order preserved by
    work_queue and gang) must reproduce the plain unthrottled single-core
    simulation of the concatenated workload, under both arbitrations."""
    wl = [SMALL, TABLE_I["DLRM-2"], SMALL]
    chip = ChipConfig(n_cores=1, design="RASA-WLBP", arbitration=arbitration)
    cfg = chip.engine
    ref = PipelineSimulator(cfg).run(_lower_many(wl, chip.policy)).cycles
    rep = simulate_chip(wl, chip, scheduler=scheduler)
    assert rep.cycles == ref
    assert rep.bw_stall_cycles == 0.0


def test_engine_reexport_delegates():
    a = core_simulate_chip(SMALL, ChipConfig(n_cores=2))
    b = simulate_chip(SMALL, ChipConfig(n_cores=2))
    assert a == b


# ------------------------------------------------------------------ scaling
@pytest.mark.parametrize("design", ["BASE", "RASA-DMDB-WLS"])
def test_speedup_monotone_under_infinite_bandwidth(design):
    """With no bandwidth cap, adding cores never slows the chip down."""
    chip = lambda n: ChipConfig(n_cores=n, design=design,
                                bw_bytes_per_cycle=math.inf)
    prev = -1.0
    for n in (1, 2, 4, 8, 16):
        rep = simulate_chip(SMALL, chip(n), partition="m_split")
        assert rep.speedup >= prev - 1e-9, f"n={n}"
        assert rep.efficiency <= 1.0 + 1e-9
        prev = rep.speedup


def test_bandwidth_binds_and_degrades_efficiency():
    """Once the shared budget binds, efficiency drops strictly below 1 and
    bandwidth-stall cycles appear; loosening the budget recovers speedup."""
    tight = simulate_chip(SMALL, ChipConfig(n_cores=8, design="RASA-DMDB-WLS",
                                            bw_bytes_per_cycle=64.0))
    loose = simulate_chip(SMALL, ChipConfig(n_cores=8, design="RASA-DMDB-WLS",
                                            bw_bytes_per_cycle=math.inf))
    assert tight.bw_stall_cycles > 0.0
    assert tight.efficiency < 1.0
    assert tight.cycles > loose.cycles
    assert 0.0 < tight.bw_stall_share < 1.0


def test_bw_stall_share_occupied_semantics():
    """bw_stall_share is defined against occupied core-cycles: makespan x
    cores that ran work -- not the sum of per-core runtimes, which would let
    drained-early cores shrink the denominator."""
    rep = simulate_chip(SMALL, ChipConfig(n_cores=8, design="RASA-DMDB-WLS",
                                          bw_bytes_per_cycle=64.0))
    active = sum(1 for c in rep.per_core_cycles if c > 0)
    assert rep.occupied_core_cycles == rep.cycles * active
    assert rep.bw_stall_share == pytest.approx(
        rep.bw_stall_cycles / (rep.cycles * active))
    # more cores than tile rows: idle cores must not enter the denominator
    tiny = GemmSpec("tiny2", 32, 64, 32)    # 2 tile rows
    rep = simulate_chip(tiny, ChipConfig(n_cores=8, design="BASE"),
                        partition="m_split")
    assert sum(1 for c in rep.per_core_cycles if c > 0) == 2
    assert rep.occupied_core_cycles == rep.cycles * 2


# ------------------------------------------------------- arbitration models
def test_shared_bandwidth_model_reduces_to_port_model():
    """share=inf must reproduce the plain load-port arbiter exactly."""
    model = SharedBandwidthLoadModel(2, math.inf)
    starts = [model.acquire(t, 1024) for t in (0.0, 0.0, 0.0, 10.0)]
    assert starts == [(0.0, 0.0), (0.5, 0.0), (1.0, 0.0), (10.0, 0.0)]


def test_throttle_delays_and_reports_stall():
    model = SharedBandwidthLoadModel(2, 1.0, burst_bytes=1024.0)
    t0, s0 = model.acquire(0.0, 1024)       # rides the burst allowance
    t1, s1 = model.acquire(0.0, 1024)       # must wait for bytes to drain
    assert (t0, s0) == (0.0, 0.0)
    assert t1 == pytest.approx(1024.0)
    assert s1 == pytest.approx(1024.0 - 0.5)


def test_token_bucket_caps_banked_allowance():
    """A core idle for a long time cannot bank unbounded credit: allowance
    accrual is capped at burst_bytes (a cumulative leaky-bucket line would
    grant ~98 banked tiles at t=100000 before throttling again)."""
    model = SharedBandwidthLoadModel(2, 1.0, burst_bytes=1024.0)
    model.acquire(0.0, 1024)                # drains the initial burst
    t1, _ = model.acquire(100_000.0, 1024)  # banked tokens capped at 1024
    t2, _ = model.acquire(100_000.0, 1024)  # bank exhausted: refill first
    assert t1 == pytest.approx(100_000.0)
    assert t2 == pytest.approx(100_000.0 + 1024.0)


def test_epoch_model_share_schedule_steps():
    """Shares step at epoch boundaries: a core alone from epoch 1 on is
    granted at the full budget there."""
    model = EpochBandwidthLoadModel(1, shares=(8.0,), epoch_cycles=100.0,
                                    tail_share=64.0, burst_bytes=400.0)
    t0, _ = model.acquire(0.0, 400)   # initial burst: granted immediately
    t1, _ = model.acquire(0.0, 400)   # 8 B/cyc: next 400 B ready at ~50
    t2, _ = model.acquire(0.0, 400)   # rest of epoch 0 refills exactly 400
    t3, _ = model.acquire(0.0, 400)   # epoch 1: tail share 64 B/cyc kicks in
    assert t0 == pytest.approx(0.0)
    assert t1 == pytest.approx(50.0)
    assert t2 == pytest.approx(100.0)
    assert t3 == pytest.approx(100.0 + 400.0 / 64.0, abs=0.2)


@given(shares=st.lists(st.floats(min_value=0.5, max_value=64.0),
                       min_size=1, max_size=8),
       gaps=st.lists(st.floats(min_value=0.0, max_value=32.0),
                     min_size=1, max_size=48),
       sizes=st.lists(st.integers(min_value=1, max_value=2048),
                      min_size=1, max_size=48),
       burst=st.floats(min_value=0.0, max_value=4096.0))
@settings(max_examples=30, deadline=None)
def test_epoch_conservation_property(shares, gaps, sizes, burst):
    """Token-bucket conservation: bytes granted within one epoch never
    exceed that epoch's budget share plus the bounded carryover (burst cap)
    plus the one grant that straddles the epoch edge."""
    E = 256.0
    model = EpochBandwidthLoadModel(2, shares, E, tail_share=8.0,
                                    burst_bytes=burst, record_grants=True)
    t = 0.0
    for gap, size in zip(gaps, sizes):
        t += gap
        model.acquire(t, size)
    per_epoch: dict[int, float] = defaultdict(float)
    for start, n_bytes in model.grants:
        per_epoch[int(start // E)] += n_bytes
    max_tile = max(sizes)
    for e, granted in per_epoch.items():
        share = shares[e] if e < len(shares) else 8.0
        assert granted <= share * E + burst + max_tile + 1e-6, \
            f"epoch {e}: granted {granted} over budget {share * E}"


def test_cluster_epoch_conservation_on_real_streams():
    """Chip-level conservation: replaying the converged schedule with grant
    recording, the cores' aggregate bytes per epoch stay within the chip
    budget (plus per-core burst carryover and straddling-tile slack)."""
    chip = ChipConfig(n_cores=2, design="RASA-WLBP", bw_bytes_per_cycle=24.0,
                      bw_burst_bytes=2048.0)
    cfg = chip.engine
    shards = assign(_skewed_workload(), chip, "work_queue")
    streams = [_lower_many(shard, chip.policy) for shard in shards]
    _, _, trace = CoreCluster(chip).run_streams(streams)
    assert trace is not None and trace.epoch_cycles == chip.epoch_cycles
    per_epoch: dict[int, float] = defaultdict(float)
    for stream in streams:
        model = EpochBandwidthLoadModel(
            cfg.load_ports, trace.shares, trace.epoch_cycles,
            tail_share=chip.bw_bytes_per_cycle,
            burst_bytes=chip.bw_burst_bytes, store_ports=chip.store_ports,
            charge_store_bytes=True, record_grants=True)
        PipelineSimulator(cfg, load_model=model).run(stream)
        for start, n_bytes in model.grants:
            per_epoch[int(start // trace.epoch_cycles)] += n_bytes
    E = trace.epoch_cycles
    budget = chip.bw_bytes_per_cycle
    # per-core slack: burst carryover + the straddling tile + one
    # retroactively-granted store (stores are served out of issue order)
    slack = chip.n_cores * (chip.bw_burst_bytes + 2 * TILE_BYTES)
    for e, granted in per_epoch.items():
        assert granted <= budget * E + slack + 1e-6, f"epoch {e}"


def test_dynamic_arbitration_beats_static_on_skew():
    """Early finishers return their share: on a skewed two-core workload a
    binding budget makes the epoch model's makespan strictly better than the
    frozen static-share model, and never worse anywhere."""
    wl = _skewed_workload()
    mk = lambda arb, bw: simulate_chip(
        wl, ChipConfig(n_cores=2, design="RASA-WLBP", bw_bytes_per_cycle=bw,
                       arbitration=arb), scheduler="work_queue")
    for bw in (24.0, 48.0, 96.0):
        dyn, sta = mk("epoch", bw), mk("static", bw)
        assert dyn.cycles <= sta.cycles, f"bw={bw}"
        assert dyn.n_mm == sta.n_mm
    dyn, sta = mk("epoch", 24.0), mk("static", 24.0)
    assert dyn.bw_stall_cycles > 0.0       # the budget binds...
    assert dyn.cycles < sta.cycles         # ...and dynamic strictly wins
    assert dyn.arbitration == "epoch" and sta.arbitration == "static"


def test_arbiter_trace_monotone_and_consistent():
    """The fixed point's activity trace is non-increasing (cores only ever
    drain) and shares are exactly budget / n_active per epoch."""
    rep = simulate_chip(_skewed_workload(),
                        ChipConfig(n_cores=2, design="RASA-WLBP",
                                   bw_bytes_per_cycle=24.0),
                        scheduler="work_queue")
    assert rep.epoch_cycles > 0 and len(rep.share_trace) > 0
    assert len(rep.share_trace) == len(rep.active_trace)
    for earlier, later in zip(rep.active_trace, rep.active_trace[1:]):
        assert earlier >= later
    for share, n in zip(rep.share_trace, rep.active_trace):
        assert share == pytest.approx(24.0 / n)
    assert rep.arb_rounds >= 2             # at least one horizon shrank


# ------------------------------------------------------- store accounting
def test_store_port_serializes_and_charges_bytes():
    model = SharedBandwidthLoadModel(2, 1.0, burst_bytes=1024.0,
                                     store_ports=1, charge_store_bytes=True)
    t0, s0 = model.acquire_store(0.0, 1024)   # rides the burst allowance
    t1, s1 = model.acquire_store(0.0, 1024)   # waits for tokens to refill
    assert (t0, s0) == (0.0, 0.0)
    assert t1 == pytest.approx(1024.0)
    assert s1 == pytest.approx(1024.0 - 1.0)  # port floor was 1.0


def test_loads_only_switch_recovers_free_stores():
    """store_ports=None (the base model and store_bytes_shared=False) keeps
    the paper's idealized stores: no serialization, no bytes."""
    base = LoadStreamModel(2)
    assert base.acquire_store(3.0, 1 << 20) == (3.0, 0.0)
    model = SharedBandwidthLoadModel(2, 1.0, burst_bytes=0.0)
    assert model.acquire_store(3.0, 1 << 20) == (3.0, 0.0)


def test_store_traffic_pressures_shared_budget():
    """Charging rasa_ts bytes against the chip budget can only lengthen a
    bandwidth-bound run; store_bytes_shared=False recovers the old
    loads-only makespan."""
    on = ChipConfig(n_cores=4, design="RASA-DMDB-WLS", bw_bytes_per_cycle=16.0)
    off = dataclasses.replace(on, store_bytes_shared=False)
    rep_on = simulate_chip(SMALL, on)
    rep_off = simulate_chip(SMALL, off)
    assert rep_on.cycles > rep_off.cycles
    assert rep_on.n_mm == rep_off.n_mm


# ---------------------------------------------------------------- scheduler
def test_work_queue_beats_round_robin_on_skew():
    """One big GEMM + many small ones on two cores: round-robin piles small
    GEMMs behind the big one, the dynamic queue routes them away."""
    chip = ChipConfig(n_cores=2, design="RASA-WLBP")
    wl = _skewed_workload()
    static = simulate_chip(wl, chip, scheduler="round_robin")
    dynamic = simulate_chip(wl, chip, scheduler="work_queue")
    assert dynamic.cycles < static.cycles
    assert static.n_mm == dynamic.n_mm      # same work either way


@pytest.mark.parametrize("scheduler", ["round_robin", "work_queue", "lpt"])
def test_schedulers_cover_all_gemms(scheduler):
    chip = ChipConfig(n_cores=3, design="BASE")
    wl = _skewed_workload()
    shards = assign(wl, chip, scheduler)
    names = sorted(s.name for shard in shards for s in shard)
    assert names == sorted(s.name for s in wl)


def test_gang_splits_dominant_gemm_and_beats_lpt():
    """A dominant GEMM that would leave cores idle under whole-GEMM LPT is
    gang-split across them; MACs are conserved through the split."""
    wl = [TABLE_I["DLRM-2"], SMALL, SMALL, SMALL, SMALL]
    chip = ChipConfig(n_cores=3, design="RASA-DMDB-WLS")
    lpt = simulate_chip(wl, chip, scheduler="lpt")
    gang = simulate_chip(wl, chip, scheduler="gang")
    assert gang.cycles < lpt.cycles
    assert gang.macs == lpt.macs == sum(s.macs for s in wl)
    # the dominant GEMM was actually split: its shards appear on >1 core
    gang_cores = sum(1 for core in gang.per_core_gemms
                     if any(n.startswith("DLRM-2") for n in core))
    assert gang_cores > 1


def test_gang_no_split_when_balanced():
    """On a balanced workload (one equal GEMM per core) splitting cannot
    finish earlier, so gang degenerates to whole-GEMM placement."""
    chip = ChipConfig(n_cores=3, design="RASA-WLBP")
    shards = assign([SMALL, SMALL, SMALL], chip, "gang")
    assert sorted(len(s) for s in shards) == [1, 1, 1]
    assert all(s[0].name == "small" for s in shards)


def test_assign_gang_single_spec():
    """gang with a one-GEMM workload: MACs conserved through whatever
    split it picks; a single-tile GEMM cannot split and lands whole."""
    chip = ChipConfig(n_cores=4, design="RASA-WLBP")
    shards = assign([SMALL], chip, "gang")
    assert sum(s.macs for core in shards for s in core) == SMALL.macs
    tiny = GemmSpec("tiny", 16, 32, 16)         # one hardware tile
    shards = assign([tiny], chip, "gang")
    placed = [s for core in shards for s in core]
    assert len(placed) == 1 and placed[0].macs == tiny.macs
    # n_cores=1: the whole workload, in submission order, on core 0
    one = ChipConfig(n_cores=1, design="RASA-WLBP")
    assert assign([SMALL], one, "gang") == [[SMALL]]


def test_assign_incremental_single_core_reduction():
    """n_cores=1: all items in submission order on core 0 -- exactly the
    work_queue placement."""
    from repro.multicore import assign_incremental
    wl = _skewed_workload()
    one = ChipConfig(n_cores=1, design="RASA-WLBP")
    assert assign_incremental(wl, one, [0.0]) == assign(wl, one,
                                                        "work_queue")
    # any backlog estimate: still core 0, still submission order
    assert assign_incremental(wl, one, [1e9]) == [list(wl)]


def test_assign_incremental_respects_backlog_and_groups():
    """Items go to the soonest-free core given the existing backlog;
    grouped items (a serving request's GEMM chain) stay on one core."""
    from repro.multicore import assign_incremental
    chip = ChipConfig(n_cores=2, design="RASA-WLBP")
    # core 0 is busy forever: everything lands on core 1
    placed = assign_incremental([SMALL, ODD], chip, [math.inf, 0.0])
    assert placed[0] == [] and placed[1] == [SMALL, ODD]
    # a group is atomic and returned as given
    group = (SMALL, ODD)
    placed = assign_incremental([group, SMALL], chip, [0.0, 0.0])
    flat = [item for core in placed for item in core]
    assert group in flat and SMALL in flat
    gcore = next(c for c, items in enumerate(placed) if group in items)
    # the single GEMM went to the other core (the group filled the first)
    assert SMALL in placed[1 - gcore]
    with pytest.raises(ValueError):
        assign_incremental([SMALL], chip, [0.0])    # one entry per core


def test_chip_report_aggregates():
    rep = simulate_chip(SMALL, ChipConfig(n_cores=4, design="RASA-WLBP"))
    assert len(rep.per_core_cycles) == 4
    assert rep.cycles == max(rep.per_core_cycles)
    assert rep.macs == SMALL.macs
    assert 0.0 < rep.utilization <= 1.0
    assert 0.0 <= rep.wlbp_rate <= 1.0
    ref = simulate(SMALL, "RASA-WLBP")
    assert rep.n_mm == ref.n_mm


def test_chip_config_validation():
    with pytest.raises(ValueError):
        ChipConfig(n_cores=0)
    with pytest.raises(ValueError):
        ChipConfig(arbitration="cyclic")
    with pytest.raises(ValueError):
        ChipConfig(epoch_cycles=0.0)
    with pytest.raises(ValueError):
        simulate_chip([], ChipConfig(n_cores=2))
