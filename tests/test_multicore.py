"""Multi-core chip model tests: partition coverage, single-core reduction,
scaling monotonicity, bandwidth contention, and workload scheduling."""

import dataclasses
import math

import pytest

from repro.core import DESIGNS, GemmSpec, TABLE_I, simulate
from repro.core.engine import simulate_chip as core_simulate_chip
from repro.multicore import (ChipConfig, SharedBandwidthLoadModel,
                             partition_gemm, simulate_chip)
from repro.multicore.partition import PARTITIONERS, _best_grid
from repro.multicore.scheduler import assign

SMALL = GemmSpec("small", 128, 256, 256)
ODD = GemmSpec("odd", 200, 96, 150)       # edge tiles in M and N


# ------------------------------------------------------------- partitioners
@pytest.mark.parametrize("strategy", PARTITIONERS)
@pytest.mark.parametrize("spec", [SMALL, ODD], ids=lambda s: s.name)
@pytest.mark.parametrize("n_cores", [1, 2, 3, 4, 8, 16])
def test_partition_conserves_macs(strategy, spec, n_cores):
    """Output-space sharding: per-core MACs must sum to the GEMM's MACs."""
    shards = partition_gemm(spec, n_cores, strategy)
    assert len(shards) == n_cores
    total = sum(s.macs for shard in shards for s in shard)
    assert total == spec.macs
    for shard in shards:
        for s in shard:
            assert s.K == spec.K            # K is never split


def test_partition_more_cores_than_tiles():
    tiny = GemmSpec("tiny", 16, 32, 16)     # a single tile
    shards = partition_gemm(tiny, 8, "m_split")
    occupied = [s for s in shards if s]
    assert len(occupied) == 1
    assert occupied[0][0].M == 16


def test_best_grid_prefers_square():
    assert _best_grid(16, 64, 64) == (4, 4)
    assert sorted(_best_grid(8, 64, 64)) == [2, 4]


def test_partition_rejects_unknown_strategy():
    with pytest.raises(ValueError):
        partition_gemm(SMALL, 4, "k_split")


# ----------------------------------------------- single-core exact reduction
@pytest.mark.parametrize("design", ["BASE", "RASA-WLBP", "RASA-DMDB-WLS"])
@pytest.mark.parametrize("strategy", PARTITIONERS)
def test_n1_reduces_to_single_core_simreport(design, strategy):
    """At n_cores=1 the chip model must reproduce the single-core simulator
    exactly: the default budget does not bind for one engine."""
    ref = simulate(SMALL, design)
    rep = simulate_chip(SMALL, ChipConfig(n_cores=1, design=design),
                        partition=strategy)
    assert rep.cycles == ref.cycles
    assert rep.speedup == 1.0 and rep.efficiency == 1.0
    assert rep.bw_stall_cycles == 0.0
    assert rep.utilization == pytest.approx(ref.utilization)


def test_engine_reexport_delegates():
    a = core_simulate_chip(SMALL, ChipConfig(n_cores=2))
    b = simulate_chip(SMALL, ChipConfig(n_cores=2))
    assert a == b


# ------------------------------------------------------------------ scaling
@pytest.mark.parametrize("design", ["BASE", "RASA-DMDB-WLS"])
def test_speedup_monotone_under_infinite_bandwidth(design):
    """With no bandwidth cap, adding cores never slows the chip down."""
    chip = lambda n: ChipConfig(n_cores=n, design=design,
                                bw_bytes_per_cycle=math.inf)
    prev = -1.0
    for n in (1, 2, 4, 8, 16):
        rep = simulate_chip(SMALL, chip(n), partition="m_split")
        assert rep.speedup >= prev - 1e-9, f"n={n}"
        assert rep.efficiency <= 1.0 + 1e-9
        prev = rep.speedup


def test_bandwidth_binds_and_degrades_efficiency():
    """Once the shared budget binds, efficiency drops strictly below 1 and
    bandwidth-stall cycles appear; loosening the budget recovers speedup."""
    tight = simulate_chip(SMALL, ChipConfig(n_cores=8, design="RASA-DMDB-WLS",
                                            bw_bytes_per_cycle=64.0))
    loose = simulate_chip(SMALL, ChipConfig(n_cores=8, design="RASA-DMDB-WLS",
                                            bw_bytes_per_cycle=math.inf))
    assert tight.bw_stall_cycles > 0.0
    assert tight.efficiency < 1.0
    assert tight.cycles > loose.cycles
    assert 0.0 < tight.bw_stall_share < 1.0


def test_shared_bandwidth_model_reduces_to_port_model():
    """share=inf must reproduce the plain load-port arbiter exactly."""
    model = SharedBandwidthLoadModel(2, math.inf)
    starts = [model.acquire(t, 1024) for t in (0.0, 0.0, 0.0, 10.0)]
    assert starts == [(0.0, 0.0), (0.5, 0.0), (1.0, 0.0), (10.0, 0.0)]


def test_throttle_delays_and_reports_stall():
    model = SharedBandwidthLoadModel(2, 1.0, burst_bytes=1024.0)
    t0, s0 = model.acquire(0.0, 1024)       # rides the burst allowance
    t1, s1 = model.acquire(0.0, 1024)       # must wait for bytes to drain
    assert (t0, s0) == (0.0, 0.0)
    assert t1 == pytest.approx(1024.0)
    assert s1 == pytest.approx(1024.0 - 0.5)


# ---------------------------------------------------------------- scheduler
def _skewed_workload():
    return [TABLE_I["DLRM-2"], SMALL, SMALL, SMALL, SMALL, SMALL]


def test_work_queue_beats_round_robin_on_skew():
    """One big GEMM + many small ones on two cores: round-robin piles small
    GEMMs behind the big one, the dynamic queue routes them away."""
    chip = ChipConfig(n_cores=2, design="RASA-WLBP")
    wl = _skewed_workload()
    static = simulate_chip(wl, chip, scheduler="round_robin")
    dynamic = simulate_chip(wl, chip, scheduler="work_queue")
    assert dynamic.cycles < static.cycles
    assert static.n_mm == dynamic.n_mm      # same work either way


@pytest.mark.parametrize("scheduler", ["round_robin", "work_queue", "lpt"])
def test_schedulers_cover_all_gemms(scheduler):
    chip = ChipConfig(n_cores=3, design="BASE")
    wl = _skewed_workload()
    shards = assign(wl, chip, scheduler)
    names = sorted(s.name for shard in shards for s in shard)
    assert names == sorted(s.name for s in wl)


def test_chip_report_aggregates():
    rep = simulate_chip(SMALL, ChipConfig(n_cores=4, design="RASA-WLBP"))
    assert len(rep.per_core_cycles) == 4
    assert rep.cycles == max(rep.per_core_cycles)
    assert rep.macs == SMALL.macs
    assert 0.0 < rep.utilization <= 1.0
    assert 0.0 <= rep.wlbp_rate <= 1.0
    ref = simulate(SMALL, "RASA-WLBP")
    assert rep.n_mm == ref.n_mm


def test_chip_config_validation():
    with pytest.raises(ValueError):
        ChipConfig(n_cores=0)
    with pytest.raises(ValueError):
        simulate_chip([], ChipConfig(n_cores=2))
