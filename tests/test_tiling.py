"""Lowering (GEMM -> RASA stream) tests: correctness for every policy,
edge tiles, instruction counts, and reuse properties."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (ALG1_POLICY, MAX_REUSE_POLICY, GemmSpec, Op,
                        RegPolicy, count_ops, lower_gemm, stream_stats,
                        validate_stream)
from repro.core.tiling import LOW_REUSE_POLICY
from repro.core.engine import reference_gemm, run_gemm
from repro.core.isa import TILE_K, TILE_M, TILE_N


POLICIES = {
    "alg1": ALG1_POLICY,
    "max_reuse": MAX_REUSE_POLICY,
    "low_reuse": LOW_REUSE_POLICY,
    "tall": RegPolicy(mc=4, nc=1, a_regs=2, b_regs=1),
    "wide": RegPolicy(mc=1, nc=4, a_regs=1, b_regs=2),
    "pressure": RegPolicy(mc=3, nc=2, a_regs=1, b_regs=1),
}


@pytest.mark.parametrize("policy", POLICIES.values(), ids=POLICIES.keys())
@pytest.mark.parametrize("shape", [(16, 32, 16), (32, 32, 32), (48, 96, 64),
                                   (17, 33, 15), (3, 2, 1), (100, 64, 40)])
def test_lowering_correct(policy, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    c = rng.normal(size=(m, n)).astype(np.float32)
    got = run_gemm(a, b, c, policy=policy)
    want = reference_gemm(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_alg1_matches_paper_example():
    """Algorithm 1: a 32x32x32 GEMM uses 4 C loads, 2 A + 2 B loads, 4 MMs,
    4 stores -- and the B register is reused on MMs 2 and 4."""
    spec = GemmSpec("alg1", 32, 32, 32)
    stream = list(lower_gemm(spec, ALG1_POLICY))
    ops = count_ops(stream)
    assert ops == {"tl": 8, "ts": 4, "mm": 4}
    stats = stream_stats(spec, ALG1_POLICY)
    assert stats["wlbp_hits"] == 2 and stats["wlbp_rate"] == 0.5


def test_mm_count_formula():
    spec = GemmSpec("x", 100, 70, 40)
    stats = stream_stats(spec)
    assert stats["mm"] == math.ceil(100 / TILE_M) * math.ceil(70 / TILE_K) * math.ceil(40 / TILE_N)


def test_reuse_rates():
    spec = GemmSpec("x", 256, 256, 256)
    assert stream_stats(spec, ALG1_POLICY)["wlbp_rate"] == pytest.approx(0.5, abs=0.01)
    # 240 = 15 M-tiles = 3 full mc=5 blocks -> exact (mc-1)/mc rate
    spec5 = GemmSpec("x", 240, 256, 256)
    assert stream_stats(spec5, MAX_REUSE_POLICY)["wlbp_rate"] == pytest.approx(0.8, abs=0.01)
    assert stream_stats(spec, LOW_REUSE_POLICY)["wlbp_rate"] == 0.0


def test_exact_tiles_shorten_ff():
    """Beyond-paper: AMX-tilecfg exact edge tiles reduce cycles vs padded."""
    from repro.core import simulate
    spec = GemmSpec("b1", 1, 512, 512)      # batch 1: tm=1 with exact tiles
    padded = simulate(spec, "BASE", RegPolicy())
    exact = simulate(spec, "BASE", RegPolicy(pad_tiles=False))
    assert exact.cycles < padded.cycles


def test_stream_is_valid():
    for policy in POLICIES.values():
        validate_stream(lower_gemm(GemmSpec("v", 33, 65, 47), policy))


def test_policy_register_budget():
    with pytest.raises(ValueError):
        RegPolicy(mc=4, nc=2, a_regs=2, b_regs=2)   # 12 > 8


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 80), st.integers(1, 96), st.integers(1, 64),
       st.sampled_from(list(POLICIES.values())))
def test_lowering_correct_property(m, k, n, policy):
    """Property: lowering + functional engine == mixed-precision reference
    for arbitrary GEMM dims and any register policy."""
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    c = rng.normal(size=(m, n)).astype(np.float32)
    got = run_gemm(a, b, c, policy=policy)
    want = reference_gemm(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
