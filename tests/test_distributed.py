"""Distribution tests: sharding rules, sequence-parallel flash decode, and
gradient-compression collective.  Multi-device cases run in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count (the main test
process keeps the real 1-device view, like the smoke tests)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.config import ParallelConfig
from repro.distributed.sharding import (activation_spec, param_spec,
                                        MeshContext)


class FakeMesh:
    def __init__(self, shape_map, axis_names):
        self.shape = shape_map
        self.axis_names = axis_names


def ctx(pods=1):
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    shape = {"data": 16, "model": 16}
    if pods > 1:
        shape["pod"] = pods
    return MeshContext(mesh=FakeMesh(shape, names),
                       parallel=ParallelConfig(pods=pods))


def test_param_rules_single_pod():
    c = ctx()
    assert param_spec("wq", (32, 6144, 8192), c) == P(None, ("data",), "model")
    assert param_spec("wo", (32, 8192, 6144), c) == P(None, "model", ("data",))
    assert param_spec("embedding", (256000, 2048), c) == P("model", ("data",))
    assert param_spec("norm1", (32, 2048), c) == P()


def test_param_rules_multi_pod_fsdp():
    c = ctx(pods=2)
    assert param_spec("w_up", (16384, 2048, 8192), c) == \
        P(None, ("pod", "data"), "model")


def test_param_rules_drop_nondivisible():
    c = ctx()
    # vocab 50280 % 16 != 0: the model axis must be dropped, fsdp kept
    assert param_spec("embedding", (50280, 768), c) == P(None, ("data",))
    # granite vocab 49155 also not divisible
    assert param_spec("lm_head", (1536, 49155), c) == P(("data",), None)


def test_activation_specs():
    c = ctx()
    assert activation_spec("btd", c) == P(("data",), "model", None)
    assert activation_spec("logits", c) == P(("data",), None, "model")
    c2 = ctx(pods=2)
    assert activation_spec("tokens", c2) == P(("pod", "data"), None)


MULTIDEV_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import sys
    sys.path.insert(0, "src")

    from repro.launch.mesh import _auto_mesh
    mesh = _auto_mesh((4, 2), ("data", "model"))

    # ---- sequence-parallel flash decode == reference ----
    from repro.serving.sp_decode import sp_flash_decode
    from repro.kernels.ref import ref_decode_attention
    rng = np.random.default_rng(0)
    b, h, s, d = 2, 4, 64, 16
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    lengths = jnp.asarray([37, 64], jnp.int32)
    got = sp_flash_decode(q, k, v, lengths, mesh)
    want = ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("SP_DECODE_OK")

    # ---- compressed psum across the data axis ----
    from repro.optim import compressed_psum
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    r = {"w": jnp.zeros(64)}
    summed, new_r = compressed_psum(g, r, mesh, axis_names=("data",))
    # replicated input summed over 4 data shards ~= 4 * g
    np.testing.assert_allclose(np.asarray(summed["w"]),
                               4 * np.asarray(g["w"]), atol=0.05)
    print("COMPRESSED_PSUM_OK")

    # ---- a sharded train step on the 4x2 mesh runs + matches 1-dev ----
    from repro.configs import get_config
    from repro.models import build_model
    from repro.training import init_train_state
    from repro.training.step import jit_train_step, state_shardings
    from repro.distributed.sharding import mesh_context
    from repro.data import SyntheticLMDataset
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = build_model(cfg)
    data = SyntheticLMDataset(cfg.model, seq_len=32, global_batch=4, seed=0)
    batch = data.batch(0)
    specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch.items()}
    with mesh_context(mesh, cfg.parallel) as ctx:
        state = init_train_state(api, jax.random.key(0))
        step = jit_train_step(api, state, specs, ctx)
        state2, metrics = step(state, batch)
        loss_sharded = float(metrics["loss"])
    # single-device reference
    from repro.training.step import build_train_step
    state = init_train_state(api, jax.random.key(0))
    ref_step = jax.jit(build_train_step(api))
    _, ref_metrics = ref_step(state, batch)
    assert abs(loss_sharded - float(ref_metrics["loss"])) < 5e-2, \\
        (loss_sharded, float(ref_metrics["loss"]))
    print("SHARDED_STEP_OK")
""")


@pytest.mark.slow
def test_multidevice_subprocess():
    res = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                         capture_output=True, text=True, timeout=900,
                         cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]))
    assert "SP_DECODE_OK" in res.stdout, res.stdout + res.stderr
    assert "COMPRESSED_PSUM_OK" in res.stdout, res.stdout + res.stderr
    assert "SHARDED_STEP_OK" in res.stdout, res.stdout + res.stderr
