"""Substrate tests: optimizer, checkpointing (atomic/async/resharding),
data determinism, gradient compression, schedules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import SyntheticLMDataset
from repro.configs import get_config
from repro.optim import (adamw_init, adamw_update, compress_int8,
                         decompress_int8, linear_warmup_cosine)


# ------------------------------------------------------------------- adamw
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(300):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, opt, _ = adamw_update(params, grads, opt, lr=0.1,
                                      weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, metrics = adamw_update(params, huge, opt, lr=0.1, grad_clip=1.0)
    assert metrics["grad_norm"] > 1e8      # reported pre-clip


def test_adamw_bf16_moments():
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    opt = adamw_init(params, dtype="bfloat16")
    assert opt.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(8, jnp.bfloat16)}
    p2, opt2, _ = adamw_update(params, g, opt, lr=1e-2)
    assert p2["w"].dtype == jnp.bfloat16
    assert opt2.v["w"].dtype == jnp.bfloat16


def test_schedule():
    lr0 = linear_warmup_cosine(0, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr10 = linear_warmup_cosine(10, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr100 = linear_warmup_cosine(100, peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0
    assert float(lr10) == pytest.approx(1.0)
    assert float(lr100) == pytest.approx(0.1, abs=1e-3)


# -------------------------------------------------------------- checkpoints
def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 7, state)
    restored, step = restore_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity(tmp_path, monkeypatch):
    """A crash mid-save must not clobber the previous checkpoint."""
    state = _state()
    save_checkpoint(tmp_path, 1, state)

    import repro.checkpoint.store as store
    real_savez = np.savez

    def boom(*a, **kw):
        raise IOError("disk full")
    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(IOError):
        save_checkpoint(tmp_path, 2, _state(1))
    monkeypatch.setattr(np, "savez", real_savez)

    assert latest_step(tmp_path) == 1
    restored, step = restore_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, state))
    assert step == 1
    # no stray tmp dirs
    assert not [p for p in os.listdir(tmp_path) if p.startswith(".tmp")]


def test_checkpoint_corruption_detected(tmp_path):
    state = _state()
    d = save_checkpoint(tmp_path, 3, state)
    # flip bytes in the arrays file
    f = d / "arrays.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        restore_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, state))


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _state(s))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [3, 4]


# --------------------------------------------------------------------- data
def test_data_deterministic_and_step_indexed():
    cfg = get_config("qwen3-1.7b", smoke=True).model
    d1 = SyntheticLMDataset(cfg, seq_len=16, global_batch=4, seed=3)
    d2 = SyntheticLMDataset(cfg, seq_len=16, global_batch=4, seed=3)
    b1 = d1.batch(42)
    b2 = d2.batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions_global_batch():
    cfg = get_config("qwen3-1.7b", smoke=True).model
    hosts = [SyntheticLMDataset(cfg, seq_len=8, global_batch=8, seed=0,
                                n_hosts=4, host_id=i) for i in range(4)]
    batches = [h.batch(0)["tokens"] for h in hosts]
    assert all(b.shape[0] == 2 for b in batches)
    # different hosts see different data
    assert not np.array_equal(batches[0], batches[1])


def test_data_tokens_in_vocab():
    cfg = get_config("gemma-2b", smoke=True).model
    d = SyntheticLMDataset(cfg, seq_len=64, global_batch=2)
    t = d.batch(0)["tokens"]
    assert t.min() >= 0 and t.max() < cfg.vocab


# -------------------------------------------------------------- compression
def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the *accumulated* compressed sum tracks the true
    accumulated gradient (residual stays bounded)."""
    rng = np.random.default_rng(1)
    residual = jnp.zeros(64)
    true_acc = np.zeros(64)
    comp_acc = np.zeros(64)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 0.1
        true_acc += np.asarray(g)
        gf = g + residual
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        residual = gf - deq
        comp_acc += np.asarray(deq)
    # accumulated difference == final residual (telescoping), hence bounded
    np.testing.assert_allclose(true_acc - comp_acc, np.asarray(residual),
                               atol=1e-5)
    assert np.abs(np.asarray(residual)).max() < 0.01
