"""Unified span-arbiter tests: the single fixed-point implementation
(`repro.multicore.arbiter`) serving both the closed-batch cluster and the
open-arrival chip -- closed-vs-online bit-equivalence, share-policy
conservation, demand-weighted shares beating equal shares, heterogeneous
BASE/RASA core mixes end-to-end on every backend, prefix caching and
retired-span pruning."""

import dataclasses
import functools
from collections import defaultdict

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import GemmSpec, TABLE_I, simulate
from repro.core import fastsim
from repro.core.timing import PipelineSimulator
from repro.multicore import (ChipConfig, CoreSpec, DemandWeightedShare,
                             EpochBandwidthLoadModel, OnlineChip,
                             SharePolicy, Span, SpanArbiter,
                             build_share_schedule, get_share_policy,
                             simulate_chip)
from repro.multicore.chip import CoreCluster, _lower_many
from repro.multicore.scheduler import assign

REL = 1e-6
SMALL = GemmSpec("small", 128, 256, 256)
BIG = GemmSpec("big", 256, 768, 768)

#: backends every end-to-end scenario must agree on
BACKENDS = ["reference", "numpy"] + (["jax"] if fastsim.has_jax() else [])


def _skewed_workload():
    return [TABLE_I["DLRM-2"], SMALL, SMALL, SMALL, SMALL, SMALL]


#: the canonical balanced heterogeneous workload: the BASE core runs one
#: copy of the GEMM, the ~6x faster RASA-DMDB-WLS core runs six -- equal
#: unthrottled durations, very different bytes/cycle demands.
HET_WL = [BIG] + [dataclasses.replace(BIG, name=f"b{i}") for i in range(6)]
MIXED2 = ("BASE", "RASA-DMDB-WLS")


# ---------------------------------------------------------------- policies
def test_share_policy_registry():
    assert isinstance(get_share_policy("equal"), SharePolicy)
    assert isinstance(get_share_policy("demand"), DemandWeightedShare)
    p = DemandWeightedShare(floor=0.5)
    assert get_share_policy(p) is p
    with pytest.raises(ValueError):
        get_share_policy("fair")
    assert get_share_policy("equal").weight(123.0) == 1.0
    assert get_share_policy("demand").weight(12.5) == 12.5
    assert get_share_policy("demand").weight(0.0) > 0.0   # floor


@given(spans=st.lists(st.tuples(st.integers(0, 12), st.integers(1, 12),
                                st.floats(min_value=1e-3, max_value=100.0)),
                      min_size=1, max_size=12),
       budget=st.floats(min_value=1.0, max_value=1024.0))
@settings(max_examples=60, deadline=None)
def test_weighted_share_conservation_property(spans, budget):
    """Policy-independent conservation: per epoch, the active spans'
    weighted shares sum to exactly the budget (and never exceed it) --
    grants can then never outrun the budget beyond the bucket slack."""
    sp = [Span(start=s, end=s + d, demands=True, weight=w)
          for s, d, w in spans]
    arb = SpanArbiter(budget, 256.0, "demand")
    arb._rebuild(sp, 0)
    shares = arb.share_trace
    for e in range(len(shares)):
        active = [x for x in sp if x.start <= e < x.end]
        total = sum(shares[e] * x.weight for x in active)
        assert total <= budget * (1 + 1e-9)
        if active:
            assert total == pytest.approx(budget)


def test_equal_weight_schedule_matches_build_share_schedule():
    """With unit weights the engine's schedule is exactly the standalone
    equal-share builder's, bit for bit."""
    spans = [(0, 4), (0, None), (2, 9), (3, 3), (5, 7)]
    shares, n_active = build_share_schedule(spans, 24.0)
    sp = [Span(start=s, end=e, demands=True) for s, e in spans]
    arb = SpanArbiter(24.0, 256.0, "equal")
    arb._rebuild(sp, 0)
    assert list(arb.share_trace) == shares
    assert list(arb.active_trace) == n_active


def test_rebuild_pads_idle_gap():
    """A relaxation whose dirty epoch lies beyond the settled horizon must
    zero-fill the idle gap, not misalign the schedule."""
    arb = SpanArbiter(16.0, 256.0)
    arb._rebuild([Span(start=0, end=2, demands=True)], 0)
    assert arb.active_trace == (1, 1)
    # chip idle during epochs 2..5, new span at 5
    arb._rebuild([Span(start=5, end=7, demands=True)], 5)
    assert arb.active_trace == (1, 1, 0, 0, 0, 1, 1)
    assert arb.share_trace[3] == 0.0       # idle epoch: nothing flows


def test_idle_epoch_share_is_zero():
    """Fully-idle epochs report 0.0 shared bandwidth, not the full budget.

    Pre-fix, ``share_trace`` rendered ``budget`` for epochs with
    ``_wsum[e] == 0`` (in both the plain and ``budget_factors`` branches),
    painting idle gaps as fully-shared in ``ChipReport.share_trace`` and
    the Perfetto counter tracks."""
    spans = [Span(start=0, end=2, demands=True),
             Span(start=4, end=6, demands=True)]
    arb = SpanArbiter(16.0, 256.0)
    arb._rebuild(spans, 0)
    assert arb.share_trace == (16.0, 16.0, 0.0, 0.0, 16.0, 16.0)
    # derated variant: busy epochs scale with the factor, idle stays 0.0
    arb = SpanArbiter(16.0, 256.0, budget_factors=(1.0, 0.5, 0.5, 0.5))
    arb._rebuild(spans, 0)
    assert arb.share_trace == (16.0, 8.0, 0.0, 0.0, 16.0, 16.0)


# ------------------------------------------- single-implementation guard
def test_both_clients_delegate_to_span_arbiter(monkeypatch):
    """The relaxation exists once: both the closed-batch cluster and the
    online chip must route through SpanArbiter.relax."""
    calls = []
    orig = SpanArbiter.relax

    def spy(self, spans, simulate, dirty_from=0, **kwargs):
        calls.append(len(spans))
        return orig(self, spans, simulate, dirty_from, **kwargs)

    monkeypatch.setattr(SpanArbiter, "relax", spy)
    simulate_chip(_skewed_workload(),
                  ChipConfig(n_cores=2, design="RASA-WLBP",
                             bw_bytes_per_cycle=24.0),
                  scheduler="work_queue")
    assert calls, "closed-batch cluster did not delegate to SpanArbiter"
    closed_calls = len(calls)
    oc = OnlineChip(ChipConfig(n_cores=2, design="RASA-WLBP",
                               bw_bytes_per_cycle=24.0))
    oc.submit(0, [SMALL])
    oc.drain()
    assert len(calls) > closed_calls, \
        "online chip did not delegate to SpanArbiter"


# ------------------------------------------- closed-vs-online equivalence
@pytest.mark.parametrize("backend", BACKENDS + ["fast"])
def test_online_all_at_epoch0_reproduces_closed_batch(backend):
    """Submitting every core's shard as one segment at epoch 0 makes the
    open-arrival model the closed batch: per-core cycles, makespan and the
    converged share/active traces must reproduce the closed-batch
    ChipReport bit-exactly on the same backend."""
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=24.0, backend=backend)
    shards = assign(_skewed_workload(), chip, "lpt")
    rep = simulate_chip(_skewed_workload(), chip, scheduler="lpt")

    oc = OnlineChip(chip)
    segs = {c: oc.submit(c, shard) for c, shard in enumerate(shards)
            if shard}
    oc.drain()
    exact = backend != "jax"    # the jax closed path reorders float ops;
    # the online model always runs the numpy segment runner

    def check(a, b):
        if exact:
            assert a == b
        else:
            assert a == pytest.approx(b, rel=REL)

    check(oc.makespan, rep.cycles)
    for c, seg in segs.items():
        check(oc.finish_time(seg), rep.per_core_cycles[c])
        assert seg.start == 0
    assert oc.active_trace == rep.active_trace
    for a, b in zip(oc.share_trace, rep.share_trace):
        check(a, b)


def test_online_epoch0_equivalence_under_demand_policy():
    """The closed-vs-online equivalence holds for the demand-weighted
    policy too: same weights, same weighted schedule, same results."""
    chip = ChipConfig(cores=MIXED2, bw_bytes_per_cycle=48.0,
                      share_policy="demand")
    shards = assign(HET_WL, chip, "lpt")
    rep = simulate_chip(HET_WL, chip, scheduler="lpt")
    oc = OnlineChip(chip)
    segs = {c: oc.submit(c, shard) for c, shard in enumerate(shards)
            if shard}
    oc.drain()
    assert oc.makespan == rep.cycles
    for c, seg in segs.items():
        assert oc.finish_time(seg) == rep.per_core_cycles[c]
        assert seg.weight == pytest.approx(rep.core_weights[c])
    assert oc.active_trace == rep.active_trace


# --------------------------------------------------- demand-weighted shares
def test_demand_weighted_beats_equal_on_skewed_demand():
    """The balanced heterogeneous workload: durations match but the RASA
    core demands ~6x the bytes/cycle of the BASE core.  Equal shares
    throttle the hungry core while the other's unused allowance evaporates
    in the bucket; demand weighting splits the budget in proportion and
    strictly improves the makespan."""
    mk = lambda pol: simulate_chip(
        HET_WL, ChipConfig(cores=MIXED2, bw_bytes_per_cycle=64.0,
                           share_policy=pol), scheduler="lpt")
    eq, dm = mk("equal"), mk("demand")
    assert dm.cycles < eq.cycles * 0.9      # >10% better (measured ~20%)
    assert dm.share_policy == "demand" and eq.share_policy == "equal"
    assert eq.core_weights == (1.0, 1.0)
    w_base, w_rasa = dm.core_weights
    assert w_rasa > 3 * w_base              # the demand skew it measured
    assert dm.macs == eq.macs


def test_demand_weighted_cluster_conservation_on_real_streams():
    """Replaying the converged *weighted* schedule with grant recording:
    aggregate bytes per epoch stay within the chip budget (plus per-core
    burst carryover and straddling-tile slack) -- the conservation
    property is policy-independent."""
    chip = ChipConfig(cores=MIXED2, bw_bytes_per_cycle=48.0,
                      bw_burst_bytes=2048.0, share_policy="demand")
    shards = assign(HET_WL, chip, "lpt")
    streams = [_lower_many(shard, chip.cores[c].policy)
               for c, shard in enumerate(shards)]
    cluster = CoreCluster(chip)
    _, _, trace = cluster.run_streams(streams)
    weights = cluster.core_weights
    per_epoch: dict[int, float] = defaultdict(float)
    max_tile = 0
    for c, stream in enumerate(streams):
        cfg = chip.cores[c].engine
        model = EpochBandwidthLoadModel(
            cfg.load_ports, [s * weights[c] for s in trace.shares],
            trace.epoch_cycles, tail_share=chip.bw_bytes_per_cycle,
            burst_bytes=chip.bw_burst_bytes,
            store_ports=chip.store_ports_for(c),
            charge_store_bytes=True, record_grants=True)
        PipelineSimulator(cfg, load_model=model).run(stream)
        for start, n_bytes in model.grants:
            per_epoch[int(start // trace.epoch_cycles)] += n_bytes
            max_tile = max(max_tile, n_bytes)
    E = trace.epoch_cycles
    budget = chip.bw_bytes_per_cycle
    slack = chip.n_cores * (chip.bw_burst_bytes + 2 * max_tile)
    for e, granted in per_epoch.items():
        assert granted <= budget * E + slack + 1e-6, f"epoch {e}"


def test_demand_policy_static_arbitration_stays_equal():
    """arbitration='static' is the frozen equal-share baseline; the share
    policy only drives the epoch arbiter."""
    rep = simulate_chip(HET_WL,
                        ChipConfig(cores=MIXED2, bw_bytes_per_cycle=48.0,
                                   arbitration="static",
                                   share_policy="demand"),
                        scheduler="lpt")
    assert rep.core_weights == (1.0, 1.0)
    assert rep.share_policy == "equal"     # the report says so, too


# ------------------------------------------------ heterogeneous core mixes
def test_chipconfig_core_vector_validation():
    chip = ChipConfig(cores=MIXED2)
    assert chip.n_cores == 2
    assert chip.cores == (CoreSpec("BASE"), CoreSpec("RASA-DMDB-WLS"))
    assert not chip.homogeneous
    assert chip.design_name == "mixed[BASE+RASA-DMDB-WLS]"
    with pytest.raises(ValueError):
        chip.engine                      # no single engine on a mixed chip
    assert chip.core_engine(0).name == "BASE"
    # homogeneous chips keep the single-engine shorthand
    homo = ChipConfig(n_cores=3, design="RASA-WLBP")
    assert homo.homogeneous and homo.engine.name == "RASA-WLBP"
    assert homo.core_specs == (CoreSpec("RASA-WLBP"),) * 3
    with pytest.raises(ValueError):
        ChipConfig(n_cores=3, cores=MIXED2)          # inconsistent
    with pytest.raises(ValueError):
        ChipConfig(cores=())
    with pytest.raises(KeyError):
        ChipConfig(cores=("RASA-TURBO",))            # unknown design
    # single_core picks the requested spec and stays consistent
    one = chip.single_core(1)
    assert one.n_cores == 1 and one.cores == (CoreSpec("RASA-DMDB-WLS"),)


def test_chipconfig_replace_rederives_default_cores():
    """The documented frozen-dataclass idiom keeps working: replacing
    design or n_cores on a default (replicated) chip re-derives the core
    vector; an explicit ``cores`` tuple stays authoritative."""
    base = ChipConfig(n_cores=4)
    rebased = dataclasses.replace(base, design="BASE")
    assert rebased.core_specs == (CoreSpec("BASE"),) * 4
    assert rebased.engine.name == "BASE"
    grown = dataclasses.replace(base, n_cores=8)
    assert grown.n_cores == 8 and len(grown.core_specs) == 8
    # explicit cores: design changes don't silently clobber the mix...
    mixed = ChipConfig(cores=MIXED2)
    redesigned = dataclasses.replace(mixed, design="BASE")
    assert redesigned.core_specs == mixed.core_specs
    # ...and resizing a heterogeneous chip must be explicit
    with pytest.raises(ValueError):
        dataclasses.replace(mixed, n_cores=4)


@functools.lru_cache(maxsize=None)
def _mixed4_report(backend):
    return simulate_chip(
        HET_WL, ChipConfig(cores=("BASE", "BASE", "RASA-WLBP",
                                  "RASA-WLBP"),
                           bw_bytes_per_cycle=48.0, backend=backend),
        scheduler="lpt")


@pytest.mark.parametrize("backend", BACKENDS)
def test_mixed_chip_end_to_end_backend_parity(backend):
    """A mixed BASE/RASA chip runs partition -> schedule -> arbitrate ->
    report on every backend, and the backends agree."""
    chip = lambda be: ChipConfig(cores=("BASE", "BASE", "RASA-WLBP",
                                        "RASA-WLBP"),
                                 bw_bytes_per_cycle=48.0, backend=be)
    ref = _mixed4_report("reference")
    rep = _mixed4_report(backend)
    assert rep.cycles == pytest.approx(ref.cycles, rel=REL)
    assert rep.per_core_cycles == pytest.approx(ref.per_core_cycles,
                                                rel=REL)
    assert rep.bw_stall_cycles == pytest.approx(ref.bw_stall_cycles,
                                                rel=REL, abs=1e-6)
    assert rep.n_mm == ref.n_mm and rep.wl_skips == ref.wl_skips
    assert rep.active_trace == ref.active_trace
    assert rep.core_designs == ("BASE", "BASE", "RASA-WLBP", "RASA-WLBP")
    # the partitioned (single-GEMM) entry point flows through too
    part = simulate_chip(BIG, chip(backend), partition="m_split")
    assert part.cycles > 0 and part.macs == BIG.macs


def test_mixed_chip_partitioned_gemm_all_backends():
    """One GEMM sharded across a mixed chip: every backend agrees and the
    slow cores' shards dominate the makespan."""
    mk = lambda be: simulate_chip(
        BIG, ChipConfig(cores=("BASE", "RASA-DMDB-WLS"),
                        bw_bytes_per_cycle=64.0, backend=be),
        partition="m_split")
    ref = mk("reference")
    for be in [b for b in BACKENDS if b != "reference"]:
        rep = mk(be)
        assert rep.cycles == pytest.approx(ref.cycles, rel=REL), be
        assert rep.per_core_cycles == pytest.approx(ref.per_core_cycles,
                                                    rel=REL), be


def test_het_scheduler_routes_reuse_friendly_to_rasa():
    """On a mixed chip the LPT scheduler must place the dominant
    (WLBP-favoring) GEMMs on the RASA cores that finish them first, and
    the mixed chip must beat the all-BASE chip end to end."""
    chip = ChipConfig(cores=("BASE", "RASA-DMDB-WLS"),
                      bw_bytes_per_cycle=256.0)
    shards = assign(HET_WL, chip, "lpt")
    # the fast core must take the lion's share of the balanced workload
    assert len(shards[1]) > len(shards[0])
    assert len(shards[0]) >= 1              # ...but BASE is not idle
    mixed = simulate_chip(HET_WL, chip, scheduler="lpt")
    allbase = simulate_chip(
        HET_WL, ChipConfig(cores=("BASE", "BASE"),
                           bw_bytes_per_cycle=256.0), scheduler="lpt")
    assert mixed.cycles < allbase.cycles
    assert mixed.macs == allbase.macs


def test_het_scheduler_n1_reduction():
    """A one-core 'mix' reduces exactly to the single-core simulator
    through the scheduler entry point (cf. the homogeneous reduction)."""
    chip = ChipConfig(cores=("RASA-WLBP",))
    wl = [SMALL, TABLE_I["DLRM-2"], SMALL]
    cfg = chip.core_engine(0)
    ref = PipelineSimulator(cfg).run(_lower_many(wl, chip.cores[0].policy))
    for sched in ("work_queue", "lpt", "gang"):
        rep = simulate_chip(wl, chip, scheduler=sched)
        assert rep.cycles == ref.cycles, sched
        assert rep.bw_stall_cycles == 0.0, sched


def test_homogeneous_placements_unchanged_by_per_core_estimates():
    """On a homogeneous chip the per-(GEMM, core) estimates are constant
    across cores, so every scheduler's placement must equal the classic
    free-at rule's -- pinned against a golden placement."""
    chip = ChipConfig(n_cores=3, design="RASA-WLBP")
    wl = _skewed_workload()
    shards = assign(wl, chip, "lpt")
    # LPT: DLRM-2 dominates on core 0, smalls round out the other cores
    names = [tuple(s.name for s in core) for core in shards]
    assert names[0][0] == "DLRM-2"
    assert sorted(n for core in names for n in core) == \
        sorted(s.name for s in wl)


def test_online_mixed_chip_per_core_engines():
    """Online segments run on their core's own engine: the same GEMM
    finishes far faster on the RASA core of a mixed chip."""
    chip = ChipConfig(cores=("BASE", "RASA-DMDB-WLS"),
                      bw_bytes_per_cycle=256.0)
    oc = OnlineChip(chip)
    a = oc.submit(0, [SMALL])
    b = oc.submit(1, [SMALL])
    oc.drain()
    assert oc.finish_time(a) > 2 * oc.finish_time(b)
    ref = simulate(SMALL, "RASA-DMDB-WLS")
    assert oc.finish_time(b) == pytest.approx(ref.cycles, rel=REL)


# ---------------------------------------------- prefix cache and pruning
def _mid_trace_run(prefix_cache):
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0)
    oc = OnlineChip(chip, snap_stride=512, prefix_cache=prefix_cache)
    segs = []
    for k in range(8):
        segs.append(oc.submit(k % 2, [SMALL]))
        oc.advance_to(oc.epoch + 3)
    oc.drain()
    return oc, segs


def test_prefix_cache_identical_results_and_prunes():
    """The settled-prefix cache and retired-span pruning change the work,
    never the answer: identical finish times and traces, with retirement
    actually happening on the cached path."""
    on, segs_on = _mid_trace_run(True)
    off, segs_off = _mid_trace_run(False)
    assert on.makespan == off.makespan
    for a, b in zip(segs_on, segs_off):
        assert on.finish_time(a) == off.finish_time(b)
        assert (a.start, a.end) == (b.start, b.end)
    assert on.share_trace == off.share_trace
    assert on.active_trace == off.active_trace
    assert on.n_retired > 0                 # pruning happened...
    assert off.n_retired == 0               # ...only on the cached path


def test_prefix_cache_batcher_report_identity():
    """run_batcher(prefix_cache=False) is the rebuild-from-epoch-0
    baseline: bit-identical BatchReport, linearly more arbiter work."""
    from repro.serving.simbatch import run_batcher, synthetic_trace
    reqs = synthetic_trace(10, seed=3, mean_gap=2, d_model=256,
                           prompt_lens=(32, 64), decode_steps=(1, 2))
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=48.0)
    on = run_batcher(reqs, chip, policy="occupancy", prefix_cache=True)
    off = run_batcher(reqs, chip, policy="occupancy", prefix_cache=False)
    assert on == off


# ------------------------------------------------------- relaxation guards
def test_span_arbiter_validation():
    with pytest.raises(ValueError):
        SpanArbiter(0.0, 1024.0)
    with pytest.raises(ValueError):
        SpanArbiter(16.0, 0.0)
    arb = SpanArbiter(16.0, 1024.0)
    trace = arb.relax([], lambda jobs: None)
    assert trace.rounds == 1 and trace.shares == ()


def test_relax_skips_are_validated_against_oracle():
    """The skip rules must not change the fixed point: reference (oracle,
    skip-free) and fast (skipping) agree, and the oracle records zero
    skips while the fast path records some."""
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0)
    wl = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"], TABLE_I["DLRM-2"],
          TABLE_I["BERT-1"], TABLE_I["DLRM-2"], TABLE_I["DLRM-2"]]
    fast = simulate_chip(wl, chip, scheduler="lpt")
    ref = simulate_chip(wl, dataclasses.replace(chip, backend="reference"),
                        scheduler="lpt")
    assert fast.cycles == pytest.approx(ref.cycles, rel=REL)
    assert ref.arb_skipped == (0,) * ref.arb_rounds
    assert sum(fast.arb_skipped) > 0
