"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs.  Also a decode-path smoke for
each family (KV cache / SSM state correctness vs prefill)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import EngineConfig
from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model


def make_batch(cfg, rng, b=2, s=32):
    m = cfg.model
    if m.family == "audio":
        toks = rng.integers(0, m.vocab, (b, s, m.n_codebooks)).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    toks = rng.integers(0, m.vocab, (b, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if m.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, 8, m.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = make_batch(cfg, np.random.default_rng(0))

    loss, metrics = api.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    # one SGD-ish gradient step must stay finite and reduce params sanely
    grads = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), \
            f"{arch}: non-finite grad"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = api.loss(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce prefill logits (cache/state
    correctness), up to bf16 accumulation noise."""
    cfg = get_config(arch, smoke=True)
    m = cfg.model
    api = build_model(cfg)
    params = api.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    b, s = 2, 8
    if m.family == "audio":
        toks = jnp.asarray(rng.integers(0, m.vocab, (b, s, m.n_codebooks)),
                           jnp.int32)
    else:
        toks = jnp.asarray(rng.integers(0, m.vocab, (b, s)), jnp.int32)

    # prefill on the full prompt
    state = api.init_decode_state(b, max_seq=32)
    logits_prefill, state_p = api.prefill(params, toks, state)

    # decode token-by-token from a fresh state
    state = api.init_decode_state(b, max_seq=32)
    logits_steps = []
    for i in range(s):
        tok = toks[:, i]
        logits_i, state = api.decode_step(params, tok, state)
        logits_steps.append(logits_i)

    # last-step decode logits == prefill logits of the last position
    got = np.asarray(logits_steps[-1], np.float32)
    want = np.asarray(logits_prefill, np.float32)
    np.testing.assert_allclose(got, want, rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-3b-a800m",
                                  "mamba2-130m"])
def test_pallas_engine_integration(arch):
    """The RASA Pallas engine (interpret mode) must agree with the XLA
    engine on the same params/batch -- the paper's technique wired through
    a real model end-to-end."""
    cfg = get_config(arch, smoke=True)
    api_xla = build_model(cfg)
    params = api_xla.init(jax.random.key(0))
    batch = make_batch(cfg, np.random.default_rng(1))
    loss_xla, _ = api_xla.loss(params, batch)

    import dataclasses
    cfg_p = dataclasses.replace(
        cfg, engine=EngineConfig(kind="pallas_rasa", schedule="wlbp",
                                 block_m=128, block_k=128, block_n=128))
    api_p = build_model(cfg_p)
    loss_p, _ = api_p.loss(params, batch)
    np.testing.assert_allclose(float(loss_xla), float(loss_p),
                               rtol=0.02, atol=0.02)


def test_param_counts_match_pool():
    """Analytic parameter counts should land near the published sizes."""
    import math
    targets = {
        "gemma-2b": (2.0e9, 3.0e9),        # 2.5B w/ embeddings
        "gemma-7b": (7.5e9, 9.5e9),        # 8.5B w/ embeddings
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "nemotron-4-15b": (14e9, 17e9),
        "grok-1-314b": (290e9, 340e9),
        "mamba2-130m": (0.10e9, 0.17e9),
        "zamba2-2.7b": (2.2e9, 3.2e9),
        "musicgen-large": (1.2e9, 2.6e9),
        "granite-moe-3b-a800m": (2.5e9, 4.2e9),
        "qwen2-vl-72b": (68e9, 78e9),
    }
    for arch, (lo, hi) in targets.items():
        n = get_config(arch).model.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"
