"""Telemetry subsystem tests (``repro.obs``).

Four pillars:

* **Replay parity** -- ``replay_events`` must reproduce the reference
  ``PipelineSimulator`` run event for event: makespan, every MM sub-stage
  window (vs ``keep_schedules``), and every bandwidth grant (vs
  ``EpochBandwidthLoadModel(record_grants=True)``), across all designs.
* **Conservation** -- the five attribution buckets sum exactly to
  ``window x cores`` per core and are non-negative, on closed-batch and
  online runs, on the reference and numpy backends alike; and the two
  backends agree on the bucket totals.
* **Perfetto golden fixture** -- the trace_event JSON of a small skewed
  4-core online run is pinned in ``tests/fixtures/perfetto_skewed4.json``;
  any drift must be a bug or a deliberate regeneration

      PYTHONPATH=src python tests/test_obs.py --regen

* **Plumbing** -- the BENCH envelope validator, the ``load_stall_cycles``
  deprecated alias, the ASCII renderer, the stage-event cap, and the
  telemetry-off default (reports carry ``telemetry=None``).
"""

import json
import pathlib
import sys

import pytest

from repro.core import DESIGNS, TABLE_I, GemmSpec, simulate
from repro.core.designs import get_design
from repro.core.fastsim import StreamModelParams
from repro.core.tiling import ALG1_POLICY, lower_gemm
from repro.core.timing import PipelineSimulator
from repro.core.trace import compile_stream
from repro.multicore import ChipConfig, simulate_chip
from repro.multicore.chip import EpochBandwidthLoadModel
from repro.obs import (TelemetryConfig, render_timeline, replay_events,
                       to_trace_events)
from repro.obs.attribution import BUCKETS, simreport_attribution
from repro.serving.simbatch import run_batcher, skewed_trace

FIXTURES = pathlib.Path(__file__).parent / "fixtures"
REL = 1e-6

#: share schedule tight enough to throttle every design's load stream
SHARES = tuple([4.0, 8.0, 16.0, 6.0] * 8)
EPOCH = 512.0
TAIL = 32.0
BURST = 2048.0

#: skewed 4-GEMM layer workload for the closed-batch conservation tests
CLOSED_WORKLOAD = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"],
                   TABLE_I["DLRM-2"], TABLE_I["DLRM-2"]]


def _stream():
    return list(lower_gemm(GemmSpec("obs", 64, 256, 256), ALG1_POLICY))


# ------------------------------------------------------- replay parity
@pytest.mark.parametrize("design", sorted(DESIGNS))
def test_replay_matches_reference(design):
    """The post-hoc event replay reproduces the reference simulator's
    makespan, MM sub-stage schedule, and grant-for-grant arbiter timing
    under a throttling share schedule."""
    cfg = get_design(design)
    stream = _stream()
    model = EpochBandwidthLoadModel(
        cfg.load_ports, SHARES, EPOCH, TAIL, burst_bytes=BURST,
        store_ports=cfg.store_ports, charge_store_bytes=True,
        record_grants=True)
    ref = PipelineSimulator(cfg, keep_schedules=True,
                            load_model=model).run(stream)
    params = StreamModelParams(cfg.load_ports, cfg.store_ports, SHARES,
                               EPOCH, TAIL, BURST, True)
    ev = replay_events(compile_stream(stream), cfg, params)

    assert ev.cycles == pytest.approx(ref.cycles, rel=REL)
    assert ev.bw_stall == pytest.approx(ref.bw_stall_cycles, rel=REL,
                                        abs=1e-6)
    assert ev.wl_skips == ref.wl_skips
    assert len(ev.mm_index) == ref.n_mm
    assert len(ev.tl_index) == ref.n_tl
    assert len(ev.ts_index) == ref.n_ts

    # MM sub-stages vs the reference keep_schedules log
    assert len(ref.schedules) == ref.n_mm
    for k, sch in enumerate(ref.schedules):
        assert int(ev.mm_index[k]) == sch.index
        assert bool(ev.mm_skip[k]) == sch.wl_skipped
        got = (ev.mm_wl_start[k], ev.mm_ff_start[k], ev.mm_ff_end[k],
               ev.mm_fs_end[k], ev.mm_dr_end[k])
        want = (sch.wl_start, sch.ff_start, sch.ff_end, sch.fs_end,
                sch.dr_end)
        assert got == pytest.approx(want, rel=REL, abs=1e-9), sch.index

    # grant-for-grant: charged accesses (loads + stores) in issue order
    replayed = sorted(
        [(int(i), float(s)) for i, s in zip(ev.tl_index, ev.tl_start)]
        + [(int(i), float(s)) for i, s in zip(ev.ts_index, ev.ts_start)])
    assert len(replayed) == len(model.grants)
    for (_, start), (g_start, _) in zip(replayed, model.grants):
        assert start == pytest.approx(g_start, rel=REL, abs=1e-9)


# -------------------------------------------------------- conservation
def _assert_conserved(att, window, n_cores):
    assert att is not None
    assert len(att.cores) == n_cores
    assert att.window == pytest.approx(window, rel=1e-9)
    for c in att.cores:
        for b in BUCKETS:
            assert getattr(c, b) >= -1e-6, (c.core, b)
        assert c.total == pytest.approx(window, rel=1e-9, abs=1e-6), c.core
    total = sum(att.total(b) for b in BUCKETS)
    assert total == pytest.approx(att.occupied_cycles, rel=1e-9, abs=1e-6)
    assert sum(att.fractions().values()) == pytest.approx(1.0, abs=1e-9)


def test_closed_chip_conservation_cross_backend():
    """Closed-batch buckets conserve per core on both backends, the
    backends agree on every bucket total, and the stages-on replay does
    not diverge (``build_chip_telemetry`` raises if it does)."""
    tcfg = TelemetryConfig(enabled=True, stages=True)
    reps = {be: simulate_chip(CLOSED_WORKLOAD,
                              ChipConfig(n_cores=4, design="RASA-WLBP",
                                         bw_bytes_per_cycle=32.0,
                                         backend=be),
                              scheduler="lpt", telemetry=tcfg)
            for be in ("reference", "numpy")}
    for be, rep in reps.items():
        assert rep.telemetry is not None, be
        _assert_conserved(rep.telemetry.attribution, rep.cycles, 4)
    ref, fast = reps["reference"], reps["numpy"]
    assert fast.cycles == pytest.approx(ref.cycles, rel=REL)
    for b in BUCKETS:
        assert fast.telemetry.attribution.total(b) == pytest.approx(
            ref.telemetry.attribution.total(b), rel=REL, abs=1e-3), b


def test_online_conservation_cross_backend():
    """Online (serving) buckets conserve on both backends and agree."""
    requests = skewed_trace(d_model=256, heavy_prompt=256, n_light=6)
    tcfg = TelemetryConfig(enabled=True, stages=True)
    # the fixed policy round-robins blindly, so light requests queue
    # behind the heavy prefills and the queue_wait bucket must trigger
    reps = {be: run_batcher(requests,
                            ChipConfig(n_cores=4, design="RASA-WLBP",
                                       bw_bytes_per_cycle=64.0, backend=be),
                            policy="fixed", telemetry=tcfg)
            for be in ("reference", "numpy")}
    for be, rep in reps.items():
        tele = rep.telemetry
        assert tele is not None and tele.kind == "online", be
        assert len(tele.segments) == len(requests), be
        _assert_conserved(rep.attribution, tele.window, 4)
        assert rep.attribution.total("queue_wait") > 0.0, be
    ref, fast = reps["reference"], reps["numpy"]
    for b in BUCKETS:
        assert fast.attribution.total(b) == pytest.approx(
            ref.attribution.total(b), rel=REL, abs=1e-3), b


def test_simreport_attribution_degenerate_form():
    """Single-engine split: window == cycles, idle == 0, fractions sum
    to one, and compute matches the lowered workload."""
    spec = TABLE_I["DLRM-2"]
    res = simulate(spec, "RASA-DMDB-WLS")
    att = simreport_attribution([spec], ALG1_POLICY, res.cycles)
    _assert_conserved(att, res.cycles, 1)
    (core,) = att.cores
    assert core.queue_wait == 0.0 and core.idle == 0.0
    assert 0.0 < core.compute <= res.cycles


# ------------------------------------------------ Perfetto golden trace
def _golden_telemetry():
    """Small skewed 4-core online run (numpy backend for determinism)."""
    requests = skewed_trace(d_model=128, heavy_prompt=256, light_prompt=32,
                            n_heavy=2, n_light=4)
    rep = run_batcher(requests,
                      ChipConfig(n_cores=4, design="RASA-WLBP",
                                 bw_bytes_per_cycle=32.0, backend="numpy"),
                      policy="occupancy",
                      telemetry=TelemetryConfig(enabled=True))
    return rep.telemetry


def _assert_trace_close(fixture, fresh, path="trace"):
    assert type(fixture) is type(fresh) or (
        isinstance(fixture, (int, float)) and isinstance(fresh, (int, float))
    ), f"{path}: type drift {type(fixture).__name__} != {type(fresh).__name__}"
    if isinstance(fixture, dict):
        assert fixture.keys() == fresh.keys(), \
            f"{path}: key drift {sorted(fixture)} != {sorted(fresh)}"
        for k in fixture:
            _assert_trace_close(fixture[k], fresh[k], f"{path}/{k}")
    elif isinstance(fixture, list):
        assert len(fixture) == len(fresh), \
            f"{path}: length drift {len(fixture)} != {len(fresh)}"
        for i, (a, b) in enumerate(zip(fixture, fresh)):
            _assert_trace_close(a, b, f"{path}[{i}]")
    elif isinstance(fixture, bool) or not isinstance(fixture, (int, float)):
        assert fixture == fresh, f"{path}: {fixture!r} != {fresh!r}"
    else:
        assert fresh == pytest.approx(fixture, rel=REL, abs=1e-6), \
            f"{path}: golden {fixture} != recomputed {fresh}"


def test_perfetto_golden_fixture():
    """The exporter's trace_event JSON for the small skewed 4-core online
    run is pinned: event set, timestamps, args and metadata."""
    p = FIXTURES / "perfetto_skewed4.json"
    assert p.exists(), (f"missing fixture {p}; regenerate with "
                        f"`python tests/test_obs.py --regen`")
    fresh = to_trace_events(_golden_telemetry())
    _assert_trace_close(json.loads(p.read_text()), fresh)


def test_trace_events_well_formed():
    """Every exported event is a dict with a phase; the document carries
    the schema marker and a conserving attribution block."""
    doc = to_trace_events(_golden_telemetry())
    events = doc["traceEvents"]
    assert events and all(isinstance(e, dict) and "ph" in e for e in events)
    phases = {e["ph"] for e in events}
    assert {"M", "X", "b", "e", "C", "i"} <= phases
    other = doc["otherData"]
    assert other["schema"] == "rasa-trace/1"
    att = other["attribution"]
    assert sum(att.values()) == pytest.approx(
        other["window_cycles"] * other["n_cores"], rel=1e-9, abs=1e-6)


def test_stage_event_cap():
    """``max_stage_events`` bounds the export; the overflow is reported
    in the trace metadata instead of silently dropped."""
    tcfg = TelemetryConfig(enabled=True, stages=True, max_stage_events=16)
    rep = simulate_chip(GemmSpec("cap", 64, 256, 256),
                        ChipConfig(n_cores=2, design="RASA-WLBP",
                                   bw_bytes_per_cycle=32.0),
                        telemetry=tcfg)
    doc = to_trace_events(rep.telemetry)
    staged = [e for e in doc["traceEvents"]
              if e.get("cat") in ("stage", "mem", "stall")]
    assert len(staged) <= 16
    assert doc["otherData"]["stage_events_dropped"] > 0


# --------------------------------------------------------- plumbing
def _bench_common():
    sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "benchmarks"))
    import common
    return common


def test_bench_envelope_validation(tmp_path):
    """``write_bench``-shaped files pass ``validate_bench``; tampered
    schema, missing keys and filename mismatches are each reported."""
    common = _bench_common()
    env = common.bench_envelope("foo", backend="fast")
    assert env["schema"] == common.BENCH_SCHEMA
    env["data"] = {"x": 1}
    good = tmp_path / "BENCH_foo.json"
    good.write_text(json.dumps(env))
    assert common.validate_bench(good) == []

    bad_schema = tmp_path / "BENCH_bar.json"
    bad_schema.write_text(json.dumps(
        dict(env, benchmark="bar", schema="rasa-bench/0")))
    assert any("schema" in e for e in common.validate_bench(bad_schema))

    incomplete = dict(env)
    del incomplete["git_rev"]
    missing = tmp_path / "BENCH_foo2.json"
    missing.write_text(json.dumps(dict(incomplete, benchmark="foo2")))
    assert any("git_rev" in e for e in common.validate_bench(missing))

    misnamed = tmp_path / "BENCH_other.json"
    misnamed.write_text(json.dumps(env))      # says "foo", named "other"
    assert any("does not match filename" in e
               for e in common.validate_bench(misnamed))

    broken = tmp_path / "BENCH_broken.json"
    broken.write_text("{not json")
    assert any("unreadable" in e for e in common.validate_bench(broken))


def test_load_stall_cycles_deprecated_alias():
    """The pre-PR-6 name keeps working on both result types."""
    res = simulate(GemmSpec("alias", 32, 128, 128), "RASA-WLBP")
    assert res.load_stall_cycles == res.bw_stall_cycles
    cfg = get_design("BASE")
    tr = PipelineSimulator(cfg).run(_stream())
    assert tr.load_stall_cycles == tr.bw_stall_cycles


def test_render_timeline_smoke():
    """The ASCII renderer shows one bar per core, the legend, and the
    attribution table."""
    out = render_timeline(_golden_telemetry(), width=60)
    lines = out.splitlines()
    assert sum(1 for ln in lines if ln.startswith("core ")) == 4
    assert "#" in out and "compute" in out and "fill/drain" in out
    bars = [ln for ln in lines if ln.startswith("core ")]
    assert all(len(ln) == len(bars[0]) for ln in bars)


def test_telemetry_off_by_default():
    """Without opt-in, reports carry no telemetry object (and the serving
    report's attribution shortcut is None)."""
    rep = simulate_chip(CLOSED_WORKLOAD,
                        ChipConfig(n_cores=2, design="RASA-WLBP"),
                        scheduler="lpt")
    assert rep.telemetry is None
    brep = run_batcher(skewed_trace(d_model=128, heavy_prompt=128,
                                    n_light=2),
                       ChipConfig(n_cores=2, design="RASA-WLBP"),
                       policy="occupancy")
    assert brep.telemetry is None and brep.attribution is None


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="recompute and overwrite the Perfetto fixture")
    if not ap.parse_args().regen:
        ap.error("run under pytest, or pass --regen to rebuild fixtures")
    FIXTURES.mkdir(exist_ok=True)
    doc = to_trace_events(_golden_telemetry())
    (FIXTURES / "perfetto_skewed4.json").write_text(
        json.dumps(doc, indent=1, sort_keys=True))
    print(f"wrote perfetto_skewed4.json ({len(doc['traceEvents'])} events)",
          file=sys.stderr)
