"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; same code path compiles for TPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import (GemmBlocks, SCHEDULES, flash_mha, rasa_matmul,
                           schedule_cost, default_blocks)
from repro.kernels.ref import (ref_attention, ref_decode_attention,
                               ref_matmul, ref_matmul_accum)

SMALL = GemmBlocks(128, 128, 128)


def rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = max(np.abs(want).max(), 1e-6)
    return np.abs(got - want).max() / scale


# ------------------------------------------------------------------ rasa_gemm
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 256),
                                   (257, 130, 100), (64, 512, 64),
                                   (1, 256, 256)])
def test_gemm_shapes(schedule, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((schedule,) + shape) % 2**32)
    a = rng.normal(size=(m, k)).astype(jnp.bfloat16)
    b = rng.normal(size=(k, n)).astype(jnp.bfloat16)
    got = rasa_matmul(a, b, schedule=schedule, blocks=SMALL)
    assert rel_err(got, ref_matmul(a, b)) < 1e-5


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_gemm_dtypes(schedule, dtype):
    rng = np.random.default_rng(7)
    a = rng.normal(size=(130, 260)).astype(dtype)
    b = rng.normal(size=(260, 140)).astype(dtype)
    got = rasa_matmul(a, b, schedule=schedule, blocks=SMALL)
    assert got.dtype == jnp.float32
    assert rel_err(got, ref_matmul(a, b)) < 1e-5


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_gemm_accumulates_into_c(schedule):
    rng = np.random.default_rng(3)
    a = rng.normal(size=(128, 256)).astype(jnp.bfloat16)
    b = rng.normal(size=(256, 128)).astype(jnp.bfloat16)
    c = rng.normal(size=(128, 128)).astype(np.float32)
    got = rasa_matmul(a, b, c, schedule=schedule, blocks=SMALL)
    assert rel_err(got, ref_matmul_accum(a, b, c)) < 1e-5


def test_gemm_schedules_bit_identical():
    """All three schedules perform the same fp32 k-order reduction."""
    rng = np.random.default_rng(11)
    a = rng.normal(size=(256, 512)).astype(jnp.bfloat16)
    b = rng.normal(size=(512, 256)).astype(jnp.bfloat16)
    outs = [np.asarray(rasa_matmul(a, b, schedule=s, blocks=SMALL))
            for s in SCHEDULES]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_default_blocks_fit_budget():
    for shape in [(8192, 8192, 8192), (128, 128, 128), (100000, 64, 64)]:
        blocks = default_blocks(*shape)
        assert 2 * blocks.vmem_bytes() <= 8 * 2**20
        assert blocks.bm % 128 == 0 or blocks.bm == min(128, shape[0])


def test_schedule_cost_model():
    """wlbp must beat base on B traffic for tall GEMMs (the WL skip), and
    wls minimizes C traffic (output-stationary)."""
    m, k, n = 8192, 4096, 4096
    blocks = GemmBlocks(256, 512, 256)
    base = schedule_cost(m, k, n, blocks, "base")
    wlbp = schedule_cost(m, k, n, blocks, "wlbp")
    wls = schedule_cost(m, k, n, blocks, "wls")
    assert wlbp["traffic_bytes"]["B"] < base["traffic_bytes"]["B"]
    assert wls["traffic_bytes"]["C"] < base["traffic_bytes"]["C"]
    assert wls["arithmetic_intensity"] > base["arithmetic_intensity"]


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 300), st.integers(1, 300), st.integers(1, 300),
       st.sampled_from(SCHEDULES))
def test_gemm_property(m, k, n, schedule):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    a = rng.normal(size=(m, k)).astype(jnp.bfloat16)
    b = rng.normal(size=(k, n)).astype(jnp.bfloat16)
    got = rasa_matmul(a, b, schedule=schedule, blocks=SMALL)
    assert rel_err(got, ref_matmul(a, b)) < 1e-5


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("sq", [128, 257, 384])
def test_flash_attention_causal(hq, hkv, sq):
    rng = np.random.default_rng(sq * hq)
    q = rng.normal(size=(2, hq, sq, 64)).astype(jnp.bfloat16)
    k = rng.normal(size=(2, hkv, sq, 64)).astype(jnp.bfloat16)
    v = rng.normal(size=(2, hkv, sq, 64)).astype(jnp.bfloat16)
    got = flash_mha(q, k, v, block_q=128, block_kv=128)
    want = ref_attention(q, k, v)
    assert rel_err(got, want) < 2e-2      # bf16 inputs/outputs


def test_flash_attention_fp32_tight():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(1, 2, 256, 128)).astype(np.float32)
    k = rng.normal(size=(1, 2, 256, 128)).astype(np.float32)
    v = rng.normal(size=(1, 2, 256, 128)).astype(np.float32)
    got = flash_mha(q, k, v, block_q=128, block_kv=128)
    assert rel_err(got, ref_attention(q, k, v)) < 1e-5


def test_flash_attention_matches_scale():
    rng = np.random.default_rng(1)
    q = rng.normal(size=(1, 2, 128, 64)).astype(np.float32)
    k = rng.normal(size=(1, 2, 128, 64)).astype(np.float32)
    v = rng.normal(size=(1, 2, 128, 64)).astype(np.float32)
    got = flash_mha(q, k, v, scale=0.5, block_q=128, block_kv=128)
    want = ref_attention(q, k, v, scale=0.5)
    assert rel_err(got, want) < 1e-5


def test_decode_attention_ref_consistency():
    """ref_decode_attention == ref_attention's last position."""
    rng = np.random.default_rng(5)
    s = 64
    q = rng.normal(size=(2, 8, 1, 32)).astype(np.float32)
    k = rng.normal(size=(2, 2, s, 32)).astype(np.float32)
    v = rng.normal(size=(2, 2, s, 32)).astype(np.float32)
    full = ref_attention(q, k, v, causal=False)
    dec = ref_decode_attention(q[:, :, 0], k, v)
    np.testing.assert_allclose(np.asarray(full[:, :, 0]), np.asarray(dec),
                               rtol=1e-5, atol=1e-5)
