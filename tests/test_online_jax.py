"""Jitted whole-trace arbitration parity suite.

``repro.multicore.jitarb`` lowers the serving batcher's entire online
settle into one XLA program; on its domain (``fixed`` admission,
``batch_size=1``, equal shares, homogeneous fault-free chip) the
``BatchReport`` must be **bit-identical** -- not approximately equal --
to the numpy incremental client.  Pinned here:

* in-domain parity across all eight designs, workload shapes, core
  counts, bandwidths and a real-model (``model_trace``) request stream;
* the ``plan`` gate: every out-of-domain configuration (demand shares,
  heterogeneous mixes, active ``FaultPlan``, other policies/batch sizes,
  non-power-of-two epochs) returns ``None`` -- and ``run_batcher`` still
  answers through the incremental-client fallback, agreeing with
  ``backend="fast"``;
* the vmapped sweep (``plan_many``/``finish_times_many``) agreeing with
  per-variant sequential runs;
* a hypothesis property drawing random small traces.

Everything is exact equality on purpose: the jitted program replays the
same share expressions and the same token-bucket arithmetic, so any ulp
of drift is a bug (see the FMA note in ``repro.core.fastsim``).
"""

import dataclasses

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.fastsim import has_jax
from repro.multicore import ChipConfig
from repro.multicore.faults import FaultPlan, core_down, core_up
from repro.multicore.jitarb import plan, plan_many, finish_times_many
from repro.serving.simbatch import (model_trace, report_from_finishes,
                                    run_batcher, synthetic_trace)

pytestmark = pytest.mark.skipif(not has_jax(), reason="jax not installed")

ALL_DESIGNS = ("BASE", "RASA-DB-WLBP", "RASA-DB-WLS", "RASA-DM-PIPE",
               "RASA-DM-WLBP", "RASA-DMDB-WLS", "RASA-PIPE", "RASA-WLBP")


def _trace(n=10, seed=1, mean_gap=2, d_model=128, **kw):
    kw.setdefault("prompt_lens", (16, 32))
    kw.setdefault("decode_steps", (1, 2))
    kw.setdefault("decode_batch", 8)
    return synthetic_trace(n, seed=seed, mean_gap=mean_gap,
                           d_model=d_model, **kw)


def _chips(**kw):
    kw.setdefault("n_cores", 2)
    kw.setdefault("design", "RASA-WLBP")
    kw.setdefault("bw_bytes_per_cycle", 32.0)
    fast = ChipConfig(backend="fast", **kw)
    return fast, dataclasses.replace(fast, backend="jax")


def _traffic(requests):
    return [(r.arrival_epoch, r.specs) for r in requests]


def _assert_identical(requests, fast, jax_chip, **batcher_kw):
    batcher_kw.setdefault("policy", "fixed")
    batcher_kw.setdefault("batch_size", 1)
    a = run_batcher(requests, fast, **batcher_kw)
    b = run_batcher(requests, jax_chip, **batcher_kw)
    assert a == b           # bit-identical BatchReport, every field
    return a


# ------------------------------------------------------ in-domain parity
@pytest.mark.parametrize("design", ALL_DESIGNS)
def test_all_designs_bit_identical(design):
    """Every design's jitted settle equals the numpy client exactly --
    per-design grant rules (WLBP skips, WLS, double-buffering, pipe
    overlap) all flow through the same shared scan program."""
    fast, jx = _chips(design=design)
    requests = _trace(8, seed=3)
    assert plan(_traffic(requests), jx) is not None
    _assert_identical(requests, fast, jx)


@pytest.mark.parametrize("kw", [
    dict(n_cores=1),
    dict(n_cores=3, bw_bytes_per_cycle=48.0),
    dict(n_cores=4, bw_bytes_per_cycle=16.0),   # bandwidth-starved
], ids=["one-core", "three-core", "starved"])
def test_shapes_and_contention_bit_identical(kw):
    fast, jx = _chips(**kw)
    requests = _trace(12, seed=4, mean_gap=1)   # overlapping spans
    _assert_identical(requests, fast, jx)


def test_burst_arrivals_bit_identical():
    """All requests in one epoch: every boundary coincides, the deepest
    relaxation case."""
    fast, jx = _chips(n_cores=2)
    requests = _trace(8, seed=6, mean_gap=0)
    _assert_identical(requests, fast, jx)


def test_model_trace_bit_identical():
    """Real-model request streams (compiled per-layer prefill + decode
    GEMM chains) stay inside the domain and agree exactly."""
    requests = model_trace("gemma-2b", 6, seed=2, mean_gap=2,
                           prompt_lens=(32,), decode_steps=(1, 2))
    fast, jx = _chips(n_cores=2, bw_bytes_per_cycle=48.0)
    assert plan(_traffic(requests), jx) is not None
    _assert_identical(requests, fast, jx)


def test_vmapped_sweep_matches_sequential():
    """An arrival-rate sweep settled as ONE vmapped launch equals the
    per-variant sequential runs."""
    base = _trace(8, seed=5, mean_gap=3)
    fast, jx = _chips(n_cores=2)
    variants = [[dataclasses.replace(r, arrival_epoch=int(r.arrival_epoch
                                                          * f))
                 for r in base] for f in (1.0, 0.5, 0.0)]
    plans = plan_many([_traffic(v) for v in variants], jx)
    assert plans is not None
    outs = finish_times_many(plans)
    for v, fin in zip(variants, outs):
        want = run_batcher(v, fast, policy="fixed", batch_size=1)
        assert report_from_finishes(v, jx, fin) == want


# ------------------------------------------------- plan gate + fallback
def test_gate_demand_shares_falls_back():
    """Demand-weighted shares are outside the jitted domain: ``plan``
    declines, and the jax-backend batcher answers via the incremental
    client -- still agreeing with fast."""
    fast, jx = _chips(share_policy="demand")
    requests = _trace(6, seed=7)
    assert plan(_traffic(requests), jx) is None
    _assert_identical(requests, fast, jx)


def test_gate_heterogeneous_mix_falls_back():
    fast, jx = _chips()
    fast = dataclasses.replace(fast, n_cores=None, design=None,
                               cores=("BASE", "RASA-WLBP"))
    jx = dataclasses.replace(jx, n_cores=None, design=None,
                             cores=("BASE", "RASA-WLBP"))
    requests = _trace(6, seed=8)
    assert plan(_traffic(requests), jx) is None
    _assert_identical(requests, fast, jx)


def test_gate_active_fault_plan_falls_back():
    fp = FaultPlan((core_down(0, 2), core_up(0, 12)))
    fast, jx = _chips(n_cores=2, fault_plan=fp)
    requests = _trace(6, seed=9)
    assert plan(_traffic(requests), jx) is None
    _assert_identical(requests, fast, jx)

    # the *empty* plan is a no-op by construction and stays in-domain
    fast0, jx0 = _chips(n_cores=2, fault_plan=FaultPlan())
    assert plan(_traffic(requests), jx0) is not None
    _assert_identical(requests, fast0, jx0)


def test_gate_other_policies_and_batch_sizes():
    """Only ``fixed``@1 routes to the kernel; everything else is served
    by the incremental client (and still matches fast exactly)."""
    fast, jx = _chips(n_cores=2)
    requests = _trace(6, seed=10)
    for kw in (dict(policy="occupancy"), dict(policy="fixed",
                                              batch_size=2)):
        _assert_identical(requests, fast, jx, **kw)


def test_gate_requires_jax_backend_and_pow2_epochs():
    requests = _trace(4, seed=11)
    fast, jx = _chips()
    assert plan(_traffic(requests), fast) is None       # backend gate
    odd = dataclasses.replace(jx, epoch_cycles=1000.0)  # not a power of 2
    assert plan(_traffic(requests), odd) is None
    assert plan([], jx) is None                         # empty trace


# ------------------------------------------------------------- property
@given(st.integers(0, 2 ** 16))
@settings(max_examples=8, deadline=None)
def test_random_traces_bit_identical(seed):
    """Random small arrival traces: the jitted settle is bit-identical
    to the numpy client wherever ``plan`` accepts."""
    import random
    rng = random.Random(seed)
    fast, jx = _chips(n_cores=rng.choice((1, 2, 3)),
                      design=rng.choice(ALL_DESIGNS),
                      bw_bytes_per_cycle=rng.choice((16.0, 32.0, 64.0)))
    requests = _trace(rng.randrange(1, 9), seed=seed % 1024,
                      mean_gap=rng.choice((0, 1, 3)))
    assert plan(_traffic(requests), jx) is not None
    _assert_identical(requests, fast, jx)
