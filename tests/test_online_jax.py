"""Jitted whole-trace arbitration parity suite.

``repro.multicore.jitarb`` lowers the serving batcher's entire online
run -- span arbitration *and* admission -- into one XLA program; on its
domain (``fixed`` admission at any batch size, the reactive
``occupancy``/``bandwidth``/``predicted`` policies, equal or
demand-weighted shares, homogeneous or mixed fault-free chips) the
``BatchReport`` must be **bit-identical** -- not approximately equal --
to the numpy incremental client.  Pinned here:

* in-domain parity across all eight designs (equal and demand-weighted
  shares), workload shapes, core counts, bandwidths, heterogeneous
  BASE/RASA mixes, batch sizes, every reactive admission policy, and a
  real-model (``model_trace``) request stream;
* the ``plan_ex`` gate: every out-of-domain configuration (active
  ``FaultPlan``, ``phase_aware`` admission, non-power-of-two epochs)
  returns a structured reason, ``run_batcher`` still answers through
  the incremental-client fallback agreeing with ``backend="fast"``,
  and the reason surfaces on ``BatchReport.jit_gate``;
* the vmapped sweep (``plan_many``/``finish_times_many``) agreeing with
  per-variant sequential runs;
* hypothesis properties: random small traces, and window-size
  independence (the sliding settled-prefix window is an implementation
  tile -- growing it must not move a single bit).

Everything is exact equality on purpose: the jitted program replays the
same share expressions and the same token-bucket arithmetic, so any ulp
of drift is a bug (see the FMA note in ``repro.core.fastsim``).
"""

import dataclasses

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.fastsim import has_jax
from repro.multicore import ChipConfig
from repro.multicore.faults import FaultPlan, core_down, core_up
from repro.multicore.jitarb import (finish_admit_times, finish_times_many,
                                    plan, plan_ex, plan_many)
from repro.serving.simbatch import (model_trace, report_from_finishes,
                                    run_batcher, synthetic_trace)

pytestmark = pytest.mark.skipif(not has_jax(), reason="jax not installed")

ALL_DESIGNS = ("BASE", "RASA-DB-WLBP", "RASA-DB-WLS", "RASA-DM-PIPE",
               "RASA-DM-WLBP", "RASA-DMDB-WLS", "RASA-PIPE", "RASA-WLBP")
REACTIVE = ("occupancy", "bandwidth", "predicted")


def _trace(n=10, seed=1, mean_gap=2, d_model=128, **kw):
    kw.setdefault("prompt_lens", (16, 32))
    kw.setdefault("decode_steps", (1, 2))
    kw.setdefault("decode_batch", 8)
    return synthetic_trace(n, seed=seed, mean_gap=mean_gap,
                           d_model=d_model, **kw)


def _chips(**kw):
    kw.setdefault("n_cores", 2)
    kw.setdefault("design", "RASA-WLBP")
    kw.setdefault("bw_bytes_per_cycle", 32.0)
    fast = ChipConfig(backend="fast", **kw)
    return fast, dataclasses.replace(fast, backend="jax")


def _hetero_chips(cores=("BASE", "RASA-WLBP"), **kw):
    kw.setdefault("bw_bytes_per_cycle", 32.0)
    fast = ChipConfig(backend="fast", n_cores=None, design=None,
                      cores=cores, **kw)
    return fast, dataclasses.replace(fast, backend="jax")


def _traffic(requests):
    return [(r.arrival_epoch, r.specs) for r in requests]


def _assert_identical(requests, fast, jax_chip, **batcher_kw):
    batcher_kw.setdefault("policy", "fixed")
    batcher_kw.setdefault("batch_size", 1)
    a = run_batcher(requests, fast, **batcher_kw)
    b = run_batcher(requests, jax_chip, **batcher_kw)
    assert a == b           # bit-identical BatchReport, every field
    assert b.jit_gate is None   # the jitted lane actually served it
    return a


def _assert_in_domain(requests, jax_chip, **plan_kw):
    p, why = plan_ex(_traffic(requests), jax_chip, **plan_kw)
    assert p is not None, f"unexpected gate: {why}"
    return p


# ------------------------------------------------------ in-domain parity
@pytest.mark.parametrize("design", ALL_DESIGNS)
def test_all_designs_bit_identical(design):
    """Every design's jitted settle equals the numpy client exactly --
    per-design grant rules (WLBP skips, WLS, double-buffering, pipe
    overlap) all flow through the same shared scan program."""
    fast, jx = _chips(design=design)
    requests = _trace(8, seed=3)
    _assert_in_domain(requests, jx)
    _assert_identical(requests, fast, jx)


@pytest.mark.parametrize("design", ALL_DESIGNS)
def test_demand_shares_bit_identical(design):
    """Demand-weighted shares jit: float span weights fold in the host
    arbiter's span order, so grants are summation-order-stable and every
    design agrees bit-for-bit."""
    fast, jx = _chips(design=design, share_policy="demand")
    requests = _trace(8, seed=7, mean_gap=1)
    _assert_in_domain(requests, jx)
    _assert_identical(requests, fast, jx)


@pytest.mark.parametrize("kw", [
    dict(n_cores=1),
    dict(n_cores=3, bw_bytes_per_cycle=48.0),
    dict(n_cores=4, bw_bytes_per_cycle=16.0),   # bandwidth-starved
], ids=["one-core", "three-core", "starved"])
def test_shapes_and_contention_bit_identical(kw):
    fast, jx = _chips(**kw)
    requests = _trace(12, seed=4, mean_gap=1)   # overlapping spans
    _assert_identical(requests, fast, jx)


def test_burst_arrivals_bit_identical():
    """All requests in one epoch: every boundary coincides, the deepest
    relaxation case."""
    fast, jx = _chips(n_cores=2)
    requests = _trace(8, seed=6, mean_gap=0)
    _assert_identical(requests, fast, jx)


@pytest.mark.parametrize("policy", REACTIVE)
def test_reactive_admission_bit_identical(policy):
    """The reactive policies run *inside* the while_loop -- headroom,
    occupancy, soonest-free placement and work conservation all replayed
    from carried state -- and agree with the host driver exactly,
    admit epochs included."""
    fast, jx = _chips(n_cores=2)
    requests = _trace(10, seed=12, mean_gap=1)
    _assert_in_domain(requests, jx, policy=policy)
    _assert_identical(requests, fast, jx, policy=policy)


@pytest.mark.parametrize("batch_size", (2, 3, 8))
def test_fixed_batch_sizes_bit_identical(batch_size):
    """``fixed`` admission at any batch size is a closed form of the
    arrival order (group flush epochs): no in-program decisions, still
    bit-identical -- admit epochs included."""
    fast, jx = _chips(n_cores=2)
    requests = _trace(9, seed=13, mean_gap=1)
    _assert_in_domain(requests, jx, batch_size=batch_size)
    _assert_identical(requests, fast, jx, batch_size=batch_size)


@pytest.mark.parametrize("policy", ("fixed", "bandwidth"))
def test_heterogeneous_mix_bit_identical(policy):
    """Mixed BASE/RASA chips jit end-to-end: engine design scalars and
    port rates ride the lane axis of the vmapped simulate chunk, and
    per-(shape, core) trace rows, weights and cost estimates enter as
    tables."""
    fast, jx = _hetero_chips()
    requests = _trace(8, seed=8, mean_gap=1)
    _assert_in_domain(requests, jx, policy=policy)
    _assert_identical(requests, fast, jx, policy=policy)


def test_hetero_rasa_mix_bit_identical():
    """A second mixed pair (pipelined vs WLBP RASA cores): per-core
    tiling policies compile distinct trace rows for the same request
    shape, and the per-(shape, core) row table routes each lane to its
    own columns."""
    fast, jx = _hetero_chips(cores=("RASA-WLBP", "RASA-PIPE"))
    requests = _trace(6, seed=14)
    _assert_in_domain(requests, jx)
    _assert_identical(requests, fast, jx)


def test_model_trace_bit_identical():
    """Real-model request streams (compiled per-layer prefill + decode
    GEMM chains) stay inside the domain and agree exactly."""
    requests = model_trace("gemma-2b", 6, seed=2, mean_gap=2,
                           prompt_lens=(32,), decode_steps=(1, 2))
    fast, jx = _chips(n_cores=2, bw_bytes_per_cycle=48.0)
    _assert_in_domain(requests, jx)
    _assert_identical(requests, fast, jx)


@pytest.mark.parametrize("policy", REACTIVE)
def test_model_trace_reactive_bit_identical(policy):
    """Reactive admission on the real-model stream: the full serving
    frontend (model configs -> GEMM chains -> reactive batcher) through
    the jitted program."""
    requests = model_trace("gemma-2b", 6, seed=2, mean_gap=1,
                           prompt_lens=(32,), decode_steps=(1, 2))
    fast, jx = _chips(n_cores=2, bw_bytes_per_cycle=48.0)
    _assert_in_domain(requests, jx, policy=policy)
    _assert_identical(requests, fast, jx, policy=policy)


def test_vmapped_sweep_matches_sequential():
    """An arrival-rate sweep settled as ONE vmapped launch equals the
    per-variant sequential runs."""
    base = _trace(8, seed=5, mean_gap=3)
    fast, jx = _chips(n_cores=2)
    variants = [[dataclasses.replace(r, arrival_epoch=int(r.arrival_epoch
                                                          * f))
                 for r in base] for f in (1.0, 0.5, 0.0)]
    plans = plan_many([_traffic(v) for v in variants], jx)
    assert plans is not None
    outs = finish_times_many(plans)
    for v, fin in zip(variants, outs):
        want = run_batcher(v, fast, policy="fixed", batch_size=1)
        assert report_from_finishes(v, jx, fin) == want


# ------------------------------------------------- plan gate + fallback
def test_gate_active_fault_plan_falls_back():
    fp = FaultPlan((core_down(0, 2), core_up(0, 12)))
    fast, jx = _chips(n_cores=2, fault_plan=fp)
    requests = _trace(6, seed=9)
    assert plan_ex(_traffic(requests), jx)[1] == "faults_active"
    a = run_batcher(requests, fast)
    b = run_batcher(requests, jx)
    assert a == b
    assert b.jit_gate == "faults_active"    # fallback is diagnosable

    # the *empty* plan is a no-op by construction and stays in-domain
    fast0, jx0 = _chips(n_cores=2, fault_plan=FaultPlan())
    assert plan(_traffic(requests), jx0) is not None
    _assert_identical(requests, fast0, jx0)


def test_gate_unsupported_policy_falls_back():
    """``phase_aware`` keeps its host-only implementation: plan_ex names
    the gate and the fallback still matches fast."""
    fast, jx = _chips(n_cores=2)
    requests = _trace(6, seed=10)
    assert plan_ex(_traffic(requests), jx,
                   policy="phase_aware")[1] == "admission_policy"
    a = run_batcher(requests, fast, policy="phase_aware", batch_size=1)
    b = run_batcher(requests, jx, policy="phase_aware", batch_size=1)
    assert a == b
    assert b.jit_gate == "admission_policy"


def test_gate_reasons_are_structured():
    requests = _trace(4, seed=11)
    fast, jx = _chips()
    assert plan_ex(_traffic(requests), fast)[1] == "backend"
    odd = dataclasses.replace(jx, epoch_cycles=1000.0)  # not a power of 2
    assert plan_ex(_traffic(requests), odd)[1] == "epoch_not_pow2"
    assert plan_ex([], jx)[1] == "no_requests"
    assert plan_ex(_traffic(requests), jx,
                   policy="fixed", batch_size=0)[1] == "batch_size"
    assert plan_ex(_traffic(requests), jx, policy="occupancy",
                   min_share=-1.0)[1] == "min_share_out_of_range"
    # legacy single-value shape still works
    assert plan(_traffic(requests), fast) is None


# ------------------------------------------------------------- property
@given(st.integers(0, 2 ** 16))
@settings(max_examples=8, deadline=None)
def test_random_traces_bit_identical(seed):
    """Random small arrival traces across the whole widened domain: the
    jitted program is bit-identical to the numpy client wherever
    ``plan_ex`` accepts."""
    import random
    rng = random.Random(seed)
    fast, jx = _chips(n_cores=rng.choice((1, 2, 3)),
                      design=rng.choice(ALL_DESIGNS),
                      bw_bytes_per_cycle=rng.choice((16.0, 32.0, 64.0)),
                      share_policy=rng.choice(("equal", "demand")))
    policy = rng.choice(("fixed",) + REACTIVE)
    batch_size = rng.choice((1, 2, 4)) if policy == "fixed" else 1
    requests = _trace(rng.randrange(1, 9), seed=seed % 1024,
                      mean_gap=rng.choice((0, 1, 3)))
    _assert_in_domain(requests, jx, policy=policy, batch_size=batch_size)
    _assert_identical(requests, fast, jx, policy=policy,
                      batch_size=batch_size)


@pytest.mark.parametrize("policy", ("fixed", "occupancy"))
def test_window_doubling_smoke(policy):
    """Deterministic pin of the window-independence property (runs even
    without hypothesis): doubling the sliding window moves no bits."""
    _, jx = _chips(n_cores=2)
    requests = _trace(8, seed=21, mean_gap=1)
    p = _assert_in_domain(requests, jx, policy=policy)
    fin0, adm0 = finish_admit_times(p)
    fin1, adm1 = finish_admit_times(dataclasses.replace(p, S=p.S * 2))
    assert (fin0 == fin1).all()
    assert (adm0 == adm1).all()


@given(st.integers(0, 2 ** 16), st.integers(1, 3))
@settings(max_examples=6, deadline=None)
def test_window_size_independence(seed, scale):
    """Chunk-boundary placement is invisible: the sliding settled-prefix
    window is sized by the span bound ``S``, and running the same plan
    with a window 2x/4x/8x larger must reproduce every finish and admit
    epoch bit-for-bit (the window only decides *where* settled epochs
    spill out of the carry, never their values)."""
    import random
    rng = random.Random(seed)
    policy = rng.choice(("fixed", "occupancy"))
    _, jx = _chips(n_cores=rng.choice((1, 2)))
    requests = _trace(rng.randrange(2, 8), seed=seed % 512,
                      mean_gap=rng.choice((0, 2)))
    p = _assert_in_domain(requests, jx, policy=policy)
    fin0, adm0 = finish_admit_times(p)
    wide = dataclasses.replace(p, S=p.S * 2 ** scale)
    fin1, adm1 = finish_admit_times(wide)
    assert (fin0 == fin1).all()
    assert (adm0 == adm1).all()
