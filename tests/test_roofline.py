"""Roofline + dry-run plumbing tests (no compiles: synthetic artifacts)."""

import json

import pytest

from repro.launch.dryrun import parse_collectives
from repro.roofline.analysis import (HW, V5E, analyze_cell, model_flops_for)


def test_parse_collectives_sums_operand_bytes():
    hlo = """
  ENTRY main {
    %ag = f32[16,128] all-gather(%x), replica_groups={}
    %ar = bf16[1024] all-reduce(%y), to_apply=%add
    %rs = (f32[8,8], f32[8,8]) reduce-scatter(%a, %b), dimensions={0}
    %cp = f32[4,4] collective-permute(%z), source_target_pairs={{0,1}}
    %agd = f32[16,128] all-gather-done(%t)
  }
    """
    out = parse_collectives(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 2 * 8 * 8 * 4
    assert out["collective-permute"] == 4 * 4 * 4
    assert out["all-gather_count"] == 1
    # -done ops must not be double counted
    assert out.get("all-gather", 0) == 16 * 128 * 4


def _cell(flops=1e12, byts=1e11, coll=1e9, devices=256, unit=1, total=10):
    return {
        "arch": "qwen3-1.7b", "shape": "train_4k", "devices": devices,
        "unit_layers": unit, "total_layers": total,
        "cost_per_device": {"flops": flops, "bytes_accessed": byts},
        "collectives_per_device_bytes": {"all-reduce": coll,
                                         "all-reduce_count": 4},
        "memory": {"peak_bytes_per_device": 8 * 2**30},
    }


def test_analyze_cell_terms():
    r = analyze_cell(_cell(flops=1e14))
    assert r.compute_s == pytest.approx(1e14 / V5E.peak_flops)
    assert r.memory_s == pytest.approx(1e11 / V5E.hbm_bw)
    assert r.collective_s == pytest.approx(1e9 / V5E.ici_bw)
    assert r.dominant == "compute"     # 0.51 s > 0.12 s > 0.02 s
    assert r.step_time_s == r.compute_s


def test_analyze_cell_depth_extrapolation():
    base = _cell()
    d0 = _cell(flops=2e10, byts=1e9, coll=1e8)
    du = _cell(flops=3e10, byts=2e9, coll=3e8)
    r = analyze_cell(base, d0=d0, du=du)
    assert r.extrapolated
    # total = d0 + 10 * (du - d0)
    assert r.flops_per_device == pytest.approx(2e10 + 10 * 1e10)
    assert r.coll_bytes_per_device == pytest.approx(1e8 + 10 * 2e8)


def test_dominant_collective():
    r = analyze_cell(_cell(flops=1e9, byts=1e9, coll=1e12))
    assert r.dominant == "collective"


def test_model_flops_conventions():
    t = model_flops_for("qwen3-1.7b", "train_4k")
    p = model_flops_for("qwen3-1.7b", "prefill_32k")
    d = model_flops_for("qwen3-1.7b", "decode_32k")
    # train: 6*N*tokens; prefill: 2*N*tokens; decode: 2*N*batch
    assert t / (4096 * 256) == pytest.approx(3 * p / (32768 * 32))
    assert d == pytest.approx(p / (32768 * 32) * 128)
    # moe uses ACTIVE params
    from repro.configs import get_config
    grok = model_flops_for("grok-1-314b", "train_4k")
    n_active = get_config("grok-1-314b").model.active_param_count()
    assert grok == pytest.approx(6.0 * n_active * 4096 * 256)
