"""Cycle-model tests: every number the paper states, plus pipeline invariants."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DESIGNS, Instr, Op, get_design,
                        steady_state_interval)
from repro.core.designs import EngineConfig
from repro.core.timing import PipelineSimulator, serial_mm_latency


def mm_stream(n, *, same_b=False, n_c=4, tm=16):
    """An ideal rasa_mm stream: operands preloaded (ready at t=0)."""
    out = []
    for i in range(n):
        b = 7 if same_b else 6 + (i % 2)
        out.append(Instr(Op.MM, dst=i % n_c, src1=4 + (i % 2), src2=b, tm=tm))
    return out


# ---------------------------------------------------------------- paper facts
def test_baseline_latency_is_95():
    """Paper §V: 'L_baseline = 95 cycles for the configuration in our
    evaluation' -- 32x16 array, T_M=16."""
    assert get_design("BASE").serial_latency(16) == 95
    assert serial_mm_latency(32, 16, 16) == 95


def test_toy_2x2_utilization():
    """Paper Fig. 1: 2x2 WS array on a 2x2 GEMM -> 7 cycles, 28.6% util."""
    toy = EngineConfig(name="toy", rows=2, cols=2)
    res = PipelineSimulator(toy).run(
        [Instr(Op.MM, dst=0, src1=1, src2=2, tm=2, tk=2, tn=2)])
    assert res.cycles == 7
    assert res.utilization == pytest.approx(2 / 7, abs=1e-6)


def test_eq1_inactive_time():
    """Eq. (2): each PE is inactive Latency_tot - T_M cycles."""
    cfg = get_design("BASE")
    res = PipelineSimulator(cfg).run(mm_stream(1))
    assert res.cycles - 16 == 95 - 16


def test_dmdb_wls_asymptote():
    """Paper §V: perfectly pipelined rasa_mm every 16 cycles -> 16/95."""
    cfg = get_design("RASA-DMDB-WLS")
    base = get_design("BASE")
    n = 2000
    t_d = PipelineSimulator(cfg).run(mm_stream(n)).cycles
    t_b = PipelineSimulator(base).run(mm_stream(n)).cycles
    assert t_d / t_b == pytest.approx(16 / 95, rel=0.01)


def test_pipe_interval_is_wl_ff_fs():
    """PIPE overlaps WL with prior DR: steady interval 2*T_K + T_M - 1 = 79."""
    cfg = get_design("RASA-PIPE")
    r = PipelineSimulator(cfg, keep_schedules=True).run(mm_stream(10))
    s = r.schedules
    assert s[-1].ff_start - s[-2].ff_start == pytest.approx(79)
    assert steady_state_interval(cfg, 16, False) == 79


def test_wlbp_reuse_interval_is_tm():
    cfg = get_design("RASA-WLBP")
    r = PipelineSimulator(cfg, keep_schedules=True).run(mm_stream(10, same_b=True))
    s = r.schedules
    assert s[-1].ff_start - s[-2].ff_start == pytest.approx(16)
    assert s[-1].wl_skipped


def test_wlbp_no_reuse_degrades_to_pipe():
    cfg = get_design("RASA-WLBP")
    pipe = get_design("RASA-PIPE")
    stream = mm_stream(50)  # alternating B registers, never reusable
    a = PipelineSimulator(cfg).run(stream).cycles
    b = PipelineSimulator(pipe).run(stream).cycles
    assert a == b


def test_dirty_bit_blocks_reuse():
    """A tile load to the weight register between rasa_mm must force WL."""
    cfg = get_design("RASA-WLBP")
    stream = [
        Instr(Op.MM, dst=0, src1=4, src2=7, tm=16),
        Instr(Op.TL, dst=7, addr=("B", 0, 1)),       # overwrite weights
        Instr(Op.MM, dst=1, src1=4, src2=7, tm=16),
    ]
    r = PipelineSimulator(cfg, keep_schedules=True).run(stream)
    assert not r.schedules[1].wl_skipped
    assert r.wl_skips == 0


def test_db_wls_hides_weight_load():
    """DB-WLS sustains interval T_M even without weight reuse, as long as
    the WL port keeps up (interval >= WL/1 port => T_K for fresh weights)."""
    cfg = get_design("RASA-DMDB-WLS")   # rows=16 -> WL=16 fits under T_M=16
    r = PipelineSimulator(cfg, keep_schedules=True).run(mm_stream(100))
    s = r.schedules
    assert s[-1].ff_start - s[-2].ff_start == pytest.approx(16)


def test_wl_port_serializes_fresh_weights():
    """With 32 rows, back-to-back *fresh* weight sets cannot beat one WL (32
    cycles) per instruction even with DB-WLS -- the insertion network is a
    single resource."""
    cfg = get_design("RASA-DB-WLS")
    r = PipelineSimulator(cfg, keep_schedules=True).run(mm_stream(100))
    s = r.schedules
    assert s[-1].ff_start - s[-2].ff_start == pytest.approx(32)


def test_c_register_dependency_serializes():
    """Chained accumulation into one C register must wait for the drain --
    the reason Algorithm 1 round-robins four C tiles."""
    cfg = get_design("RASA-DMDB-WLS")
    chained = PipelineSimulator(cfg).run(mm_stream(50, n_c=1)).cycles
    rotated = PipelineSimulator(cfg).run(mm_stream(50, n_c=4)).cycles
    assert chained > 2 * rotated


def test_dm_halves_rows():
    cfg = get_design("RASA-DM-WLBP")
    assert cfg.rows == 16 and cfg.macs_per_pe == 2
    assert cfg.peak_macs_per_cycle == get_design("BASE").peak_macs_per_cycle


def test_wls_requires_db():
    with pytest.raises(ValueError):
        EngineConfig(name="bad", wls=True, double_buffer=False)


@pytest.mark.parametrize("design", sorted(DESIGNS))
@pytest.mark.parametrize("reused", [False, True])
def test_steady_state_interval_matches_simulator(design, reused):
    """The analytic issue-to-issue interval must agree with the simulated
    back-to-back rasa_mm interval for every design, with and without
    weight-register reuse."""
    cfg = get_design(design)
    r = PipelineSimulator(cfg, keep_schedules=True).run(
        mm_stream(200, same_b=reused))
    s = r.schedules
    measured = s[-1].ff_start - s[-2].ff_start
    # reuse only fires on WLBP designs; the analytic form takes the
    # *effective* reuse the dirty-bit tracking would see.
    effective_reuse = reused and cfg.wlbp
    assert measured == pytest.approx(
        steady_state_interval(cfg, 16, effective_reuse)), design


# ---------------------------------------------------------- pipeline invariants
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 1), st.integers(0, 1)),
                min_size=1, max_size=60),
       st.sampled_from(sorted(DESIGNS)))
def test_schedule_monotone_and_ordered(ops, design):
    """Property: for every design and stream, (i) stages of one instruction
    are ordered WL<=FF<FS<DR, (ii) FF starts never decrease (in-order array),
    (iii) no design is slower than BASE on the same stream."""
    stream = [Instr(Op.MM, dst=c, src1=4 + a, src2=6 + b, tm=16)
              for c, a, b in ops]
    cfg = get_design(design)
    r = PipelineSimulator(cfg, keep_schedules=True).run(stream)
    prev_ff = -1.0
    for s in r.schedules:
        assert s.wl_start <= s.ff_start
        assert s.ff_start < s.ff_end <= s.fs_end <= s.dr_end
        assert s.ff_start >= prev_ff
        prev_ff = s.ff_start
    base = PipelineSimulator(get_design("BASE")).run(stream)
    assert r.cycles <= base.cycles + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200))
def test_throughput_bounds(n):
    """No design may exceed peak: useful MACs <= cycles * peak."""
    for design in DESIGNS:
        cfg = get_design(design)
        r = PipelineSimulator(cfg).run(mm_stream(n))
        assert r.useful_macs <= r.cycles * cfg.peak_macs_per_cycle + 1e-6
        assert 0.0 <= r.utilization <= 1.0
