"""Workload frontend: compile every ``repro.configs`` model into chip
workloads (ISSUE 7 acceptance suite).

Covers: every arch compiles for both phases; compiled workloads run
through ``simulate_chip`` on all three backends with identical makespans;
MoE placement groups are scheduler-atomic; repeated layers dedup to one
compiled shape; the dimension-cap option reproduces the LLM-projection
shapes; malleable-width gang refinement beats greedy on the pinned skewed
workload; and real-model serving traces flow through the batcher.
"""

from __future__ import annotations

import pytest

from repro.core.tiling import GemmSpec
from repro.multicore.chip import ChipConfig, simulate_chip
from repro.multicore.scheduler import assign_units, scheduled_workload_report
from repro.workload import (CompileOptions, Workload, WorkloadOp,
                            compile_workload)

ARCH_NAMES = [
    "qwen2-vl-72b", "nemotron-4-15b", "qwen3-1.7b", "gemma-2b", "gemma-7b",
    "musicgen-large", "mamba2-130m", "grok-1-314b", "granite-moe-3b-a800m",
    "zamba2-2.7b",
]

#: small enough for the oracle (reference) backend, big enough that every
#: block kind still lowers at least one GEMM
TINY = CompileOptions(dim_cap=256, max_layers=1, max_experts=2)


def test_arch_registry_matches():
    from repro.configs import ARCH_NAMES as REGISTRY
    assert set(ARCH_NAMES) == set(REGISTRY)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("phase", ["prefill", "decode"])
def test_every_arch_compiles(arch, phase):
    w = compile_workload(arch, batch=2, seq=32, phase=phase, options=TINY)
    assert isinstance(w, Workload)
    assert w.phase == phase and w.arch == arch
    assert w.ops and w.macs > 0
    assert all(isinstance(op, WorkloadOp) and op.spec.M >= 1 for op in w.ops)
    # the prefill point carries batch*seq tokens through the projections,
    # decode carries batch -- so prefill strictly outworks decode
    other = compile_workload(arch, batch=2, seq=32,
                             phase="decode" if phase == "prefill" else
                             "prefill", options=TINY)
    pre, dec = (w, other) if phase == "prefill" else (other, w)
    assert pre.macs > dec.macs


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("phase", ["prefill", "decode"])
def test_backend_identical_makespans(arch, phase):
    """Acceptance: every compiled workload runs through ``simulate_chip``
    on all three backends with identical makespans."""
    w = compile_workload(arch, batch=1, seq=16, phase=phase, options=TINY)
    reports = {
        be: simulate_chip(w, ChipConfig(n_cores=2, backend=be,
                                        bw_bytes_per_cycle=128))
        for be in ("reference", "numpy", "jax")
    }
    ref = reports["reference"]
    assert ref.phase == phase
    for be, rep in reports.items():
        assert rep.cycles == pytest.approx(ref.cycles), be
        assert rep.per_core_cycles == pytest.approx(ref.per_core_cycles), be
        assert rep.macs == ref.macs == w.macs


def test_unknown_phase_and_arch_raise():
    with pytest.raises(ValueError, match="phase"):
        compile_workload("gemma-2b", batch=1, seq=8, phase="train")
    with pytest.raises(KeyError):
        compile_workload("not-a-model", batch=1, seq=8)


# ------------------------------------------------------------ dedup/caching
def test_repeated_layers_share_specs():
    """Spec names are canonical per block kind, so depth never multiplies
    the distinct-shape count: the trace compiler lowers each shape once."""
    one = compile_workload("gemma-7b", batch=4, seq=64, phase="prefill",
                           options=CompileOptions(max_layers=1))
    full = compile_workload("gemma-7b", batch=4, seq=64, phase="prefill")
    assert full.n_layers > 1 and full.layers_modeled == full.n_layers
    assert len(full.ops) == len(one.ops) * full.n_layers
    assert {s for s, _ in full.unique_specs()} == \
        {s for s, _ in one.unique_specs()}
    assert all(n == full.n_layers for _, n in full.unique_specs())


def test_dim_cap_reproduces_projection_shapes():
    """The projection benchmark's dimension-cap heuristic is the compile
    option now: capped dims never exceed the cap, uncapped ones match."""
    cap = 512
    w = compile_workload("grok-1-314b", batch=1, seq=1, phase="decode",
                         options=CompileOptions(dim_cap=cap, max_layers=1))
    assert all(s.K <= cap and s.N <= cap for s in w.specs)
    raw = compile_workload("grok-1-314b", batch=1, seq=1, phase="decode",
                           options=CompileOptions(max_layers=1))
    assert any(s.K > cap or s.N > cap for s in raw.specs)
    assert [s.name for s in w.specs] == [s.name for s in raw.specs]


# --------------------------------------------------------- phase semantics
def test_decode_is_small_m():
    w = compile_workload("gemma-2b", batch=8, seq=512, phase="decode",
                         options=CompileOptions(max_layers=1))
    assert all(s.M == 8 for s in w.specs)
    p = compile_workload("gemma-2b", batch=8, seq=512, phase="prefill",
                         options=CompileOptions(max_layers=1))
    assert all(s.M == 8 * 512 for s in p.specs)


def test_ssm_decode_is_recurrent():
    """Decode lowers the O(1) recurrent step, not the chunked scan: its
    cost must not grow with the context length."""
    opts = CompileOptions(max_layers=1)
    short = compile_workload("mamba2-130m", batch=1, seq=64,
                             phase="decode", options=opts)
    long = compile_workload("mamba2-130m", batch=1, seq=4096,
                            phase="decode", options=opts)
    assert short.macs == long.macs
    pre_short = compile_workload("mamba2-130m", batch=1, seq=64,
                                 phase="prefill", options=opts)
    pre_long = compile_workload("mamba2-130m", batch=1, seq=1024,
                                phase="prefill", options=opts)
    assert pre_long.macs > pre_short.macs


def test_hybrid_shares_attention_at_stride():
    """Zamba2: every layer runs the SSM block; attention + FFN only at the
    shared-block stride."""
    from repro.configs import get_config
    m = get_config("zamba2-2.7b").model
    w = compile_workload(m, batch=1, seq=16, phase="decode")
    by_layer = {}
    for op in w.ops:
        by_layer.setdefault(op.layer, set()).add(op.block)
    for layer, blocks in by_layer.items():
        assert "ssm" in blocks
        expect_attn = layer % m.hybrid.attn_every == 0
        assert ("attn" in blocks) == expect_attn, layer


# ------------------------------------------------------- placement groups
def test_moe_groups_are_atomic_units():
    w = compile_workload("granite-moe-3b-a800m", batch=4, seq=8,
                         phase="decode",
                         options=CompileOptions(dim_cap=256, max_layers=2,
                                                max_experts=2))
    units = w.units()
    moe_units = [u for u in units if len(u) > 1]
    # 2 experts per layer x 2 layers, each one up+down (+gate) unit
    assert len(moe_units) == 4
    assert len(units) < len(w.ops)
    # groups never merge across experts or layers
    groups = {op.group for op in w.ops if op.group}
    assert len(groups) == 4


def test_moe_units_spread_across_cores():
    """Expert parallelism as a placement consequence: distinct expert
    units land on distinct cores while each expert's GEMMs stay whole."""
    w = compile_workload("granite-moe-3b-a800m", batch=4, seq=8,
                         phase="decode",
                         options=CompileOptions(dim_cap=256, max_layers=1,
                                                max_experts=4))
    chip = ChipConfig(n_cores=4, design="RASA-DMDB-WLS")
    rep = scheduled_workload_report(w, chip, scheduler="work_queue")
    assert rep.phase == "decode"
    moe_cores = [c for c, names in enumerate(rep.per_core_gemms)
                 if any(".moe." in n for n in names)]
    assert len(moe_cores) > 1
    # each core's moe ops form whole groups (a multiple of the group size)
    group_len = len(next(u for u in w.units() if len(u) > 1))
    for names in rep.per_core_gemms:
        n_moe = sum(1 for n in names if ".moe." in n)
        assert n_moe % group_len == 0


def test_moe_routing_conserves_routed_tokens():
    """max_experts folds the expert-parallel width but never drops routed
    tokens: total expert M-rows == m_tokens * top_k regardless of cap."""
    from repro.configs import get_config
    m = get_config("granite-moe-3b-a800m").model
    routed = 4 * m.moe.top_k
    for cap in (2, 4, None):
        w = compile_workload(m, batch=4, seq=8, phase="decode",
                             options=CompileOptions(max_layers=1,
                                                    max_experts=cap))
        up_rows = sum(s.M for s in w.specs if s.name.endswith(".moe.up"))
        assert up_rows >= routed
        assert up_rows - routed < (cap or m.moe.n_experts)  # ceil slack


# ----------------------------------------------------- gang_refine (pinned)
def test_gang_refine_beats_greedy_on_skewed_workload():
    """The pinned malleable-width case: greedy gang commits the dominant
    GEMMs to myopic widths; the refinement hill-climb re-widens them and
    strictly beats greedy's simulated makespan on the skewed 4-core
    workload (and never loses elsewhere, by LPT fallback)."""
    wl = [GemmSpec("wide", 1024, 512, 128),
          GemmSpec("mid", 256, 1024, 64),
          GemmSpec("deep", 16, 1024, 1024)]
    chip = ChipConfig(n_cores=4, design="RASA-DMDB-WLS")
    greedy = simulate_chip(wl, chip, scheduler="gang")
    refined = simulate_chip(wl, chip, scheduler="gang_refine")
    assert refined.cycles < greedy.cycles
    assert refined.macs == greedy.macs == sum(s.macs for s in wl)


def test_gang_refine_single_core_reduction():
    wl = [GemmSpec("a", 64, 128, 64), GemmSpec("b", 32, 128, 64)]
    one = ChipConfig(n_cores=1, design="RASA-WLBP")
    assert assign_units([(s,) for s in wl], one, "gang_refine") == [wl]


def test_gang_refine_never_worse_than_lpt():
    """Fallback contract: refinement keeps its schedule only when it beats
    whole-GEMM LPT, so it can never lose to it."""
    wl = [GemmSpec("even", 128, 256, 256)] * 4
    chip = ChipConfig(n_cores=4, design="RASA-DMDB-WLS")
    lpt = simulate_chip(wl, chip, scheduler="lpt")
    refined = simulate_chip(wl, chip, scheduler="gang_refine")
    assert refined.cycles <= lpt.cycles + 1e-9


# ------------------------------------------------------------ serving trace
def test_model_trace_flows_through_batcher():
    from repro.serving import model_trace, run_batcher
    reqs = model_trace("qwen3-1.7b", 3, seed=1, prompt_lens=(16,),
                       decode_steps=(2,),
                       options=CompileOptions(dim_cap=256, max_layers=1))
    # prefill is the compiled per-layer stream, decode the per-step chains
    assert all(isinstance(r.prefill, tuple) and len(r.prefill) > 1
               for r in reqs)
    step_len = len(reqs[0].decode) // 2
    assert reqs[0].decode[:step_len] == reqs[0].decode[step_len:]
    rep = run_batcher(reqs, ChipConfig(n_cores=2, bw_bytes_per_cycle=128),
                      policy="occupancy")
    assert rep.makespan > 0 and rep.n_requests == 3
    assert rep.macs == sum(r.macs for r in reqs)


def test_model_trace_decode_steps_share_specs():
    """Decode steps reuse identical specs, so the trace compiler lowers
    one step no matter the chain length (the dedup idiom end-to-end)."""
    from repro.serving import model_trace
    reqs = model_trace("gemma-2b", 2, seed=0, prompt_lens=(16,),
                       decode_steps=(4,),
                       options=CompileOptions(dim_cap=256, max_layers=1))
    distinct = {s for r in reqs for s in r.decode}
    per_step = len(reqs[0].decode) // 4
    assert len(distinct) == per_step
