"""Golden-fixture regression tests for the paper-figure reproductions.

The analytic numbers behind Fig. 2 (PE utilization), Fig. 5 (normalized
runtime of the canonical designs) and Fig. 7 (batch sensitivity) are
checked into ``tests/fixtures/`` and re-derived here from the live timing
model, so a refactor of ``repro.core.timing`` / ``repro.core.tiling``
cannot silently drift the reproduction: any cycle-level change must either
be a bug or come with a deliberate fixture regeneration

    PYTHONPATH=src python tests/test_golden_figures.py --regen

Fixtures pin raw cycles *and* the normalized figure numbers, across all
eight canonical designs, on the fast backend (backend-independence is the
parity suite's job; the fixtures only need one deterministic backend).
Fig. 5 uses the FC-layer subset (the ResNet conv layers' multi-million
instruction streams would dominate suite runtime without adding design
coverage); Fig. 7 stops at batch 256 for the same reason -- the asymptote
claim itself is asserted in ``benchmarks/fig7_batch.py``.
"""

import json
import pathlib

import pytest

from repro.core import DESIGNS, TABLE_I, batch_sweep, sweep_workload
from repro.core.designs import EngineConfig
from repro.core.isa import Instr, Op
from repro.core.timing import PipelineSimulator, serial_mm_latency

FIXTURES = pathlib.Path(__file__).parent / "fixtures"
REL = 1e-6

FIG2_DIMS = ((4, 4), (8, 8), (16, 16), (32, 16), (32, 32))
FIG2_TMS = (1, 2, 4, 8, 16, 32, 64, 128, 256)
FIG5_LAYERS = ("DLRM-1", "DLRM-2", "BERT-1", "BERT-3")
FIG7_BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def compute_fig2() -> dict:
    """util(T_M) per systolic-array dim, simulator-checked closed form."""
    out = {}
    for rows, cols in FIG2_DIMS:
        cfg = EngineConfig(name=f"sa{rows}x{cols}", rows=rows, cols=cols)
        for tm in FIG2_TMS:
            res = PipelineSimulator(cfg).run(
                [Instr(Op.MM, dst=0, src1=1, src2=2,
                       tm=tm, tk=rows, tn=cols)])
            closed = tm / serial_mm_latency(rows, cols, tm)
            assert abs(res.utilization - closed) < 1e-9
            out[f"{rows}x{cols}_tm{tm}"] = res.utilization
    return out


def compute_fig5() -> dict:
    """Cycles + BASE-normalized runtime per (layer, design), Alg-1 policy."""
    specs = [TABLE_I[k] for k in FIG5_LAYERS]
    grid = sweep_workload(specs, backend="fast")
    out = {}
    for layer, row in zip(FIG5_LAYERS, grid):
        base = row["BASE"].cycles
        for design in sorted(DESIGNS):
            out[f"{layer}/{design}"] = {
                "cycles": row[design].cycles,
                "normalized": row[design].cycles / base,
            }
    return out


def compute_fig7() -> dict:
    """RASA-DMDB-WLS batch sweep: cycles + BASE-normalized runtime."""
    sweep = batch_sweep(batches=FIG7_BATCHES)
    grid = sweep_workload(list(sweep.values()),
                          designs=["BASE", "RASA-DMDB-WLS"], backend="fast")
    out = {}
    for batch, row in zip(FIG7_BATCHES, grid):
        out[str(batch)] = {
            "cycles": row["RASA-DMDB-WLS"].cycles,
            "normalized": row["RASA-DMDB-WLS"].cycles / row["BASE"].cycles,
        }
    return out


COMPUTE = {
    "fig2_utilization": compute_fig2,
    "fig5_runtime": compute_fig5,
    "fig7_batch": compute_fig7,
}


def _assert_close(fixture, fresh, path):
    if isinstance(fixture, dict):
        assert isinstance(fresh, dict) and fixture.keys() == fresh.keys(), \
            f"{path}: key drift {sorted(fixture)} != {sorted(fresh)}"
        for k in fixture:
            _assert_close(fixture[k], fresh[k], f"{path}/{k}")
    else:
        assert fresh == pytest.approx(fixture, rel=REL), \
            f"{path}: golden {fixture} != recomputed {fresh}"


@pytest.mark.parametrize("name", sorted(COMPUTE))
def test_golden_figure(name):
    """The live timing model reproduces the checked-in figure numbers."""
    p = FIXTURES / f"{name}.json"
    assert p.exists(), (f"missing fixture {p}; regenerate with "
                        f"`python tests/test_golden_figures.py --regen`")
    _assert_close(json.loads(p.read_text()), COMPUTE[name](), name)


def test_fig7_small_batches_flat():
    """The Fig. 7 headline -- batches 1..16 cost exactly the same -- must
    hold in the fixture itself (not only in the recomputation)."""
    table = json.loads((FIXTURES / "fig7_batch.json").read_text())
    small = [table[str(b)]["cycles"] for b in (1, 2, 4, 8, 16)]
    assert max(small) - min(small) < 1e-9


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="recompute and overwrite the fixture files")
    if not ap.parse_args().regen:
        ap.error("run under pytest, or pass --regen to rebuild fixtures")
    FIXTURES.mkdir(exist_ok=True)
    for name, fn in sorted(COMPUTE.items()):
        out = fn()
        (FIXTURES / f"{name}.json").write_text(json.dumps(out, indent=2,
                                                          sort_keys=True))
        print(f"wrote {name}.json ({len(out)} entries)", file=sys.stderr)
