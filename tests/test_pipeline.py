"""GPipe pipeline parallelism: correctness vs sequential execution
(multi-device subprocess, like test_distributed)."""

import pathlib
import subprocess
import sys
import textwrap

import pytest

SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.training.pipeline import pipeline_apply, split_stages

    from repro.launch.mesh import _auto_mesh
    mesh = _auto_mesh((4,), ("pod",))

    L, D, B = 8, 16, 8
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32),
              "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def layer(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    def stage_fn(stage_params, h):
        # apply this stage's layers sequentially
        def body(h, p):
            return layer(p, h), None
        h, _ = jax.lax.scan(body, h, stage_params)
        return h

    # sequential reference
    ref = x
    for i in range(L):
        ref = layer(jax.tree.map(lambda a: a[i], params), ref)

    staged = split_stages(params, 4)
    got = pipeline_apply(staged, x, stage_fn, mesh, axis="pod",
                         n_microbatches=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK")

    # also check gradients flow through the pipeline
    def loss(params, x):
        return pipeline_apply(split_stages(params, 4), x, stage_fn, mesh,
                              axis="pod", n_microbatches=4).sum()
    g = jax.grad(loss)(params, x)
    def ref_loss(params, x):
        h = x
        for i in range(L):
            h = layer(jax.tree.map(lambda a: a[i], params), h)
        return h.sum()
    g_ref = jax.grad(ref_loss)(params, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
    print("PIPELINE_GRAD_OK")
""")


@pytest.mark.slow
def test_pipeline_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SNIPPET], capture_output=True, text=True,
        timeout=600, cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
    assert "PIPELINE_GRAD_OK" in res.stdout, res.stdout + res.stderr
