"""Fused SSD chunk kernel vs the model's chunked-scan oracle
(shape/chunk sweeps, interpret mode)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ssd_chunk_fused, hbm_bytes_fused
from repro.models.ssm import ssd_chunked


def _oracle(x, dt, a, b, c, chunk):
    bh = x.shape[0]
    ys, fins = [], []
    for i in range(bh):
        yi, fi = ssd_chunked(x[i:i + 1, :, None, :], dt[i:i + 1, :, None],
                             a[i:i + 1], b[i:i + 1, :, None, :],
                             c[i:i + 1, :, None, :], chunk=chunk)
        ys.append(np.asarray(yi)[0, :, 0])
        fins.append(np.asarray(fi)[0, 0].T)      # -> [n, p]
    return np.stack(ys), np.stack(fins)


@pytest.mark.parametrize("shape", [(2, 64, 8, 8), (3, 128, 16, 8),
                                   (1, 256, 32, 16)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_fused_matches_oracle(shape, chunk):
    bh, s, p, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.normal(size=(bh, s, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bh, s)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(bh,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    y, fin = ssd_chunk_fused(x, dt, a, b, c, chunk=chunk, interpret=True)
    want_y, want_fin = _oracle(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y), want_y, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(fin), want_fin, rtol=2e-5,
                               atol=2e-5)


def test_fused_bf16():
    rng = np.random.default_rng(0)
    bh, s, p, n = 2, 64, 16, 8
    x = jnp.asarray(rng.normal(size=(bh, s, p)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bh, s)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(bh,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.bfloat16)
    c = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.bfloat16)
    y, fin = ssd_chunk_fused(x, dt, a, b, c, chunk=32, interpret=True)
    want_y, want_fin = _oracle(x.astype(jnp.float32), dt, a,
                               b.astype(jnp.float32),
                               c.astype(jnp.float32), 32)
    rel = np.abs(np.asarray(y, np.float32) - want_y).max() / np.abs(want_y).max()
    assert rel < 3e-2


def test_cost_model_napkin():
    """The B1.3 napkin: fused traffic for one zamba2 layer-pass is ~1 GB
    vs the measured multi-TB unfused accounting."""
    # zamba2: d_inner=5120, heads=80, p=64, n=64; per-device b=16
    bytes_per_layer = hbm_bytes_fused(bh=16 * 80, s=4096, p=64, n=64)
    assert bytes_per_layer < 3 * 2**30      # ~2.7 GB streamed operands
    # vs the unfused XLA accounting for the same layer: ~1.2 TB/unit-layer
    # (EXPERIMENTS.md B1.3) -> the kernel removes >99% of the bound
