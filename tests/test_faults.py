"""Fault-injection & graceful-degradation tests: FaultPlan validation and
zero-cost-off identity, deterministic preemption/migration on both chip
clients, cross-backend parity under faults (deterministic scenarios plus a
hypothesis property over seeded random plans), six-bucket attribution
conservation, mid-fault snapshot/restore, deadline/retry/abandonment
accounting, and the phase-aware / degraded admission policy pins."""

import dataclasses
import math
import pickle

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import ALG1_POLICY, GemmSpec, TABLE_I
from repro.core.fastsim import completed_prefix
from repro.core.tiling import lower_gemm
from repro.core.trace import compile_stream, slice_trace
from repro.multicore import (EMPTY_PLAN, ChipConfig, FaultEvent, FaultPlan,
                             OnlineChip, bw_derate, core_down, core_up,
                             random_plan, simulate_chip, slow_core)
from repro.multicore.chip import stream_model_params
from repro.obs import TelemetryConfig
from repro.obs.attribution import BUCKETS
from repro.serving.simbatch import (ServeRequest, run_batcher, skewed_trace,
                                    synthetic_trace)

REL = 1e-6

#: the closed-batch fault workload (4 Table-I GEMMs over 2 cores)
CLOSED_WORKLOAD = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"], TABLE_I["DLRM-2"],
                   TABLE_I["DLRM-2"]]
CLOSED_KW = dict(n_cores=2, design="RASA-WLBP", bw_bytes_per_cycle=32.0,
                 backend="numpy")

#: the serving fault scenario: mini skewed trace + a down window and a
#: thermal derate placed inside its ~190-epoch busy window
SERVE_KW = dict(n_cores=4, design="RASA-WLBP", bw_bytes_per_cycle=64.0)
SERVE_PLAN = FaultPlan((core_down(0, 3), core_up(0, 30),
                        bw_derate(0.7, 5, 20)))


def _mini_skew():
    return skewed_trace(d_model=256, heavy_prompt=256, n_light=6)


def _heavy(name, epoch, d=256):
    """A prefill-heavy request (prefill is ~94% of its MACs)."""
    return ServeRequest(
        name, epoch, GemmSpec(f"{name}.pf", M=256, K=d, N=d),
        tuple(GemmSpec(f"{name}.d{j}", M=8, K=d, N=d) for j in range(2)))


def _light(name, epoch, d=256):
    """A decode-heavy request (decode is 3/4 of its MACs)."""
    return ServeRequest(
        name, epoch, GemmSpec(f"{name}.pf", M=16, K=d, N=d),
        tuple(GemmSpec(f"{name}.d{j}", M=8, K=d, N=d) for j in range(6)))


def _same_outcome(a, b):
    """Equal BatchReports up to the policy label."""
    return dataclasses.replace(a, policy=b.policy) == b


# ------------------------------------------------------------ validation
def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meltdown", 0)
    with pytest.raises(ValueError, match="epoch must be >= 0"):
        FaultEvent("bw_derate", -1, factor=0.5, until=4)
    with pytest.raises(ValueError, match="needs a core index"):
        FaultEvent("core_down", 3)
    with pytest.raises(ValueError, match=r"factor must be in \(0, 1\]"):
        bw_derate(0.0, 1, 4)
    with pytest.raises(ValueError, match=r"factor must be in \(0, 1\]"):
        slow_core(0, 1.5)
    with pytest.raises(ValueError, match="pass until"):
        FaultEvent("bw_derate", 1, factor=0.5)
    with pytest.raises(ValueError, match="must be > "):
        bw_derate(0.5, 4, 4)


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown preemption policy"):
        FaultPlan((core_down(0, 1),), preemption="teleport")
    # the plan only attaches to the epoch arbiter
    with pytest.raises(ValueError, match="requires arbitration='epoch'"):
        ChipConfig(n_cores=2, fault_plan=FaultPlan((core_down(0, 1),)),
                   arbitration="static")
    # events must name cores that exist
    with pytest.raises(ValueError, match="on a 2-core chip"):
        ChipConfig(n_cores=2, fault_plan=FaultPlan((core_down(5, 1),)))


def test_empty_plan_normalizes_to_none():
    """``FaultPlan()`` is the no-op plan: ChipConfig folds it to ``None``,
    so an empty-plan chip config *is* the fault-free config (zero-cost
    off by construction)."""
    assert EMPTY_PLAN.is_empty
    chip = ChipConfig(n_cores=2, fault_plan=FaultPlan())
    assert chip.fault_plan is None
    assert chip == ChipConfig(n_cores=2)


def test_random_plan_seed_determinism():
    kw = dict(horizon=64, n_core_faults=2, down_epochs=8, n_derates=1,
              derate_factor=0.5, derate_epochs=8)
    assert random_plan(4, seed=7, **kw) == random_plan(4, seed=7, **kw)
    assert random_plan(4, seed=7, **kw) != random_plan(4, seed=8, **kw)
    plan = random_plan(4, seed=7, **kw)
    assert plan.has_core_events and plan.needs_online
    assert sum(e.kind == "bw_derate" for e in plan.events) == 1


# ------------------------------------------- preemption cut primitives
def test_slice_trace_matches_compile_stream():
    """``slice_trace(trace, k)`` must equal ``compile_stream(stream[k:])``
    field for field, at every cut -- the preemption remainder is a fresh
    lowering, just cheaper."""
    stream = tuple(lower_gemm(GemmSpec("cut", 96, 256, 256), ALG1_POLICY))
    trace = compile_stream(stream)
    for k in (0, 1, 7, len(stream) // 2, len(stream) - 1, len(stream)):
        got = slice_trace(trace, k)
        want = compile_stream(stream[k:])
        for f in ("opcode", "r_dst", "r_a", "r_b", "nbytes", "tm", "macs",
                  "reusable"):
            assert (getattr(got, f) == getattr(want, f)).all(), (k, f)
        assert (got.n_tl, got.n_ts, got.n_mm) == \
            (want.n_tl, want.n_ts, want.n_mm), k
        assert got.useful_macs == want.useful_macs, k
    with pytest.raises(ValueError, match="out of range"):
        slice_trace(trace, len(stream) + 1)


def test_completed_prefix_monotone_and_bounded():
    """The deterministic preemption cut: 0 instructions at limit 0, the
    whole trace once the limit passes its solo runtime, and monotone
    non-decreasing in between."""
    chip = ChipConfig(n_cores=1, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0)
    engine = chip.core_specs[0].engine
    trace = compile_stream(lower_gemm(GemmSpec("pfx", 64, 256, 256),
                                      chip.core_specs[0].policy))
    params = stream_model_params(chip, engine)
    assert completed_prefix(trace, engine, params, 0.0) == 0
    assert completed_prefix(trace, engine, params, math.inf) == len(trace)
    last = 0
    for limit in (100.0, 1000.0, 5000.0, 20000.0, 1e6):
        k = completed_prefix(trace, engine, params, limit)
        assert last <= k <= len(trace)
        last = k
    assert last == len(trace)


def test_two_preemptions_resume_from_checkpoints(monkeypatch):
    """Repeated ``core_down`` preemptions of one segment replay only the
    work past its latest valid snapshot when computing the cut, never the
    whole history.  Pre-fix, every preemption's ``completed_prefix`` cut
    replayed the segment from instruction 0 -- up to a full snapshot
    stride of re-simulation per preemption, compounding across repeated
    outages of the same logical segment."""
    import repro.multicore.online as online_mod
    stride = 64
    spec = GemmSpec("long", 128, 256, 256)
    kw = dict(n_cores=2, design="RASA-WLBP", bw_bytes_per_cycle=16.0,
              backend="fast")

    clean = OnlineChip(ChipConfig(**kw), snap_stride=stride)
    h = clean.submit(0, [spec])
    clean.drain()
    F = math.ceil(clean.finish_time(h) / clean.chip.epoch_cycles)
    assert F >= 9            # room for two mid-flight outages

    plan = FaultPlan((core_down(0, F // 3), core_up(0, F // 3 + 1),
                      core_down(1, 2 * F // 3), core_up(1, 2 * F // 3 + 1)))
    cuts, replays = [], []
    orig = online_mod.completed_prefix

    def spy(trace, cfg, params, limit, *args, **kwargs):
        carry = kwargs.get("carry", args[0] if args else None)
        n = orig(trace, cfg, params, limit, *args, **kwargs)
        cuts.append(n)
        replays.append(n - (carry.i if carry is not None else 0))
        return n

    monkeypatch.setattr(online_mod, "completed_prefix", spy)
    sim = OnlineChip(ChipConfig(fault_plan=plan, **kw), snap_stride=stride)
    sim.submit(0, [spec])
    sim.drain()
    assert sim.n_preempted == 2 and len(cuts) == 2
    # meaningful scenario: each cut lands well past the first checkpoints
    assert all(n > 2 * stride for n in cuts)
    # the fix: each replay covers at most the tail past the last snapshot
    assert all(r <= 2 * stride for r in replays)
    assert sim.stats.get("preempt_replay_instrs") == sum(replays)


# --------------------------------------------- closed-batch fault client
def test_core_down_preempts_migrates_and_logs():
    plan = FaultPlan((core_down(0, 2), core_up(0, 12)))
    base = simulate_chip(CLOSED_WORKLOAD, ChipConfig(**CLOSED_KW),
                         scheduler="lpt")
    rep = simulate_chip(CLOSED_WORKLOAD,
                        ChipConfig(fault_plan=plan, **CLOSED_KW),
                        scheduler="lpt")
    assert rep.n_preemptions >= 1
    assert rep.n_migrations >= 1
    assert rep.cycles > base.cycles          # the outage costs wall-clock
    assert rep.fault_lost_cycles > 0.0
    assert rep.fault_log == ((2, "core0 down"), (12, "core0 up"))
    assert rep.macs == base.macs             # no work lost from the answer


def test_restart_preemption_loses_at_least_resume():
    """``restart`` discards the checkpointed prefix ``resume`` keeps: with
    a late outage it must lose strictly more work and finish no earlier."""
    reps = {}
    for prem in ("resume", "restart"):
        plan = FaultPlan((core_down(0, 300), core_up(0, 500)),
                         preemption=prem)
        reps[prem] = simulate_chip(CLOSED_WORKLOAD,
                                   ChipConfig(fault_plan=plan, **CLOSED_KW),
                                   scheduler="lpt")
    assert reps["restart"].fault_lost_cycles > \
        reps["resume"].fault_lost_cycles
    assert reps["restart"].cycles >= reps["resume"].cycles


def test_bw_derate_and_slow_core_closed_batch():
    """Windowed thermal derate and DVFS throttle both cost cycles on the
    closed path (no core events -> no preemption machinery involved)."""
    base = simulate_chip(CLOSED_WORKLOAD, ChipConfig(**CLOSED_KW),
                         scheduler="lpt")
    derate = simulate_chip(
        CLOSED_WORKLOAD,
        ChipConfig(fault_plan=FaultPlan((bw_derate(0.5, 0, 10),)),
                   **CLOSED_KW), scheduler="lpt")
    slow = simulate_chip(
        CLOSED_WORKLOAD,
        ChipConfig(fault_plan=FaultPlan((slow_core(0, 0.5),)),
                   **CLOSED_KW), scheduler="lpt")
    assert derate.cycles > base.cycles
    assert slow.cycles > base.cycles
    assert derate.n_preemptions == slow.n_preemptions == 0
    # the derate window scales the arbiter budget epoch by epoch
    plan = FaultPlan((bw_derate(0.5, 2, 4), bw_derate(0.5, 3, 5)))
    assert plan.budget_factors() == (1.0, 1.0, 0.5, 0.25, 0.5)


# ------------------------------------------------- cross-backend parity
@pytest.mark.parametrize("policy", ["occupancy", "degraded"])
def test_fault_backend_parity(policy):
    """Identical fault-run outcomes on the reference, fast and numpy
    backends: the preemption cut and every downstream decision epoch are
    replayed bit-identically."""
    requests = _mini_skew()
    reps = {be: run_batcher(requests,
                            ChipConfig(backend=be, fault_plan=SERVE_PLAN,
                                       **SERVE_KW),
                            policy=policy, snap_stride=512)
            for be in ("reference", "fast", "numpy")}
    ref = reps["reference"]
    for be in ("fast", "numpy"):
        rep = reps[be]
        assert rep.makespan == pytest.approx(ref.makespan, rel=REL), be
        assert rep.finish_times == pytest.approx(ref.finish_times,
                                                 rel=REL), be
        assert rep.latencies == pytest.approx(ref.latencies, rel=REL), be
        assert rep.admit_epochs == ref.admit_epochs, be
        assert (rep.retries, rep.abandoned) == \
            (ref.retries, ref.abandoned), be


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 9), down=st.integers(1, 24),
       n_derates=st.integers(0, 2),
       preemption=st.sampled_from(("resume", "restart")))
def test_random_fault_plans_backend_parity(seed, down, n_derates,
                                           preemption):
    """Hypothesis property: any seeded random FaultPlan produces the same
    BatchReport on the fast and numpy backends -- fault handling never
    introduces backend-dependent behavior."""
    plan = random_plan(2, seed=seed, horizon=40, n_core_faults=1,
                       down_epochs=down, n_derates=n_derates,
                       derate_factor=0.7, derate_epochs=6,
                       preemption=preemption)
    requests = synthetic_trace(4, seed=seed % 97, mean_gap=2, d_model=128,
                               prompt_lens=(16, 32), decode_steps=(1, 2))
    reps = {be: run_batcher(requests,
                            ChipConfig(n_cores=2, design="RASA-WLBP",
                                       bw_bytes_per_cycle=32.0, backend=be,
                                       fault_plan=plan),
                            policy="occupancy", snap_stride=256)
            for be in ("fast", "numpy")}
    fast, np_ = reps["fast"], reps["numpy"]
    assert fast.makespan == pytest.approx(np_.makespan, rel=REL)
    assert fast.finish_times == pytest.approx(np_.finish_times, rel=REL)
    assert fast.admit_epochs == np_.admit_epochs
    assert fast.macs == np_.macs == sum(r.macs for r in requests)


def test_zero_event_plan_serving_bit_identical():
    """Zero-cost off on the serving path: no deadlines + an empty plan +
    the pre-existing policies -> the BatchReport is *equal* to one from a
    build that never heard of faults (the new report fields sit at their
    inert defaults)."""
    requests = _mini_skew()
    plain = run_batcher(requests, ChipConfig(**SERVE_KW),
                        policy="occupancy")
    empty = run_batcher(requests,
                        ChipConfig(fault_plan=FaultPlan(), **SERVE_KW),
                        policy="occupancy", max_attempts=5,
                        backoff_epochs=3)   # inert without deadlines
    assert plain == empty
    assert plain.deadline_miss_rate == 0.0
    assert plain.retries == plain.abandoned == 0
    assert plain.served_macs == plain.macs
    assert plain.goodput_macs_per_cycle == \
        pytest.approx(plain.throughput_macs_per_cycle, rel=1e-12)


# -------------------------------------------------- bucket conservation
def _assert_six_bucket_conserved(att, window, n_cores):
    assert att is not None
    assert set(BUCKETS) == {"compute", "fill_drain", "bw_stall",
                            "fault_lost", "queue_wait", "idle"}
    assert att.window == pytest.approx(window, rel=1e-9)
    for c in att.cores:
        for b in BUCKETS:
            assert getattr(c, b) >= -1e-6, (c.core, b)
        assert c.total == pytest.approx(window, rel=1e-9, abs=1e-6), c.core
    total = sum(att.total(b) for b in BUCKETS)
    assert total == pytest.approx(window * n_cores, rel=1e-9, abs=1e-6)


def test_closed_fault_conservation_cross_backend():
    tcfg = TelemetryConfig(enabled=True)
    plan = FaultPlan((core_down(0, 2), core_up(0, 12)))
    reps = {be: simulate_chip(CLOSED_WORKLOAD,
                              ChipConfig(**{**CLOSED_KW, "backend": be,
                                            "fault_plan": plan}),
                              scheduler="lpt", telemetry=tcfg)
            for be in ("reference", "numpy")}
    for be, rep in reps.items():
        att = rep.telemetry.attribution
        _assert_six_bucket_conserved(att, rep.cycles, 2)
        assert att.total("fault_lost") == \
            pytest.approx(rep.fault_lost_cycles, rel=REL), be
        assert att.total("fault_lost") > 0.0, be
    for b in BUCKETS:
        assert reps["numpy"].telemetry.attribution.total(b) == pytest.approx(
            reps["reference"].telemetry.attribution.total(b),
            rel=REL, abs=1e-3), b


def test_online_fault_conservation_cross_backend():
    tcfg = TelemetryConfig(enabled=True)
    requests = _mini_skew()
    reps = {be: run_batcher(requests,
                            ChipConfig(backend=be, fault_plan=SERVE_PLAN,
                                       **SERVE_KW),
                            policy="occupancy", snap_stride=512,
                            telemetry=tcfg)
            for be in ("reference", "numpy")}
    for be, rep in reps.items():
        _assert_six_bucket_conserved(rep.attribution,
                                     rep.telemetry.window, 4)
        assert rep.attribution.total("fault_lost") > 0.0, be
        # the fault instants surface as labeled marks for the exporters
        labels = [m[1] for m in rep.telemetry.marks]
        assert "core0 down" in labels and "core0 up" in labels, be
    for b in BUCKETS:
        assert reps["numpy"].attribution.total(b) == pytest.approx(
            reps["reference"].attribution.total(b), rel=REL, abs=1e-3), b


# ----------------------------------------------- snapshot mid-fault-run
def test_snapshot_restore_mid_fault_bit_identical():
    """Checkpoint *inside* the down window (after a preemption, with the
    resume chain live), pickle round-trip, restore, finish: bit-identical
    to the uninterrupted run."""
    requests = _mini_skew()
    chip = ChipConfig(backend="fast", fault_plan=SERVE_PLAN, **SERVE_KW)

    def drive(sim):
        for i, r in enumerate(requests):
            if r.arrival_epoch > sim.epoch:
                sim.advance_to(r.arrival_epoch)
            sim.submit(i % 4, r.specs)

    straight = OnlineChip(chip, snap_stride=512)
    drive(straight)
    straight.drain()

    sim = OnlineChip(chip, snap_stride=512)
    drive(sim)
    sim.advance_to(10)                       # inside the [3, 30) outage
    assert sim.n_preempted >= 1
    assert sim.down_cores == (True, False, False, False)
    blob = pickle.dumps(sim.snapshot())
    resumed = OnlineChip.restore(pickle.loads(blob))
    del sim
    resumed.drain()

    assert resumed.makespan == straight.makespan
    assert resumed.share_trace == straight.share_trace
    assert resumed.active_trace == straight.active_trace
    assert resumed.n_retired == straight.n_retired
    assert resumed.n_preempted == straight.n_preempted
    assert resumed.fault_log == straight.fault_log


# ------------------------------------- deadlines, retry and abandonment
def test_deadline_retry_then_abandon_accounting():
    """A request that can never be admitted before its per-attempt
    deadline retries with backoff, then is abandoned: infinite latency,
    excluded from the makespan, counted in the miss rate and excluded
    from goodput."""
    d = 256
    big = ServeRequest("big", 0, GemmSpec("big.pf", M=512, K=d, N=d))
    small = ServeRequest("small", 1, GemmSpec("s.pf", M=16, K=d, N=d),
                         deadline=2048.0)
    chip = ChipConfig(n_cores=1, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0, backend="fast")
    rep = run_batcher((big, small), chip, policy="occupancy",
                      max_attempts=2, backoff_epochs=1)
    assert rep.retries == 1                     # one backoff re-arrival
    assert rep.abandoned == 1
    assert rep.deadline_miss_rate == pytest.approx(0.5)
    assert math.isinf(rep.latencies[1]) and math.isinf(rep.finish_times[1])
    assert rep.makespan == rep.finish_times[0]  # abandoned never extends it
    assert rep.served_macs == big.macs
    assert rep.goodput_macs_per_cycle < rep.throughput_macs_per_cycle
    assert rep.admit_epochs[1] == -1            # never entered the chip


def test_admitted_request_runs_to_completion_late():
    """An admitted request is never killed: finishing past its deadline is
    a miss (zero goodput) but still a served, finite-latency request."""
    late = ServeRequest("late", 0,
                        GemmSpec("late.pf", M=64, K=256, N=256),
                        deadline=1.0)
    chip = ChipConfig(n_cores=1, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0, backend="fast")
    rep = run_batcher((late,), chip, policy="occupancy")
    assert rep.retries == rep.abandoned == 0
    assert rep.deadline_miss_rate == 1.0
    assert rep.served_macs == 0
    assert not math.isinf(rep.latencies[0])


def test_batcher_knob_validation():
    reqs = (_light("l0", 0),)
    chip = ChipConfig(n_cores=1, backend="fast")
    with pytest.raises(ValueError, match="max_attempts"):
        run_batcher(reqs, chip, max_attempts=0)
    with pytest.raises(ValueError, match="backoff_epochs"):
        run_batcher(reqs, chip, backoff_epochs=-1)
    with pytest.raises(ValueError, match="max_prefills"):
        run_batcher(reqs, chip, max_prefills=0)


# ------------------------------------------- degradation policy behavior
def test_degraded_sheds_prefill_when_core_down():
    """Under an outage the degraded policy holds prefill-heavy work back
    and lets later decode-heavy requests queue-jump; healthy it is exactly
    ``occupancy``."""
    reqs = (_light("l0", 0), _heavy("h0", 2), _light("l1", 3),
            _light("l2", 4))
    plan = FaultPlan((core_down(0, 1), core_up(0, 200)))
    kw = dict(n_cores=3, design="RASA-WLBP", bw_bytes_per_cycle=48.0,
              backend="fast")
    assert _same_outcome(
        run_batcher(reqs, ChipConfig(**kw), policy="degraded"),
        run_batcher(reqs, ChipConfig(**kw), policy="occupancy"))

    occ = run_batcher(reqs, ChipConfig(fault_plan=plan, **kw),
                      policy="occupancy")
    deg = run_batcher(reqs, ChipConfig(fault_plan=plan, **kw),
                      policy="degraded")
    admit_occ = dict(zip(occ.names, occ.admit_epochs))
    admit_deg = dict(zip(deg.names, deg.admit_epochs))
    # occupancy admits in arrival order: the heavy prefill first
    assert admit_occ["h0"] < admit_occ["l1"]
    # degraded sheds it until the core comes back; the lights jump ahead
    assert admit_deg["h0"] >= 200
    assert admit_deg["l1"] < admit_deg["h0"]
    assert admit_deg["l1"] <= admit_occ["l1"]
    # shedding is load-shaping, not load-shedding: everything still served
    assert deg.macs == occ.macs
    assert not any(math.isinf(f) for f in deg.finish_times)


def test_phase_aware_beats_occupancy_on_decode_heavy_model_trace():
    """The satellite pin: on a decode-heavy real-model trace (short
    prompts, long decode chains) behind a burst of prefill-heavy
    requests, capping concurrent prefills must cut the decode class's
    mean latency (and the p50) below plain occupancy."""
    from repro.serving.simbatch import model_trace
    from repro.workload.compile import CompileOptions
    opt = CompileOptions(dim_cap=128, max_layers=1)
    heavy = model_trace("qwen3-1.7b", 4, seed=0, mean_gap=0,
                        prompt_lens=(256,), decode_steps=(1,),
                        decode_batch=8, options=opt)
    light = model_trace("qwen3-1.7b", 8, seed=1, mean_gap=1,
                        prompt_lens=(16,), decode_steps=(8,),
                        decode_batch=8, options=opt)
    reqs = tuple(dataclasses.replace(r, name=f"h{i}")
                 for i, r in enumerate(heavy)) + \
        tuple(dataclasses.replace(r, name=f"l{i}")
              for i, r in enumerate(light))
    assert all(r.prefill_heavy for r in reqs[:4])
    assert not any(r.prefill_heavy for r in reqs[4:])
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=64.0, backend="fast")
    occ = run_batcher(reqs, chip, policy="occupancy")
    pha = run_batcher(reqs, chip, policy="phase_aware")

    def decode_mean(rep):
        lat = [l for n, l in zip(rep.names, rep.latencies)
               if n.startswith("l")]
        return sum(lat) / len(lat)

    # a real win, not a tie-breaker: the decode class's mean latency
    # drops by at least 10% once the prefill storm is capped
    assert decode_mean(pha) < 0.9 * decode_mean(occ)
    assert pha.macs == occ.macs


def test_phase_aware_cap_inert_on_pure_decode_trace():
    """With no prefill-heavy request in flight the cap never binds:
    phase_aware degenerates to occupancy exactly."""
    reqs = tuple(_light(f"l{i}", i) for i in range(5))
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0, backend="fast")
    assert _same_outcome(run_batcher(reqs, chip, policy="phase_aware"),
                         run_batcher(reqs, chip, policy="occupancy"))
