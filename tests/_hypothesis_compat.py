"""Graceful degradation when ``hypothesis`` is not installed.

Property-based tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly.  With hypothesis present this is a pure
re-export; without it, ``@given``-decorated tests become individual skips
(reported as such) while the rest of the module keeps running -- the suite
degrades instead of erroring at collection.
"""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stub for ``strategies``: strategy constructors return None, which
        is fine because the stub ``given`` never draws from them."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
