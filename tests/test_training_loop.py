"""Integration tests: end-to-end training with fault injection, restart
recovery, straggler detection, and decode serving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import build_model
from repro.serving import ServeSession
from repro.training import LoopConfig, TrainLoop, init_train_state
from repro.training.step import build_train_step


def _setup(arch="qwen3-1.7b", steps=8, batch=4, seq=32, micro=1):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, train=TrainConfig(
        global_batch=batch, seq_len=seq, lr=1e-3, total_steps=steps,
        warmup_steps=2, microbatches=micro))
    api = build_model(cfg)
    data = SyntheticLMDataset(cfg.model, seq_len=seq, global_batch=batch,
                              seed=1)
    state = init_train_state(api, jax.random.key(0))
    step_fn = jax.jit(build_train_step(api), donate_argnums=(0,))
    return cfg, api, data, state, step_fn


def test_loss_decreases():
    cfg, api, data, state, step_fn = _setup(steps=30)
    losses = []
    for s in range(30):
        state, metrics = step_fn(state, data.batch(s))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses[0]} -> {losses[-1]}"


def test_microbatching_matches_full_batch():
    """Gradient accumulation must be numerically close to the full batch."""
    cfg, api, data, state, _ = _setup(batch=4, micro=1)
    cfg2 = dataclasses.replace(cfg, train=dataclasses.replace(
        cfg.train, microbatches=2))
    api2 = build_model(cfg2)
    step1 = jax.jit(build_train_step(api))
    step2 = jax.jit(build_train_step(api2))
    batch = data.batch(0)
    s1, m1 = step1(state, batch)
    s2, m2 = step2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-2)
    # parameters after one update stay close
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2)


def test_loop_recovers_from_injected_faults(tmp_path):
    """Kill the step twice mid-run; the loop must restore from checkpoint
    and still finish all steps with the right final step count."""
    cfg, api, data, state, step_fn = _setup(steps=12)
    boom_at = {4, 9}

    def fault_hook(step):
        if step in boom_at:
            boom_at.remove(step)
            raise RuntimeError("injected node failure")

    loop = TrainLoop(
        step_fn=step_fn, state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=12, checkpoint_every=3,
                       checkpoint_dir=str(tmp_path), max_restarts=5,
                       log_every=100),
        fault_hook=fault_hook, log_fn=lambda *_: None)
    final = loop.run()
    assert int(jax.device_get(final.step)) == 12
    assert loop.restarts == 2
    # data pipeline is step-indexed: the loop must have consumed step 11
    assert loop.metrics_history[-1]["step"] == 11


def test_loop_restart_resumes_from_checkpoint(tmp_path):
    """Simulate a full job restart: second loop picks up where the first
    checkpointed, and the state matches a never-interrupted run."""
    cfg, api, data, state0, step_fn = _setup(steps=10)

    # uninterrupted reference
    ref_state = state0
    for s in range(10):
        ref_state, _ = step_fn(ref_state, data.batch(s))

    # run 1: stops (preempted) after 6 steps
    cfg1 = LoopConfig(total_steps=6, checkpoint_every=3,
                      checkpoint_dir=str(tmp_path), log_every=100)
    _, api2, data2, state1, step_fn2 = _setup(steps=10)
    loop1 = TrainLoop(step_fn=step_fn2, state=state1, batch_fn=data2.batch,
                      cfg=cfg1, log_fn=lambda *_: None)
    loop1.run()

    # run 2 ("new job"): fresh state, must restore step 6 and continue
    _, api3, data3, state2, step_fn3 = _setup(steps=10)
    cfg2 = LoopConfig(total_steps=10, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path), log_every=100)
    loop2 = TrainLoop(step_fn=step_fn3, state=state2, batch_fn=data3.batch,
                      cfg=cfg2, log_fn=lambda *_: None)
    final = loop2.run()
    assert int(jax.device_get(final.step)) == 10
    for a, b in zip(jax.tree.leaves(final.params),
                    jax.tree.leaves(ref_state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)


def test_straggler_monitor_flags_slow_steps():
    from repro.training.loop import StragglerMonitor
    mon = StragglerMonitor(factor=2.0)
    for _ in range(20):
        assert not mon.observe(0.1)
    assert mon.observe(0.5)
    assert mon.flagged == 1


def test_serve_session_greedy_decode():
    cfg = get_config("gemma-2b", smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    session = ServeSession(api, params, max_seq=48)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.model.vocab, (2, 8)),
        jnp.int32)
    out = session.generate(prompts, steps=4)
    assert out.shape == (2, 4)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.model.vocab


def test_serve_session_compiles_once_across_generates():
    """generate routes through cached jitted prefill/decode steps: the
    model functions are traced once per batch size, not once per call."""
    cfg = get_config("gemma-2b", smoke=True)
    api = build_model(cfg)
    counts = {"prefill": 0, "decode": 0}
    orig_prefill, orig_decode = api.prefill, api.decode_step

    def counting_prefill(params, tokens, state):
        counts["prefill"] += 1
        return orig_prefill(params, tokens, state)

    def counting_decode(params, tok, state):
        counts["decode"] += 1
        return orig_decode(params, tok, state)

    api = dataclasses.replace(api, prefill=counting_prefill,
                              decode_step=counting_decode)
    params = api.init(jax.random.key(0))
    session = ServeSession(api, params, max_seq=48)
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.model.vocab, (2, 8)),
        jnp.int32)
    out1 = session.generate(prompts, steps=3)
    assert counts == {"prefill": 1, "decode": 1}    # one trace each
    out2 = session.generate(prompts, steps=3)
    assert counts == {"prefill": 1, "decode": 1}    # no re-trace
    assert out1.shape == out2.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
