"""Contention-aware serving batcher tests: open-arrival semantics, cross-
backend parity of every arrival/departure scenario, admission-policy
behavior (occupancy-aware must beat fixed-batch on the skewed 4-core
trace), degenerate inputs, and the hypothesis property that no request is
lost, duplicated, or completed before it arrives."""

import dataclasses
import math

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import GemmSpec, simulate
from repro.multicore import ChipConfig, OnlineChip
from repro.serving.simbatch import (POLICIES, run_batcher, skewed_trace,
                                    synthetic_trace)

REL = 1e-6
SMALL = GemmSpec("small", 128, 256, 256)


def _mini_skew():
    """Scaled-down canonical skewed trace (oracle-affordable)."""
    return skewed_trace(d_model=256, heavy_prompt=256, n_light=6)


#: named arrival/departure scenarios of the parity suite: (requests, chip
#: kwargs).  Small enough that the reference oracle stays affordable.
SCENARIOS = {
    "steady": (synthetic_trace(5, seed=1, mean_gap=2, d_model=256,
                               prompt_lens=(32, 64), decode_steps=(1, 2)),
               dict(n_cores=2, design="RASA-WLBP",
                    bw_bytes_per_cycle=32.0)),
    "burst": (synthetic_trace(6, seed=2, mean_gap=0, d_model=256,
                              prompt_lens=(32,), decode_steps=(1,)),
              dict(n_cores=3, design="RASA-DMDB-WLS",
                   bw_bytes_per_cycle=48.0)),
    "skewed4": (_mini_skew(),
                dict(n_cores=4, design="RASA-WLBP",
                     bw_bytes_per_cycle=64.0)),
}


# --------------------------------------------------- cross-backend parity
@pytest.mark.parametrize("policy", ["fixed", "occupancy"])
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_batcher_backend_parity(scenario, policy):
    """Identical makespans (and per-request finishes) on the reference,
    fast and numpy backends for every scenario in the parity suite."""
    requests, kwargs = SCENARIOS[scenario]
    reps = {be: run_batcher(requests,
                            ChipConfig(backend=be, **kwargs),
                            policy=policy, snap_stride=512)
            for be in ("reference", "fast", "numpy")}
    ref = reps["reference"]
    for be in ("fast", "numpy"):
        rep = reps[be]
        assert rep.makespan == pytest.approx(ref.makespan, rel=REL), be
        assert rep.finish_times == pytest.approx(ref.finish_times,
                                                 rel=REL), be
        assert rep.latencies == pytest.approx(ref.latencies, rel=REL), be
        assert rep.admit_epochs == ref.admit_epochs, be


# ----------------------------------------------------- policy behavior
def test_occupancy_beats_fixed_on_skewed_trace():
    """The acceptance scenario: on the skewed 4-core trace the
    occupancy-aware policy achieves strictly lower makespan than the
    fixed-batch baseline at equal offered load."""
    requests, kwargs = SCENARIOS["skewed4"]
    fixed = run_batcher(requests, ChipConfig(**kwargs), policy="fixed")
    occ = run_batcher(requests, ChipConfig(**kwargs), policy="occupancy")
    assert occ.makespan < fixed.makespan
    assert occ.p50_latency <= fixed.p50_latency
    assert occ.macs == fixed.macs      # same offered load either way


def test_predicted_no_worse_than_occupancy_on_skewed_trace():
    """The predicted-occupancy policy forecasts departures from the
    settled share-schedule prefix instead of reacting to current
    occupancy: on the skewed 4-core trace it must be no worse than
    ``occupancy`` (and, like it, strictly beat the fixed baseline)."""
    requests, kwargs = SCENARIOS["skewed4"]
    occ = run_batcher(requests, ChipConfig(**kwargs), policy="occupancy")
    pred = run_batcher(requests, ChipConfig(**kwargs), policy="predicted")
    fixed = run_batcher(requests, ChipConfig(**kwargs), policy="fixed")
    assert pred.makespan <= occ.makespan
    assert pred.makespan < fixed.makespan
    assert pred.macs == occ.macs
    # full-scale skew as well (the benchmark's acceptance scenario)
    full = skewed_trace()
    occ_f = run_batcher(full, ChipConfig(**kwargs), policy="occupancy")
    pred_f = run_batcher(full, ChipConfig(**kwargs), policy="predicted")
    assert pred_f.makespan <= occ_f.makespan


def test_predicted_backend_parity():
    """The predicted policy's admission decisions and timings agree across
    the reference, fast and numpy backends."""
    requests, kwargs = SCENARIOS["steady"]
    reps = {be: run_batcher(requests, ChipConfig(backend=be, **kwargs),
                            policy="predicted", snap_stride=512)
            for be in ("reference", "fast", "numpy")}
    ref = reps["reference"]
    for be in ("fast", "numpy"):
        assert reps[be].makespan == pytest.approx(ref.makespan, rel=REL)
        assert reps[be].finish_times == pytest.approx(ref.finish_times,
                                                      rel=REL)
        assert reps[be].admit_epochs == ref.admit_epochs


def test_predicted_queues_on_soon_free_core():
    """With a positive lookahead the predicted policy may queue behind a
    core that drains within the window -- admissions can land strictly
    earlier than occupancy's, never later; lookahead=0 degenerates to
    reacting to settled-idle cores only."""
    requests = synthetic_trace(6, seed=7, mean_gap=1, d_model=256,
                               prompt_lens=(64,), decode_steps=(2,))
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=48.0)
    occ = run_batcher(requests, chip, policy="occupancy")
    pred = run_batcher(requests, chip, policy="predicted", lookahead=4)
    assert all(p <= o for p, o in zip(pred.admit_epochs,
                                      occ.admit_epochs))
    zero = run_batcher(requests, chip, policy="predicted", lookahead=0)
    assert zero.n_requests == len(requests)
    with pytest.raises(ValueError):
        run_batcher(requests, chip, policy="predicted", lookahead=-1)


def test_bandwidth_threshold_paces_admission():
    """A high share floor forces serial admission; dropping it to zero
    admits everything at arrival."""
    requests = synthetic_trace(4, seed=3, mean_gap=0, d_model=256,
                               prompt_lens=(32,), decode_steps=(1,))
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0)
    eager = run_batcher(requests, chip, policy="bandwidth", min_share=0.0)
    paced = run_batcher(requests, chip, policy="bandwidth",
                        min_share=1e9)
    assert eager.admit_epochs == (0, 0, 0, 0)
    # work conservation admits exactly one at a time: strictly staggered
    assert len(set(paced.admit_epochs)) == len(paced.admit_epochs)
    assert paced.makespan > eager.makespan


def test_fixed_batch_waits_for_full_group():
    """The fixed policy admits in groups of batch_size: nothing enters the
    chip until a full group (or the end of the trace) is waiting."""
    requests = synthetic_trace(5, seed=4, mean_gap=3, d_model=256,
                               prompt_lens=(32,), decode_steps=(1,))
    rep = run_batcher(requests, ChipConfig(n_cores=2, design="RASA-WLBP"),
                      policy="fixed", batch_size=2)
    arr = rep.arrival_epochs
    adm = rep.admit_epochs
    # each pair admitted together, when its second member has arrived
    assert adm[0] == adm[1] == max(arr[0], arr[1])
    assert adm[2] == adm[3] == max(arr[2], arr[3])
    # the odd tail request enters once arrivals are exhausted
    assert adm[4] >= arr[4]
    # a larger group must keep the chip idle until it fills: the idle-chip
    # work-conservation override does not apply to the fixed baseline
    rep = run_batcher(requests, ChipConfig(n_cores=2, design="RASA-WLBP"),
                      policy="fixed", batch_size=4)
    adm = rep.admit_epochs
    assert adm[0] == adm[1] == adm[2] == adm[3] == max(arr[:4])
    assert adm[4] >= arr[4]


def test_report_preserves_submission_order():
    """Per-request arrays come back in the caller's order (with names),
    not arrival-sorted; makespan measures first arrival to last retire."""
    proto = synthetic_trace(3, seed=6, mean_gap=3, d_model=256,
                            prompt_lens=(32,), decode_steps=(1,))
    # distinct arrival epochs: with ties, FIFO (= submission) order would
    # legitimately change placement and thus the latencies themselves
    base = tuple(dataclasses.replace(r, arrival_epoch=4 * i)
                 for i, r in enumerate(proto))
    rev = tuple(reversed(base))
    chip = ChipConfig(n_cores=2, design="RASA-WLBP")
    fwd = run_batcher(base, chip, policy="occupancy")
    bwd = run_batcher(rev, chip, policy="occupancy")
    assert fwd.names == tuple(r.name for r in base)
    assert bwd.names == tuple(reversed(fwd.names))
    assert bwd.latencies == tuple(reversed(fwd.latencies))
    assert bwd.arrival_epochs == tuple(reversed(fwd.arrival_epochs))
    # a trace starting late is not charged the pre-arrival idle time
    late = [dataclasses.replace(r, arrival_epoch=r.arrival_epoch + 50)
            for r in base]
    shifted = run_batcher(late, chip, policy="occupancy")
    assert shifted.makespan == pytest.approx(fwd.makespan, rel=REL)


# -------------------------------------------------- degenerate inputs
def test_empty_trace():
    rep = run_batcher([], ChipConfig(n_cores=2))
    assert rep.makespan == 0.0
    assert rep.latencies == () and rep.n_requests == 0
    assert rep.p50_latency == 0.0 and rep.p99_latency == 0.0


def test_single_request_single_core_reduces_to_simulate():
    """One request on a one-core chip retires exactly when the plain
    single-engine simulation of its concatenated stream does."""
    requests = synthetic_trace(1, seed=0, d_model=256, prompt_lens=(64,),
                               decode_steps=(2,))
    chip = ChipConfig(n_cores=1, design="RASA-DMDB-WLS")
    rep = run_batcher(requests, chip, policy="occupancy")
    from repro.core.timing import PipelineSimulator
    from repro.multicore.chip import _lower_many
    ref = PipelineSimulator(chip.engine).run(
        _lower_many(requests[0].specs, chip.policy)).cycles
    assert rep.makespan == pytest.approx(ref, rel=REL)
    assert rep.latencies[0] == pytest.approx(ref, rel=REL)


def test_zero_headroom_still_completes():
    """min_share above the whole budget can never admit through the
    policy; work conservation must still drain the trace serially."""
    requests = synthetic_trace(3, seed=5, mean_gap=0, d_model=256,
                               prompt_lens=(32,), decode_steps=(1,))
    rep = run_batcher(requests, ChipConfig(n_cores=2, design="RASA-WLBP"),
                      policy="occupancy", min_share=math.inf)
    assert rep.n_requests == 3
    assert all(f > 0 for f in rep.finish_times)
    assert len(set(rep.admit_epochs)) == 3      # one at a time


def test_batcher_input_validation():
    with pytest.raises(ValueError):
        run_batcher([], ChipConfig(), policy="greedy")
    with pytest.raises(ValueError):
        run_batcher([], ChipConfig(), batch_size=0)
    reqs = synthetic_trace(2, seed=0)
    dup = (reqs[0], reqs[0])
    with pytest.raises(ValueError):
        run_batcher(dup, ChipConfig())
    with pytest.raises(TypeError):
        run_batcher([], ChipConfig(), n_cores=2)


# ------------------------------------------------- OnlineChip edge cases
def test_online_chip_validation():
    with pytest.raises(ValueError):
        OnlineChip(ChipConfig(arbitration="static"))
    with pytest.raises(ValueError):
        OnlineChip(ChipConfig(n_cores=2), snap_stride=0)
    oc = OnlineChip(ChipConfig(n_cores=2))
    with pytest.raises(ValueError):
        oc.submit(5, [SMALL])
    with pytest.raises(ValueError):
        oc.submit(0, [])
    oc.advance_to(3)
    with pytest.raises(ValueError):
        oc.advance_to(1)
    seg = oc.submit(0, [SMALL])
    assert seg.start == 3                      # starts at the current epoch
    queued = oc.submit(0, [SMALL])             # behind the first segment
    assert queued.start is None or queued.start > 3


def test_online_chip_departure_returns_bandwidth():
    """Arrivals raise n_active, departures lower it: the converged active
    trace steps up at the injection epoch and back down as work drains."""
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=24.0)
    oc = OnlineChip(chip)
    big = oc.submit(0, [GemmSpec("big", 512, 1024, 64)])
    oc.advance_to(2)
    small = oc.submit(1, [SMALL])
    oc.drain()
    active = oc.active_trace
    assert max(active) == 2
    # epochs before the arrival see only the first segment
    assert all(n == 1 for n in active[:2])
    # after the small one drains its share returns: tail is single-active
    assert active[-1] == 1
    assert oc.finish_time(big) > oc.finish_time(small)
    # and while both were active each epoch share was budget / n_active
    for share, n in zip(oc.share_trace, active):
        assert share == pytest.approx(24.0 / n)


def test_online_chip_live_queries():
    chip = ChipConfig(n_cores=2, design="RASA-WLBP")
    oc = OnlineChip(chip)
    assert oc.core_busy() == [False, False]
    assert oc.n_active() == 0
    assert oc.live_share() == chip.bw_bytes_per_cycle
    oc.submit(0, [SMALL])
    assert oc.core_busy() == [True, False]
    assert oc.n_active() == 1
    free = oc.free_at_estimate()
    assert free[0] > free[1] == 0.0
    queued = oc.submit(0, [SMALL])     # behind the running segment
    assert queued.start is None
    with pytest.raises(RuntimeError):
        oc.finish_time(queued)


# ------------------------------------------------ shared arrival process
def test_arrival_process_pinned_and_shared():
    """The RNG arrival loop exists once (``arrival_process``): its draw
    sequence is pinned so the synthetic/model trace dedup is provably
    behavior-preserving, and a seed yields the same arrival pattern in
    both builders."""
    from repro.serving.simbatch import arrival_process, model_trace
    menus = dict(prompt_lens=(32, 64, 128), decode_steps=(2, 4, 8))
    # generated by the pre-dedup synthetic_trace loop at seed=3, mean_gap=2
    assert arrival_process(8, 3, 2, **menus) == (
        (0, 0, 32, 8), (1, 4, 32, 4), (2, 8, 64, 8), (3, 12, 32, 8),
        (4, 12, 64, 4), (5, 16, 32, 2), (6, 19, 128, 8), (7, 22, 64, 8))
    syn = synthetic_trace(8, seed=3, mean_gap=2, d_model=64, **menus)
    assert tuple((r.arrival_epoch, r.specs[0].M, len(r.decode))
                 for r in syn) == (
        (0, 32, 8), (4, 32, 4), (8, 64, 8), (12, 32, 8), (12, 64, 4),
        (16, 32, 2), (19, 128, 8), (22, 64, 8))
    # the model-trace builder sees the identical arrival pattern
    mdl = model_trace("qwen3-1.7b", 8, seed=3, mean_gap=2,
                      prompt_lens=(16,), decode_steps=(2, 4, 8))
    assert tuple(r.arrival_epoch for r in mdl) == \
        tuple(e for _, e, _, _ in arrival_process(8, 3, 2, prompt_lens=(16,),
                                                  decode_steps=(2, 4, 8)))


# -------------------------------------------------- transactional settle
def test_settle_transactional_on_failing_simulate():
    """A settle whose simulate callback raises must leave the chip exactly
    as it was before the attempt -- arbiter prefix, stamps, per-segment
    results -- with the dirty marker intact, so the retried settle is
    bit-identical to a chip that never saw the failure.  Pre-fix, the
    partially rebuilt ``_wsum`` survived the exception and disagreed with
    the marker on retry."""
    requests, kwargs = SCENARIOS["steady"]
    chip = ChipConfig(backend="fast", **kwargs)

    def drive(sim):
        n = sim.chip.n_cores
        for i, r in enumerate(requests):
            if r.arrival_epoch > sim.epoch:
                sim.advance_to(r.arrival_epoch)
            sim.submit(i % n, r.specs)
        return sim

    clean = drive(OnlineChip(chip))
    clean.drain()

    sim = drive(OnlineChip(chip))
    arb = sim._arb
    pre_wsum, pre_nact = list(arb._wsum), list(arb._nact)
    pre_stamp = arb._stamp
    pre_segs = [(s.sid, s.result, s._snaps, s.span._vis, s.span.last_grant)
                for s in sim._active]

    def failing(seg, vis):
        raise RuntimeError("injected simulate failure")

    sim._simulate = failing
    with pytest.raises(RuntimeError, match="injected simulate failure"):
        sim.drain()            # queued segments start -> dirty -> settle

    # the failed attempt must not have torn any settle state
    assert list(arb._wsum) == pre_wsum
    assert list(arb._nact) == pre_nact
    assert arb._stamp == pre_stamp
    by_sid = {s.sid: s for s in sim._active}
    for sid, result, snaps, vis, lg in pre_segs:
        s = by_sid[sid]
        assert s.result is result and s._snaps is snaps
        assert s.span._vis == vis and s.span.last_grant == lg
    assert sim._dirty                      # marker survives the failure

    del sim._simulate                      # disarm: back to the real one
    sim.drain()                            # the retry settles cleanly
    assert sim.makespan == clean.makespan
    assert sim.share_trace == clean.share_trace
    assert sim.active_trace == clean.active_trace
    assert sim.n_retired == clean.n_retired


# --------------------------------------------------- hypothesis property
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 9), n=st.integers(1, 7),
       gap=st.integers(0, 4), policy=st.sampled_from(POLICIES),
       batch_size=st.integers(1, 4))
def test_no_request_lost_duplicated_or_early(seed, n, gap, policy,
                                             batch_size):
    """Open-arrival conservation: every submitted request is served exactly
    once, admitted no earlier than it arrived, and finishes strictly after
    both its arrival and its admission epoch."""
    requests = synthetic_trace(n, seed=seed, mean_gap=gap, d_model=128,
                               prompt_lens=(16, 32), decode_steps=(1, 2),
                               decode_batch=8)
    chip = ChipConfig(n_cores=2, design="RASA-WLBP",
                      bw_bytes_per_cycle=32.0, backend="numpy")
    rep = run_batcher(requests, chip, policy=policy,
                      batch_size=batch_size, snap_stride=256)
    assert rep.n_requests == n
    assert len(rep.latencies) == len(rep.finish_times) == n
    E = rep.epoch_cycles
    for req, admit, finish, lat in zip(requests, rep.admit_epochs,
                                       rep.finish_times, rep.latencies):
        assert admit >= req.arrival_epoch                  # not served early
        assert finish > admit * E                          # service > 0
        assert lat == pytest.approx(finish - req.arrival_epoch * E)
        assert lat > 0
    assert rep.makespan == max(rep.finish_times) - \
        min(rep.arrival_epochs) * E        # first arrival to last retire
    assert rep.macs == sum(r.macs for r in requests)       # nothing lost
