"""End-to-end reproduction checks against the paper's published results.

These are the headline claims of §V.  Where our simulator cannot be
trace-identical to the paper's MacSim+SDE setup (we rebuilt the lowering;
see EXPERIMENTS.md §Fig5) we assert the *bracketing*: the paper's number
must lie between our reuse-hostile and reuse-maximizing register policies.
"""

import numpy as np
import pytest

from repro.core import (DESIGNS, TABLE_I, normalized_runtime, simulate,
                        sweep_designs, ALG1_POLICY, MAX_REUSE_POLICY)
from repro.core.area import (AREA_OVERHEAD, PAPER_ENERGY_EFFICIENCY,
                             PAPER_RUNTIME_REDUCTION, area_mm2,
                             energy_efficiency, BASELINE_AREA_MM2)
from repro.core.tiling import LOW_REUSE_POLICY
from repro.core.workloads import batch_sweep

# keep CI fast: a representative subset (benchmarks run the full Table I)
FAST_WORKLOADS = ["DLRM-2", "BERT-1"]


def test_pipe_reduction_close_to_paper():
    """PIPE: paper 15.7% avg reduction; analytic bound 1-79/95 = 16.8%.
    PIPE does not depend on the reuse pattern, so we expect a tight match."""
    red = np.mean([1 - normalized_runtime(TABLE_I[w], "RASA-PIPE")
                   for w in FAST_WORKLOADS])
    assert red == pytest.approx(PAPER_RUNTIME_REDUCTION["RASA-PIPE"], abs=0.03)


@pytest.mark.parametrize("design", ["RASA-WLBP", "RASA-DM-WLBP"])
def test_reuse_sensitive_designs_bracket_paper(design):
    """WLBP designs depend on the weight-reuse rate of the lowering: the
    paper's reduction must fall between our reuse-hostile and
    reuse-maximizing register policies."""
    paper = PAPER_RUNTIME_REDUCTION[design]
    lo = np.mean([1 - normalized_runtime(TABLE_I[w], design, LOW_REUSE_POLICY)
                  for w in FAST_WORKLOADS])
    hi = np.mean([1 - normalized_runtime(TABLE_I[w], design, MAX_REUSE_POLICY)
                  for w in FAST_WORKLOADS])
    assert min(lo, hi) - 0.02 <= paper <= max(lo, hi) + 0.02, \
        f"{design}: paper {paper} outside [{lo:.3f}, {hi:.3f}]"


@pytest.mark.parametrize("design", ["RASA-DB-WLS", "RASA-DMDB-WLS"])
def test_wls_designs_close_to_paper(design):
    """WLS hides WL regardless of reuse; our engine-only model is slightly
    more optimistic than the paper's full-core trace simulation (no ROB /
    frontend effects).  Require agreement within 6 points."""
    paper = PAPER_RUNTIME_REDUCTION[design]
    got = np.mean([1 - normalized_runtime(TABLE_I[w], design)
                   for w in FAST_WORKLOADS])
    assert got == pytest.approx(paper, abs=0.06), f"{design}: {got:.3f} vs {paper}"


def test_relative_design_ordering():
    """Fig. 5: BASE > PIPE > WLBP > DM-WLBP > DB-WLS ~= DMDB-WLS (runtime)."""
    spec = TABLE_I["DLRM-1"]
    r = {d: normalized_runtime(spec, d) for d in
         ["RASA-PIPE", "RASA-WLBP", "RASA-DM-WLBP", "RASA-DB-WLS",
          "RASA-DMDB-WLS"]}
    assert 1.0 > r["RASA-PIPE"] > r["RASA-WLBP"] > r["RASA-DM-WLBP"]
    assert r["RASA-DM-WLBP"] > r["RASA-DB-WLS"]
    assert abs(r["RASA-DB-WLS"] - r["RASA-DMDB-WLS"]) < 0.05


def test_batch_asymptote():
    """Fig. 7: DMDB-WLS normalized runtime approaches 16/95 = 0.168 for
    large batch, and small batches (<=16) all cost the same."""
    sweep = batch_sweep(nin=512, non=512, batches=(1, 2, 4, 8, 16, 1024))
    runs = {b: normalized_runtime(s, "RASA-DMDB-WLS") for b, s in sweep.items()}
    small = [simulate(sweep[b], "RASA-DMDB-WLS").cycles for b in (1, 2, 4, 8, 16)]
    assert max(small) == pytest.approx(min(small), rel=1e-6), \
        "batches <=16 must use the same number of rasa_mm"
    assert runs[1024] == pytest.approx(16 / 95, abs=0.02)


def test_large_batch_mm_count_equal_small():
    sweep = batch_sweep(nin=256, non=256, batches=(1, 16))
    a = simulate(sweep[1], "BASE")
    b = simulate(sweep[16], "BASE")
    assert a.n_mm == b.n_mm        # 16 is the smallest granularity of work


# ------------------------------------------------------------- area / energy
def test_area_constants():
    assert area_mm2("RASA-DMDB-WLS") == pytest.approx(0.847, abs=0.01)
    assert BASELINE_AREA_MM2 == pytest.approx(0.803, abs=0.01)
    assert AREA_OVERHEAD["DB"] == 1.031
    assert AREA_OVERHEAD["DM"] == 1.026
    assert AREA_OVERHEAD["DMDB"] == 1.055


@pytest.mark.parametrize("opt,design,reduction", [
    ("DB", "RASA-DB-WLS", 0.781),
    ("DM", "RASA-DM-WLBP", 0.555),
    ("DMDB", "RASA-DMDB-WLS", 0.792),
])
def test_energy_efficiency_model_reproduces_paper(opt, design, reduction):
    """EE = speedup/area-overhead reproduces 4.38x/2.19x/4.59x within 2%."""
    speedup = 1.0 / (1.0 - reduction)
    ee = energy_efficiency(design, speedup)
    assert ee == pytest.approx(PAPER_ENERGY_EFFICIENCY[opt], rel=0.02)


def test_sweep_designs_reports():
    reports = sweep_designs(TABLE_I["DLRM-2"])
    assert set(reports) == set(DESIGNS)
    base = reports["BASE"]
    assert base.macs == TABLE_I["DLRM-2"].macs
    for rep in reports.values():
        assert rep.cycles > 0 and 0 < rep.utilization <= 1
    # utilization of the best design should be several x the baseline's
    assert (reports["RASA-DMDB-WLS"].utilization
            > 3 * reports["BASE"].utilization)
