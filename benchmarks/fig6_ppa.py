"""Paper Fig. 6 + §V area/energy: performance-per-area and energy
efficiency of the RASA-Data options (published physical constants +
simulated runtimes; reproduces 4.38x / 2.19x / 4.59x)."""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

import numpy as np

from repro.core import TABLE_I, normalized_runtime
from repro.core.area import (area_mm2, energy_efficiency,
                             PAPER_ENERGY_EFFICIENCY, perf_per_area)

from common import emit  # type: ignore

BEST_CONTROL = {"DB": "RASA-DB-WLS", "DM": "RASA-DM-WLBP",
                "DMDB": "RASA-DMDB-WLS"}


def main() -> None:
    for data_opt, design in BEST_CONTROL.items():
        norm = np.mean([normalized_runtime(spec, design)
                        for spec in TABLE_I.values()])
        speedup = 1.0 / norm
        ppa = perf_per_area(design, speedup)
        ee = energy_efficiency(design, speedup)
        emit(f"fig6_{design}", 0.0,
             f"area_mm2={area_mm2(design):.3f};speedup={speedup:.2f};"
             f"ppa={ppa:.2f};energy_eff={ee:.2f};"
             f"paper_ee={PAPER_ENERGY_EFFICIENCY[data_opt]}")


if __name__ == "__main__":
    main()
