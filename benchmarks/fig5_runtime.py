"""Paper Fig. 5: normalized runtime of the RASA designs on Table I layers.

Reports per-layer normalized runtimes + the averages the paper quotes
(PIPE -15.7%, WLBP -30.9%, DB-WLS -78.1%, DM-WLBP -55.5%, DMDB-WLS -79.2%),
for the Algorithm-1 register policy and the two bracketing policies
(EXPERIMENTS.md §Fig5 discusses the deviation).
"""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

import numpy as np

from repro.core import TABLE_I, normalized_runtime
from repro.core.area import PAPER_RUNTIME_REDUCTION
from repro.core.tiling import ALG1_POLICY, LOW_REUSE_POLICY, MAX_REUSE_POLICY

from common import cache_json, emit, timeit  # type: ignore

DESIGNS = ["RASA-PIPE", "RASA-WLBP", "RASA-DB-WLS", "RASA-DM-PIPE",
           "RASA-DM-WLBP", "RASA-DMDB-WLS"]
POLICIES = {"alg1": ALG1_POLICY, "low_reuse": LOW_REUSE_POLICY,
            "max_reuse": MAX_REUSE_POLICY}


def run(force: bool = False) -> dict:
    def compute():
        out = {}
        for pol_name, pol in POLICIES.items():
            for layer, spec in TABLE_I.items():
                for design in DESIGNS:
                    out[f"{pol_name}/{layer}/{design}"] = normalized_runtime(
                        spec, design, pol)
        return out
    return cache_json("fig5_runtime", compute, force=force)


def main() -> None:
    us = timeit(lambda: normalized_runtime(TABLE_I["DLRM-2"], "RASA-PIPE"),
                warmup=1, iters=1)
    table = run()
    for key, v in sorted(table.items()):
        emit(f"fig5_{key}", us, f"norm_runtime={v:.3f}")
    print("\n# averages over Table I (normalized runtime; paper in parens)")
    for design in DESIGNS:
        for pol in POLICIES:
            avg = np.mean([table[f"{pol}/{l}/{design}"] for l in TABLE_I])
            paper = PAPER_RUNTIME_REDUCTION.get(design)
            ref = f" (paper {1-paper:.3f})" if paper and pol == "alg1" else ""
            print(f"# {design:16s} policy={pol:10s} avg={avg:.3f}{ref}")


if __name__ == "__main__":
    main()
