"""Simulator-core throughput: reference loop vs trace-compiled backends.

Measures (1) single-stream instructions/second per backend, (2) end-to-end
wall time of the 8-design x multi-GEMM sweep (``repro.core.sweep_workload``)
on the reference backend vs the fast backend (cold = includes trace + XLA
compilation, warm = steady state), and (3) the 4-core epoch-arbitration
comparison from ``multicore_scaling`` on the reference vs fast chip backend.

Results go to ``benchmarks/results/BENCH_sim_throughput.json`` -- the perf
trajectory artifact CI uploads next to the multicore benchmark.

    PYTHONPATH=src python benchmarks/sim_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.core import (TABLE_I, get_design, simulate, sweep_workload,
                        PipelineSimulator)
from repro.core import fastsim, simulator, tiling
from repro.core import trace as trace_mod
from repro.core.tiling import ALG1_POLICY, lowered_stream
from repro.core.trace import gemm_trace
from repro.multicore import ChipConfig, simulate_chip

from common import emit, write_bench  # type: ignore

#: the multi-GEMM design-sweep workload (all DLRM + BERT layers of Table I;
#: the ResNet50 layers' ~2M-instruction streams are left out to keep the CI
#: smoke run bounded)
SWEEP_WORKLOAD = ("DLRM-1", "DLRM-2", "DLRM-3", "BERT-1", "BERT-2", "BERT-3")
SMOKE_WORKLOAD = ("DLRM-2", "BERT-1", "DLRM-1")

#: skewed scheduler workload for the multicore section (cf.
#: benchmarks/multicore_scaling.py)
MC_WORKLOAD = ("DLRM-2", "BERT-1", "DLRM-2", "BERT-1", "DLRM-2", "DLRM-2")
MC_BW = 32.0


def _clear_caches() -> None:
    simulator._simulate_cached.cache_clear()
    tiling._lowered_stream_cached.cache_clear()
    # dropping the trace cache also releases the per-trace MM analyses
    # (fastsim._MM_CACHE holds them under weak keys)
    trace_mod._compiled_trace_cached.cache_clear()


def bench_stream(design: str = "RASA-WLBP", spec_name: str = "BERT-1") -> dict:
    """Single-stream instructions/second per backend."""
    spec = TABLE_I[spec_name]
    cfg = get_design(design)
    stream = lowered_stream(spec, ALG1_POLICY)
    trace = gemm_trace(spec, ALG1_POLICY)
    n = len(stream)
    out = {"design": design, "workload": spec_name, "n_instrs": n}

    t0 = time.perf_counter()
    ref = PipelineSimulator(cfg).run(stream)
    out["reference_instrs_per_sec"] = n / (time.perf_counter() - t0)

    t0 = time.perf_counter()
    fast = fastsim.run_trace_numpy(trace, cfg)
    out["numpy_instrs_per_sec"] = n / (time.perf_counter() - t0)
    assert fast.cycles == ref.cycles

    if fastsim.has_jax():
        cfgs = [get_design(d) for d in
                ("BASE", "RASA-PIPE", "RASA-WLBP", "RASA-DB-WLS",
                 "RASA-DM-PIPE", "RASA-DM-WLBP", "RASA-DMDB-WLS",
                 "RASA-DB-WLBP")]
        fastsim.sweep_trace(trace, cfgs, backend="jax")    # compile
        t0 = time.perf_counter()
        res = fastsim.sweep_trace(trace, cfgs, backend="jax")
        dt = time.perf_counter() - t0
        # batched rate: per-design instructions retired per second
        out["jax_batch8_instrs_per_sec"] = n * len(cfgs) / dt
        assert abs(res[2].cycles - ref.cycles) <= 1e-6 * ref.cycles
    return out


def bench_sweep(workload: tuple[str, ...]) -> dict:
    """8-design x multi-GEMM sweep: reference vs fast, cold and warm."""
    specs = [TABLE_I[k] for k in workload]
    out = {"workload": list(workload), "n_designs": 8,
           "n_instrs": sum(len(lowered_stream(s, ALG1_POLICY))
                           for s in specs)}

    _clear_caches()
    t0 = time.perf_counter()
    ref = sweep_workload(specs, backend="reference")
    out["reference_s"] = time.perf_counter() - t0

    _clear_caches()          # cold really means cold: traces recompile too
    t0 = time.perf_counter()
    cold = sweep_workload(specs, backend="fast")
    out["fast_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = sweep_workload(specs, backend="fast")
    out["fast_warm_s"] = time.perf_counter() - t0

    for r, w in zip(ref, warm):
        for k in r:
            rel = abs(r[k].cycles - w[k].cycles) / max(1.0, r[k].cycles)
            assert rel <= 1e-6, (k, r[k].cycles, w[k].cycles)
    out["speedup_cold"] = out["reference_s"] / out["fast_cold_s"]
    out["speedup_warm"] = out["reference_s"] / out["fast_warm_s"]
    out["backend_resolved"] = fastsim.resolve_backend(
        "fast", out["n_instrs"] * 8)
    return out


def bench_multicore() -> dict:
    """Epoch-arbitration comparison wall time, reference vs fast backend."""
    specs = [TABLE_I[k] for k in MC_WORKLOAD]
    out = {"workload": list(MC_WORKLOAD), "n_cores": 4,
           "bw_bytes_per_cycle": MC_BW}
    reps = {}
    for backend in ("reference", "fast"):
        t0 = time.perf_counter()
        for arb in ("static", "epoch"):
            reps[backend, arb] = simulate_chip(
                specs, ChipConfig(n_cores=4, design="RASA-WLBP",
                                  bw_bytes_per_cycle=MC_BW, arbitration=arb,
                                  backend=backend),
                scheduler="lpt")
        out[f"{backend}_s"] = time.perf_counter() - t0
    for arb in ("static", "epoch"):
        ref, fast = reps["reference", arb], reps["fast", arb]
        rel = abs(ref.cycles - fast.cycles) / ref.cycles
        assert rel <= 1e-6, (arb, ref.cycles, fast.cycles)
        out[f"{arb}_cycles"] = fast.cycles
    out["epoch_arb_skipped"] = list(reps["fast", "epoch"].arb_skipped)
    out["speedup"] = out["reference_s"] / out["fast_s"]
    return out


def run(smoke: bool = False) -> dict:
    table = {
        "stream": bench_stream(),
        "sweep": bench_sweep(SMOKE_WORKLOAD if smoke else SWEEP_WORKLOAD),
        "multicore": bench_multicore(),
        "jax_available": fastsim.has_jax(),
        "smoke": smoke,
    }
    write_bench("sim_throughput", table, backend="fast")
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smaller sweep workload (CI smoke run)")
    args = ap.parse_args(argv)
    t = run(smoke=args.smoke)

    s = t["stream"]
    print(f"# single stream ({s['design']} x {s['workload']}, "
          f"{s['n_instrs']} instrs)")
    for k in ("reference", "numpy", "jax_batch8"):
        key = f"{k}_instrs_per_sec"
        if key in s:
            print(f"{k:<12} {s[key]:>12.0f} instrs/s")
            emit(f"sim_throughput_{k}", 0.0, f"ips={s[key]:.0f}")

    w = t["sweep"]
    print(f"\n# 8-design x {len(w['workload'])}-GEMM sweep "
          f"({w['n_instrs']} instrs/design)")
    print(f"reference {w['reference_s']:.2f}s   fast cold "
          f"{w['fast_cold_s']:.2f}s ({w['speedup_cold']:.1f}x)   "
          f"fast warm {w['fast_warm_s']:.2f}s ({w['speedup_warm']:.1f}x)")
    emit("sim_throughput_sweep", 0.0,
         f"ref={w['reference_s']:.2f}s;warm={w['fast_warm_s']:.2f}s;"
         f"speedup={w['speedup_warm']:.1f}")

    m = t["multicore"]
    print(f"\n# 4-core epoch arbitration (x2 models, {MC_BW:.0f} B/cyc)")
    print(f"reference {m['reference_s']:.2f}s   fast {m['fast_s']:.2f}s "
          f"({m['speedup']:.1f}x)   skipped/round={m['epoch_arb_skipped']}")
    emit("sim_throughput_multicore", 0.0,
         f"ref={m['reference_s']:.2f}s;fast={m['fast_s']:.2f}s;"
         f"speedup={m['speedup']:.1f}")


if __name__ == "__main__":
    main()
