"""Perf hillclimb harness: measure roofline terms for config VARIANTS of a
cell without touching the cached baseline artifacts.

    PYTHONPATH=src python benchmarks/hillclimb.py --arch grok-1-314b \
        --shape train_4k --variant fused_gate_up --variant remat_dots

Each variant is a named config transform; the harness compiles the full
cell (memory proof) + unrolled d0/d_unit (accurate flops/bytes/collectives)
and prints the three terms next to the baseline.  Results go to
benchmarks/results/hillclimb/<cell>__<variant>.json.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

RESULTS = Path(__file__).resolve().parent / "results" / "hillclimb"
DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


# ---------------------------------------------------------------- variants

def v_baseline(cfg):
    return cfg


def v_fused_gate_up(cfg):
    return dataclasses.replace(
        cfg, model=dataclasses.replace(cfg.model, fuse_gate_up=True))


def v_remat_dots(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="dots"))


def v_serve_tp(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel,
                                          serve_param_sharding="tp"))


def v_microbatch4(cfg):
    return dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, microbatches=4))


def v_no_sp(cfg):
    # drop sequence parallelism of the residual stream: fewer per-layer
    # all-gathers at the cost of bigger carries (memory <-> collective)
    return cfg  # marker; applied via env knob below


def v_cap1(cfg):
    m = cfg.model
    return dataclasses.replace(
        cfg, model=dataclasses.replace(
            m, moe=dataclasses.replace(m.moe, capacity_factor=1.0)))


def v_groups64(cfg):
    m = cfg.model
    return dataclasses.replace(
        cfg, model=dataclasses.replace(
            m, moe=dataclasses.replace(m.moe, dispatch_groups=64)))


def v_ssd_chunk128(cfg):
    import dataclasses as dc
    m = cfg.model
    return dc.replace(cfg, model=dc.replace(
        m, ssm=dc.replace(m.ssm, chunk=128)))


def v_ssd_chunk64(cfg):
    import dataclasses as dc
    m = cfg.model
    return dc.replace(cfg, model=dc.replace(
        m, ssm=dc.replace(m.ssm, chunk=64)))


def v_opt_bf16(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel,
                                          opt_state_dtype="bfloat16"))


VARIANTS = {
    "baseline": v_baseline,
    "fused_gate_up": v_fused_gate_up,
    "remat_dots": v_remat_dots,
    "serve_tp": v_serve_tp,
    "microbatch4": v_microbatch4,
    "moe_cap1": v_cap1,
    "moe_groups64": v_groups64,
    "ssd_chunk128": v_ssd_chunk128,
    "ssd_chunk64": v_ssd_chunk64,
    "opt_bf16": v_opt_bf16,
}


def measure(arch: str, shape: str, variant: str, full: bool = True) -> dict:
    """Compile the variant cell + reduced-depth artifacts; return terms."""
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.distributed.sharding import mesh_context
    from repro.launch.dryrun import build_step, parse_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import analyze_cell

    transform = VARIANTS[variant]
    seq_len, batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=False)

    def compile_cfg(cfg):
        with mesh_context(mesh, cfg.parallel) as ctx:
            fn, args, sh, don = build_step(cfg, kind, seq_len, batch, ctx)
            c = jax.jit(fn, in_shardings=sh,
                        donate_argnums=don).lower(*args).compile()
            mem = c.memory_analysis()
            cost = c.cost_analysis()
            colls = parse_collectives(c.as_text())
        return {
            "memory": {"peak_bytes_per_device":
                       mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
                       "temp_bytes_per_device": mem.temp_size_in_bytes},
            "cost_per_device": {"flops": cost.get("flops", 0.0),
                                "bytes_accessed": cost.get("bytes accessed", 0.0)},
            "collectives_per_device_bytes": colls,
        }

    base_cfg = get_config(arch)
    cfg = transform(base_cfg)
    unit = (cfg.model.hybrid.attn_every
            if cfg.model.family == "hybrid" else 1)

    def depth_cfg(c, depth):
        return dataclasses.replace(
            c,
            model=dataclasses.replace(c.model, n_layers=depth),
            parallel=dataclasses.replace(c.parallel, scan_layers=False),
            engine=dataclasses.replace(c.engine, attn_q_chunk=seq_len,
                                       attn_kv_chunk=seq_len,
                                       ce_chunk=seq_len, unroll_ssd=True))

    out = {"arch": arch, "shape": shape, "variant": variant,
           "devices": 256, "unit_layers": unit,
           "total_layers": cfg.model.n_layers}
    t0 = time.time()
    if full:
        out.update(compile_cfg(cfg))
    d0 = compile_cfg(depth_cfg(cfg, 0))
    du = compile_cfg(depth_cfg(cfg, unit))
    out["elapsed_s"] = round(time.time() - t0, 1)

    cell = {**out, "cost_per_device": out.get(
        "cost_per_device", d0["cost_per_device"]),
        "memory": out.get("memory", d0["memory"]),
        "collectives_per_device_bytes": out.get(
            "collectives_per_device_bytes", {})}
    d0f = {"cost_per_device": d0["cost_per_device"],
           "collectives_per_device_bytes": d0["collectives_per_device_bytes"]}
    duf = {"cost_per_device": du["cost_per_device"],
           "collectives_per_device_bytes": du["collectives_per_device_bytes"]}
    r = analyze_cell(cell, d0=d0f, du=duf)
    out["roofline"] = {
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "dominant": r.dominant,
        "step_time_s": r.step_time_s, "mfu": r.mfu,
        "useful_flops_ratio": r.useful_flops_ratio,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{arch}__{shape}__{variant}.json").write_text(
        json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--skip-full", action="store_true",
                    help="skip the full-depth compile (terms only)")
    args = ap.parse_args()
    for v in (args.variant or ["baseline"]):
        r = measure(args.arch, args.shape, v, full=not args.skip_full)
        rf = r["roofline"]
        mem = r.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
        print(f"{args.arch} x {args.shape} [{v}]: "
              f"compute {rf['compute_s']:.3f}s  memory {rf['memory_s']:.3f}s  "
              f"coll {rf['collective_s']:.3f}s  -> {rf['dominant']} "
              f"(step {rf['step_time_s']:.3f}s, MFU {rf['mfu']:.1%}, "
              f"mem {mem:.1f} GiB)", flush=True)


if __name__ == "__main__":
    main()
