"""Perf hillclimb harness, two search modes.

Roofline mode (model-config variants, XLA-compiled terms):

    PYTHONPATH=src python benchmarks/hillclimb.py --arch grok-1-314b \
        --shape train_4k --variant fused_gate_up --variant remat_dots

Each variant is a named config transform; the harness compiles the full
cell (memory proof) + unrolled d0/d_unit (accurate flops/bytes/collectives)
and prints the three terms next to the baseline.  Results go to
benchmarks/results/hillclimb/<cell>__<variant>.json.

Engine design-search mode (matrix-engine configs, cycle simulator):

    PYTHONPATH=src python benchmarks/hillclimb.py --design-search \
        --workload bert --steps 20

Hillclimbs the RASA engine design space (array shape under the paper's
equal-multiplier constraint, control optimizations, LSQ parameters,
register policy) to minimize simulated cycles on a Table-I workload.
Every step evaluates the whole neighborhood in one batched fast-backend
design sweep (``repro.core.sweep_workload``), and perturbed frozen
``EngineConfig``s hit ``_simulate_cached`` instead of re-simulating.
Results go to benchmarks/results/hillclimb/design_search__<workload>.json.
"""

import argparse
import dataclasses
import json
import os
import time
from pathlib import Path

import common  # noqa: F401  -- puts <repo>/src on sys.path

RESULTS = Path(__file__).resolve().parent / "results" / "hillclimb"
DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


# ---------------------------------------------------------------- variants

def v_baseline(cfg):
    return cfg


def v_fused_gate_up(cfg):
    return dataclasses.replace(
        cfg, model=dataclasses.replace(cfg.model, fuse_gate_up=True))


def v_remat_dots(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="dots"))


def v_serve_tp(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel,
                                          serve_param_sharding="tp"))


def v_microbatch4(cfg):
    return dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, microbatches=4))


def v_no_sp(cfg):
    # drop sequence parallelism of the residual stream: fewer per-layer
    # all-gathers at the cost of bigger carries (memory <-> collective)
    return cfg  # marker; applied via env knob below


def v_cap1(cfg):
    m = cfg.model
    return dataclasses.replace(
        cfg, model=dataclasses.replace(
            m, moe=dataclasses.replace(m.moe, capacity_factor=1.0)))


def v_groups64(cfg):
    m = cfg.model
    return dataclasses.replace(
        cfg, model=dataclasses.replace(
            m, moe=dataclasses.replace(m.moe, dispatch_groups=64)))


def v_ssd_chunk128(cfg):
    import dataclasses as dc
    m = cfg.model
    return dc.replace(cfg, model=dc.replace(
        m, ssm=dc.replace(m.ssm, chunk=128)))


def v_ssd_chunk64(cfg):
    import dataclasses as dc
    m = cfg.model
    return dc.replace(cfg, model=dc.replace(
        m, ssm=dc.replace(m.ssm, chunk=64)))


def v_opt_bf16(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel,
                                          opt_state_dtype="bfloat16"))


VARIANTS = {
    "baseline": v_baseline,
    "fused_gate_up": v_fused_gate_up,
    "remat_dots": v_remat_dots,
    "serve_tp": v_serve_tp,
    "microbatch4": v_microbatch4,
    "moe_cap1": v_cap1,
    "moe_groups64": v_groups64,
    "ssd_chunk128": v_ssd_chunk128,
    "ssd_chunk64": v_ssd_chunk64,
    "opt_bf16": v_opt_bf16,
}


def measure(arch: str, shape: str, variant: str, full: bool = True) -> dict:
    """Compile the variant cell + reduced-depth artifacts; return terms."""
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax  # noqa: F401  (mesh construction below needs devices)
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.distributed.sharding import mesh_context
    from repro.launch.dryrun import build_step, parse_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import analyze_cell

    transform = VARIANTS[variant]
    seq_len, batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=False)

    def compile_cfg(cfg):
        with mesh_context(mesh, cfg.parallel) as ctx:
            fn, args, sh, don = build_step(cfg, kind, seq_len, batch, ctx)
            c = jax.jit(fn, in_shardings=sh,
                        donate_argnums=don).lower(*args).compile()
            mem = c.memory_analysis()
            cost = c.cost_analysis()
            colls = parse_collectives(c.as_text())
        return {
            "memory": {"peak_bytes_per_device":
                       mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
                       "temp_bytes_per_device": mem.temp_size_in_bytes},
            "cost_per_device": {"flops": cost.get("flops", 0.0),
                                "bytes_accessed": cost.get("bytes accessed", 0.0)},
            "collectives_per_device_bytes": colls,
        }

    base_cfg = get_config(arch)
    cfg = transform(base_cfg)
    unit = (cfg.model.hybrid.attn_every
            if cfg.model.family == "hybrid" else 1)

    def depth_cfg(c, depth):
        return dataclasses.replace(
            c,
            model=dataclasses.replace(c.model, n_layers=depth),
            parallel=dataclasses.replace(c.parallel, scan_layers=False),
            engine=dataclasses.replace(c.engine, attn_q_chunk=seq_len,
                                       attn_kv_chunk=seq_len,
                                       ce_chunk=seq_len, unroll_ssd=True))

    out = {"arch": arch, "shape": shape, "variant": variant,
           "devices": 256, "unit_layers": unit,
           "total_layers": cfg.model.n_layers}
    t0 = time.time()
    if full:
        out.update(compile_cfg(cfg))
    d0 = compile_cfg(depth_cfg(cfg, 0))
    du = compile_cfg(depth_cfg(cfg, unit))
    out["elapsed_s"] = round(time.time() - t0, 1)

    cell = {**out, "cost_per_device": out.get(
        "cost_per_device", d0["cost_per_device"]),
        "memory": out.get("memory", d0["memory"]),
        "collectives_per_device_bytes": out.get(
            "collectives_per_device_bytes", {})}
    d0f = {"cost_per_device": d0["cost_per_device"],
           "collectives_per_device_bytes": d0["collectives_per_device_bytes"]}
    duf = {"cost_per_device": du["cost_per_device"],
           "collectives_per_device_bytes": du["collectives_per_device_bytes"]}
    r = analyze_cell(cell, d0=d0f, du=duf)
    out["roofline"] = {
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "dominant": r.dominant,
        "step_time_s": r.step_time_s, "mfu": r.mfu,
        "useful_flops_ratio": r.useful_flops_ratio,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{arch}__{shape}__{variant}.json").write_text(
        json.dumps(out, indent=2))
    return out


# ------------------------------------------- engine design-space hillclimb

#: Table-I workloads the engine search can optimize for
SEARCH_WORKLOADS = {
    "bert": ("BERT-1", "BERT-2", "BERT-3"),
    "dlrm": ("DLRM-1", "DLRM-2", "DLRM-3"),
    "mixed": ("DLRM-2", "BERT-1", "DLRM-3", "BERT-3"),
}

#: equal-multiplier constraint (paper §V: every array has 512 multipliers)
N_MULTIPLIERS = 512


def _engine_candidates(state):
    """Single-knob neighbors of (engine kwargs, policy) under constraints."""
    import repro.core.tiling as tiling
    kw, policy = state
    POLICIES = (
        tiling.RegPolicy(mc=2, nc=2, a_regs=2, b_regs=2),
        tiling.RegPolicy(mc=4, nc=1, a_regs=2, b_regs=1),
        tiling.RegPolicy(mc=5, nc=1, a_regs=2, b_regs=1),
        tiling.RegPolicy(mc=1, nc=4, a_regs=1, b_regs=2),
        tiling.RegPolicy(mc=3, nc=1, a_regs=2, b_regs=2),
    )
    out = []
    for rows in (8, 16, 32, 64):
        for macs in (1, 2):
            cols = N_MULTIPLIERS // (rows * macs)
            if rows * macs * cols != N_MULTIPLIERS or cols < 4 or cols > 64:
                continue
            if (rows, macs) != (kw["rows"], kw["macs_per_pe"]):
                out.append(({**kw, "rows": rows, "cols": cols,
                             "macs_per_pe": macs}, policy))
    for flags in ((False, False, False, False), (True, False, False, False),
                  (True, True, False, False), (True, True, True, True),
                  (True, False, True, True), (True, True, False, True)):
        pipe, wlbp, wls, db = flags
        cand = {**kw, "pipe": pipe, "wlbp": wlbp, "wls": wls,
                "double_buffer": db}
        if cand != kw:
            out.append((cand, policy))
    for lat in (2, 5, 10, 20):
        if lat != kw["load_latency"]:
            out.append(({**kw, "load_latency": lat}, policy))
    for ports in (1, 2, 4):
        if ports != kw["load_ports"]:
            out.append(({**kw, "load_ports": ports}, policy))
    for pol in POLICIES:
        if pol != policy:
            out.append((kw, pol))
    return out


def design_search(workload: str = "bert", steps: int = 20,
                  backend: str = "fast") -> dict:
    """Greedy hillclimb over EngineConfig x RegPolicy on simulated cycles."""
    from repro.core import DESIGNS, TABLE_I, EngineConfig, get_design
    from repro.core import sweep_workload
    from repro.core.simulator import _simulate_cached
    from repro.core.tiling import ALG1_POLICY
    from repro.obs.attribution import simreport_attribution

    specs = [TABLE_I[k] for k in SEARCH_WORKLOADS[workload]]
    counter = [0]

    def attribution(policy, cycles) -> dict:
        """Unthrottled {compute, fill_drain, ...} split of one candidate --
        the 'why does this design win' column of the search log."""
        return simreport_attribution(specs, policy, cycles).fractions()

    def to_cfg(kw) -> EngineConfig:
        counter[0] += 1
        return EngineConfig(name=f"probe-{counter[0]}", **kw)

    seen: dict = {}

    def evaluate(states):
        """Batched cost of unseen states (total cycles over the workload)."""
        todo = [s for s in states
                if (_key(s)) not in seen]
        by_policy: dict = {}
        for s in todo:
            by_policy.setdefault(s[1], []).append(s)
        for policy, group in by_policy.items():
            cfgs = [to_cfg(kw) for kw, _ in group]
            rows = sweep_workload(specs, cfgs, policy, backend=backend)
            for s, cfg in zip(group, cfgs):
                seen[_key(s)] = sum(row[cfg.name].cycles for row in rows)
        return [seen[_key(s)] for s in states]

    def _key(state):
        kw, policy = state
        return (tuple(sorted(kw.items())), policy)

    start_cfg = get_design("RASA-DMDB-WLS")
    start = ({f.name: getattr(start_cfg, f.name)
              for f in dataclasses.fields(start_cfg) if f.name != "name"},
             ALG1_POLICY)
    cur, (cur_cost,) = start, evaluate([start])
    path = [{"step": 0, "engine": dict(cur[0]),
             "policy": dataclasses.asdict(cur[1]), "cycles": cur_cost,
             "attribution": attribution(cur[1], cur_cost)}]
    t0 = time.time()
    probes = 1
    for step in range(1, steps + 1):
        neigh = _engine_candidates(cur)
        probes += sum(1 for s in neigh if _key(s) not in seen)
        costs = evaluate(neigh)
        best_i = min(range(len(neigh)), key=lambda i: costs[i])
        if costs[best_i] >= cur_cost:
            break
        cur, cur_cost = neigh[best_i], costs[best_i]
        path.append({"step": step, "engine": dict(cur[0]),
                     "policy": dataclasses.asdict(cur[1]),
                     "cycles": cur_cost,
                     "attribution": attribution(cur[1], cur_cost)})
    elapsed = time.time() - t0

    # named baselines (exercises the EngineConfig-keyed _simulate_cached)
    baselines = {}
    for name in DESIGNS:
        cfg = get_design(name)
        baselines[name] = sum(
            _simulate_cached(s, cfg, ALG1_POLICY, backend).cycles
            for s in specs)
    out = {"workload": workload, "specs": [s.name for s in specs],
           "backend": backend, "probes": probes, "elapsed_s": elapsed,
           "path": path, "best_cycles": cur_cost,
           "named_baselines": baselines,
           "speedup_vs_best_named": min(baselines.values()) / cur_cost}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"design_search__{workload}.json").write_text(
        json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--design-search", action="store_true",
                    help="hillclimb the RASA engine design space instead of "
                         "the model-config roofline")
    ap.add_argument("--workload", default="bert",
                    choices=sorted(SEARCH_WORKLOADS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--backend", default="fast",
                    choices=("reference", "fast", "numpy", "jax"))
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--skip-full", action="store_true",
                    help="skip the full-depth compile (terms only)")
    args = ap.parse_args()

    if args.design_search:
        r = design_search(args.workload, args.steps, args.backend)
        base = min(r["named_baselines"].items(), key=lambda kv: kv[1])
        print(f"design search [{args.workload}] {r['probes']} probes in "
              f"{r['elapsed_s']:.1f}s ({len(r['path']) - 1} accepted moves)")
        for p in r["path"]:
            e = p["engine"]
            a = p["attribution"]
            print(f"  step {p['step']:>2}  {p['cycles']:>12.0f} cyc  "
                  f"{e['rows']}x{e['cols']}x{e['macs_per_pe']} "
                  f"pipe={e['pipe']} wlbp={e['wlbp']} wls={e['wls']} "
                  f"lat={e['load_latency']} ports={e['load_ports']} "
                  f"policy={p['policy']['mc']}x{p['policy']['nc']}  "
                  f"compute={a['compute']:.0%} "
                  f"fill/drain={a['fill_drain']:.0%}")
        print(f"best {r['best_cycles']:.0f} cyc vs best named "
              f"{base[0]} {base[1]:.0f} cyc "
              f"({r['speedup_vs_best_named']:.2f}x)")
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required without --design-search")
    for v in (args.variant or ["baseline"]):
        r = measure(args.arch, args.shape, v, full=not args.skip_full)
        rf = r["roofline"]
        mem = r.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
        print(f"{args.arch} x {args.shape} [{v}]: "
              f"compute {rf['compute_s']:.3f}s  memory {rf['memory_s']:.3f}s  "
              f"coll {rf['collective_s']:.3f}s  -> {rf['dominant']} "
              f"(step {rf['step_time_s']:.3f}s, MFU {rf['mfu']:.1%}, "
              f"mem {mem:.1f} GiB)", flush=True)


if __name__ == "__main__":
    main()
