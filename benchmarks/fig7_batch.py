"""Paper Fig. 7: batch-size sensitivity of RASA-DMDB-WLS.

Claims reproduced: batches 1..16 cost the same (16 is the smallest work
granularity); large batches approach the 16/95 = 0.168 asymptote.
"""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.core import batch_sweep, normalized_runtime, simulate
from repro.core.area import PAPER_BEST_NORMALIZED_RUNTIME

from common import cache_json, emit  # type: ignore


def run(force: bool = False) -> dict:
    def compute():
        sweep = batch_sweep(nin=1024, non=1024,
                            batches=(1, 2, 4, 8, 16, 32, 64, 128, 256,
                                     512, 1024, 2048))
        return {str(b): normalized_runtime(spec, "RASA-DMDB-WLS")
                for b, spec in sweep.items()}
    return cache_json("fig7_batch", compute, force=force)


def main() -> None:
    table = run()
    for b, v in table.items():
        emit(f"fig7_batch{b}", 0.0, f"norm_runtime={v:.3f}")
    small = [table[str(b)] for b in (1, 2, 4, 8, 16)]
    assert max(small) - min(small) < 1e-9, "batches <=16 must cost the same"
    assert abs(table["2048"] - PAPER_BEST_NORMALIZED_RUNTIME) < 0.02
    print(f"# asymptote: {table['2048']:.3f} (paper bound "
          f"{PAPER_BEST_NORMALIZED_RUNTIME:.3f})")


if __name__ == "__main__":
    main()
