"""Kernel-level benchmark: RASA-scheduled Pallas GEMM schedules.

On CPU the kernels run in interpret mode (semantics, not speed), so the
*performance* signal here is the DMA cost model (schedule_cost) -- bytes
moved per schedule -- which is what the perf loop optimizes.  Wall-times
of the jnp reference are included as the call-overhead baseline.
"""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

import numpy as np
import jax.numpy as jnp

from repro.kernels import GemmBlocks, SCHEDULES, rasa_matmul, schedule_cost
from repro.kernels.ref import ref_matmul

from common import emit, timeit  # type: ignore

SHAPES = [(1024, 1024, 1024), (4096, 2048, 2048), (16384, 6144, 6144)]


def main() -> None:
    blocks = GemmBlocks(256, 512, 256)
    for (m, k, n) in SHAPES:
        for sched in SCHEDULES:
            c = schedule_cost(m, k, n, blocks, sched)
            emit(f"kernel_gemm_{m}x{k}x{n}_{sched}", 0.0,
                 f"bytes={c['total_bytes']};ai={c['arithmetic_intensity']:.1f}")
    # numerics spot check + reference wall time (interpret mode)
    rng = np.random.default_rng(0)
    a = rng.normal(size=(256, 256)).astype(jnp.bfloat16)
    b = rng.normal(size=(256, 256)).astype(jnp.bfloat16)
    us = timeit(lambda: np.asarray(
        rasa_matmul(a, b, schedule="wls", blocks=GemmBlocks(128, 128, 128))))
    ref = np.asarray(ref_matmul(a, b))
    got = np.asarray(rasa_matmul(a, b, schedule="wls",
                                 blocks=GemmBlocks(128, 128, 128)))
    err = float(np.abs(got - ref).max() / np.abs(ref).max())
    emit("kernel_gemm_interpret_256", us, f"relerr={err:.2e}")


if __name__ == "__main__":
    main()
