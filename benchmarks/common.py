"""Shared benchmark plumbing: CSV emission + result caching."""

from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def cache_json(key: str, fn, force: bool = False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{key}.json"
    if p.exists() and not force:
        return json.loads(p.read_text())
    out = fn()
    p.write_text(json.dumps(out, indent=2))
    return out


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time of fn(*args) in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
