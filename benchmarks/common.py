"""Shared benchmark plumbing: CSV emission + fingerprinted result caching."""

from __future__ import annotations

import hashlib
import inspect
import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def model_fingerprint(*sources) -> str:
    """Content hash of the model code a benchmark's numbers depend on.

    ``sources`` are modules (hashed by source file) or path strings.  Pass
    the result as ``cache_json(..., fingerprint=...)`` so that editing the
    simulator invalidates cached benchmark results instead of silently
    serving stale numbers.
    """
    h = hashlib.sha256()
    for src in sources:
        path = Path(src) if isinstance(src, (str, Path)) else \
            Path(inspect.getsourcefile(src))
        h.update(path.name.encode())
        h.update(path.read_bytes())
    return h.hexdigest()[:16]


def cache_json(key: str, fn, force: bool = False,
               fingerprint: str | None = None):
    """Return the cached result for ``key``, or compute and cache ``fn()``.

    With ``fingerprint`` given, the cache file embeds it and a cached result
    is served only when its fingerprint matches -- anything else (legacy
    un-fingerprinted files included) is recomputed.  ``force=True`` always
    recomputes.
    """
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{key}.json"
    if p.exists() and not force:
        cached = json.loads(p.read_text())
        wrapped = isinstance(cached, dict) and "__fingerprint__" in cached
        if fingerprint is None:
            return cached["data"] if wrapped else cached
        if wrapped and cached["__fingerprint__"] == fingerprint:
            return cached["data"]
    out = fn()
    payload = out if fingerprint is None else \
        {"__fingerprint__": fingerprint, "data": out}
    p.write_text(json.dumps(payload, indent=2))
    return out


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time of fn(*args) in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
