"""Shared benchmark plumbing: CSV emission, fingerprinted result caching,
and the common ``BENCH_*.json`` envelope every benchmark emits through.

Importing this module also puts ``<repo>/src`` on ``sys.path`` (resolved
from this file, not the CWD), so every benchmark starts with
``import common`` and then imports ``repro.*`` directly -- no per-script
``sys.path.insert(0, "src")`` boilerplate that silently breaks when the
script is launched from anywhere but the repo root.

Importing it also configures ``XLA_FLAGS`` for the jax benchmarks (see
``XLA_THUNK_FLAG`` below) -- which is why ``import common`` must stay the
*first* import of every benchmark script: the flag must be set before the
first jax/XLA import anywhere in the process.
"""

from __future__ import annotations

import datetime
import hashlib
import inspect
import json
import os
import platform
import subprocess
import sys
import time
from pathlib import Path

_SRC = str(Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

#: The XLA:CPU thunk runtime dispatches each fused computation through a
#: buffer-assignment interpreter -- fine for big tensor ops, ~8x overhead
#: on the jitted arbitration program's long chains of tiny while-loop
#: bodies.  The legacy emitter compiles the same HLO straight through;
#: results stay bit-identical (``benchmarks/online_scaling.py`` asserts
#: jit-vs-numpy ``BatchReport`` equality under this flag on every run).
#: Knob: set ``RASA_BENCH_XLA_THUNK_RT=1`` to keep the stock thunk
#: runtime instead (e.g. to measure its cost).
XLA_THUNK_FLAG = "--xla_cpu_use_thunk_runtime=false"


def _setup_xla_flags() -> bool:
    """Disable the XLA:CPU thunk runtime for this process (idempotent).

    Returns whether the flag is active.  Must run before the first jax
    import; importing :mod:`common` first does that for every benchmark.
    """
    if os.environ.get("RASA_BENCH_XLA_THUNK_RT") == "1":
        return False
    if XLA_THUNK_FLAG.split("=")[0] not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = \
            (os.environ.get("XLA_FLAGS", "") + " " + XLA_THUNK_FLAG).strip()
    return XLA_THUNK_FLAG in os.environ.get("XLA_FLAGS", "")


XLA_THUNK_RT_DISABLED = _setup_xla_flags()

RESULTS = Path(__file__).resolve().parent / "results"

#: envelope schema version of every BENCH_*.json; bump on breaking changes
BENCH_SCHEMA = "rasa-bench/1"

#: envelope keys every BENCH file must carry (checked by validate_bench)
BENCH_KEYS = ("schema", "benchmark", "git_rev", "timestamp_utc", "backend",
              "host", "python", "data")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=10)
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except OSError:
        return "unknown"


def bench_envelope(benchmark: str, backend: str | None = None) -> dict:
    """The shared metadata block of a ``BENCH_<benchmark>.json`` file.

    Makes the perf trajectory machine-comparable across PRs: which commit,
    when, on which host/interpreter, and on which simulation backend the
    numbers were produced.
    """
    return {
        "schema": BENCH_SCHEMA,
        "benchmark": benchmark,
        "git_rev": _git_rev(),
        "timestamp_utc": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "backend": backend,
        "host": platform.node() or "unknown",
        "python": platform.python_version(),
    }


def write_bench(benchmark: str, data, backend: str | None = None) -> Path:
    """Write ``BENCH_<benchmark>.json``: the shared envelope + ``data``."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"BENCH_{benchmark}.json"
    payload = bench_envelope(benchmark, backend)
    payload["data"] = data
    path.write_text(json.dumps(payload, indent=2))
    return path


def validate_bench(path: Path) -> list[str]:
    """Schema-check one BENCH file; returns a list of problems (empty = ok).

    Checked: parseable JSON object, every envelope key present, schema
    version match, and the embedded benchmark name agreeing with the
    ``BENCH_<name>.json`` filename.
    """
    path = Path(path)
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path.name}: top level must be an object, "
                f"got {type(doc).__name__}"]
    errors = [f"{path.name}: missing envelope key {k!r}"
              for k in BENCH_KEYS if k not in doc]
    if doc.get("schema") not in (None, BENCH_SCHEMA):
        errors.append(f"{path.name}: schema {doc['schema']!r} != "
                      f"{BENCH_SCHEMA!r}")
    expect = path.stem.removeprefix("BENCH_")
    if "benchmark" in doc and doc["benchmark"] != expect:
        errors.append(f"{path.name}: benchmark {doc['benchmark']!r} does "
                      f"not match filename ({expect!r})")
    return errors


def model_fingerprint(*sources) -> str:
    """Content hash of the model code a benchmark's numbers depend on.

    ``sources`` are modules (hashed by source file) or path strings.  Pass
    the result as ``cache_json(..., fingerprint=...)`` so that editing the
    simulator invalidates cached benchmark results instead of silently
    serving stale numbers.
    """
    h = hashlib.sha256()
    for src in sources:
        path = Path(src) if isinstance(src, (str, Path)) else \
            Path(inspect.getsourcefile(src))
        h.update(path.name.encode())
        h.update(path.read_bytes())
    return h.hexdigest()[:16]


def cache_json(key: str, fn, force: bool = False,
               fingerprint: str | None = None):
    """Return the cached result for ``key``, or compute and cache ``fn()``.

    With ``fingerprint`` given, the cache file embeds it and a cached result
    is served only when its fingerprint matches -- anything else (legacy
    un-fingerprinted files included) is recomputed.  ``force=True`` always
    recomputes.
    """
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{key}.json"
    if p.exists() and not force:
        cached = json.loads(p.read_text())
        wrapped = isinstance(cached, dict) and "__fingerprint__" in cached
        if fingerprint is None:
            return cached["data"] if wrapped else cached
        if wrapped and cached["__fingerprint__"] == fingerprint:
            return cached["data"]
    out = fn()
    payload = out if fingerprint is None else \
        {"__fingerprint__": fingerprint, "data": out}
    p.write_text(json.dumps(payload, indent=2))
    return out


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time of fn(*args) in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
