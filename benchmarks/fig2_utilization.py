"""Paper Fig. 2: PE utilization vs T_M for different systolic-array dims.

util(T_M) = T_M / (2*rows + T_M + cols - 1) on the BASE design; verified
against the cycle simulator (not just the closed form).
"""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.core.designs import EngineConfig
from repro.core.isa import Instr, Op
from repro.core.timing import PipelineSimulator, serial_mm_latency

from common import emit  # type: ignore


DIMS = [(4, 4), (8, 8), (16, 16), (32, 16), (32, 32)]
TMS = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def run() -> dict:
    table = {}
    for rows, cols in DIMS:
        cfg = EngineConfig(name=f"sa{rows}x{cols}", rows=rows, cols=cols)
        for tm in TMS:
            sim = PipelineSimulator(cfg)
            res = sim.run([Instr(Op.MM, dst=0, src1=1, src2=2,
                                 tm=tm, tk=rows, tn=cols)])
            closed = tm / serial_mm_latency(rows, cols, tm)
            assert abs(res.utilization - closed) < 1e-9
            table[f"{rows}x{cols}_tm{tm}"] = round(res.utilization, 4)
    return table


def main() -> None:
    table = run()
    for k, v in table.items():
        emit(f"fig2_util_{k}", 0.0, f"util={v}")
    # the paper's qualitative claim: larger T_M -> utilization -> 1
    assert table["32x16_tm256"] > 0.7 > table["32x16_tm16"]


if __name__ == "__main__":
    main()
