"""Contention-aware serving batcher: latency/makespan vs. offered load.

Drives synthetic serving-request traces (prefill GEMM + decode micro-GEMMs
per request) through the online chip model under the three admission
policies of ``repro.serving.simbatch`` -- the blind fixed-batch baseline,
bandwidth-threshold admission, and the occupancy-aware policy -- across a
sweep of offered loads (mean inter-arrival gap in scheduling epochs), plus
the canonical skewed 4-core acceptance scenario.  Reported per cell: p50 /
p99 request latency (cycles), makespan, and MACs/cycle throughput, all on
the fast simulation backend (results are backend-independent; the parity
suite pins reference == fast).

Results go to ``benchmarks/results/BENCH_serving_batch.json`` -- uploaded
by CI next to the other benchmark artifacts.

    PYTHONPATH=src python benchmarks/serving_batch.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.multicore import ChipConfig
from repro.serving.simbatch import (POLICIES, run_batcher, skewed_trace,
                                    synthetic_trace)

from common import RESULTS, emit  # type: ignore

#: offered-load sweep: mean inter-arrival gap in epochs (small = heavy)
LOADS = (1, 4, 16)
SMOKE_LOADS = (2, 8)
BW = 64.0           # binding enough on 4 RASA-WLBP cores that policy matters


def _cell(rep) -> dict:
    return {
        "makespan": rep.makespan,
        "p50_latency": rep.p50_latency,
        "p99_latency": rep.p99_latency,
        "mean_latency": rep.mean_latency,
        "throughput_macs_per_cycle": rep.throughput_macs_per_cycle,
        "admit_epochs": list(rep.admit_epochs),
    }


def run(smoke: bool = False) -> dict:
    n_req, d_model = (8, 256) if smoke else (16, 512)
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=BW, backend="fast")
    table: dict = {"smoke": smoke, "chip": {
        "n_cores": chip.n_cores, "design": chip.design,
        "bw_bytes_per_cycle": chip.bw_bytes_per_cycle,
        "epoch_cycles": chip.epoch_cycles}, "load_sweep": {}, "skewed": {}}

    for gap in (SMOKE_LOADS if smoke else LOADS):
        trace = synthetic_trace(n_req, seed=0, mean_gap=gap,
                                d_model=d_model)
        for policy in POLICIES:
            rep = run_batcher(trace, chip, policy=policy)
            table["load_sweep"][f"gap{gap}_{policy}"] = _cell(rep)

    skew = skewed_trace(d_model=256, heavy_prompt=256, n_light=6) if smoke \
        else skewed_trace()
    for policy in POLICIES:
        rep = run_batcher(skew, chip, policy=policy)
        table["skewed"][policy] = _cell(rep)
    fixed = table["skewed"]["fixed"]["makespan"]
    occ = table["skewed"]["occupancy"]["makespan"]
    table["skewed"]["occupancy_vs_fixed_makespan"] = occ / fixed
    assert occ < fixed, "occupancy-aware admission must beat fixed-batch " \
                        "on the skewed trace"

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_serving_batch.json").write_text(
        json.dumps(table, indent=2))
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smaller trace (CI smoke run)")
    args = ap.parse_args(argv)
    t = run(smoke=args.smoke)
    print(f"# offered-load sweep (4 cores, RASA-WLBP, {BW:.0f} B/cyc)")
    print(f"{'cell':<22}{'makespan':>12}{'p50':>12}{'p99':>12}")
    for key, v in t["load_sweep"].items():
        print(f"{key:<22}{v['makespan']:>12.0f}{v['p50_latency']:>12.0f}"
              f"{v['p99_latency']:>12.0f}")
        emit(f"serving_{key}", 0.0,
             f"makespan={v['makespan']:.0f};p99={v['p99_latency']:.0f}")
    print("\n# skewed acceptance scenario")
    for policy in POLICIES:
        v = t["skewed"][policy]
        print(f"{policy:<12} makespan={v['makespan']:>12.0f} "
              f"p50={v['p50_latency']:>10.0f} p99={v['p99_latency']:>10.0f}")
        emit(f"serving_skewed_{policy}", 0.0,
             f"makespan={v['makespan']:.0f}")
    ratio = t["skewed"]["occupancy_vs_fixed_makespan"]
    print(f"occupancy-aware makespan = {ratio:.3f}x fixed-batch "
          f"(lower is better; <1 required)")


if __name__ == "__main__":
    main()
