"""Contention-aware serving batcher: latency/makespan vs. offered load.

Drives synthetic serving-request traces (prefill GEMM + decode micro-GEMMs
per request) through the online chip model under the three admission
policies of ``repro.serving.simbatch`` -- the blind fixed-batch baseline,
bandwidth-threshold admission, and the occupancy-aware policy -- across a
sweep of offered loads (mean inter-arrival gap in scheduling epochs), plus
the canonical skewed 4-core acceptance scenario.  Reported per cell: p50 /
p99 request latency (cycles), makespan, and MACs/cycle throughput, all on
the fast simulation backend (results are backend-independent; the parity
suite pins reference == fast).

Also: the whole-scenario ``vmap`` demo -- an arrival-rate sweep (same
request universe, arrival epochs rescaled per variant) settled as ONE
vmapped launch of the jitted whole-trace arbiter
(:func:`repro.multicore.jitarb.finish_times_many`), each variant's report
asserted bit-identical to a sequential numpy-client run.

Results go to ``benchmarks/results/BENCH_serving_batch.json`` -- uploaded
by CI next to the other benchmark artifacts.

    PYTHONPATH=src python benchmarks/serving_batch.py [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import time
from pathlib import Path

# importing common first also selects the legacy XLA:CPU emitter for the
# vmapped arbitration demo (see common.XLA_THUNK_FLAG -- the single
# documented knob; bit-identical results, asserted below)
import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.multicore import ChipConfig, jitarb  # noqa: E402
from repro.obs import TelemetryConfig, write_trace  # noqa: E402
from repro.obs.attribution import BUCKETS  # noqa: E402
from repro.serving.simbatch import (POLICIES,  # noqa: E402
                                    report_from_finishes, run_batcher,
                                    skewed_trace, synthetic_trace)

from common import RESULTS, emit, write_bench  # type: ignore  # noqa: E402

#: offered-load sweep: mean inter-arrival gap in epochs (small = heavy)
LOADS = (1, 4, 16)
SMOKE_LOADS = (2, 8)
BW = 64.0           # binding enough on 4 RASA-WLBP cores that policy matters


def _cell(rep) -> dict:
    return {
        "makespan": rep.makespan,
        "p50_latency": rep.p50_latency,
        "p99_latency": rep.p99_latency,
        "mean_latency": rep.mean_latency,
        "throughput_macs_per_cycle": rep.throughput_macs_per_cycle,
        "admit_epochs": list(rep.admit_epochs),
    }


#: arrival-rate sweep factors: each variant compresses the base trace's
#: arrival epochs by this much (smaller = heavier offered load)
RATE_FACTORS = (1.0, 0.5, 0.25)


def rate_sweep_vmap(smoke: bool = False) -> dict:
    """The whole-serving-scenario ``vmap`` demo: an arrival-rate sweep of
    one request universe runs as ONE device launch.

    Every variant keeps the same request shapes and only rescales the
    arrival epochs, so :func:`repro.multicore.jitarb.plan_many` unifies
    the trace table and :func:`finish_times_many` settles all variants in
    a single vmapped XLA call.  Each variant's ``BatchReport`` must be
    bit-identical to a sequential numpy-client run (asserted) -- the
    sweep changes the launch shape, never the answer.
    """
    n_req = 24 if smoke else 64
    base = synthetic_trace(n_req, seed=3, mean_gap=4, d_model=128,
                           prompt_lens=(16, 32, 64), decode_steps=(1, 2),
                           decode_batch=8)
    chip_np = ChipConfig(n_cores=4, design="RASA-WLBP",
                         bw_bytes_per_cycle=32.0, backend="fast")
    chip_jit = dataclasses.replace(chip_np, backend="jax")
    variants = [[dataclasses.replace(r, arrival_epoch=int(r.arrival_epoch
                                                          * f))
                 for r in base] for f in RATE_FACTORS]

    plans = jitarb.plan_many([[(r.arrival_epoch, r.specs) for r in v]
                              for v in variants], chip_jit)
    assert plans is not None, "sweep unexpectedly outside the jitarb domain"
    t0 = time.perf_counter()
    outs = jitarb.finish_times_many(plans)
    t_vmap = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracles = [run_batcher(v, chip_np, policy="fixed", batch_size=1)
               for v in variants]
    t_seq = time.perf_counter() - t0

    cells = {}
    for f, v, fin, oracle in zip(RATE_FACTORS, variants, outs, oracles):
        rep = report_from_finishes(v, chip_jit, fin)
        assert rep == oracle, \
            f"vmapped variant x{f} diverged from the sequential numpy " \
            f"client -- the sweep may only change the launch shape"
        cells[f"x{f}"] = {"makespan": rep.makespan,
                          "p50_latency": rep.p50_latency,
                          "p99_latency": rep.p99_latency}
    return {"n_requests": n_req, "factors": list(RATE_FACTORS),
            "seconds_vmap_launch": t_vmap, "seconds_numpy_seq": t_seq,
            "identical_reports": True, "cells": cells}


def run(smoke: bool = False) -> dict:
    n_req, d_model = (8, 256) if smoke else (16, 512)
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=BW, backend="fast")
    table: dict = {"smoke": smoke, "chip": {
        "n_cores": chip.n_cores, "design": chip.design,
        "bw_bytes_per_cycle": chip.bw_bytes_per_cycle,
        "epoch_cycles": chip.epoch_cycles}, "load_sweep": {}, "skewed": {}}

    for gap in (SMOKE_LOADS if smoke else LOADS):
        trace = synthetic_trace(n_req, seed=0, mean_gap=gap,
                                d_model=d_model)
        for policy in POLICIES:
            rep = run_batcher(trace, chip, policy=policy)
            table["load_sweep"][f"gap{gap}_{policy}"] = _cell(rep)

    skew = skewed_trace(d_model=256, heavy_prompt=256, n_light=6) if smoke \
        else skewed_trace()
    tcfg = TelemetryConfig(enabled=True, stages=True)
    skew_reports = {}
    for policy in POLICIES:
        # telemetry on: the skewed scenario doubles as the acceptance run
        # for the Perfetto artifact + bucket-conservation property
        rep = run_batcher(skew, chip, policy=policy, telemetry=tcfg)
        skew_reports[policy] = rep
        att = rep.attribution
        occupied = sum(att.total(b) for b in BUCKETS)
        assert math.isclose(occupied, att.occupied_cycles,
                            rel_tol=1e-9, abs_tol=1e-6), \
            f"attribution buckets must sum to window x cores " \
            f"({occupied} != {att.occupied_cycles})"
        table["skewed"][policy] = {**_cell(rep),
                                   "attribution": att.fractions()}
    fixed = table["skewed"]["fixed"]["makespan"]
    occ = table["skewed"]["occupancy"]["makespan"]
    table["skewed"]["occupancy_vs_fixed_makespan"] = occ / fixed
    assert occ < fixed, "occupancy-aware admission must beat fixed-batch " \
                        "on the skewed trace"

    # Perfetto-loadable artifact of the occupancy run (CI uploads it)
    RESULTS.mkdir(parents=True, exist_ok=True)
    write_trace(skew_reports["occupancy"].telemetry,
                RESULTS / "serving_skewed.trace.json")

    table["rate_sweep_vmap"] = rate_sweep_vmap(smoke)

    write_bench("serving_batch", table, backend="fast")
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smaller trace (CI smoke run)")
    args = ap.parse_args(argv)
    t = run(smoke=args.smoke)
    print(f"# offered-load sweep (4 cores, RASA-WLBP, {BW:.0f} B/cyc)")
    print(f"{'cell':<22}{'makespan':>12}{'p50':>12}{'p99':>12}")
    for key, v in t["load_sweep"].items():
        print(f"{key:<22}{v['makespan']:>12.0f}{v['p50_latency']:>12.0f}"
              f"{v['p99_latency']:>12.0f}")
        emit(f"serving_{key}", 0.0,
             f"makespan={v['makespan']:.0f};p99={v['p99_latency']:.0f}")
    print("\n# skewed acceptance scenario (attribution: "
          + "/".join(BUCKETS) + ")")
    for policy in POLICIES:
        v = t["skewed"][policy]
        att = "/".join(f"{v['attribution'][b]:.0%}" for b in BUCKETS)
        print(f"{policy:<12} makespan={v['makespan']:>12.0f} "
              f"p50={v['p50_latency']:>10.0f} p99={v['p99_latency']:>10.0f} "
              f"{att}")
        emit(f"serving_skewed_{policy}", 0.0,
             f"makespan={v['makespan']:.0f}")
    ratio = t["skewed"]["occupancy_vs_fixed_makespan"]
    print(f"occupancy-aware makespan = {ratio:.3f}x fixed-batch "
          f"(lower is better; <1 required)")

    rs = t["rate_sweep_vmap"]
    print(f"\n# arrival-rate sweep as ONE vmapped launch "
          f"({rs['n_requests']} requests x {len(rs['factors'])} variants)")
    for key, v in rs["cells"].items():
        print(f"{key:<12} makespan={v['makespan']:>12.0f} "
              f"p50={v['p50_latency']:>10.0f} p99={v['p99_latency']:>10.0f}")
    print(f"one launch {rs['seconds_vmap_launch']:.2f}s (incl. one-off "
          f"compile; see online_scaling.py for at-scale timings) vs "
          f"sequential numpy {rs['seconds_numpy_seq']:.2f}s (identical "
          f"BatchReports: {rs['identical_reports']})")
    emit("serving_rate_sweep_vmap", rs["seconds_vmap_launch"] * 1e6,
         f"variants={len(rs['factors'])};n={rs['n_requests']}")


if __name__ == "__main__":
    main()
