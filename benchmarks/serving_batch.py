"""Contention-aware serving batcher: latency/makespan vs. offered load.

Drives synthetic serving-request traces (prefill GEMM + decode micro-GEMMs
per request) through the online chip model under the three admission
policies of ``repro.serving.simbatch`` -- the blind fixed-batch baseline,
bandwidth-threshold admission, and the occupancy-aware policy -- across a
sweep of offered loads (mean inter-arrival gap in scheduling epochs), plus
the canonical skewed 4-core acceptance scenario.  Reported per cell: p50 /
p99 request latency (cycles), makespan, and MACs/cycle throughput, all on
the fast simulation backend (results are backend-independent; the parity
suite pins reference == fast).

Results go to ``benchmarks/results/BENCH_serving_batch.json`` -- uploaded
by CI next to the other benchmark artifacts.

    PYTHONPATH=src python benchmarks/serving_batch.py [--smoke]
"""

from __future__ import annotations

import argparse
import math
from pathlib import Path

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.multicore import ChipConfig
from repro.obs import TelemetryConfig, write_trace
from repro.obs.attribution import BUCKETS
from repro.serving.simbatch import (POLICIES, run_batcher, skewed_trace,
                                    synthetic_trace)

from common import RESULTS, emit, write_bench  # type: ignore

#: offered-load sweep: mean inter-arrival gap in epochs (small = heavy)
LOADS = (1, 4, 16)
SMOKE_LOADS = (2, 8)
BW = 64.0           # binding enough on 4 RASA-WLBP cores that policy matters


def _cell(rep) -> dict:
    return {
        "makespan": rep.makespan,
        "p50_latency": rep.p50_latency,
        "p99_latency": rep.p99_latency,
        "mean_latency": rep.mean_latency,
        "throughput_macs_per_cycle": rep.throughput_macs_per_cycle,
        "admit_epochs": list(rep.admit_epochs),
    }


def run(smoke: bool = False) -> dict:
    n_req, d_model = (8, 256) if smoke else (16, 512)
    chip = ChipConfig(n_cores=4, design="RASA-WLBP",
                      bw_bytes_per_cycle=BW, backend="fast")
    table: dict = {"smoke": smoke, "chip": {
        "n_cores": chip.n_cores, "design": chip.design,
        "bw_bytes_per_cycle": chip.bw_bytes_per_cycle,
        "epoch_cycles": chip.epoch_cycles}, "load_sweep": {}, "skewed": {}}

    for gap in (SMOKE_LOADS if smoke else LOADS):
        trace = synthetic_trace(n_req, seed=0, mean_gap=gap,
                                d_model=d_model)
        for policy in POLICIES:
            rep = run_batcher(trace, chip, policy=policy)
            table["load_sweep"][f"gap{gap}_{policy}"] = _cell(rep)

    skew = skewed_trace(d_model=256, heavy_prompt=256, n_light=6) if smoke \
        else skewed_trace()
    tcfg = TelemetryConfig(enabled=True, stages=True)
    skew_reports = {}
    for policy in POLICIES:
        # telemetry on: the skewed scenario doubles as the acceptance run
        # for the Perfetto artifact + bucket-conservation property
        rep = run_batcher(skew, chip, policy=policy, telemetry=tcfg)
        skew_reports[policy] = rep
        att = rep.attribution
        occupied = sum(att.total(b) for b in BUCKETS)
        assert math.isclose(occupied, att.occupied_cycles,
                            rel_tol=1e-9, abs_tol=1e-6), \
            f"attribution buckets must sum to window x cores " \
            f"({occupied} != {att.occupied_cycles})"
        table["skewed"][policy] = {**_cell(rep),
                                   "attribution": att.fractions()}
    fixed = table["skewed"]["fixed"]["makespan"]
    occ = table["skewed"]["occupancy"]["makespan"]
    table["skewed"]["occupancy_vs_fixed_makespan"] = occ / fixed
    assert occ < fixed, "occupancy-aware admission must beat fixed-batch " \
                        "on the skewed trace"

    # Perfetto-loadable artifact of the occupancy run (CI uploads it)
    RESULTS.mkdir(parents=True, exist_ok=True)
    write_trace(skew_reports["occupancy"].telemetry,
                RESULTS / "serving_skewed.trace.json")

    write_bench("serving_batch", table, backend="fast")
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smaller trace (CI smoke run)")
    args = ap.parse_args(argv)
    t = run(smoke=args.smoke)
    print(f"# offered-load sweep (4 cores, RASA-WLBP, {BW:.0f} B/cyc)")
    print(f"{'cell':<22}{'makespan':>12}{'p50':>12}{'p99':>12}")
    for key, v in t["load_sweep"].items():
        print(f"{key:<22}{v['makespan']:>12.0f}{v['p50_latency']:>12.0f}"
              f"{v['p99_latency']:>12.0f}")
        emit(f"serving_{key}", 0.0,
             f"makespan={v['makespan']:.0f};p99={v['p99_latency']:.0f}")
    print("\n# skewed acceptance scenario (attribution: "
          + "/".join(BUCKETS) + ")")
    for policy in POLICIES:
        v = t["skewed"][policy]
        att = "/".join(f"{v['attribution'][b]:.0%}" for b in BUCKETS)
        print(f"{policy:<12} makespan={v['makespan']:>12.0f} "
              f"p50={v['p50_latency']:>10.0f} p99={v['p99_latency']:>10.0f} "
              f"{att}")
        emit(f"serving_skewed_{policy}", 0.0,
             f"makespan={v['makespan']:.0f}")
    ratio = t["skewed"]["occupancy_vs_fixed_makespan"]
    print(f"occupancy-aware makespan = {ratio:.3f}x fixed-batch "
          f"(lower is better; <1 required)")


if __name__ == "__main__":
    main()
