"""Goodput under faults: admission policies vs. fault severity.

Drives the canonical skewed 4-core serving trace (two prefill-heavy
requests ahead of ten decode-dominated ones, ``skewed_trace``) through
the online chip under escalating fault scenarios -- a core-down window,
thermal bandwidth derating, a two-core outage -- once per admission
policy, with per-class deadlines calibrated from each class's measured
solo latency (3x: a served request that took more than three times its
unloaded latency has missed its SLO).

The ranking metric is **goodput**: MACs of requests served within their
deadline per makespan cycle (``BatchReport.goodput_macs_per_cycle``).
Blind fixed batching keeps its throughput under faults but serves the
skewed tail late -- the work completes, the deadlines don't -- while the
chip-state-aware policies route around the outage and keep goodput.  The
acceptance floor (asserted at full scale, on the ``moderate`` scenario):
the best resilient policy must hold **>= 1.3x** the goodput of ``fixed``.

Also swept: a seedable :func:`repro.multicore.faults.random_plan` row,
the fault-rate knob (same seed = same plan on every backend).

Results go to ``benchmarks/results/BENCH_fault_tolerance.json`` (the
``rasa-bench/1`` envelope); CI runs ``--smoke``, which shrinks the trace
and skips the floor assertion (the ratio needs the full-size skew to be
meaningful) but exercises every scenario x policy cell.

    PYTHONPATH=src python benchmarks/fault_tolerance.py [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.multicore import (ChipConfig, FaultPlan, bw_derate, core_down,
                             core_up, random_plan)
from repro.serving.simbatch import run_batcher, skewed_trace

from common import emit, write_bench  # type: ignore

CHIP_KW = dict(n_cores=4, design="RASA-WLBP", bw_bytes_per_cycle=128.0,
               backend="fast", arbitration="epoch")

POLICIES = ("fixed", "bandwidth", "occupancy", "predicted", "degraded")
RESILIENT = ("bandwidth", "occupancy", "predicted", "degraded")
MIN_GOODPUT_RATIO = 1.3     # acceptance floor, asserted at full scale
DEADLINE_SCALE = 3.0        # deadline = 3x the class's solo latency
ACCEPT_SCENARIO = "moderate"

#: full-size and smoke-size knobs of the canonical skewed trace
TRACE_FULL = dict(d_model=512, heavy_prompt=512, light_prompt=32,
                  n_heavy=2, n_light=10, decode_batch=8)
TRACE_SMOKE = dict(d_model=128, heavy_prompt=192, light_prompt=16,
                   n_heavy=2, n_light=6, decode_batch=4)


def _scenarios(smoke: bool) -> dict[str, FaultPlan | None]:
    """Escalating fault severities.  Epoch numbers are placed inside the
    trace's busy window (the full skewed run spans ~1000 epochs, the
    smoke run ~100; the fractions below hit both)."""
    s = 0.1 if smoke else 1.0
    e = lambda x: max(1, round(x * s))  # noqa: E731
    return {
        "none": None,
        "mild": FaultPlan((core_down(0, e(30)), core_up(0, e(300)))),
        "moderate": FaultPlan((core_down(0, e(30)), core_up(0, e(300)),
                               bw_derate(0.6, e(60), e(160)))),
        "severe": FaultPlan((core_down(0, e(30)), core_up(0, e(300)),
                             core_down(1, e(350)), core_up(1, e(650)),
                             bw_derate(0.5, e(60), e(260)))),
        "random": random_plan(4, seed=7, horizon=e(600),
                              n_core_faults=1, down_epochs=e(250),
                              n_derates=1, derate_factor=0.6,
                              derate_epochs=e(100)),
    }


def run(smoke: bool = False) -> dict:
    chip0 = ChipConfig(**CHIP_KW)
    trace = skewed_trace(**(TRACE_SMOKE if smoke else TRACE_FULL))

    # calibrate per-class deadlines from measured solo latency
    solo_h = run_batcher(trace[:1], chip0, policy="occupancy").latencies[0]
    light = next(r for r in trace if r.name.startswith("l"))
    solo_l = run_batcher([light], chip0, policy="occupancy").latencies[0]
    dl = {"h": DEADLINE_SCALE * solo_h, "l": DEADLINE_SCALE * solo_l}
    reqs = tuple(dataclasses.replace(r, deadline=dl[r.name[0]])
                 for r in trace)

    scenarios = {}
    for sname, plan in _scenarios(smoke).items():
        chip = chip0 if plan is None else \
            dataclasses.replace(chip0, fault_plan=plan)
        row = {}
        for pol in POLICIES:
            rep = run_batcher(reqs, chip, policy=pol)
            row[pol] = {
                "goodput_macs_per_cycle": rep.goodput_macs_per_cycle,
                "throughput_macs_per_cycle": rep.throughput_macs_per_cycle,
                "deadline_miss_rate": rep.deadline_miss_rate,
                "retries": rep.retries,
                "abandoned": rep.abandoned,
                "makespan": rep.makespan,
                "p99_latency": rep.p99_latency
                if rep.abandoned == 0 else None,
            }
        scenarios[sname] = {
            "events": [] if plan is None else [e.label for e in plan.events],
            "policies": row,
        }

    acc = scenarios[ACCEPT_SCENARIO]["policies"]
    fixed_gp = acc["fixed"]["goodput_macs_per_cycle"]
    best = max(RESILIENT, key=lambda p: acc[p]["goodput_macs_per_cycle"])
    best_gp = acc[best]["goodput_macs_per_cycle"]
    ratio = best_gp / fixed_gp if fixed_gp else float("inf")
    if not smoke:
        assert ratio >= MIN_GOODPUT_RATIO, \
            f"resilient admission must hold >= {MIN_GOODPUT_RATIO}x the " \
            f"goodput of blind fixed batching under the " \
            f"{ACCEPT_SCENARIO!r} fault scenario (best {best!r} = " \
            f"{ratio:.2f}x)"

    table = {
        "smoke": smoke,
        "chip": dict(CHIP_KW),
        "trace": dict(TRACE_SMOKE if smoke else TRACE_FULL),
        "deadline_scale": DEADLINE_SCALE,
        "deadlines": {"heavy": dl["h"], "light": dl["l"]},
        "scenarios": scenarios,
        "acceptance": {
            "scenario": ACCEPT_SCENARIO,
            "floor": MIN_GOODPUT_RATIO,
            "fixed_goodput": fixed_gp,
            "best_policy": best,
            "best_goodput": best_gp,
            "ratio": ratio,
            "asserted": not smoke,
        },
    }
    write_bench("fault_tolerance", table, backend=CHIP_KW["backend"])
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken trace (CI smoke run; exercises every "
                         "scenario/policy cell, skips the ratio floor)")
    args = ap.parse_args(argv)
    t = run(smoke=args.smoke)
    print(f"# goodput (MACs/cycle) under faults, skewed 4-core trace"
          f"{' [smoke]' if args.smoke else ''}")
    print(f"{'scenario':<10}" + "".join(f"{p:>11}" for p in POLICIES)
          + f"{'miss(fix/occ)':>15}")
    for sname, row in t["scenarios"].items():
        pols = row["policies"]
        cells = "".join(
            f"{pols[p]['goodput_macs_per_cycle']:>11.1f}" for p in POLICIES)
        miss = (f"{pols['fixed']['deadline_miss_rate']:.2f}/"
                f"{pols['occupancy']['deadline_miss_rate']:.2f}")
        print(f"{sname:<10}{cells}{miss:>15}")
    a = t["acceptance"]
    print(f"acceptance[{a['scenario']}]: best {a['best_policy']} = "
          f"{a['ratio']:.2f}x fixed (floor {a['floor']}x, "
          f"asserted={a['asserted']})")
    emit("fault_tolerance_goodput_ratio", a["ratio"] * 1e6,
         f"best={a['best_policy']};scenario={a['scenario']}")


if __name__ == "__main__":
    main()
