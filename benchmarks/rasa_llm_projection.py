"""Beyond-paper: project the ten assigned LM architectures onto a
RASA-equipped CPU.

For each architecture, compile its decode-phase layer GEMMs (batch=1 and
batch=16) through the real-model workload frontend
(:mod:`repro.workload`), lower them through the register-aware tiler, and
compare BASE vs RASA-DMDB-WLS cycles -- i.e. "how much does the paper's
technique help a 2024-era LLM on a CPU matrix engine".  The small-expert
granite MoE (d_ff_expert=512) is the register-limited small-T_M regime
where RASA's WL-skip matters most.

Two projections per architecture:

* **single-core** (the original, contention-free view): one layer's GEMMs
  on one engine at full bandwidth, BASE vs RASA; scaling to the full model
  is ``x n_layers``.
* **chip** (contention-aware): the decode model compiled onto a 4-core
  RASA chip under the shared-bandwidth arbiter, reporting the makespan and
  the stall attribution (compute / fill-drain / bandwidth) -- the number
  the single-core view cannot see.  The chip simulates a
  ``CHIP_LAYER_WINDOW``-layer steady-state window at the same dimension
  cap and scales the makespan linearly to the model's full depth:
  identical layers repeat the same placement pattern, so per-layer chip
  cycles are depth-stable to <0.01% beyond 4 layers (spot-checked against
  8-layer windows on the largest dense and MoE configs).
"""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

import repro.core.designs
import repro.core.isa
import repro.core.simulator
import repro.core.tiling
import repro.core.timing
import repro.core.trace
import repro.workload.compile
from repro.configs import ARCH_NAMES
from repro.core import GemmSpec, simulate
from repro.core.tiling import ALG1_POLICY
from repro.multicore.chip import ChipConfig, simulate_chip
from repro.obs.attribution import simreport_attribution
from repro.workload import CompileOptions, compile_workload

from common import cache_json, emit, model_fingerprint  # type: ignore

#: the projection's dimension-cap heuristic, now an explicit compile
#: option: relative BASE -> RASA behaviour in the small-T_M decode regime
#: is insensitive to K/N beyond a few thousand (simulation cost isn't)
PROJECTION_OPTIONS = CompileOptions(dim_cap=4096, max_layers=1)

#: the contention-aware chip the full model is compiled onto
CHIP = ChipConfig(n_cores=4, design="RASA-DMDB-WLS")

#: layers in the chip view's simulated steady-state window; the makespan
#: scales ``x (n_layers / layers_modeled)`` to full depth (see module doc)
CHIP_LAYER_WINDOW = 4


def layer_gemms(arch: str, batch: int) -> list[GemmSpec]:
    """One decode layer's GEMMs -- the workload frontend's lowering under
    the projection's dimension cap (kept as the module's public helper)."""
    return list(compile_workload(arch, batch=batch, seq=1, phase="decode",
                                 options=PROJECTION_OPTIONS).specs)


def run(force: bool = False) -> dict:
    def compute():
        table = {}
        chip_opts = CompileOptions(dim_cap=PROJECTION_OPTIONS.dim_cap,
                                   max_layers=CHIP_LAYER_WINDOW)
        for arch in ARCH_NAMES:
            for batch in (1, 16):
                specs = layer_gemms(arch, batch)
                base = rasa = 0.0
                for spec in specs:
                    base += simulate(spec, "BASE").cycles
                    rasa += simulate(spec, "RASA-DMDB-WLS").cycles
                # contention-aware: a steady-state layer window scheduled
                # onto the shared-bandwidth chip, scaled to full depth
                wl = compile_workload(arch, batch=batch, seq=1,
                                      phase="decode", options=chip_opts)
                chip = simulate_chip(wl, CHIP, scheduler="work_queue")
                depth_scale = wl.n_layers / wl.layers_modeled
                table[f"{arch}_b{batch}"] = {
                    "base_cycles": base, "rasa_cycles": rasa,
                    "speedup": base / max(rasa, 1e-9),
                    # where the remaining RASA cycles go: the compute vs.
                    # fill/drain split explains *why* a shape speeds up
                    "attribution": simreport_attribution(
                        specs, ALG1_POLICY, rasa).fractions(),
                    # single-core full-model projection vs the chip run
                    # (both scaled to the model's full n_layers depth)
                    "single_core_model_cycles": rasa * wl.n_layers,
                    "chip_cycles": chip.cycles * depth_scale,
                    "chip_window_layers": wl.layers_modeled,
                    "chip_bw_stall_cycles":
                        chip.bw_stall_cycles * depth_scale,
                    "chip_utilization": chip.utilization,
                    "chip_attribution": chip.attribution.fractions(),
                }
        return table
    fingerprint = model_fingerprint(
        repro.core.designs, repro.core.isa, repro.core.simulator,
        repro.core.tiling, repro.core.timing, repro.core.trace,
        repro.workload.compile, __file__)
    return cache_json("rasa_llm_projection", compute, force=force,
                      fingerprint=fingerprint)


def main() -> None:
    table = run()
    for key, v in table.items():
        a = v["attribution"]
        ca = v["chip_attribution"]
        emit(f"rasa_llm_{key}", 0.0,
             f"speedup={v['speedup']:.2f};base={v['base_cycles']:.0f};"
             f"compute={a['compute']:.2f};fill_drain={a['fill_drain']:.2f};"
             f"chip={v['chip_cycles']:.0f};"
             f"single_core_model={v['single_core_model_cycles']:.0f};"
             f"chip_bw_stall={ca.get('bw_stall', 0.0):.2f}")


if __name__ == "__main__":
    main()
