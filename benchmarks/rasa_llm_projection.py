"""Beyond-paper: project the ten assigned LM architectures onto a
RASA-equipped CPU.

For each architecture, collect its per-layer GEMMs (decode batch=1 and
batch=16), lower them through the register-aware tiler, and compare BASE
vs RASA-DMDB-WLS cycles -- i.e. "how much does the paper's technique help
a 2024-era LLM on a CPU matrix engine".  The small-expert granite MoE
(d_ff_expert=512) is the register-limited small-T_M regime where RASA's
WL-skip matters most.
"""

from __future__ import annotations

import sys
sys.path.insert(0, "src")

import repro.core.designs
import repro.core.isa
import repro.core.simulator
import repro.core.tiling
import repro.core.timing
import repro.core.trace
from repro.configs import ARCH_NAMES, get_config
from repro.core import GemmSpec, simulate
from repro.core.tiling import ALG1_POLICY
from repro.obs.attribution import simreport_attribution

from common import cache_json, emit, model_fingerprint  # type: ignore


def layer_gemms(arch: str, batch: int) -> list[GemmSpec]:
    m = get_config(arch).model
    d, hd = m.d_model, m.resolved_head_dim
    # cap the enormous dims: the projection's point is the relative
    # BASE -> RASA speedup in the small-T_M decode regime, which is
    # insensitive to K/N beyond a few thousand (simulation cost isn't)
    cap = 4096
    d = min(d, cap)
    out = []
    if m.n_heads:
        out.append(GemmSpec(f"{arch}-qkv", batch, d,
                            min((m.n_heads + 2 * m.n_kv_heads) * hd, cap)))
        out.append(GemmSpec(f"{arch}-wo", batch, min(m.n_heads * hd, cap), d))
    if m.moe is not None:
        # top_k experts active per token
        for i in range(min(m.moe.top_k, 4)):
            out.append(GemmSpec(f"{arch}-exp{i}-up", batch, d,
                                min(m.moe.d_ff_expert, cap)))
            out.append(GemmSpec(f"{arch}-exp{i}-dn", batch,
                                min(m.moe.d_ff_expert, cap), d))
    elif m.d_ff:
        out.append(GemmSpec(f"{arch}-ff-up", batch, d, min(m.d_ff, cap)))
        out.append(GemmSpec(f"{arch}-ff-dn", batch, min(m.d_ff, cap), d))
    if m.ssm is not None:
        di = min(m.ssm.expand * d, cap)
        out.append(GemmSpec(f"{arch}-ssm-in", batch, d, 2 * di))
        out.append(GemmSpec(f"{arch}-ssm-out", batch, di, d))
    return out


def run(force: bool = False) -> dict:
    def compute():
        table = {}
        for arch in ARCH_NAMES:
            for batch in (1, 16):
                specs = layer_gemms(arch, batch)
                base = rasa = 0.0
                for spec in specs:
                    base += simulate(spec, "BASE").cycles
                    rasa += simulate(spec, "RASA-DMDB-WLS").cycles
                table[f"{arch}_b{batch}"] = {
                    "base_cycles": base, "rasa_cycles": rasa,
                    "speedup": base / max(rasa, 1e-9),
                    # where the remaining RASA cycles go: the compute vs.
                    # fill/drain split explains *why* a shape speeds up
                    "attribution": simreport_attribution(
                        specs, ALG1_POLICY, rasa).fractions()}
        return table
    fingerprint = model_fingerprint(
        repro.core.designs, repro.core.isa, repro.core.simulator,
        repro.core.tiling, repro.core.timing, repro.core.trace, __file__)
    return cache_json("rasa_llm_projection", compute, force=force,
                      fingerprint=fingerprint)


def main() -> None:
    table = run()
    for key, v in table.items():
        a = v["attribution"]
        emit(f"rasa_llm_{key}", 0.0,
             f"speedup={v['speedup']:.2f};base={v['base_cycles']:.0f};"
             f"compute={a['compute']:.2f};fill_drain={a['fill_drain']:.2f}")


if __name__ == "__main__":
    main()
