"""Multi-core scaling sweep: cores x designs x partitioners on one GEMM.

For {1, 2, 4, 8, 16} cores x {BASE, RASA-WLBP, RASA-DMDB-WLS} x {m_split,
block2d} this reports chip cycles, parallel efficiency vs. the single-core
run, and the share of occupied core-cycles lost to the shared 256 B/cycle
tile-traffic budget.  The headline result: the faster the engine, the fewer
cores it takes to hit the bandwidth wall -- BASE scales almost linearly to
16 cores while RASA-DMDB-WLS saturates around 4, and the 2D block-cyclic
partitioner beats M-split at high core counts because M-split re-streams
the full B matrix on every core.

Two further sections exercise the chip model's scheduling layers:

* scheduler comparison (static round-robin vs. dynamic work-queue vs. LPT
  vs. gang) on a skewed multi-GEMM layer workload -- gang may split a
  dominant GEMM across otherwise-idle cores;
* arbitration comparison (frozen static shares vs. epoch-based dynamic
  shares) on the same skewed workload under a tight budget, showing how
  much the static model over-estimates the makespan when early finishers
  never return their bandwidth share.

Results are cached in ``benchmarks/results/`` keyed by a fingerprint of the
simulator sources: editing the model invalidates the cache.  ``--force``
recomputes unconditionally.
"""

from __future__ import annotations

import argparse
import common  # noqa: F401  -- puts <repo>/src on sys.path

import repro.core.designs
import repro.core.fastsim
import repro.core.isa
import repro.core.simulator
import repro.core.tiling
import repro.core.timing
import repro.core.trace
import repro.core.workloads
import repro.multicore.arbiter
import repro.multicore.chip
import repro.multicore.partition
import repro.multicore.scheduler
from repro.core import TABLE_I, GemmSpec
from repro.multicore import CHIP_BACKENDS, ChipConfig, simulate_chip

from common import (RESULTS, cache_json, emit, model_fingerprint,  # type: ignore
                    write_bench)

SPEC = GemmSpec("BERT-1", 256, 768, 768)    # Table I BERT-1 dims
CORES = (1, 2, 4, 8, 16)
DESIGNS = ("BASE", "RASA-WLBP", "RASA-DMDB-WLS")
PARTITIONERS = ("m_split", "block2d")
SCHEDULERS = ("round_robin", "work_queue", "lpt", "gang")
#: skewed layer workload for the scheduler/arbitration comparisons
SCHED_WORKLOAD = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"], TABLE_I["DLRM-2"],
                  TABLE_I["BERT-1"], TABLE_I["DLRM-2"], TABLE_I["DLRM-2"]]
#: budget for the arbitration section: tight enough that four RASA-WLBP
#: cores are bandwidth-bound and the share model choice matters.
ARB_BW = 32.0


def _fingerprint() -> str:
    return model_fingerprint(
        repro.multicore.arbiter, repro.multicore.chip,
        repro.multicore.partition,
        repro.multicore.scheduler, repro.core.timing, repro.core.tiling,
        repro.core.designs, repro.core.isa, repro.core.simulator,
        repro.core.trace, repro.core.fastsim,
        repro.core.workloads, __file__)


def _rle(values) -> list[list]:
    """Run-length encode a trace: [[value, run_length], ...]."""
    out: list[list] = []
    for v in values:
        if out and out[-1][0] == v:
            out[-1][1] += 1
        else:
            out.append([v, 1])
    return out


def run(force: bool = False, backend: str = "fast") -> dict:
    def compute():
        table: dict = {"partition": {}, "scheduler": {}, "arbitration": {}}
        for design in DESIGNS:
            for part in PARTITIONERS:
                for n in CORES:
                    rep = simulate_chip(
                        SPEC, ChipConfig(n_cores=n, design=design,
                                         backend=backend),
                        partition=part)
                    table["partition"][f"{design}_{part}_c{n}"] = {
                        "cycles": rep.cycles,
                        "speedup": rep.speedup,
                        "efficiency": rep.efficiency,
                        "bw_stall_share": rep.bw_stall_share,
                        "utilization": rep.utilization,
                        "wlbp_rate": rep.wlbp_rate,
                    }
        for sched in SCHEDULERS:
            rep = simulate_chip(SCHED_WORKLOAD,
                                ChipConfig(n_cores=4, design="RASA-WLBP",
                                           backend=backend),
                                scheduler=sched)
            table["scheduler"][sched] = {
                "cycles": rep.cycles, "speedup": rep.speedup,
                "per_core_gemms": [list(g) for g in rep.per_core_gemms],
            }
        for arb in ("static", "epoch"):
            rep = simulate_chip(
                SCHED_WORKLOAD,
                ChipConfig(n_cores=4, design="RASA-WLBP",
                           bw_bytes_per_cycle=ARB_BW, arbitration=arb,
                           backend=backend),
                scheduler="lpt")
            table["arbitration"][arb] = {
                "cycles": rep.cycles,
                "bw_stall_cycles": rep.bw_stall_cycles,
                "bw_stall_share": rep.bw_stall_share,
                "arb_rounds": rep.arb_rounds,
                "epoch_cycles": rep.epoch_cycles,
                "active_trace_rle": _rle(rep.active_trace),
            }
        sta = table["arbitration"]["static"]["cycles"]
        dyn = table["arbitration"]["epoch"]["cycles"]
        table["arbitration"]["static_overestimate"] = sta / dyn - 1.0
        return table
    # non-default backends get their own cache file: an oracle re-run must
    # never be served from the fast backend's cache (and vice versa)
    key = "multicore_scaling" if backend == "fast" \
        else f"multicore_scaling_{backend}"
    table = cache_json(key, compute, force=force, fingerprint=_fingerprint())
    if backend == "fast":
        write_bench("multicore_scaling", table, backend=backend)
        _write_trace_artifact()
    return table


def _write_trace_artifact() -> None:
    """Perfetto artifact of the epoch-arbitration scenario (CI uploads it)."""
    from repro.obs import TelemetryConfig, write_trace
    rep = simulate_chip(
        SCHED_WORKLOAD,
        ChipConfig(n_cores=4, design="RASA-WLBP",
                   bw_bytes_per_cycle=ARB_BW, arbitration="epoch",
                   backend="fast"),
        scheduler="lpt",
        telemetry=TelemetryConfig(enabled=True, stages=True))
    RESULTS.mkdir(parents=True, exist_ok=True)
    write_trace(rep.telemetry, RESULTS / "multicore_epoch.trace.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--force", action="store_true",
                    help="recompute even if a fingerprint-matching cache "
                         "file exists")
    ap.add_argument("--backend", default="fast", choices=CHIP_BACKENDS,
                    help="simulation backend (results are backend-"
                         "independent; 'reference' is the exactness oracle)")
    args = ap.parse_args(argv)
    table = run(force=args.force, backend=args.backend)
    print(f"# {SPEC.name} ({SPEC.M}x{SPEC.K}x{SPEC.N}), 256 B/cyc shared budget")
    print(f"{'design':<16}{'partition':<10}{'cores':>6}{'cycles':>12}"
          f"{'eff':>8}{'stall':>8}")
    for design in DESIGNS:
        for part in PARTITIONERS:
            for n in CORES:
                key = f"{design}_{part}_c{n}"
                v = table["partition"][key]
                print(f"{design:<16}{part:<10}{n:>6}{v['cycles']:>12.0f}"
                      f"{v['efficiency']:>8.3f}{v['bw_stall_share']:>8.3f}")
                emit(f"multicore_{key}", 0.0,
                     f"eff={v['efficiency']:.3f};"
                     f"stall={v['bw_stall_share']:.3f};"
                     f"cycles={v['cycles']:.0f}")
    print("\n# scheduler comparison (4 cores, RASA-WLBP, 6-layer workload)")
    for sched in SCHEDULERS:
        v = table["scheduler"][sched]
        print(f"{sched:<14} makespan={v['cycles']:>12.0f} "
              f"speedup={v['speedup']:.2f}")
        emit(f"multicore_sched_{sched}", 0.0,
             f"cycles={v['cycles']:.0f};speedup={v['speedup']:.2f}")
    print(f"\n# arbitration comparison (4 cores, RASA-WLBP, LPT, "
          f"{ARB_BW:.0f} B/cyc budget)")
    for arb in ("static", "epoch"):
        v = table["arbitration"][arb]
        extra = ""
        if arb == "epoch":
            extra = (f"  rounds={v['arb_rounds']}"
                     f"  active(rle)={v['active_trace_rle']}")
        print(f"{arb:<8} makespan={v['cycles']:>12.0f} "
              f"stall-share={v['bw_stall_share']:.3f}{extra}")
        emit(f"multicore_arb_{arb}", 0.0,
             f"cycles={v['cycles']:.0f};stall={v['bw_stall_share']:.3f}")
    over = table["arbitration"]["static_overestimate"]
    print(f"static model over-estimates the makespan by {over:.1%} "
          f"(bandwidth freed by early finishers is never redistributed)")


if __name__ == "__main__":
    main()
