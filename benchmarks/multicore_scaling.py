"""Multi-core scaling sweep: cores x designs x partitioners on one GEMM.

For {1, 2, 4, 8, 16} cores x {BASE, RASA-WLBP, RASA-DMDB-WLS} x {m_split,
block2d} this reports chip cycles, parallel efficiency vs. the single-core
run, and the share of core-cycles lost to the shared 256 B/cycle tile-load
budget.  The headline result: the faster the engine, the fewer cores it
takes to hit the bandwidth wall -- BASE scales almost linearly to 16 cores
while RASA-DMDB-WLS saturates around 4, and the 2D block-cyclic partitioner
beats M-split at high core counts because M-split re-streams the full B
matrix on every core.

Also includes a scheduler comparison (static round-robin vs. dynamic
work-queue vs. LPT) on a skewed multi-GEMM layer workload.
"""

from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core import TABLE_I, GemmSpec
from repro.multicore import ChipConfig, simulate_chip

from common import cache_json, emit  # type: ignore

SPEC = GemmSpec("BERT-1", 256, 768, 768)    # Table I BERT-1 dims
CORES = (1, 2, 4, 8, 16)
DESIGNS = ("BASE", "RASA-WLBP", "RASA-DMDB-WLS")
PARTITIONERS = ("m_split", "block2d")
#: skewed layer workload for the scheduler comparison
SCHED_WORKLOAD = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"], TABLE_I["DLRM-2"],
                  TABLE_I["BERT-1"], TABLE_I["DLRM-2"], TABLE_I["DLRM-2"]]


def run(force: bool = False) -> dict:
    def compute():
        table: dict = {"partition": {}, "scheduler": {}}
        for design in DESIGNS:
            for part in PARTITIONERS:
                for n in CORES:
                    rep = simulate_chip(
                        SPEC, ChipConfig(n_cores=n, design=design),
                        partition=part)
                    table["partition"][f"{design}_{part}_c{n}"] = {
                        "cycles": rep.cycles,
                        "speedup": rep.speedup,
                        "efficiency": rep.efficiency,
                        "bw_stall_share": rep.bw_stall_share,
                        "utilization": rep.utilization,
                        "wlbp_rate": rep.wlbp_rate,
                    }
        for sched in ("round_robin", "work_queue", "lpt"):
            rep = simulate_chip(SCHED_WORKLOAD,
                                ChipConfig(n_cores=4, design="RASA-WLBP"),
                                scheduler=sched)
            table["scheduler"][sched] = {
                "cycles": rep.cycles, "speedup": rep.speedup,
                "per_core_gemms": [list(g) for g in rep.per_core_gemms],
            }
        return table
    return cache_json("multicore_scaling", compute, force=force)


def main() -> None:
    table = run()
    print(f"# {SPEC.name} ({SPEC.M}x{SPEC.K}x{SPEC.N}), 256 B/cyc shared budget")
    print(f"{'design':<16}{'partition':<10}{'cores':>6}{'cycles':>12}"
          f"{'eff':>8}{'stall':>8}")
    for design in DESIGNS:
        for part in PARTITIONERS:
            for n in CORES:
                key = f"{design}_{part}_c{n}"
                v = table["partition"][key]
                print(f"{design:<16}{part:<10}{n:>6}{v['cycles']:>12.0f}"
                      f"{v['efficiency']:>8.3f}{v['bw_stall_share']:>8.3f}")
                emit(f"multicore_{key}", 0.0,
                     f"eff={v['efficiency']:.3f};"
                     f"stall={v['bw_stall_share']:.3f};"
                     f"cycles={v['cycles']:.0f}")
    print("\n# scheduler comparison (4 cores, RASA-WLBP, 6-layer workload)")
    for sched, v in table["scheduler"].items():
        print(f"{sched:<14} makespan={v['cycles']:>12.0f} "
              f"speedup={v['speedup']:.2f}")
        emit(f"multicore_sched_{sched}", 0.0,
             f"cycles={v['cycles']:.0f};speedup={v['speedup']:.2f}")


if __name__ == "__main__":
    main()
