"""Online-arbiter scaling: settled-prefix caching vs. rebuild-from-epoch-0.

Drives a long synthetic serving trace (default 1000 requests) through the
open-arrival chip model twice -- once with the span arbiter's settled-prefix
cache and retired-span pruning (the default), once in the pre-refactor
rebuild-from-epoch-0 baseline mode (``prefix_cache=False``: every settle
re-derives every epoch's share from every span ever submitted, exactly the
behavior that made thousand-request traces intractable) -- and reports both
wall times.  The two runs must produce an **identical** ``BatchReport``
(the cache changes the work, never the answer; asserted here), and at full
scale the cached run must be at least 5x faster (asserted: the acceptance
criterion of the arbiter unification).

Also emitted per run: arbiter settle/round counts, how the fast path
re-simulated (full replays vs. snapshot resumes), and how many spans were
retired out of the relaxation set.

Results go to ``benchmarks/results/BENCH_online_scaling.json`` -- uploaded
by CI next to the other benchmark artifacts (CI runs ``--smoke``, which
checks the identity but not the 5x floor: the quadratic term needs the
full trace length to dominate).  Measured at the full 1000 requests:
14.1s cached vs. 1548.9s baseline = **109.5x** -- expect the full run to
spend ~25 minutes in the baseline; that intractability is precisely what
the unified arbiter's prefix cache removes.

``--resume`` additionally demonstrates checkpointed long-run simulation:
the trace is driven halfway, the chip is checkpointed
(:meth:`OnlineChip.snapshot`), round-tripped through ``pickle``, restored,
and driven to completion -- the restored run's makespan, share schedule
and retirement counts must be **bit-identical** to the uninterrupted run
(asserted; the ``resume_check`` block lands in the BENCH file).

    PYTHONPATH=src python benchmarks/online_scaling.py [--smoke] [-n N]
                                                       [--resume]
"""

from __future__ import annotations

import argparse
import pickle
import time
from pathlib import Path

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.core.fastsim import SNAP_STRIDE
from repro.multicore import ChipConfig, OnlineChip
from repro.serving.simbatch import _Batcher, synthetic_trace

from common import emit, write_bench  # type: ignore

N_FULL = 1000
N_SMOKE = 100
MIN_SPEEDUP = 5.0       # acceptance floor, asserted at full scale

#: light per-request shapes: keeps both runs simulation-cheap so the
#: baseline's quadratic arbiter term is what the comparison measures
TRACE_KW = dict(seed=0, mean_gap=2, d_model=128, prompt_lens=(16, 32, 64),
                decode_steps=(1, 2), decode_batch=8)
CHIP_KW = dict(n_cores=4, design="RASA-WLBP", bw_bytes_per_cycle=32.0,
               backend="fast")


def _run(requests, chip: ChipConfig, prefix_cache: bool):
    min_share = chip.bw_bytes_per_cycle / (2.0 * chip.n_cores)
    batcher = _Batcher(requests, chip, "occupancy", 4, min_share,
                       SNAP_STRIDE, 1, prefix_cache)
    t0 = time.perf_counter()
    rep = batcher.run()
    elapsed = time.perf_counter() - t0
    sim = batcher.sim
    return rep, elapsed, {**sim.stats, "n_retired": sim.n_retired}


def _drive(sim: OnlineChip, requests, start: int = 0,
           upto_epoch: int | None = None) -> int:
    """Submit ``requests[start:]`` round-robin at their arrival epochs,
    stopping before the first arrival past ``upto_epoch``; returns the
    index of the first unsubmitted request."""
    n = sim.chip.n_cores
    i = start
    while i < len(requests):
        r = requests[i]
        if upto_epoch is not None and r.arrival_epoch > upto_epoch:
            return i
        if r.arrival_epoch > sim.epoch:
            sim.advance_to(r.arrival_epoch)
        sim.submit(i % n, r.specs)
        i += 1
    return i


def resume_check(n_requests: int) -> dict:
    """Checkpoint halfway, pickle-round-trip, restore, finish: the result
    must be bit-identical to the uninterrupted run."""
    requests = synthetic_trace(n_requests, **TRACE_KW)
    chip = ChipConfig(**CHIP_KW)
    half = requests[len(requests) // 2].arrival_epoch

    straight = OnlineChip(chip, snap_stride=SNAP_STRIDE)
    _drive(straight, requests)
    straight.drain()

    sim = OnlineChip(chip, snap_stride=SNAP_STRIDE)
    k = _drive(sim, requests, upto_epoch=half)
    sim.advance_to(half)
    blob = pickle.dumps(sim.snapshot())
    resumed = OnlineChip.restore(pickle.loads(blob))
    del sim                              # the checkpoint stands alone
    _drive(resumed, requests, start=k)
    resumed.drain()

    identical = (resumed.makespan == straight.makespan
                 and resumed.share_trace == straight.share_trace
                 and resumed.active_trace == straight.active_trace
                 and resumed.n_retired == straight.n_retired)
    assert identical, \
        "restoring a checkpoint changed the simulation -- snapshot/restore " \
        "must be bit-identical to never having checkpointed"
    return {
        "n_requests": n_requests,
        "checkpoint_epoch": half,
        "snapshot_pickle_bytes": len(blob),
        "makespan": straight.makespan,
        "identical": identical,
    }


def run(n_requests: int, smoke: bool = False,
        resume: bool = False) -> dict:
    requests = synthetic_trace(n_requests, **TRACE_KW)
    chip = ChipConfig(**CHIP_KW)
    rep_on, t_on, stats_on = _run(requests, chip, prefix_cache=True)
    rep_off, t_off, stats_off = _run(requests, chip, prefix_cache=False)

    assert rep_on == rep_off, \
        "prefix caching changed the BatchReport -- it may only change the " \
        "work, never the answer"
    speedup = t_off / t_on if t_on else float("inf")
    if n_requests >= N_FULL:
        # the floor is only meaningful once the baseline's quadratic
        # arbiter term dominates; short custom -n runs just report
        assert speedup >= MIN_SPEEDUP, \
            f"prefix caching must be >= {MIN_SPEEDUP}x faster than the " \
            f"rebuild-from-epoch-0 baseline at {n_requests} requests " \
            f"(measured {speedup:.1f}x)"

    table = {
        "smoke": smoke,
        "n_requests": n_requests,
        "chip": {k: v for k, v in CHIP_KW.items()},
        "trace": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in TRACE_KW.items()},
        "prefix_cache_on": {"seconds": t_on, **stats_on},
        "prefix_cache_off": {"seconds": t_off, **stats_off},
        "speedup": speedup,
        "identical_reports": True,
        "makespan": rep_on.makespan,
        "p50_latency": rep_on.p50_latency,
        "p99_latency": rep_on.p99_latency,
    }
    if resume:
        table["resume_check"] = resume_check(n_requests)
    write_bench("online_scaling", table, backend="fast")
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help=f"small trace ({N_SMOKE} requests, CI smoke run; "
                         f"checks report identity, not the speedup floor)")
    ap.add_argument("-n", "--requests", type=int, default=None,
                    help=f"trace length (default {N_FULL}, "
                         f"smoke {N_SMOKE})")
    ap.add_argument("--resume", action="store_true",
                    help="also checkpoint the chip halfway, pickle "
                         "round-trip, restore and finish -- asserting the "
                         "result is bit-identical to the straight run")
    args = ap.parse_args(argv)
    n = args.requests or (N_SMOKE if args.smoke else N_FULL)
    t = run(n, smoke=args.smoke, resume=args.resume)
    on, off = t["prefix_cache_on"], t["prefix_cache_off"]
    print(f"# online arbiter scaling, {n} requests "
          f"(4 cores, RASA-WLBP, {CHIP_KW['bw_bytes_per_cycle']:.0f} B/cyc)")
    print(f"{'mode':<24}{'seconds':>10}{'settles':>9}{'rounds':>8}"
          f"{'resumed':>9}{'retired':>9}")
    for name, row in (("prefix cache ON", on), ("rebuild from 0", off)):
        print(f"{name:<24}{row['seconds']:>10.2f}{row['settles']:>9}"
              f"{row['rounds']:>8}{row['sims_resumed']:>9}"
              f"{row['n_retired']:>9}")
    print(f"speedup: {t['speedup']:.1f}x (identical BatchReport: "
          f"{t['identical_reports']})")
    if "resume_check" in t:
        rc = t["resume_check"]
        print(f"resume: checkpoint @ epoch {rc['checkpoint_epoch']} "
              f"({rc['snapshot_pickle_bytes']} pickled bytes), restored "
              f"run bit-identical: {rc['identical']}")
    emit("online_scaling_prefix_cache", on["seconds"] * 1e6,
         f"speedup={t['speedup']:.1f};n={n}")


if __name__ == "__main__":
    main()
