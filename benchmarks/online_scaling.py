"""Online-arbiter scaling: the jitted whole-trace program vs. the numpy
client, plus the settled-prefix cache vs. rebuild-from-epoch-0.

Two comparisons, one trace family (light per-request shapes so arbitration
-- not engine simulation -- is what the wall clock measures):

**Jitted arbitration** (the headline, default 10k requests, ``-n`` scales
to 100k): the same open-arrival trace settles once through the numpy
incremental client (``backend="fast"``: the oracle) and once through the
whole-trace XLA program (``backend="jax"``, :mod:`repro.multicore.jitarb`
-- the entire boundary loop, share relaxation and token-bucket replay as
one ``lax.while_loop``).  The two ``BatchReport``s must be **bit-identical**
(asserted), and at full scale the jitted settle must be at least
``JIT_MIN_SPEEDUP`` (5x) faster than the numpy client (asserted on the
warm number: the one-off XLA compile is per trace-shape universe, not per
trace -- re-settling any same-shape trace, e.g. an arrival-rate sweep or
a load rescale, pays none of it).  The cold end-to-end time *including*
that compile is reported too and must still beat numpy
(``JIT_MIN_COLD_SPEEDUP``, asserted).  Measured at 10k requests: 113.1s
numpy vs. 15.3s cold / 11.0s warm = **7.4x cold / 10.3x warm**.

**Widened-domain points**: one small trace each through reactive
admission (``occupancy``/``bandwidth``/``predicted``), demand-weighted
shares, and a mixed BASE/RASA chip -- all settled by the same jitted
program (PR10's domain extensions) and asserted bit-identical to the
numpy client, with ``BatchReport.jit_gate`` confirming none of them fell
back.  A deliberate out-of-domain probe (``phase_aware``) checks the
structured plan-gate reason.  At ``-n 100000`` and beyond, the sliding
settled-prefix window's memory contract is asserted too: peak RSS stays
under ``JIT_MAX_RSS_MB`` regardless of trace horizon (the ``scale_100k``
block records the design point either way, so CI validates the contract
from the smoke run).

**Settled-prefix cache** (the earlier acceptance run, capped at 1000
requests): the numpy client with its settled-prefix cache and retired-span
pruning vs. the pre-refactor rebuild-from-epoch-0 mode
(``prefix_cache=False``) -- identical reports asserted, >= 5x at full
scale.  Measured at 1000 requests: 14.1s cached vs. 1548.9s baseline =
**109.5x**; the cap exists because the baseline is quadratic (~25 min at
1000 -- 10k would take days, which is rather the point).

Also emitted per run: arbiter settle/round counts, how the fast path
re-simulated (full replays vs. snapshot resumes), spans retired out of the
relaxation set, and the jitted kernel's relaxation-round / block-replay
counters.

Results go to ``benchmarks/results/BENCH_online_scaling.json`` -- uploaded
by CI next to the other benchmark artifacts and schema-checked by
``benchmarks/run.py --check-telemetry`` (CI runs ``--smoke``, which checks
the identities but not the speedup floors: compile time and the quadratic
term need full-scale traces to dominate).

``--resume`` additionally demonstrates checkpointed long-run simulation:
the trace is driven halfway, the chip is checkpointed
(:meth:`OnlineChip.snapshot`), round-tripped through ``pickle``, restored,
and driven to completion -- the restored run's makespan, share schedule
and retirement counts must be **bit-identical** to the uninterrupted run
(asserted; the ``resume_check`` block lands in the BENCH file).

    PYTHONPATH=src python benchmarks/online_scaling.py [--smoke] [-n N]
                                                       [--resume]
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import pickle
import resource
import time
from pathlib import Path

# importing common first also disables the XLA:CPU thunk runtime for this
# process -- ~8x on this program's tiny while-loop bodies, bit-identical
# results (the parity asserts below run under the flag; see
# common.XLA_THUNK_FLAG for the single documented knob)
import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.core.fastsim import SNAP_STRIDE  # noqa: E402
from repro.multicore import ChipConfig, OnlineChip, jitarb  # noqa: E402
from repro.serving.simbatch import (_Batcher, run_batcher,  # noqa: E402
                                    synthetic_trace)

from common import emit, write_bench  # type: ignore  # noqa: E402

N_JIT_FULL = 10_000     # headline trace length (``-n`` scales to 100k+)
N_JIT_100K = 100_000    # chunked-window design point (``-n 100000``)
N_CACHE_FULL = 1000     # rebuild-from-0 baseline is quadratic: capped
N_SMOKE = 100
MIN_SPEEDUP = 5.0       # settled-prefix-cache floor, asserted at full scale
JIT_MIN_SPEEDUP = 5.0   # jitted-vs-numpy settle floor (warm)
JIT_MIN_COLD_SPEEDUP = 2.0  # incl. the one-off compile, jit must still win
#: peak-RSS ceiling of the 100k design point: the sliding settled-prefix
#: window keeps the carried state O(S), so memory must not scale with the
#: trace horizon (asserted whenever ``-n`` >= 100k)
JIT_MAX_RSS_MB = 8192.0

#: light per-request shapes: keeps both runs simulation-cheap so the
#: arbitration cost is what the comparison measures
TRACE_KW = dict(seed=0, mean_gap=2, d_model=128, prompt_lens=(16, 32, 64),
                decode_steps=(1, 2), decode_batch=8)
CHIP_KW = dict(n_cores=4, design="RASA-WLBP", bw_bytes_per_cycle=32.0,
               backend="fast")


def _run(requests, chip: ChipConfig, prefix_cache: bool):
    min_share = chip.bw_bytes_per_cycle / (2.0 * chip.n_cores)
    batcher = _Batcher(requests, chip, "occupancy", 4, min_share,
                       SNAP_STRIDE, 1, prefix_cache)
    t0 = time.perf_counter()
    rep = batcher.run()
    elapsed = time.perf_counter() - t0
    sim = batcher.sim
    return rep, elapsed, {**sim.stats, "n_retired": sim.n_retired}


def jit_check(n_requests: int, full_scale: bool) -> dict:
    """The headline comparison: one open-arrival trace, settled by the
    numpy incremental client and by the whole-trace XLA program; the
    reports must be bit-identical and (at full scale) the jitted path
    >= ``JIT_MIN_SPEEDUP`` faster."""
    requests = synthetic_trace(n_requests, **TRACE_KW)
    chip_np = ChipConfig(**CHIP_KW)
    chip_jit = dataclasses.replace(chip_np, backend="jax")

    t0 = time.perf_counter()
    rep_jit = run_batcher(requests, chip_jit, policy="fixed", batch_size=1)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep_warm = run_batcher(requests, chip_jit, policy="fixed", batch_size=1)
    t_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep_np = run_batcher(requests, chip_np, policy="fixed", batch_size=1)
    t_np = time.perf_counter() - t0

    assert rep_jit == rep_np and rep_warm == rep_np, \
        "jitted whole-trace arbitration must produce a bit-identical " \
        "BatchReport vs. the numpy oracle"
    assert rep_jit.jit_gate is None, \
        f"headline trace unexpectedly gated: {rep_jit.jit_gate}"

    # kernel-side counters (relaxation rounds, block replays) off a warm
    # re-settle -- negligible next to the timed runs above
    stats: dict = {}
    p = jitarb.plan([(r.arrival_epoch, r.specs) for r in requests],
                    chip_jit)
    assert p is not None, "trace unexpectedly outside the jitarb domain"
    jitarb.finish_times(p, stats)

    # a deliberately out-of-domain probe: the structured plan-gate reason
    # is what makes silent numpy fallbacks diagnosable, so its presence
    # is part of the benchmark contract (validated by run.py)
    _, gate_probe = jitarb.plan_ex(
        [(r.arrival_epoch, r.specs) for r in requests[:4]], chip_jit,
        policy="phase_aware")
    assert gate_probe == "admission_policy"

    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss \
        / 1024.0
    speedup = t_np / t_cold if t_cold else float("inf")
    speedup_warm = t_np / t_warm if t_warm else float("inf")
    if full_scale:
        assert speedup_warm >= JIT_MIN_SPEEDUP, \
            f"the jitted settle must be >= {JIT_MIN_SPEEDUP}x faster " \
            f"than the numpy path at {n_requests} requests " \
            f"(measured {speedup_warm:.1f}x warm)"
        assert speedup >= JIT_MIN_COLD_SPEEDUP, \
            f"even counting its one-off compile the jitted path must be " \
            f">= {JIT_MIN_COLD_SPEEDUP}x faster at {n_requests} requests " \
            f"(measured {speedup:.1f}x cold)"
    if n_requests >= N_JIT_100K:
        assert peak_rss_mb <= JIT_MAX_RSS_MB, \
            f"peak RSS {peak_rss_mb:.0f} MB exceeds the " \
            f"{JIT_MAX_RSS_MB:.0f} MB bound at {n_requests} requests -- " \
            f"the sliding settled-prefix window must keep memory O(S)"
    return {
        "n_requests": n_requests,
        "asserted": full_scale,
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "seconds_numpy": t_np,
        "seconds_jit_cold": t_cold,
        "seconds_jit_warm": t_warm,
        "speedup": speedup,
        "speedup_warm": speedup_warm,
        "identical_reports": True,
        "jit_gate": rep_jit.jit_gate,
        "gate_probe": gate_probe,
        "peak_rss_mb": peak_rss_mb,
        "kernel_rounds": stats.get("rounds"),
        "kernel_blocks": stats.get("blocks"),
        "makespan": rep_jit.makespan,
        "p50_latency": rep_jit.p50_latency,
        "p99_latency": rep_jit.p99_latency,
    }


#: widened-domain coverage points: each settles one small trace through
#: the numpy client and the jitted program, asserting bit-identity --
#: reactive admission, demand-weighted shares and a mixed BASE/RASA chip
#: all through the same kernel (PR10's domain extensions)
DOMAIN_POINTS = (
    ("occupancy", dict(policy="occupancy"), dict()),
    ("bandwidth", dict(policy="bandwidth"), dict()),
    ("predicted", dict(policy="predicted"), dict()),
    ("demand_shares", dict(policy="fixed", batch_size=1),
     dict(share_policy="demand")),
    ("hetero_mix", dict(policy="occupancy"),
     dict(n_cores=None, design=None, cores=("BASE", "RASA-WLBP",
                                            "RASA-WLBP", "RASA-WLBP"))),
)


def domain_check(n_requests: int) -> dict:
    """Settle one trace per widened-domain point through both paths;
    every report pair must be bit-identical and un-gated."""
    out: dict = {}
    for name, run_kw, chip_kw in DOMAIN_POINTS:
        kw = {**CHIP_KW, **chip_kw}
        chip_np = ChipConfig(**kw)
        chip_jit = dataclasses.replace(chip_np, backend="jax")
        requests = synthetic_trace(n_requests, **TRACE_KW)
        t0 = time.perf_counter()
        rep_jit = run_batcher(requests, chip_jit, **run_kw)
        t_jit = time.perf_counter() - t0
        t0 = time.perf_counter()
        rep_np = run_batcher(requests, chip_np, **run_kw)
        t_np = time.perf_counter() - t0
        assert rep_jit == rep_np, \
            f"domain point {name!r}: jitted BatchReport differs from " \
            f"the numpy oracle"
        assert rep_jit.jit_gate is None, \
            f"domain point {name!r} unexpectedly gated: {rep_jit.jit_gate}"
        out[name] = {
            "n_requests": n_requests,
            "seconds_numpy": t_np,
            "seconds_jit_cold": t_jit,
            "identical_reports": True,
            "jit_gate": rep_jit.jit_gate,
            "makespan": rep_jit.makespan,
        }
    return out


def _drive(sim: OnlineChip, requests, start: int = 0,
           upto_epoch: int | None = None) -> int:
    """Submit ``requests[start:]`` round-robin at their arrival epochs,
    stopping before the first arrival past ``upto_epoch``; returns the
    index of the first unsubmitted request."""
    n = sim.chip.n_cores
    i = start
    while i < len(requests):
        r = requests[i]
        if upto_epoch is not None and r.arrival_epoch > upto_epoch:
            return i
        if r.arrival_epoch > sim.epoch:
            sim.advance_to(r.arrival_epoch)
        sim.submit(i % n, r.specs)
        i += 1
    return i


def resume_check(n_requests: int) -> dict:
    """Checkpoint halfway, pickle-round-trip, restore, finish: the result
    must be bit-identical to the uninterrupted run."""
    requests = synthetic_trace(n_requests, **TRACE_KW)
    chip = ChipConfig(**CHIP_KW)
    half = requests[len(requests) // 2].arrival_epoch

    straight = OnlineChip(chip, snap_stride=SNAP_STRIDE)
    _drive(straight, requests)
    straight.drain()

    sim = OnlineChip(chip, snap_stride=SNAP_STRIDE)
    k = _drive(sim, requests, upto_epoch=half)
    sim.advance_to(half)
    blob = pickle.dumps(sim.snapshot())
    resumed = OnlineChip.restore(pickle.loads(blob))
    del sim                              # the checkpoint stands alone
    _drive(resumed, requests, start=k)
    resumed.drain()

    identical = (resumed.makespan == straight.makespan
                 and resumed.share_trace == straight.share_trace
                 and resumed.active_trace == straight.active_trace
                 and resumed.n_retired == straight.n_retired)
    assert identical, \
        "restoring a checkpoint changed the simulation -- snapshot/restore " \
        "must be bit-identical to never having checkpointed"
    return {
        "n_requests": n_requests,
        "checkpoint_epoch": half,
        "snapshot_pickle_bytes": len(blob),
        "makespan": straight.makespan,
        "identical": identical,
    }


def run(n_requests: int, smoke: bool = False,
        resume: bool = False) -> dict:
    jit = jit_check(n_requests, full_scale=n_requests >= N_JIT_FULL)
    domain = domain_check(min(n_requests, 500))

    # the 100k chunked-window design point: measured when this run is at
    # scale, otherwise recorded as the contract (floors + RSS bound) so
    # CI payload validation can gate on it from the smoke run
    measured_100k = n_requests >= N_JIT_100K
    scale_100k = {
        "n_requests": N_JIT_100K,
        "min_speedup_warm": JIT_MIN_SPEEDUP,
        "max_rss_mb": JIT_MAX_RSS_MB,
        "measured": measured_100k,
    }
    if measured_100k:
        scale_100k.update(speedup_warm=jit["speedup_warm"],
                          peak_rss_mb=jit["peak_rss_mb"])

    n_cache = min(n_requests, N_CACHE_FULL)
    requests = synthetic_trace(n_cache, **TRACE_KW)
    chip = ChipConfig(**CHIP_KW)
    rep_on, t_on, stats_on = _run(requests, chip, prefix_cache=True)
    rep_off, t_off, stats_off = _run(requests, chip, prefix_cache=False)

    assert rep_on == rep_off, \
        "prefix caching changed the BatchReport -- it may only change the " \
        "work, never the answer"
    speedup = t_off / t_on if t_on else float("inf")
    if n_cache >= N_CACHE_FULL:
        # the floor is only meaningful once the baseline's quadratic
        # arbiter term dominates; short custom -n runs just report
        assert speedup >= MIN_SPEEDUP, \
            f"prefix caching must be >= {MIN_SPEEDUP}x faster than the " \
            f"rebuild-from-epoch-0 baseline at {n_cache} requests " \
            f"(measured {speedup:.1f}x)"

    table = {
        "smoke": smoke,
        "n_requests": n_cache,
        "chip": {k: v for k, v in CHIP_KW.items()},
        "trace": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in TRACE_KW.items()},
        "jit": jit,
        "domain": domain,
        "scale_100k": scale_100k,
        "prefix_cache_on": {"seconds": t_on, **stats_on},
        "prefix_cache_off": {"seconds": t_off, **stats_off},
        "speedup": speedup,
        "identical_reports": True,
        "makespan": rep_on.makespan,
        "p50_latency": rep_on.p50_latency,
        "p99_latency": rep_on.p99_latency,
    }
    if resume:
        table["resume_check"] = resume_check(n_cache)
    write_bench("online_scaling", table, backend="fast")
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help=f"small trace ({N_SMOKE} requests, CI smoke run; "
                         f"checks the report identities, not the speedup "
                         f"floors)")
    ap.add_argument("-n", "--requests", type=int, default=None,
                    help=f"jitted-comparison trace length (default "
                         f"{N_JIT_FULL}, smoke {N_SMOKE}; the prefix-cache "
                         f"comparison is capped at {N_CACHE_FULL} -- its "
                         f"baseline is quadratic)")
    ap.add_argument("--resume", action="store_true",
                    help="also checkpoint the chip halfway, pickle "
                         "round-trip, restore and finish -- asserting the "
                         "result is bit-identical to the straight run")
    args = ap.parse_args(argv)
    n = args.requests or (N_SMOKE if args.smoke else N_JIT_FULL)
    t = run(n, smoke=args.smoke, resume=args.resume)

    j = t["jit"]
    print(f"# jitted whole-trace arbitration, {j['n_requests']} requests "
          f"({CHIP_KW['n_cores']} cores, {CHIP_KW['design']}, "
          f"{CHIP_KW['bw_bytes_per_cycle']:.0f} B/cyc)")
    print(f"{'path':<24}{'seconds':>10}")
    print(f"{'numpy client':<24}{j['seconds_numpy']:>10.2f}")
    print(f"{'jit (cold, w/ compile)':<24}{j['seconds_jit_cold']:>10.2f}")
    print(f"{'jit (warm)':<24}{j['seconds_jit_warm']:>10.2f}")
    print(f"speedup: {j['speedup']:.1f}x cold / {j['speedup_warm']:.1f}x "
          f"warm (identical BatchReport: {j['identical_reports']}; "
          f"{j['kernel_rounds']} relaxation rounds, "
          f"{j['kernel_blocks']} block replays; peak RSS "
          f"{j['peak_rss_mb']:.0f} MB)")

    print(f"\n# widened-domain parity points "
          f"({next(iter(t['domain'].values()))['n_requests']} requests)")
    print(f"{'point':<16}{'numpy s':>10}{'jit s':>10}{'identical':>11}")
    for name, row in t["domain"].items():
        print(f"{name:<16}{row['seconds_numpy']:>10.2f}"
              f"{row['seconds_jit_cold']:>10.2f}"
              f"{str(row['identical_reports']):>11}")

    on, off = t["prefix_cache_on"], t["prefix_cache_off"]
    print(f"\n# settled-prefix cache, {t['n_requests']} requests")
    print(f"{'mode':<24}{'seconds':>10}{'settles':>9}{'rounds':>8}"
          f"{'resumed':>9}{'retired':>9}")
    for name, row in (("prefix cache ON", on), ("rebuild from 0", off)):
        print(f"{name:<24}{row['seconds']:>10.2f}{row['settles']:>9}"
              f"{row['rounds']:>8}{row['sims_resumed']:>9}"
              f"{row['n_retired']:>9}")
    print(f"speedup: {t['speedup']:.1f}x (identical BatchReport: "
          f"{t['identical_reports']})")
    if "resume_check" in t:
        rc = t["resume_check"]
        print(f"resume: checkpoint @ epoch {rc['checkpoint_epoch']} "
              f"({rc['snapshot_pickle_bytes']} pickled bytes), restored "
              f"run bit-identical: {rc['identical']}")
    emit("online_scaling_jit", j["seconds_jit_cold"] * 1e6,
         f"speedup={j['speedup']:.1f};n={j['n_requests']}")
    emit("online_scaling_prefix_cache", on["seconds"] * 1e6,
         f"speedup={t['speedup']:.1f};n={t['n_requests']}")


if __name__ == "__main__":
    main()
