"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``
prints ``name,us_per_call,derived`` CSV lines per benchmark.

``python benchmarks/run.py --check-telemetry`` instead validates every
emitted ``BENCH_*.json`` against the shared envelope schema
(``common.BENCH_SCHEMA``) and every ``*.trace.json`` artifact for
Chrome-trace shape, exiting non-zero on any violation -- the CI gate that
keeps the perf trajectory machine-comparable across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
import common  # noqa: F401  -- puts <repo>/src on sys.path


def _check_model_serving(path) -> list[str]:
    """Payload validation for BENCH_model_serving.json beyond the envelope:
    the per-family serving cells and the K-split acceptance demo must be
    present and well-formed."""
    problems: list[str] = []
    data = json.loads(path.read_text()).get("data", {})
    fams = data.get("families", {})
    for family in ("dense", "moe", "ssm"):
        row = fams.get(family)
        if not isinstance(row, dict):
            problems.append(f"{path.name}: missing family {family!r}")
            continue
        for chip in ("rasa4", "base4", "mixed"):
            cell = row.get(chip)
            if not isinstance(cell, dict) or not all(
                    isinstance(cell.get(k), (int, float))
                    for k in ("makespan", "p50_latency", "p99_latency")):
                problems.append(f"{path.name}: {family}/{chip} cell "
                                f"missing makespan/p50/p99")
    demo = data.get("k_split_demo", {})
    m = demo.get("m_split", {}).get("speedup")
    k = demo.get("k_split", {}).get("speedup")
    if not (isinstance(m, (int, float)) and abs(m - 1.0) < 1e-6):
        problems.append(f"{path.name}: k_split_demo m_split speedup "
                        f"must be 1.0 (got {m})")
    if not (isinstance(k, (int, float)) and 1.0 < k < 4.0):
        problems.append(f"{path.name}: k_split_demo k_split speedup "
                        f"must scale sublinearly past 1 core (got {k})")
    return problems


def _check_fault_tolerance(path) -> list[str]:
    """Payload validation for BENCH_fault_tolerance.json: every
    scenario x policy goodput cell plus a coherent acceptance block."""
    problems: list[str] = []
    data = json.loads(path.read_text()).get("data", {})
    scenarios = data.get("scenarios", {})
    for sname in ("none", "mild", "moderate", "severe", "random"):
        row = scenarios.get(sname, {}).get("policies")
        if not isinstance(row, dict):
            problems.append(f"{path.name}: missing scenario {sname!r}")
            continue
        for pol, cell in row.items():
            if not all(isinstance(cell.get(k), (int, float))
                       for k in ("goodput_macs_per_cycle",
                                 "throughput_macs_per_cycle",
                                 "deadline_miss_rate", "makespan")):
                problems.append(f"{path.name}: {sname}/{pol} cell missing "
                                f"goodput/throughput/miss/makespan")
    acc = data.get("acceptance", {})
    ratio, floor = acc.get("ratio"), acc.get("floor")
    if not isinstance(ratio, (int, float)):
        problems.append(f"{path.name}: acceptance block missing ratio")
    elif acc.get("asserted") and ratio < floor:
        problems.append(f"{path.name}: asserted goodput ratio {ratio:.2f} "
                        f"below the {floor}x floor")
    return problems


def _check_online_scaling(path) -> list[str]:
    """Payload validation for BENCH_online_scaling.json: both comparison
    blocks present, their report identities asserted, and any full-scale
    speedup floor the run claims to have asserted actually met."""
    problems: list[str] = []
    data = json.loads(path.read_text()).get("data", {})
    jit = data.get("jit")
    if not isinstance(jit, dict):
        problems.append(f"{path.name}: missing jit comparison block")
    else:
        for k in ("seconds_numpy", "seconds_jit_cold", "seconds_jit_warm",
                  "speedup", "speedup_warm"):
            if not isinstance(jit.get(k), (int, float)):
                problems.append(f"{path.name}: jit block missing {k}")
        if jit.get("identical_reports") is not True:
            problems.append(f"{path.name}: jit vs numpy BatchReport "
                            f"identity not asserted")
        if jit.get("asserted") and isinstance(jit.get("speedup_warm"),
                                              (int, float)) \
                and jit["speedup_warm"] < 5.0:
            problems.append(f"{path.name}: asserted warm jit speedup "
                            f"{jit['speedup_warm']:.2f} below the 5x floor")
        # the structured plan-gate reason is the diagnosability contract
        # of PR10: the headline run must record it (None = served by the
        # jitted lane) and the out-of-domain probe must name its gate
        if "jit_gate" not in jit:
            problems.append(f"{path.name}: jit block missing the "
                            f"plan-gate reason field 'jit_gate'")
        if not isinstance(jit.get("gate_probe"), str):
            problems.append(f"{path.name}: jit block missing the "
                            f"out-of-domain 'gate_probe' reason")
    domain = data.get("domain")
    if not isinstance(domain, dict) or not domain:
        problems.append(f"{path.name}: missing widened-domain parity "
                        f"block")
    else:
        for name, row in domain.items():
            if row.get("identical_reports") is not True:
                problems.append(f"{path.name}: domain point {name!r} "
                                f"identity not asserted")
            if "jit_gate" not in row:
                problems.append(f"{path.name}: domain point {name!r} "
                                f"missing the plan-gate reason field")
    s100 = data.get("scale_100k")
    if not isinstance(s100, dict) or s100.get("n_requests") != 100_000:
        problems.append(f"{path.name}: missing the 100k-request "
                        f"chunked-window design point (scale_100k)")
    else:
        for k in ("min_speedup_warm", "max_rss_mb", "measured"):
            if k not in s100:
                problems.append(f"{path.name}: scale_100k missing {k}")
        if s100.get("measured"):
            if not (isinstance(s100.get("speedup_warm"), (int, float))
                    and s100["speedup_warm"] >= s100["min_speedup_warm"]):
                problems.append(f"{path.name}: measured 100k speedup "
                                f"{s100.get('speedup_warm')} below the "
                                f"{s100.get('min_speedup_warm')}x floor")
            if not (isinstance(s100.get("peak_rss_mb"), (int, float))
                    and s100["peak_rss_mb"] <= s100["max_rss_mb"]):
                problems.append(f"{path.name}: measured 100k peak RSS "
                                f"{s100.get('peak_rss_mb')} above the "
                                f"{s100.get('max_rss_mb')} MB bound")
    for blk in ("prefix_cache_on", "prefix_cache_off"):
        if not isinstance(data.get(blk, {}).get("seconds"), (int, float)):
            problems.append(f"{path.name}: missing {blk} timing")
    if data.get("identical_reports") is not True:
        problems.append(f"{path.name}: prefix-cache BatchReport identity "
                        f"not asserted")
    return problems


#: every attribution bucket a trace export may carry; fault-free exports
#: omit fault_lost (see repro.obs.attribution.BUCKETS)
_BUCKETS = ("compute", "fill_drain", "bw_stall", "fault_lost",
            "queue_wait", "idle")


def _check_trace_attribution(path, doc) -> list[str]:
    """Conservation invariant of an exported trace's attribution rollup:
    the buckets must sum to window x cores exactly (1e-6 relative)."""
    other = doc.get("otherData", {})
    att = other.get("attribution")
    if not isinstance(att, dict):
        return []                # pre-attribution artifact: envelope-only
    unknown = sorted(set(att) - set(_BUCKETS))
    if unknown:
        return [f"{path.name}: unknown attribution bucket(s) {unknown}"]
    occupied = other.get("window_cycles", 0) * other.get("n_cores", 0)
    total = sum(att.values())
    if abs(total - occupied) > 1e-6 * max(1.0, occupied):
        return [f"{path.name}: attribution buckets sum to {total}, "
                f"window x cores = {occupied} -- conservation violated"]
    return []


def check_telemetry() -> int:
    """Validate all BENCH envelopes + trace artifacts; 0 = all valid."""
    from common import RESULTS, validate_bench
    problems: list[str] = []
    benches = sorted(RESULTS.glob("BENCH_*.json"))
    for path in benches:
        problems += validate_bench(path)
        if path.name == "BENCH_model_serving.json":
            problems += _check_model_serving(path)
        if path.name == "BENCH_fault_tolerance.json":
            problems += _check_fault_tolerance(path)
        if path.name == "BENCH_online_scaling.json":
            problems += _check_online_scaling(path)
    traces = sorted(RESULTS.glob("*.trace.json"))
    for path in traces:
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{path.name}: unreadable ({e})")
            continue
        events = doc.get("traceEvents") if isinstance(doc, dict) else None
        if not isinstance(events, list) or not events:
            problems.append(f"{path.name}: no traceEvents array")
        elif not all(isinstance(e, dict) and "ph" in e for e in events):
            problems.append(f"{path.name}: malformed trace events "
                            f"(every event needs a 'ph' phase)")
        if isinstance(doc, dict):
            problems += _check_trace_attribution(path, doc)
    print(f"checked {len(benches)} BENCH files, {len(traces)} trace "
          f"artifacts: {len(problems)} problem(s)")
    for p in problems:
        print(f"  {p}")
    return 1 if problems else 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check-telemetry", action="store_true",
                    help="validate emitted BENCH_*.json envelopes and "
                         "*.trace.json artifacts instead of running "
                         "benchmarks")
    args = ap.parse_args(argv)
    if args.check_telemetry:
        raise SystemExit(check_telemetry())

    import fig2_utilization
    import fig5_runtime
    import fig6_ppa
    import fig7_batch
    import kernel_bench
    import rasa_llm_projection
    import roofline_report

    for mod in (fig2_utilization, fig5_runtime, fig6_ppa, fig7_batch,
                kernel_bench, rasa_llm_projection, roofline_report):
        print(f"\n## {mod.__name__}")
        mod.main()


if __name__ == "__main__":
    main()
