"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``
prints ``name,us_per_call,derived`` CSV lines per benchmark.
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    import fig2_utilization
    import fig5_runtime
    import fig6_ppa
    import fig7_batch
    import kernel_bench
    import rasa_llm_projection
    import roofline_report

    for mod in (fig2_utilization, fig5_runtime, fig6_ppa, fig7_batch,
                kernel_bench, rasa_llm_projection, roofline_report):
        print(f"\n## {mod.__name__}")
        mod.main()


if __name__ == "__main__":
    main()
