"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/results/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all``) and prints the three-term roofline
per (arch x shape) on the single-pod mesh.
"""

from __future__ import annotations

import common  # noqa: F401  -- puts <repo>/src on sys.path

from pathlib import Path

from repro.roofline import analyze_all, format_report

from common import emit  # type: ignore

DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


def main() -> None:
    cells = analyze_all(DRYRUN)
    if not cells:
        print("# no dry-run artifacts; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for c in cells:
        emit(f"roofline_{c.arch}_{c.shape}", c.step_time_s * 1e6,
             f"bound={c.dominant};mfu={c.mfu:.3f};"
             f"mem_gib={c.peak_mem_bytes/2**30:.2f}")
    print()
    print(format_report(cells))


if __name__ == "__main__":
    main()
