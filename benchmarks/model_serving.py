"""Real-model serving on the simulated chip: dense, MoE, and SSM configs
served end-to-end on homogeneous and mixed BASE/RASA chips.

The real-model counterpart of ``serving_batch.py``: request traces come
from the workload frontend (:func:`repro.serving.model_trace` -- each
request is a compiled per-layer prefill stream plus a chain of compiled
decode steps), not synthetic single-GEMM shapes.  One architecture per
model family:

  dense -- gemma-2b          (GQA attention + gated FFN)
  moe   -- granite-moe-3b    (small-expert register-limited regime)
  ssm   -- mamba2-130m       (attention-free; SSD scan ops)

Each is served on three 4-core chips: homogeneous RASA-DMDB-WLS,
homogeneous BASE, and a mixed 2xBASE + 2xRASA chip (the heterogeneous
scheduler routes reuse-friendly GEMMs to the cores that finish them
first).  Reported per cell: p50/p99 request latency, makespan, and
MACs/cycle throughput.

The benchmark also pins the K-split acceptance demo: a decode-phase GEMM
(M = decode batch, a single tile-row) cannot occupy more than one core
under M-split (speedup stays 1x) but scales across all four under K-split
-- while the cross-core reduction's partial traffic is charged to the
shared bandwidth budget, so the speedup stays strictly below linear.

Results go to ``benchmarks/results/BENCH_model_serving.json``.

    python benchmarks/model_serving.py [--smoke]
"""

from __future__ import annotations

import argparse

import common  # noqa: F401  -- puts <repo>/src on sys.path

from repro.multicore.chip import ChipConfig, CoreSpec, simulate_chip
from repro.serving.simbatch import model_trace, run_batcher
from repro.workload import CompileOptions, compile_workload

from common import emit, write_bench  # type: ignore

#: one architecture per model family (dense / MoE / SSM)
FAMILY_ARCHS = {
    "dense": "gemma-2b",
    "moe": "granite-moe-3b-a800m",
    "ssm": "mamba2-130m",
}

BW = 128.0
RASA = "RASA-DMDB-WLS"


def _chips(backend: str = "fast") -> dict[str, ChipConfig]:
    kw = dict(bw_bytes_per_cycle=BW, backend=backend)
    return {
        "rasa4": ChipConfig(n_cores=4, design=RASA, **kw),
        "base4": ChipConfig(n_cores=4, design="BASE", **kw),
        "mixed": ChipConfig(n_cores=4, cores=(
            CoreSpec("BASE"), CoreSpec("BASE"),
            CoreSpec(RASA), CoreSpec(RASA)), **kw),
    }


def _cell(rep) -> dict:
    return {
        "makespan": rep.makespan,
        "p50_latency": rep.p50_latency,
        "p99_latency": rep.p99_latency,
        "mean_latency": rep.mean_latency,
        "throughput_macs_per_cycle": rep.throughput_macs_per_cycle,
    }


def k_split_demo(smoke: bool = False) -> dict:
    """Decode GEMM scaling: M-split cannot leave one core, K-split can.

    The whole-model serving cells above place decode GEMMs whole; this is
    the partitioner-level view of *why* K-split exists: a decode
    projection has a single M tile-row, so output-space sharding strands
    3 of 4 cores, while K-split spreads the depth loop and pays the
    reduction's bandwidth bill.
    """
    wl = compile_workload(FAMILY_ARCHS["dense"], batch=8, seq=1,
                          phase="decode",
                          options=CompileOptions(dim_cap=2048, max_layers=1))
    spec = max(wl.specs, key=lambda s: s.K)   # the deepest decode GEMM
    chip = ChipConfig(n_cores=4, design=RASA, bw_bytes_per_cycle=BW,
                      backend="fast")
    m = simulate_chip(spec, chip, partition="m_split")
    k = simulate_chip(spec, chip, partition="k_split")
    occupied = lambda rep: sum(1 for c in rep.per_core_cycles if c > 0)
    out = {
        "spec": {"name": spec.name, "M": spec.M, "K": spec.K, "N": spec.N},
        "m_split": {"speedup": m.speedup, "cores_occupied": occupied(m)},
        "k_split": {"speedup": k.speedup, "cores_occupied": occupied(k),
                    "bw_stall_cycles": k.bw_stall_cycles},
    }
    assert occupied(m) == 1 and abs(m.speedup - 1.0) < 1e-9, \
        "a single-tile-row decode GEMM must strand M-split on one core"
    assert occupied(k) == 4 and 1.0 < k.speedup < 4.0, \
        "K-split must scale the decode GEMM beyond one core, sublinearly"
    return out


def run(smoke: bool = False) -> dict:
    n_req = 4 if smoke else 8
    options = CompileOptions(dim_cap=512 if smoke else 1024, max_layers=1)
    table: dict = {"smoke": smoke, "families": {},
                   "k_split_demo": k_split_demo(smoke)}
    for family, arch in FAMILY_ARCHS.items():
        trace = model_trace(arch, n_req, seed=0, mean_gap=2,
                            prompt_lens=(16, 32) if smoke else (32, 64),
                            decode_steps=(1, 2) if smoke else (2, 4),
                            options=options)
        cells = {}
        for chip_name, chip in _chips().items():
            rep = run_batcher(trace, chip, policy="occupancy")
            cells[chip_name] = _cell(rep)
        assert cells["rasa4"]["makespan"] < cells["base4"]["makespan"], \
            f"{arch}: the RASA chip must serve the trace faster than BASE"
        table["families"][family] = {"arch": arch, **cells}
    write_bench("model_serving", table, backend="fast")
    return table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smaller traces (CI smoke run)")
    args = ap.parse_args(argv)
    t = run(smoke=args.smoke)
    print(f"{'family':<8}{'arch':<24}{'chip':<8}"
          f"{'makespan':>12}{'p50':>12}{'p99':>12}")
    for family, row in t["families"].items():
        for chip_name in ("rasa4", "base4", "mixed"):
            v = row[chip_name]
            print(f"{family:<8}{row['arch']:<24}{chip_name:<8}"
                  f"{v['makespan']:>12.0f}{v['p50_latency']:>12.0f}"
                  f"{v['p99_latency']:>12.0f}")
            emit(f"model_serving_{family}_{chip_name}", 0.0,
                 f"makespan={v['makespan']:.0f};p99={v['p99_latency']:.0f}")
    d = t["k_split_demo"]
    print(f"\n# K-split decode demo on {d['spec']['name']} "
          f"[M={d['spec']['M']}, K={d['spec']['K']}, N={d['spec']['N']}]")
    print(f"m_split: speedup={d['m_split']['speedup']:.2f} "
          f"(cores occupied: {d['m_split']['cores_occupied']})")
    print(f"k_split: speedup={d['k_split']['speedup']:.2f} "
          f"(cores occupied: {d['k_split']['cores_occupied']}, "
          f"bw stall: {d['k_split']['bw_stall_cycles']:.0f} cycles)")
    emit("model_serving_k_split", 0.0,
         f"m_split={d['m_split']['speedup']:.2f};"
         f"k_split={d['k_split']['speedup']:.2f}")


if __name__ == "__main__":
    main()
