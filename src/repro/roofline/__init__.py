"""Roofline analysis from compiled dry-run artifacts."""

from .analysis import (HW, CellRoofline, analyze_cell, analyze_all,
                       format_report)

__all__ = ["HW", "CellRoofline", "analyze_cell", "analyze_all",
           "format_report"]
