"""Three-term roofline from the dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

Sources: ``compiled.cost_analysis()`` (per-device, so the per-device values
are used directly with per-device peaks) and the optimized-HLO collective
parse from dryrun.py.  cost_analysis counts a scan body once (measured), so
*totals* are reconstructed from layer-unrolled reduced-depth compiles:

    total = embed_head + n_units x per_unit

where a "unit" is one scanned layer (transformers/ssm) or one group of
``attn_every`` layers + the shared block (hybrid).  The dry-run stores the
full-depth artifact (memory/sharding proof) and the reduced-depth artifacts
(flops/bytes/collectives); this module combines them.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per direction).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from ..config import SHAPES


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12         # bf16 / chip
    hbm_bw: float = 819e9              # bytes/s / chip
    ici_bw: float = 50e9               # bytes/s / link
    hbm_bytes: float = 16 * 2**30      # v5e HBM capacity


V5E = HW()

COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    devices: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float                 # 6*N*D (dense) / 6*N_active*D (moe)
    peak_mem_bytes: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    extrapolated: bool = False

    def finalize(self, hw: HW = V5E) -> "CellRoofline":
        self.compute_s = self.flops_per_device / hw.peak_flops
        self.memory_s = self.bytes_per_device / hw.hbm_bw
        self.collective_s = self.coll_bytes_per_device / hw.ici_bw
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/padding/masked-attention
        waste shows up here)."""
        total_hlo = self.flops_per_device * self.devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * self.devices * V5E.peak_flops
        return self.model_flops / denom if denom else 0.0


def model_flops_for(arch: str, shape: str) -> float:
    """6*N*D (N = active params, D = tokens processed).  For decode shapes
    D = batch (one token per sequence) but attention also reads the cache:
    +2*cache_token_kv_flops; we report the 6*N*D convention and note cache
    reads separately in §Roofline."""
    from ..configs import get_config
    cfg = get_config(arch)
    seq, batch, kind = SHAPES[shape]
    n_active = cfg.model.active_param_count()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens     # forward only
    return 2.0 * n_active * batch          # decode: one token/sequence


def load_cell(results_dir: Path, arch: str, shape: str,
              multi_pod: bool = False) -> dict | None:
    pod = "pod2" if multi_pod else "pod1"
    p = results_dir / f"{arch}__{shape}__{pod}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def _coll_sum(cell: dict) -> float:
    colls = cell.get("collectives_per_device_bytes", {})
    return sum(v for k, v in colls.items() if not k.endswith("_count"))


def analyze_cell(cell: dict, hw: HW = V5E,
                 d0: dict | None = None, du: dict | None = None) -> CellRoofline:
    """Roofline terms for one cell.  With the reduced-depth unrolled
    artifacts (d0 = embed+head only, du = one unit of layers), totals are

        total = d0 + n_units * (du - d0)

    which corrects cost_analysis's count-scan-body-once behaviour.  Without
    them, the raw (undercounted) scanned numbers are used and flagged."""
    flops = cell["cost_per_device"]["flops"]
    byts = cell["cost_per_device"]["bytes_accessed"]
    coll = _coll_sum(cell)
    extrapolated = False
    if d0 is not None and du is not None and not d0.get("skipped"):
        unit = cell.get("unit_layers", 1)
        n_units = cell.get("total_layers", unit) // unit
        def comb(a, b):
            return a + n_units * max(b - a, 0.0)
        flops = comb(d0["cost_per_device"]["flops"],
                     du["cost_per_device"]["flops"])
        byts = comb(d0["cost_per_device"]["bytes_accessed"],
                    du["cost_per_device"]["bytes_accessed"])
        coll = comb(_coll_sum(d0), _coll_sum(du))
        extrapolated = True
    r = CellRoofline(
        arch=cell["arch"], shape=cell["shape"], devices=cell["devices"],
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=coll,
        model_flops=model_flops_for(cell["arch"], cell["shape"]),
        peak_mem_bytes=cell["memory"]["peak_bytes_per_device"],
        extrapolated=extrapolated,
    )
    return r.finalize(hw)


def _load_depth(results_dir: Path, arch: str, shape: str, depth: int) -> dict | None:
    p = results_dir / f"{arch}__{shape}__pod1__d{depth}.json"
    return json.loads(p.read_text()) if p.exists() else None


def analyze_all(results_dir: str | Path, multi_pod: bool = False) -> list[CellRoofline]:
    results_dir = Path(results_dir)
    from ..configs import all_cells, get_config
    out = []
    for arch, shape, ok, why in all_cells():
        cell = load_cell(results_dir, arch, shape, multi_pod)
        if cell is None or cell.get("skipped"):
            continue
        unit = cell.get("unit_layers", 1)
        d0 = _load_depth(results_dir, arch, shape, 0)
        du = _load_depth(results_dir, arch, shape, unit)
        out.append(analyze_cell(cell, d0=d0, du=du))
    return out


def format_report(cells: list[CellRoofline], hw: HW = V5E) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bound':>10s} {'mem_GiB':>8s} {'MFU%':>6s} "
           f"{'useful%':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        lines.append(
            f"{c.arch:24s} {c.shape:12s} {c.compute_s:10.4f} "
            f"{c.memory_s:10.4f} {c.collective_s:10.4f} {c.dominant:>10s} "
            f"{c.peak_mem_bytes/2**30:8.2f} {100*c.mfu:6.1f} "
            f"{100*c.useful_flops_ratio:8.1f}")
    return "\n".join(lines)
