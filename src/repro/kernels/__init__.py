"""Pallas TPU kernels for the RASA framework (validated in interpret mode).

- :mod:`repro.kernels.rasa_gemm`       -- RASA-scheduled tiled GEMM (the
  paper's matrix engine mapped onto the MXU pipeline; DESIGN.md §3)
- :mod:`repro.kernels.flash_attention` -- blockwise causal attention
- :mod:`repro.kernels.ops`             -- jit'd public wrappers
- :mod:`repro.kernels.ref`             -- pure-jnp oracles
"""

from .ops import flash_mha, rasa_matmul
from .rasa_gemm import GemmBlocks, SCHEDULES, default_blocks, rasa_gemm, schedule_cost
from .flash_attention import flash_attention
from .ssd_chunk import hbm_bytes_fused, ssd_chunk_fused
from . import ref

__all__ = ["flash_mha", "rasa_matmul", "GemmBlocks", "SCHEDULES",
           "default_blocks", "rasa_gemm", "schedule_cost",
           "flash_attention", "ssd_chunk_fused", "hbm_bytes_fused", "ref"]
