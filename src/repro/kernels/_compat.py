"""jax version-compat shims shared by the Pallas kernels."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes the TPU compiler params as TPUCompilerParams; newer
# releases renamed it to CompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported (need >=0.4.35)")
