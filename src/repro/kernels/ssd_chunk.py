"""Fused Mamba2 SSD chunk kernel (Pallas TPU).

This is the kernel the zamba2 hillclimb identified as the memory-term fix
(EXPERIMENTS.md §Perf B1.3): the XLA lowering of the chunked SSD spends
its HBM traffic on elementwise passes over [B,S,H,*] intermediates; this
kernel keeps one chunk's working set (scores [q,q] ~256 KiB + x/B/C/state
blocks ~1.3 MiB) in VMEM and streams only the operands.

Grid: (BH, nc) with the chunk axis sequential ("arbitrary") -- the running
inter-chunk state lives in a VMEM scratch accumulator across chunk steps,
exactly like the rasa_gemm "wls" schedule keeps its fp32 accumulator
(shadow-buffer analogy: the state is the stationary operand carried across
grid steps).

Layout (heads flattened into the grid):
  x:  [BH, S, P]    dt: [BH, S]    B/C: [BH, S, N]    A: scalar per (b,h)
Returns y [BH, S, P] and the final state [BH, P, N].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import _CompilerParams


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, fin_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]                                   # scalar decay rate (<0)
    x = x_ref[0].astype(jnp.float32)               # [q, P]
    dt = dt_ref[0].astype(jnp.float32)             # [q]
    b = b_ref[0].astype(jnp.float32)               # [q, N]
    c = c_ref[0].astype(jnp.float32)               # [q, N]

    dA = dt * a                                    # [q] (negative)
    seg = jnp.cumsum(dA)                           # [q]
    xdt = x * dt[:, None]                          # [q, P]

    # intra-chunk: scores[i,j] = c_i.b_j * exp(seg_i - seg_j), i >= j
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # [q, q]
    diff = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = jnp.where(ii >= jj, diff, -1e30)
    w = cb * jnp.exp(diff)                         # [q, q]
    y = jnp.dot(w, xdt, preferred_element_type=jnp.float32)    # [q, P]

    # inter-chunk: y_i += (c_i . state_prev) * exp(seg_i)
    prev = state_ref[...]                          # [N, P]
    y = y + jnp.dot(c, prev,
                    preferred_element_type=jnp.float32) * jnp.exp(seg)[:, None]

    # state update: state = exp(seg_last)*prev + sum_j b_j (xdt_j)^T decay_j
    wj = jnp.exp(seg[-1] - seg)                    # [q]
    st_c = jnp.dot((b * wj[:, None]).T, xdt,
                   preferred_element_type=jnp.float32)         # [N, P]
    state_ref[...] = prev * jnp.exp(seg[-1]) + st_c

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _fin():
        fin_ref[0] = state_ref[...].astype(fin_ref.dtype)


def ssd_chunk_fused(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, *, chunk: int = 256,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: [BH, S, P]; dt: [BH, S]; a: [BH]; b/c: [BH, S, N].

    Returns (y [BH, S, P], final_state [BH, N, P]).
    """
    bh, s, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, fin = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),             # a
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),   # x
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),     # dt
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # b
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # c
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),   # y
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),   # final state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, x, dt, b, c)
    return y, fin


def hbm_bytes_fused(bh: int, s: int, p: int, n: int,
                    in_bytes: int = 2) -> int:
    """Cost model: streamed operands only (x, dt, b, c in; y out; state
    negligible) -- the §Perf B1.3 napkin."""
    return bh * s * (2 * p + 2 * n + 1) * in_bytes + bh * n * p * 4
