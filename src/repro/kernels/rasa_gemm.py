"""RASA-scheduled tiled GEMM as a Pallas TPU kernel.

This is the TPU adaptation of the paper's matrix engine (DESIGN.md §3).
The MXU *is* a weight-stationary systolic array; what RASA controls on a
CPU -- when the stationary operand is (re)loaded and how consecutive
``rasa_mm`` overlap -- is on TPU controlled by the *grid iteration order*
and the Pallas software pipeline:

  schedule="base"  grid (k, m, n), n innermost.  The B block changes on
                   every grid step: the "weight load" (HBM->VMEM copy of B)
                   is paid every time.  This is the BASE design: WL before
                   every rasa_mm.
  schedule="wlbp"  grid (k, n, m), m innermost.  For a fixed (k, n) the
                   B block is *revisited*; Pallas elides the copy -- the
                   compile-time analogue of the WLBP dirty-bit skip.  C is
                   streamed in/out per step (the register round-robin).
  schedule="wls"   grid (m, n, k), k innermost with an fp32 VMEM scratch
                   accumulator.  B blocks stream, but every copy is
                   prefetched by the double-buffered pipeline during the
                   previous step's compute -- the DB-WLS shadow-buffer
                   schedule.  Output-stationary: C written once.

Block sizes (bm, bk, bn) are the "tile register" dims; on TPU they are
bounded by VMEM instead of eight 1 KB registers, and must be multiples of
the MXU/VREG tiling (128 lanes; 16 sublanes for bf16).  The `dm` analogue
(two MACs per PE with a merge) corresponds to doubling bk at half the bm
grid -- exposed simply as block-shape tuning here.

The `schedule_cost` model mirrors core/timing.py at the DMA level and is
used by the perf loop for napkin math before each change.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import _CompilerParams

SCHEDULES = ("base", "wlbp", "wls")


@dataclasses.dataclass(frozen=True)
class GemmBlocks:
    bm: int = 256
    bk: int = 512
    bn: int = 256

    def vmem_bytes(self, in_dtype_bytes: int = 2) -> int:
        """Working set per pipeline stage (x2 when double buffered)."""
        return (self.bm * self.bk * in_dtype_bytes
                + self.bk * self.bn * in_dtype_bytes
                + self.bm * self.bn * 4)


# --------------------------------------------------------------------------
# kernel bodies
# --------------------------------------------------------------------------

def _accum_kernel(c_in_ref, a_ref, b_ref, o_ref):
    """C-streaming body (base / wlbp): o = c_in + a @ b.

    Each pallas_call covers ONE k-chunk (the T_K reduction that maps onto
    the array in a single rasa_mm); chaining across k-chunks happens at the
    JAX level through the C buffer -- the analogue of streaming partial
    sums through the C tile register between rasa_mm instructions.  Cross-
    grid-step accumulation through aliased HBM is deliberately avoided: it
    would race with the double-buffered pipeline on real hardware.
    """
    o_ref[...] = (c_in_ref[...].astype(jnp.float32)
                  + jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
                  ).astype(o_ref.dtype)


def _scratch_kernel(a_ref, b_ref, c_in_ref, o_ref, acc_ref, *, k_axis: int):
    """Output-stationary body (wls): accumulate in VMEM scratch; write once."""
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_in_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(k_axis) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# --------------------------------------------------------------------------
# pallas_call assembly
# --------------------------------------------------------------------------

def _ws_call(a: jax.Array, b: jax.Array, c: jax.Array, schedule: str,
             blocks: GemmBlocks, out_dtype, interpret: bool) -> jax.Array:
    """One weight-stationary pallas_call over a single k-chunk.

    base: grid (m, n) with n innermost -- the B block changes every step
          (WL paid per rasa_mm).
    wlbp: grid (n, m) with m innermost -- the B block is revisited across
          the whole m sweep; Pallas elides the copy (the WL skip).
    """
    m, k = a.shape
    n = b.shape[1]
    bm, bk, bn = blocks.bm, blocks.bk, blocks.bn
    assert k == bk, "one WS call covers exactly one k-chunk"
    mt, nt = m // bm, n // bn
    if schedule == "base":
        grid = (mt, nt)
        a_spec = pl.BlockSpec((bm, bk), lambda i, j: (i, 0))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j: (0, j))
        c_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    else:  # wlbp
        grid = (nt, mt)
        a_spec = pl.BlockSpec((bm, bk), lambda j, i: (i, 0))
        b_spec = pl.BlockSpec((bk, bn), lambda j, i: (0, j))
        c_spec = pl.BlockSpec((bm, bn), lambda j, i: (i, j))
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[c_spec, a_spec, b_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        input_output_aliases={0: 0},
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(c, a, b)


def rasa_gemm(a: jax.Array, b: jax.Array, c: jax.Array | None = None,
              *, schedule: str = "wls", blocks: GemmBlocks | None = None,
              out_dtype: jnp.dtype = jnp.float32,
              interpret: bool = False) -> jax.Array:
    """C (+)= A @ B with a RASA-scheduled Pallas kernel.

    a: [M, K], b: [K, N], optional c: [M, N] accumulator input.
    Shapes must be multiples of the block dims (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    blocks = blocks or default_blocks(m, k, n)
    bm, bk, bn = blocks.bm, blocks.bk, blocks.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (f"shape ({m},{k},{n}) not divisible by blocks {blocks}; "
         f"use ops.rasa_matmul which pads")
    mt, nt, kt = m // bm, n // bn, k // bk
    if c is None:
        c = jnp.zeros((m, n), out_dtype)
    else:
        c = c.astype(out_dtype)

    if schedule == "wls":
        # output-stationary fused reduction: grid (m, n, k), k innermost,
        # fp32 scratch accumulator, C written exactly once.
        grid = (mt, nt, kt)
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
        return pl.pallas_call(
            functools.partial(_scratch_kernel, k_axis=2),
            grid=grid,
            in_specs=[a_spec, b_spec, c_spec],
            out_specs=c_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(a, b, c)

    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; one of {SCHEDULES}")

    # base / wlbp: weight-stationary; k-chunks chained through the C buffer
    # (the C tile-register stream), one pallas_call per chunk.
    out = c
    for kk in range(kt):
        out = _ws_call(a[:, kk * bk:(kk + 1) * bk],
                       b[kk * bk:(kk + 1) * bk, :],
                       out, schedule, blocks, out_dtype, interpret)
    return out


def default_blocks(m: int, k: int, n: int,
                   vmem_budget_bytes: int = 8 * 2**20) -> GemmBlocks:
    """Pick MXU-aligned blocks that fit the (double-buffered) VMEM budget."""
    def shrink(x, b):
        while b > 128 and x % b != 0:
            b //= 2
        return min(b, max(128, x))
    bm = shrink(m, 256)
    bk = shrink(k, 512)
    bn = shrink(n, 256)
    blocks = GemmBlocks(bm, bk, bn)
    while 2 * blocks.vmem_bytes() > vmem_budget_bytes and blocks.bk > 128:
        blocks = GemmBlocks(blocks.bm, blocks.bk // 2, blocks.bn)
    return blocks


# --------------------------------------------------------------------------
# DMA cost model (napkin math for the perf loop; mirrors core/timing.py)
# --------------------------------------------------------------------------

def schedule_cost(m: int, k: int, n: int, blocks: GemmBlocks,
                  schedule: str, in_bytes: int = 2, out_bytes: int = 4) -> dict:
    """Bytes moved HBM<->VMEM per schedule (the kernel-level roofline)."""
    mt, kt, nt = m // blocks.bm, k // blocks.bk, n // blocks.bn
    a_bytes = m * k * in_bytes
    b_bytes = k * n * in_bytes
    c_bytes = m * n * out_bytes
    if schedule == "base":
        # (k, m, n): A elided across n-inner; B refetched every step ("WL
        # before every rasa_mm"); C streamed in+out on every k pass.
        traffic = {"A": a_bytes, "B": b_bytes * mt, "C": 2 * c_bytes * kt}
    elif schedule == "wlbp":
        # (k, n, m): B elided across m-inner (the WL skip); A refetched per n.
        traffic = {"A": a_bytes * nt, "B": b_bytes, "C": 2 * c_bytes * kt}
    else:  # wls: (m, n, k) output-stationary, C written once
        traffic = {"A": a_bytes * nt, "B": b_bytes * mt, "C": 2 * c_bytes}
    total = sum(traffic.values())
    flops = 2 * m * k * n
    return {"schedule": schedule, "traffic_bytes": traffic,
            "total_bytes": total, "flops": flops,
            "arithmetic_intensity": flops / total}
