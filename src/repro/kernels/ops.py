"""Public jit'd wrappers around the Pallas kernels.

Handles: CPU fallback (interpret mode), padding to block multiples, GQA head
expansion, and batched (3D+) matmul via vmap-free reshapes.  Models call
these through ``repro.models.common.matmul`` so the engine is selectable per
config (``xla`` | ``pallas_rasa``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .rasa_gemm import GemmBlocks, default_blocks, rasa_gemm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("schedule", "blocks", "interpret",
                                             "out_dtype"))
def rasa_matmul(a: jax.Array, b: jax.Array, c: jax.Array | None = None,
                *, schedule: str = "wls", blocks: GemmBlocks | None = None,
                out_dtype: jnp.dtype = jnp.float32,
                interpret: bool | None = None) -> jax.Array:
    """C (+)= A @ B via the RASA-scheduled Pallas kernel, any 2D shapes.

    Pads to block multiples (zero padding is exact for matmul) and strips.
    ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    blocks = blocks or default_blocks(m, k, n)
    ap = _pad_to(a, (blocks.bm, blocks.bk))
    bp = _pad_to(b, (blocks.bk, blocks.bn))
    cp = None if c is None else _pad_to(c.astype(out_dtype),
                                        (blocks.bm, blocks.bn))
    out = rasa_gemm(ap, bp, cp, schedule=schedule, blocks=blocks,
                    out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_kv", "interpret"))
def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array,
              *, causal: bool = True, scale: float | None = None,
              block_q: int = 512, block_kv: int = 512,
              interpret: bool | None = None) -> jax.Array:
    """GQA flash attention: q [B,Hq,S,D], k/v [B,Hkv,S,D] -> [B,Hq,S,D].

    kv heads are broadcast to query groups; sequence dims padded to block
    multiples (padded kv positions masked out by causality for the padded
    query rows; padded q rows are stripped).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    # zero-padded kv positions are only sound when masked by causality
    assert causal or (sq % min(block_q, sq) == 0
                      and k.shape[2] % min(block_kv, k.shape[2]) == 0)
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)

    bq = min(block_q, max(128, 1 << (sq - 1).bit_length()))
    bkv = min(block_kv, max(128, 1 << (k.shape[2] - 1).bit_length()))
    qp = _pad_to(q, (1, 1, bq, 1))
    kp = _pad_to(k, (1, 1, bkv, 1))
    vp = _pad_to(v, (1, 1, bkv, 1))
    sqp, skvp = qp.shape[2], kp.shape[2]

    out = flash_attention(
        qp.reshape(b * hq, sqp, d),
        kp.reshape(b * hq, skvp, d),
        vp.reshape(b * hq, skvp, d),
        causal=causal, scale=scale, block_q=bq, block_kv=bkv,
        interpret=interpret)
    return out.reshape(b, hq, sqp, d)[:, :, :sq, :]
