"""Blockwise (flash) causal attention as a Pallas TPU kernel.

Used for the long-prefill shapes (prefill_32k): attention logits are never
materialized; running max / sum-of-exp / weighted accumulator live in VMEM
scratch across the kv-block loop.  The kv loop is the innermost grid axis,
so k/v block copies are prefetched by the Pallas pipeline during compute --
the same WLS-style overlap the RASA schedule uses for weights.

Layout: q [BH, Sq, D], k/v [BH, Skv, D] (batch*heads flattened; GQA handled
by the ops.py wrapper).  fp32 softmax state, output cast to q.dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, bq: int, bkv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bkv, d]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bkv]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                               # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)                   # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked kv blocks (upper triangle)
        pl.when(ki * bkv <= qi * bq + bq - 1)(body)
    else:
        body()

    @pl.when(ki == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, scale: float | None = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, D], k/v: [BH, Skv, D] -> [BH, Sq, D]."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, "ops.py pads to block multiples"
    if scale is None:
        scale = d ** -0.5

    grid = (bh, sq // bq, skv // bkv)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bkv=bkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
