"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth used by tests (assert_allclose over shape/dtype
sweeps) and by models when the Pallas engine is disabled.  Semantics mirror
the RASA PE datapath: bf16 (or given dtype) operands, fp32 accumulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(a: jax.Array, b: jax.Array,
               out_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """C = A @ B with fp32 accumulation (bf16-in/fp32-out PE semantics)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def ref_matmul_accum(a: jax.Array, b: jax.Array, c: jax.Array,
                     out_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """C += A @ B (the rasa_mm contract)."""
    return (c.astype(jnp.float32)
            + jnp.dot(a, b, preferred_element_type=jnp.float32)).astype(out_dtype)


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True, scale: float | None = None,
                  bias: jax.Array | None = None) -> jax.Array:
    """Multi-head attention oracle.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] with Hq % Hkv == 0 (GQA --
    kv heads are broadcast over query-head groups).  fp32 softmax.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if group > 1:
        kf = jnp.repeat(kf, group, axis=1)
        vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if bias is not None:
        logits = logits + bias
    if causal:
        skv = k.shape[2]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def ref_decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array | None = None,
                         scale: float | None = None) -> jax.Array:
    """Single-token decode attention oracle.

    q: [B, Hq, D]; caches: [B, Hkv, S, D]; lengths: [B] valid cache lengths
    (None = all valid).  Returns [B, Hq, D].
    """
    b, hq, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, group, d) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qf, kf)
    if lengths is not None:
        mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, vf)
    return out.reshape(b, hq, d).astype(q.dtype)
