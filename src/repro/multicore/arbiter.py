"""Unified span-based bandwidth arbitration: one fixed-point core.

The chip-scale analogue of RASA's fill/drain overlap is the epoch
bandwidth arbiter: time is sliced into scheduling epochs, every consumer
still drawing on the shared budget gets a share, and a consumer that
drains early returns its share to the survivors.  Two clients need that
relaxation -- the closed-batch :class:`repro.multicore.chip.CoreCluster`
(every core's stream fixed up front) and the open-arrival
:class:`repro.multicore.online.OnlineChip` (work arrives and departs at
epoch boundaries mid-run) -- and both are expressed here as the *same*
monotone fixed point over generic activity **spans** ``[start_epoch,
end_epoch)``: the closed batch is the special case "all spans start at
epoch 0", the online model staggers the starts.  This module is the single
implementation; neither client carries its own relaxation loop.

How the fixed point works
-------------------------
Each :class:`Span` is one consumer of the shared budget.  A relaxation
round (:meth:`SpanArbiter.relax`) builds the per-epoch share schedule from
the current spans, asks the client to (re-)simulate every span whose
*visible* schedule changed, reads back the epoch of each span's last
granted access, and shrinks the span's end to it.  Shrinking spans only
ever *raise* later epochs' shares, shares pointwise-raised only move
grants earlier, so the ends decrease monotonically until the fixed point
(typically 2-4 rounds, capped at :data:`MAX_ARBITER_ROUNDS`).

Three skip rules keep the relaxation cheap.  The closed-batch client runs
its reference backend fully skip-free (``oracle=True``) to validate them;
the online client's reference backend disables only the unthrottled skip
(``unthrottled_skip=False``) and keeps the two deterministically-safe
rules -- its oracle property is instead pinned by the prefix-cache on/off
identity and closed-vs-online equivalence suites:

* **visible-schedule skip** -- a span only observes its share prefix plus
  its tail; results are deterministic in that visible schedule, so a span
  whose visible schedule did not change since its last simulation is not
  re-simulated (counted per round in :attr:`ArbiterTrace.skipped`).
* **unthrottled skip** -- a span the arbiter never delayed runs
  identically under any pointwise-larger schedule; within one relaxation
  rounds only raise shares, so its result is final.
* **settled-fact skip** -- events at epoch ``t`` move shares only in
  epochs ``>= t``, so a span that drained at or before ``dirty_from`` can
  never change again (the open-arrival client's causality argument).

Prefix caching
--------------
The arbiter keeps the per-epoch active-weight sums persistently.  A
relaxation with ``dirty_from = d`` recomputes the schedule only from
epoch ``d`` on -- everything below ``d`` is a settled fact (**invariant**:
no event at epoch ``>= d`` can move a share in an epoch ``< d``, and no
span's end ever shrinks below ``d`` during the relaxation, because shares
below ``d`` are exactly what they were when those grants settled).  This,
plus the clients pruning retired spans out of the span list, is what makes
thousand-request online traces tractable: per-settle work scales with the
*active* spans and the dirty suffix, not with the whole history.
``prefix_cache=False`` keeps the rebuild-from-epoch-0 behavior as the
benchmark baseline (``benchmarks/online_scaling.py``).

Share policies
--------------
Epoch shares are weighted: span *i* active in epoch *e* is granted
``budget * w_i / W(e)`` bytes/cycle, where ``W(e)`` sums the active spans'
weights -- so per-epoch grants always sum to exactly the budget
(conservation by construction).  The :class:`SharePolicy` maps a span's
measured demand to its weight:

* ``equal`` -- every span weighs 1: the classic ``budget / n_active(e)``
  equal split.
* ``demand`` -- weight proportional to the span's unthrottled bytes/cycle
  demand: bandwidth-hungry consumers get more, nearly-compute-bound ones
  stop hoarding share their token bucket would never spend.

Policies plug into :class:`~repro.multicore.chip.ChipConfig` via
``share_policy`` and land once, here, for both clients.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

#: relaxation-round cap; the monotone iteration converges in a handful of
#: rounds, this only guards pathological streams.
MAX_ARBITER_ROUNDS = 32


# --------------------------------------------------------------------------
# share policies
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SharePolicy:
    """Maps a span's measured demand to its arbitration weight.

    Span *i*'s share in epoch *e* is ``budget * w_i / W(e)`` over the
    active spans' weight sum ``W(e)``; the weights are fixed per span for
    the whole relaxation (a weight that moved with the schedule would
    break the monotonicity argument).  The base class is the equal-share
    policy: every span weighs 1.
    """

    name: str = "equal"

    #: does this policy need the client to measure per-span demand
    #: (unthrottled bytes/cycle)?  Equal shares do not, so clients skip
    #: the extra unthrottled probe entirely.
    needs_demand: bool = False

    def weight(self, demand: float) -> float:
        return 1.0


@dataclasses.dataclass(frozen=True)
class DemandWeightedShare(SharePolicy):
    """Weights proportional to unthrottled bytes/cycle demand.

    ``floor`` keeps every active span schedulable (a zero weight would
    starve a span that still has traffic); demands below it are clamped.
    Because shares are normalized by the active weight sum, per-epoch
    grants still sum to exactly the budget -- the conservation property is
    policy-independent.
    """

    name: str = "demand"
    needs_demand: bool = True
    floor: float = 1e-3

    def weight(self, demand: float) -> float:
        return max(float(demand), self.floor)


SHARE_POLICIES = ("equal", "demand")


def get_share_policy(policy: "str | SharePolicy") -> SharePolicy:
    """Resolve a policy name (see :data:`SHARE_POLICIES`) or instance."""
    if isinstance(policy, SharePolicy):
        return policy
    if policy == "equal":
        return SharePolicy()
    if policy == "demand":
        return DemandWeightedShare()
    raise ValueError(f"unknown share policy {policy!r}; "
                     f"available: {SHARE_POLICIES}")


# --------------------------------------------------------------------------
# spans and the relaxation trace
# --------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class Span:
    """One consumer's activity on the shared budget (identity-hashed).

    ``start``/``end`` are absolute epochs bounding the half-open interval
    during which the consumer draws on the budget; ``end=None`` means
    "active indefinitely" -- the relaxation's opening assumption for any
    span whose drain epoch is not yet known.  ``last_grant`` and
    ``throttled`` are written by the client's simulation callback:
    ``last_grant`` is the start time of the consumer's last granted
    access in cycles *local to its start boundary* (the closed batch
    starts at epoch 0, so local == absolute there).
    """

    start: int
    end: int | None = None
    demands: bool = True
    weight: float = 1.0
    last_grant: float = 0.0
    throttled: bool = True
    _vis: tuple | None = dataclasses.field(default=None, repr=False)
    _stamp: int = dataclasses.field(default=-1, repr=False)


@dataclasses.dataclass(frozen=True)
class ArbiterTrace:
    """Per-epoch outcome of one arbitration fixed point."""

    epoch_cycles: float
    #: bytes/cycle granted per unit weight, per epoch.  Under the equal
    #: policy every active consumer weighs 1, so this is exactly the
    #: bytes/cycle each active consumer receives (``budget / n_active``);
    #: under weighted policies consumer *i* receives ``shares[e] * w_i``.
    shares: tuple[float, ...]
    #: number of consumers still drawing on the budget, per epoch
    n_active: tuple[int, ...]
    #: relaxation rounds until the activity spans converged
    rounds: int
    #: per relaxation round, how many spans were *not* re-simulated
    #: because one of the skip rules applied (see module docs); the
    #: skip-free oracle records zeros.
    skipped: tuple[int, ...] = ()


#: the client's simulation callback: for each ``(span_index, share_prefix,
#: tail_share)`` job, simulate that span's consumer under the visible
#: schedule (``share_prefix`` is local to the span's start boundary) and
#: write ``spans[i].last_grant`` / ``spans[i].throttled``.
SimulateFn = Callable[[Sequence[tuple[int, tuple, float]]], None]


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------

class SpanArbiter:
    """The monotone fixed-point relaxation over activity spans.

    One instance per arbitration context: the closed-batch cluster builds
    a fresh one per ``run_streams`` call, the online chip keeps one for
    the lifetime of the run (its settled-prefix cache is the scalability
    mechanism).  ``oracle=True`` disables the visible-schedule and
    unthrottled skips so the reference backend stays a literal,
    skip-free oracle the fast paths are validated against.
    """

    def __init__(self, budget: float, epoch_cycles: float,
                 policy: "str | SharePolicy" = "equal", *,
                 oracle: bool = False, unthrottled_skip: bool = True,
                 prefix_cache: bool = True,
                 max_rounds: int = MAX_ARBITER_ROUNDS,
                 budget_factors: Sequence[float] = ()):
        if not budget > 0:
            raise ValueError("budget must be > 0")
        if not epoch_cycles > 0:
            raise ValueError("epoch_cycles must be > 0")
        self.budget = budget
        self.epoch_cycles = epoch_cycles
        self.policy = get_share_policy(policy)
        #: per-epoch budget multipliers (thermal/bandwidth derating);
        #: epoch ``e`` distributes ``budget * budget_factors[e]`` among its
        #: active spans, epochs beyond the array run at the full budget.
        #: Trailing 1.0s are trimmed so a no-op plan is exactly ().
        fac = tuple(float(f) for f in budget_factors)
        while fac and fac[-1] == 1.0:
            fac = fac[:-1]
        if any(not (0.0 < f <= 1.0) for f in fac):
            raise ValueError("budget_factors must all be in (0, 1]: a zero "
                             "or negative epoch budget would starve the "
                             "token bucket")
        self.budget_factors = fac
        self.oracle = oracle
        #: the unthrottled skip may be disabled on its own (the online
        #: reference backend keeps the always-safe visible-schedule skip
        #: but re-simulates throttled spans every round)
        self.unthrottled_skip = unthrottled_skip
        self.prefix_cache = prefix_cache
        self.max_rounds = max_rounds
        #: settled per-epoch active-weight sums / active counts (the
        #: prefix cache; epochs below the last relax's ``dirty_from``
        #: are never recomputed)
        self._wsum: list[float] = []
        self._nact: list[int] = []
        self._stamp = 0
        #: cumulative relaxation rounds across relax() calls
        self.rounds_total = 0

    # -- schedule state ----------------------------------------------------
    @property
    def share_trace(self) -> tuple[float, ...]:
        """Converged bytes/cycle per unit weight, per epoch.

        Epochs with no active demanding span report ``0.0``: nothing is
        flowing, so rendering the full budget there (as the pre-fix code
        did) painted fully-idle epochs as fully-shared in
        ``ChipReport.share_trace`` and the Perfetto counter tracks.
        """
        b = self.budget
        fac = self.budget_factors
        if not fac:
            return tuple(b / w if w else 0.0 for w in self._wsum)
        nf = len(fac)
        return tuple((b * fac[e] if e < nf else b) / w if w else 0.0
                     for e, w in enumerate(self._wsum))

    @property
    def active_trace(self) -> tuple[int, ...]:
        return tuple(self._nact)

    @property
    def settled_horizon(self) -> int:
        """Number of epochs the settled schedule covers.  Relaxing with
        ``dirty_from`` at this horizon keeps the whole cached prefix -- the
        no-share-moved case (e.g. a zero-traffic arrival)."""
        return len(self._wsum)

    def _rebuild(self, spans: Sequence[Span], d: int) -> None:
        """Recompute the weight/active arrays for epochs >= ``d``.

        Difference-array sweep over the spans overlapping ``[d, horizon)``;
        the prefix below ``d`` is kept verbatim (see module docs for why
        it can never change).  ``end=None`` spans fill through the horizon
        -- beyond it they run at their tail share.

        With ``prefix_cache=False`` this is instead the literal
        pre-refactor rebuild -- every epoch re-derived from every span,
        from epoch 0, every round -- kept as the measured baseline of
        ``benchmarks/online_scaling.py`` (same values, quadratically more
        work on long traces).

        Measured-weight policies (``needs_demand``) always use the fresh
        per-epoch fold, in span-list order, even with the prefix cache on:
        the difference-array running sum accumulates float weights in
        span-*event* order with ``+w``/``-w`` cancellations, which is not
        bit-reproducible by any fixed-order reduction (and therefore not
        by the jitted whole-trace program, ``repro.multicore.jitarb``).
        The fold keeps ``prefix_cache`` on/off and jitted/incremental all
        bit-identical; equal shares keep the O(spans + width) sweep (unit
        weights make the running sum exact in any order).
        """
        horizon = d
        for s in spans:
            if s.demands and s.end is not None and s.end > horizon:
                horizon = s.end

        def fold(lo: int, hi: int) -> None:
            for e in range(lo, hi):
                w, n = 0.0, 0
                for s in spans:
                    if s.demands and s.start <= e and (s.end is None
                                                       or s.end > e):
                        w += s.weight
                        n += 1
                self._wsum.append(w)
                self._nact.append(n)

        if not self.prefix_cache:
            self._wsum, self._nact = [], []
            fold(0, horizon)
            return
        if self.policy.needs_demand:
            del self._wsum[d:]
            del self._nact[d:]
            while len(self._wsum) < d:
                self._wsum.append(0.0)
                self._nact.append(0)
            fold(d, horizon)
            return
        width = horizon - d
        dw = [0.0] * (width + 1)
        dn = [0] * (width + 1)
        for s in spans:
            if not s.demands:
                continue
            lo = max(s.start, d)
            hi = horizon if s.end is None else s.end
            if hi <= lo:
                continue
            dw[lo - d] += s.weight
            dw[hi - d] -= s.weight
            dn[lo - d] += 1
            dn[hi - d] -= 1
        del self._wsum[d:]
        del self._nact[d:]
        while len(self._wsum) < d:
            # idle gap between the settled horizon and the first event
            # epoch: nothing was active there
            self._wsum.append(0.0)
            self._nact.append(0)
        w, n = 0.0, 0
        for k in range(width):
            w += dw[k]
            n += dn[k]
            self._wsum.append(w)
            self._nact.append(n)

    def _visible(self, s: Span, w_forever: float) -> tuple[tuple, float]:
        """A span's visible schedule: its local share prefix plus tail.

        Monotonicity keeps every grant inside the prefix, so this is all
        the simulation can observe.  For a still-open span the tail is its
        weighted split of the budget among the open spans (the opening
        round's everyone-active-forever assumption); for a closed span the
        tail is the full budget -- by construction every other span has
        drained beyond its horizon.

        With ``budget_factors`` (thermal derating) the per-epoch budget is
        ``b * f(e)``; an open span's prefix is extended through the whole
        derate window so every derated epoch carries its exact factor --
        the tails stay factor-free, which keeps the schedules pointwise
        rising across rounds (the derate window lives entirely inside the
        prefix, where monotonicity is argued epoch-by-epoch).
        """
        b = self.budget
        wsum = self._wsum
        fac = self.budget_factors
        if not fac:
            if s.end is None:
                prefix = tuple(b * s.weight / wsum[e] if wsum[e] else b
                               for e in range(s.start, len(wsum)))
                return prefix, b * s.weight / w_forever
            prefix = tuple(b * s.weight / wsum[e] if wsum[e] else b
                           for e in range(s.start, s.end))
            return prefix, b
        nf = len(fac)
        nw = len(wsum)

        def share(e: int) -> float:
            be = b * fac[e] if e < nf else b
            # beyond the built horizon only the still-open spans are
            # active: their weight sum is exactly w_forever
            w = wsum[e] if e < nw else w_forever
            return be * s.weight / w if w else be

        if s.end is None:
            hi = max(nw, nf)
            return (tuple(share(e) for e in range(s.start, hi)),
                    b * s.weight / w_forever)
        return tuple(share(e) for e in range(s.start, s.end)), b

    # -- the fixed point ---------------------------------------------------
    def relax(self, spans: Sequence[Span], simulate: SimulateFn,
              dirty_from: int = 0, collect_trace: bool = True
              ) -> ArbiterTrace:
        """Relax the share schedule over ``spans`` to its fixed point.

        ``spans`` are the consumers whose activity may still change --
        the closed batch passes every core, the online client only its
        non-retired segments (retired spans' contributions live on in the
        settled prefix).  Dirty spans must arrive with ``end=None``
        ("active indefinitely": pointwise-minimal shares, the monotone
        iteration's safe starting point).  ``dirty_from`` is the earliest
        epoch any share may move; the settled prefix below it is reused
        (unless ``prefix_cache=False``, which recomputes from epoch 0 --
        same values, linearly more work).

        ``simulate`` is called once per round with the batch of spans
        needing (re-)simulation; it must set each span's ``last_grant``
        and ``throttled``.  Returns the converged :class:`ArbiterTrace`
        covering the *full* schedule (settled prefix included) --
        ``collect_trace=False`` skips materializing the O(horizon) share/
        active tuples for callers that only need the round counts (the
        online client's per-settle hot path; its trace queries read the
        arbiter's properties on demand instead).
        """
        d = dirty_from if self.prefix_cache else 0
        self._stamp += 1
        stamp = self._stamp
        skipped: list[int] = []
        rounds = 0
        for rounds in range(1, self.max_rounds + 1):
            self._rebuild(spans, d)
            w_forever = sum(s.weight for s in spans
                            if s.demands and s.end is None)
            jobs: list[tuple[int, tuple, float]] = []
            for i, s in enumerate(spans):
                if not s.demands:
                    # schedule-independent: no shared traffic at all --
                    # one simulation under the plain port model suffices
                    # (the oracle re-runs it, staying literal)
                    if s._stamp < 0 or self.oracle:
                        jobs.append((i, (), math.inf))
                    continue
                if s.end is not None and s.end <= d and s._stamp >= 0:
                    continue            # settled fact
                vis = self._visible(s, w_forever)
                unthrottled = (self.unthrottled_skip and not self.oracle
                               and s._stamp == stamp and not s.throttled)
                if self.oracle or s._stamp < 0 or (s._vis != vis
                                                   and not unthrottled):
                    jobs.append((i, vis[0], vis[1]))
            skipped.append(len(spans) - len(jobs))
            if jobs:
                # the callback may diff a span's previous visible schedule
                # (``_vis``) against the new one -- e.g. to resume from a
                # snapshot below the first changed epoch -- so ``_vis`` is
                # updated only after the simulations ran.
                simulate(jobs)
                for i, prefix, tail in jobs:
                    spans[i]._vis = (prefix, tail)
                    spans[i]._stamp = stamp
            E = self.epoch_cycles
            converged = True
            for s in spans:
                if not s.demands:
                    e = s.start
                else:
                    e = s.start + int(s.last_grant // E) + 1
                    if s.end is not None and s.end < e:
                        e = s.end
                if e != s.end:
                    s.end = e
                    converged = False
            if converged:
                break
        self.rounds_total += rounds
        return ArbiterTrace(epoch_cycles=self.epoch_cycles,
                            shares=self.share_trace if collect_trace else (),
                            n_active=self.active_trace if collect_trace
                            else (),
                            rounds=rounds, skipped=tuple(skipped))


def build_share_schedule(spans: Sequence[tuple[int, int | None]],
                         budget: float) -> tuple[list[float], list[int]]:
    """Per-epoch ``(share, n_active)`` from equal-weight activity spans.

    The standalone (non-relaxing) form of the engine's schedule builder,
    kept for direct inspection and tests: ``spans[i]`` is the half-open
    epoch interval ``[start, end)`` during which consumer *i* draws on
    ``budget`` (``end=None`` = active indefinitely), and epoch *e*'s share
    is ``budget / n_active(e)`` up to the largest finite end.
    """
    horizon = max((e for _, e in spans if e is not None), default=0)
    shares, n_active = [], []
    for e in range(horizon):
        n = sum(1 for s, h in spans if s <= e and (h is None or h > e))
        shares.append(budget / n if n else budget)
        n_active.append(n)
    return shares, n_active
