"""Whole-trace jitted span arbitration: an online serving run as ONE XLA
program.

The incremental client (:class:`repro.multicore.online.OnlineChip`) walks
an arrival trace on the host: every start boundary marks the in-flight
spans dirty, relaxes the share fixed point, and re-simulates dirty
segments -- with ``backend="jax"`` one batched scan per relaxation round.
For very long traces the remaining host work (the boundary event loop and
one device dispatch per round) dominates.  This module lowers that *whole
loop* into a single ``lax.while_loop`` program:

* the arbiter's **settled-prefix cache is a carried array**: ``wsum[e]``
  holds the per-epoch active-weight sums, ``nw`` (the settled horizon) and
  ``dirty_from`` are data, and each settle rewrites only the
  ``[dirty_from, horizon)`` window via ``dynamic_update_slice`` -- the
  literal array form of the incremental rebuild;
* **retired spans are masked, not pruned**: each core lane carries only
  its *current* segment (a replaced segment's end always precedes every
  later boundary, so it is a settled fact -- the same causality argument
  the host client's retirement rests on), and its contribution lives on
  in the carried prefix;
* the host client's **snapshot cache is a carried array too**: every
  relaxation re-sim records the 15-slot timing carry at each
  ``_BLOCK``-instruction boundary, and later rounds resume from the
  deepest snapshot whose ``last_grant`` precedes the dirty boundary.
  Such a carry is fully determined by grants in the settled prefix
  (``bt <= last_grant`` is a step invariant, and engine-side pipeline
  state depends on the schedule only through grant times), so resuming
  from it is bit-exact -- and each round costs the dirty *suffix*, not
  the whole trace;
* the outer ``while_loop`` replays the boundary event loop (per-core
  candidate = max(next arrival, core-free epoch); all cores sharing the
  minimal boundary start together), and an inner ``while_loop`` runs the
  relaxation rounds, each round re-simulating the non-settled lanes with
  a block-chunked vmapped :func:`repro.core.fastsim._sim_chunk_fn` scan.

**Domain.** The program covers the serving batcher's ``fixed`` admission
policy with ``batch_size=1`` on a homogeneous fault-free chip under
``share_policy="equal"`` -- the regime where the weight sums are integer
counts (exact in any summation order) and admission degenerates to
"assign request *r* of the arrival-sorted order to core ``r % n_cores``".
:func:`plan` returns ``None`` outside this domain and callers fall back
to the incremental client; inside it, results are **bit-identical** to
the numpy oracle (pinned by ``tests/test_online_jax.py`` and asserted at
scale by ``benchmarks/online_scaling.py``):

* the per-instruction scan is the shared ``sim_chunk`` program (bit-exact
  with the numpy token bucket);
* every share is the same expression numpy evaluates
  (``budget / wsum[e]``, tails ``budget / w_forever`` open and ``budget``
  closed), and with the power-of-two ``epoch_cycles`` all boundary
  arithmetic (``floor(last_grant / E)``, ``ceil(finish / E)``) is exact;
* skip rules only avoid re-simulating values that could not change
  (settled spans are frozen, resumes replay the settled prefix's exact
  state), so the program walks the *same* end-estimate trajectory to the
  same fixed point as the host relaxation.

Since everything dynamic enters as arrays, arrival traces ``vmap``: an
arrival-rate sweep runs as one device launch (:func:`finish_times_many`,
demonstrated by ``benchmarks/serving_batch.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

from ..core.designs import EngineConfig
from ..core.fastsim import _design_scalars, _pow2, has_jax, run_segment
from ..core.isa import NUM_TREGS
from ..core.tiling import GemmSpec
from ..core.trace import OP_NOP, CompiledTrace, compiled_trace
from .arbiter import MAX_ARBITER_ROUNDS
from .chip import ChipConfig, demands_bandwidth, stream_model_params

__all__ = ["plan", "plan_many", "finish_times", "finish_times_many", "Plan"]

#: snapshot granularity of the in-program resume cache (instructions per
#: simulated block); trace columns are padded to a multiple of this
_BLOCK = 64


# --------------------------------------------------------------------------
# host-side planning
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """Host-precomputed arrays for one (or many) kernel launches.

    Everything the kernel needs that depends only on the *chip and the
    request shapes* is shared; the per-trace arrays (arrivals, queue
    assignment, trace ids) are what an arrival-rate sweep maps over.
    """

    chip: ChipConfig
    engine: EngineConfig
    cols: tuple                 # 7 stacked trace columns, each [U, L]
    tr_len: np.ndarray          # [U] i32 true (unpadded) trace lengths
    arrival: np.ndarray         # [N] f64 arrival epochs (sorted order)
    qidx: np.ndarray            # [C, maxQ] i32 sorted ranks per core
    qlen: np.ndarray            # [C] i32
    tid_of: np.ndarray          # [N] i32 trace id per sorted rank
    order: np.ndarray           # [N] caller index per sorted rank
    S: int                      # share-window epochs (>= max span length)
    H: int                      # carried-schedule epochs
    maxq: int


def _uniform_specs(chip: ChipConfig) -> bool:
    head = chip.core_specs[0]
    return all(cs == head for cs in chip.core_specs)


def _stack_cols(traces: Sequence[CompiledTrace], length: int) -> tuple:
    padded = [t.padded(length) for t in traces]
    return tuple(
        np.stack([(tr.opcode, tr.r_dst, tr.r_a, tr.r_b, tr.nbytes, tr.tm,
                   tr.reusable)[f] for tr in padded])
        for f in range(7))


def plan(traffic: Sequence[tuple[int, Sequence[GemmSpec]]],
         chip: ChipConfig) -> Plan | None:
    """Precompute the kernel inputs for one arrival trace.

    ``traffic`` is ``(arrival_epoch, specs)`` per request, in caller
    order.  Returns ``None`` when the trace or chip falls outside the
    jitted program's domain (the caller then uses the incremental
    client); raising here would turn a routing decision into an error.
    """
    if not traffic or not has_jax():
        return None
    if chip.backend != "jax" or chip.arbitration != "epoch":
        return None
    if getattr(chip.share_policy, "name", "") != "equal":
        return None
    if chip.fault_plan is not None and not chip.fault_plan.is_empty:
        return None
    if not _uniform_specs(chip):
        return None
    E = chip.epoch_cycles
    if not (math.isfinite(E) and E > 0
            and math.log2(E).is_integer()):
        return None     # power-of-two epochs make t/E arithmetic exact
    budget = chip.bw_bytes_per_cycle
    if not math.isfinite(budget):
        return None

    spec0 = chip.core_specs[0]
    engine, policy = spec0.engine, spec0.policy
    C = chip.n_cores
    N = len(traffic)
    order_in = sorted(range(N), key=lambda i: traffic[i][0])

    keys: dict[tuple, int] = {}
    traces: list[CompiledTrace] = []
    tid_of = np.zeros(N, dtype=np.int32)
    arrival = np.zeros(N, dtype=np.float64)
    for r, i in enumerate(order_in):
        ep, specs = traffic[i]
        key = tuple(dataclasses.replace(s, name="") for s in specs)
        t = keys.get(key)
        if t is None:
            t = keys[key] = len(traces)
            traces.append(compiled_trace(key, policy))
        tid_of[r] = t
        arrival[r] = float(ep)
    for tr in traces:
        if len(tr) == 0 or not demands_bandwidth(chip, None, tr):
            return None     # zero-traffic segments take the host path

    # sound per-segment span bound: every relaxed share is >= budget / C
    # (at most C unit-weight spans are active), so a segment's epoch count
    # under any reachable schedule is bounded by its constant-min-share run
    lens = []
    for tr in traces:
        res, _, _ = run_segment(
            tr, engine, stream_model_params(chip, engine, (), E, budget / C))
        lens.append(int(res.cycles // E) + 2)
    l_max = max(lens)

    qlen = np.zeros(C, dtype=np.int32)
    for r in range(N):
        qlen[r % C] += 1
    maxq = int(qlen.max())
    qidx = np.full((C, max(1, maxq)), -1, dtype=np.int32)
    fill = np.zeros(C, dtype=np.int32)
    for r in range(N):
        c = r % C
        qidx[c, fill[c]] = r
        fill[c] += 1

    # an open span's visible prefix can reach the horizon set by another
    # lane, at most ~2 span lengths past its own start (see module docs)
    S = _pow2(2 * l_max + 4, lo=8)
    H = int(arrival.max()) + (maxq + 2) * l_max + S + 8
    L = -(-max(len(t) for t in traces) // _BLOCK) * _BLOCK
    return Plan(chip=chip, engine=engine, cols=_stack_cols(traces, L),
                tr_len=np.asarray([len(t) for t in traces],
                                  dtype=np.int32),
                arrival=arrival, qidx=qidx, qlen=qlen, tid_of=tid_of,
                order=np.asarray(order_in, dtype=np.int64),
                S=S, H=H, maxq=max(1, maxq))


# --------------------------------------------------------------------------
# the program
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _kernel(C: int, N: int, maxq: int, U: int, L: int, S: int, H: int,
            design: tuple, charge_store: bool, store_free: bool,
            max_rounds: int):
    """Build (jit, vmapped-jit) of the whole-trace program for one static
    shape/design signature.  Everything dynamic -- arrivals, queues,
    trace columns, the budget -- is a traced argument, so same-shape
    launches (an arrival sweep, a re-run) reuse the executable."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..core.fastsim import _B_CORES, _sim_chunk_fn

    lane_sim = jax.vmap(_sim_chunk_fn(False, False),
                        in_axes=(0, 0, None, None, _B_CORES))
    INF = jnp.inf
    NB = L // _BLOCK
    tree = jax.tree_util.tree_map

    def program(cols, tr_len, arrival, qidx, qlen, tid_of,
                E, budget, burst, inv_load, inv_store, packed=True):
        f64 = jnp.float64

        def fresh_carry():
            z = jnp.zeros((C,), f64)
            return (jnp.zeros((C, NUM_TREGS), f64),
                    jnp.full((C,), -1.0, f64), z, z, z,
                    jnp.zeros((C,), bool), z, z,
                    jnp.zeros((C,), jnp.int32), z, z, z, z,
                    jnp.full((C,), burst, f64), z)

        # In the single-trace kernel snapshots live in ONE flat f64 buffer
        # [C, NB+1, D]: a packed 15-element carry per block boundary, so
        # one concatenate + one scatter per simulated block replaces 15 of
        # each -- on CPU the per-block dispatch cost is what the resume
        # cache trades against.  bool/int32 fields roundtrip through f64
        # exactly.  The vmapped kernel keeps the 15-array tuple form:
        # batched scatters into one wide buffer lower to a slower generic
        # scatter than the per-field updates do.
        LG = NUM_TREGS + 11         # packed column of ``last_grant``

        def pack(cy):
            return jnp.concatenate(
                [cy[0]] + [(c if c.dtype == jnp.float64
                            else c.astype(f64))[:, None] for c in cy[1:]],
                axis=1)

        def unpack(p):
            R = NUM_TREGS

            def at(i):
                return p[:, R + i]

            return (p[:, :R], at(0), at(1), at(2), at(3), at(4) != 0.0,
                    at(5), at(6), at(7).astype(jnp.int32), at(8), at(9),
                    at(10), at(11), at(12), at(13))

        def blank_snaps():
            # snapshot slot k of lane l = the carry before block k; slot 0
            # is the fresh segment state, deeper slots start invalid (an
            # inf last_grant never precedes a dirty boundary)
            if packed:
                snaps = jnp.repeat(pack(fresh_carry())[:, None, :],
                                   NB + 1, axis=1)
                return snaps.at[:, 1:, LG].set(INF)
            snaps = tree(
                lambda a: jnp.repeat(a[:, None, ...], NB + 1, axis=1),
                fresh_carry())
            return snaps[:12] + (snaps[12].at[:, 1:].set(INF),) + snaps[13:]

        def reset_snaps(snaps, starts):
            if packed:
                return jnp.where(starts[:, None, None], blank_snaps(),
                                 snaps)
            return tree(
                lambda a, blank: jnp.where(
                    starts[:, None, None] if a.ndim == 3
                    else starts[:, None], blank, a),
                snaps, blank_snaps())

        def snap_lg(snaps):
            return snaps[:, :, LG] if packed else snaps[12]

        def snap_read(snaps, k0):
            if packed:
                return unpack(snaps[jnp.arange(C), k0])
            return tree(lambda a: a[jnp.arange(C), k0], snaps)

        def snap_write(snaps, b, act, carry):
            if packed:
                return snaps.at[:, b + 1].set(
                    jnp.where(act[:, None], pack(carry), snaps[:, b + 1]))
            return tree(
                lambda s, c: s.at[:, b + 1].set(
                    jnp.where(act[:, None] if c.ndim == 2 else act,
                              c, s[:, b + 1])),
                snaps, carry)

        def settle(wsum, nw, tid, cur, start, ends, lg, te, snaps, d, mxn,
                   p_sh, p_nsh, p_tail):
            """One arbiter settle: zero-fill the idle gap, then relax."""
            e_all = jnp.arange(H, dtype=f64)
            wsum = jnp.where((e_all >= nw) & (e_all < d), 0.0, wsum)
            live = tid >= 0
            need = live & jnp.isinf(ends)   # dirty or just-started spans
            tid_s = jnp.maximum(tid, 0)
            lane_cols = tuple(c[tid_s] for c in cols)       # [C, L]
            nblk = (tr_len[tid_s] + (_BLOCK - 1)) // _BLOCK  # [C]
            cutoff = (d - start) * E        # settled-time limit, per lane

            def resim(snaps, bucket, sim, fc):
                """Re-simulate the ``sim`` lanes under the current shares.

                A snapshot is reusable when every grant it has absorbed
                lies either in the settled prefix (frozen forever) or
                before the first epoch whose visible share differs from
                the lane's previous sim -- so each lane resumes from its
                deepest such snapshot instead of instruction zero."""
                lim = jnp.maximum(fc * E, cutoff)
                valid = snap_lg(snaps) < lim[:, None]        # [C, NB+1]
                k0 = jnp.max(jnp.where(valid,
                                       jnp.arange(NB + 1, dtype=jnp.int32),
                                       0), axis=1)           # [C]
                blo = jnp.min(jnp.where(sim, k0, NB + 1))
                bhi = jnp.max(jnp.where(sim, nblk, 0))
                carry = snap_read(snaps, k0)

                def block(bs):
                    b, carry, snaps = bs
                    act = sim & (k0 <= b) & (b < nblk)
                    off = b * _BLOCK
                    xs = tuple(
                        lax.dynamic_slice(cc, (jnp.zeros_like(off), off),
                                          (C, _BLOCK))
                        for cc in lane_cols)
                    idx = (off + jnp.arange(_BLOCK)).astype(f64)
                    new = lane_sim(carry, xs, idx, design, bucket)[0]
                    carry = tree(
                        lambda a, n: jnp.where(
                            act[:, None] if n.ndim == 2 else act, n, a),
                        carry, new)
                    snaps = snap_write(snaps, b, act, carry)
                    return b + 1, carry, snaps

                bF, carry, snaps = lax.while_loop(
                    lambda bs: bs[0] < bhi, block, (blo, carry, snaps))
                return carry[7], carry[12], snaps, bF - blo

            def round_body(st):
                (wsum, nw, ends, lg, te, r, _, mxn, snaps, blk,
                 p_sh, p_nsh, p_tail) = st
                closed = live & jnp.isfinite(ends)
                horizon = jnp.maximum(
                    d, jnp.max(jnp.where(closed, ends, d)))
                k = jnp.arange(S, dtype=f64)
                e = d + k                                       # [S]
                hi = jnp.where(jnp.isinf(ends), horizon, ends)  # [C]
                act = (live[:, None] & (start[:, None] <= e[None, :])
                       & (e[None, :] < hi[:, None]))
                win = jnp.sum(act, axis=0).astype(f64)
                wsum = lax.dynamic_update_slice(
                    wsum, win, (d.astype(jnp.int32),))
                open_ = live & jnp.isinf(ends)
                wf = jnp.sum(open_).astype(f64)
                n_sh = jnp.where(jnp.isinf(ends), horizon - start,
                                 ends - start)
                mxn = jnp.maximum(mxn,
                                  jnp.max(jnp.where(need, n_sh, 0.0)))
                n_sh = jnp.clip(n_sh, 0.0, float(S))
                tail = jnp.where(open_, budget / wf, budget)
                gidx = jnp.clip(
                    start[:, None].astype(jnp.int32)
                    + jnp.arange(S, dtype=jnp.int32)[None, :], 0, H - 1)
                shares = budget / wsum[gidx]                    # [C, S]
                bucket = (shares, n_sh, E, tail, burst, n_sh * E,
                          charge_store, store_free, inv_store, inv_load)
                # first epoch whose visible share differs from the lane's
                # previous sim: epochs below it replay identically, so an
                # unchanged lane is skipped outright (the host relaxation's
                # unchanged-visibility skip) and a changed one resumes from
                # its deepest snapshot before the divergence
                m = jnp.minimum(n_sh, p_nsh)
                diff = (k[None, :] < m[:, None]) & (shares != p_sh)
                fc = jnp.min(jnp.where(diff, k[None, :], INF), axis=1)
                cap = jnp.where((n_sh != p_nsh) | (tail != p_tail), m, INF)
                fc = jnp.minimum(fc, cap)
                sim = need & jnp.isfinite(fc)
                te_n, lg_n, snaps, nblks = resim(snaps, bucket, sim, fc)
                te = jnp.where(sim, te_n, te)
                lg = jnp.where(sim, lg_n, lg)
                sel = sim[:, None]
                p_sh = jnp.where(sel, shares, p_sh)
                p_nsh = jnp.where(sim, n_sh, p_nsh)
                p_tail = jnp.where(sim, tail, p_tail)
                e_new = start + jnp.floor(lg / E) + 1.0
                e_new = jnp.where(need, jnp.minimum(e_new, ends), ends)
                conv = jnp.all(e_new == ends)
                return (wsum, horizon, e_new, lg, te, r + 1, conv, mxn,
                        snaps, blk + nblks, p_sh, p_nsh, p_tail)

            st = (wsum, nw, ends, lg, te, jnp.int32(0),
                  jnp.asarray(False), mxn, snaps, jnp.int32(0),
                  p_sh, p_nsh, p_tail)
            st = lax.while_loop(
                lambda s: (~s[6]) & (s[5] < max_rounds), round_body, st)
            return (st[0], st[1], st[2], st[3], st[4], st[7], st[8],
                    st[5], st[9], st[10], st[11], st[12])

        def outer_body(c):
            (qhead, tid, cur, start, ends, lg, te, wsum, nw, finish,
             mxn, mxd, snaps, _, _, p_sh, p_nsh, p_tail) = c
            has_q = qhead < qlen
            alive = jnp.any(has_q)
            nxt = qidx[jnp.arange(C), jnp.minimum(qhead, maxq - 1)]
            nxt_s = jnp.clip(nxt, 0, N - 1)
            free = jnp.maximum(start, jnp.ceil((start * E + te) / E))
            free = jnp.where(tid >= 0, free, 0.0)
            b_c = jnp.where(has_q, jnp.maximum(free, arrival[nxt_s]), INF)
            bstar = jnp.min(b_c)
            starts = has_q & (b_c == bstar)
            tid2 = jnp.where(starts, tid_of[nxt_s], tid)
            cur2 = jnp.where(starts, nxt_s, cur)
            start2 = jnp.where(starts, bstar, start)
            ends2 = jnp.where(starts, INF, ends)
            lg2 = jnp.where(starts, 0.0, lg)
            te2 = jnp.where(starts, 0.0, te)
            qhead2 = qhead + starts.astype(qhead.dtype)
            snaps2 = reset_snaps(snaps, starts)
            # a fresh span has no previous sim: p_nsh = -1 forces a full
            # first simulation and invalidates every non-fresh snapshot
            p_nsh2 = jnp.where(starts, -1.0, p_nsh)
            p_tail2 = jnp.where(starts, -1.0, p_tail)
            # the boundary event reopens every span still active there
            ends2 = jnp.where((tid2 >= 0) & (ends2 > bstar), INF, ends2)
            (wsum2, nw2, ends2, lg2, te2, mxn2, snaps2, n_r, n_b,
             p_sh2, p_nsh2, p_tail2) = settle(
                wsum, nw, tid2, cur2, start2, ends2, lg2, te2, snaps2,
                bstar, mxn, p_sh, p_nsh2, p_tail2)
            slot = jnp.where(tid2 >= 0, cur2, N)
            finish2 = finish.at[slot].set(
                jnp.where(tid2 >= 0, start2 * E + te2, finish[slot]))
            mxd2 = jnp.maximum(mxd, bstar)
            new = (qhead2, tid2, cur2, start2, ends2, lg2, te2, wsum2,
                   nw2, finish2, mxn2, mxd2, snaps2,
                   c[13] + n_r, c[14] + n_b, p_sh2, p_nsh2, p_tail2)
            # vmapped launches batch the while_loop: keep dead lanes'
            # state bit-frozen so their carried schedule stays settled
            return tree(lambda a, b: jnp.where(alive, a, b), new, c)

        z = jnp.zeros((C,), f64)
        c0 = (jnp.zeros(C, dtype=qlen.dtype),
              jnp.full((C,), -1, jnp.int32), jnp.zeros(C, jnp.int32),
              z, jnp.full((C,), -INF, f64), z, z,
              jnp.zeros((H,), f64), jnp.asarray(0.0, f64),
              jnp.zeros((N + 1,), f64), jnp.asarray(0.0, f64),
              jnp.asarray(0.0, f64), blank_snaps(),
              jnp.int32(0), jnp.int32(0),
              jnp.zeros((C, S), f64), jnp.full((C,), -1.0, f64),
              jnp.full((C,), -1.0, f64))
        cF = lax.while_loop(lambda c: jnp.any(c[0] < qlen), outer_body, c0)
        return cF[9][:N], cF[10], cF[11], cF[13], cF[14]

    one = jax.jit(functools.partial(program, packed=True))
    many = jax.jit(jax.vmap(
        functools.partial(program, packed=False),
        in_axes=((None, None, 0, 0, 0, 0) + (None,) * 5)))
    return one, many


def _launch_args(p: Plan):
    params = stream_model_params(p.chip, p.engine)
    store_free = params.store_ports is None
    statics = (p.chip.n_cores, len(p.arrival), p.maxq, p.cols[0].shape[0],
               p.cols[0].shape[1], p.S, p.H, _design_scalars(p.engine),
               bool(params.charge_store_bytes), store_free,
               MAX_ARBITER_ROUNDS)
    scalars = (np.float64(p.chip.epoch_cycles),
               np.float64(p.chip.bw_bytes_per_cycle),
               np.float64(p.chip.bw_burst_bytes),
               np.float64(1.0 / params.load_ports),
               np.float64(1.0 / params.store_ports) if not store_free
               else np.float64(1.0))
    return statics, scalars


def _check(p: Plan, mxn: float, mxd: float) -> None:
    if mxn > p.S or mxd > p.H - p.S - 1:
        raise RuntimeError(
            f"jitted arbitration window bound violated (span epochs "
            f"{mxn} vs window {p.S}, boundary {mxd} vs schedule "
            f"{p.H - p.S - 1}): the host span bound is unsound here")


def finish_times(p: Plan, stats: dict | None = None) -> np.ndarray:
    """Run one planned trace; absolute finish cycles in caller order.

    When ``stats`` is given, the kernel's relaxation-round and
    simulated-block counters are recorded into it (benchmark diagnostics).
    """
    from jax.experimental import enable_x64

    statics, scalars = _launch_args(p)
    fn = _kernel(*statics)[0]
    with enable_x64():
        fin, mxn, mxd, n_r, n_b = fn(p.cols, p.tr_len, p.arrival, p.qidx,
                                     p.qlen, p.tid_of, *scalars)
        fin = np.asarray(fin)
        _check(p, float(mxn), float(mxd))
        if stats is not None:
            stats["rounds"] = int(n_r)
            stats["blocks"] = int(n_b)
    out = np.zeros(len(fin), dtype=np.float64)
    out[p.order] = fin
    return out


def finish_times_many(plans: Sequence[Plan]) -> list[np.ndarray]:
    """Run a family of same-shape plans (e.g. an arrival-rate sweep) as
    one vmapped launch.  All plans must come from :func:`plan_many`."""
    from jax.experimental import enable_x64

    head = plans[0]
    statics, scalars = _launch_args(head)
    fn = _kernel(*statics)[1]
    with enable_x64():
        fin, mxn, mxd, _, _ = fn(head.cols, head.tr_len,
                           np.stack([p.arrival for p in plans]),
                           np.stack([p.qidx for p in plans]),
                           np.stack([p.qlen for p in plans]),
                           np.stack([p.tid_of for p in plans]), *scalars)
        fin = np.asarray(fin)
        for p, x, d in zip(plans, np.asarray(mxn), np.asarray(mxd)):
            _check(p, float(x), float(d))
    outs = []
    for v, p in enumerate(plans):
        out = np.zeros(fin.shape[1], dtype=np.float64)
        out[p.order] = fin[v]
        outs.append(out)
    return outs


def plan_many(traffics: Sequence[Sequence[tuple[int, Sequence[GemmSpec]]]],
              chip: ChipConfig) -> list[Plan] | None:
    """Plan several arrival traces over the *same* request-shape universe
    so they share one executable (common trace table, window and horizon
    bounds).  Returns ``None`` if any variant falls outside the domain or
    the variants disagree on request count."""
    plans = [plan(t, chip) for t in traffics]
    if any(p is None for p in plans) or not plans:
        return None
    n = {len(p.arrival) for p in plans}
    if len(n) != 1:
        return None
    # unify shapes: same trace table, same S/H/maxq across variants
    key_of: dict[bytes, int] = {}
    all_cols: list[tuple] = []
    all_len: list[int] = []
    remap: list[np.ndarray] = []
    L = max(p.cols[0].shape[1] for p in plans)
    for p in plans:
        pad = L - p.cols[0].shape[1]
        ids = np.zeros(p.cols[0].shape[0], dtype=np.int32)
        for u in range(p.cols[0].shape[0]):
            row = tuple(
                np.concatenate([c[u], np.full(pad, OP_NOP if f == 0 else 0,
                                              dtype=c[u].dtype)])
                for f, c in enumerate(p.cols))
            sig = b"".join(np.ascontiguousarray(a).tobytes() for a in row)
            t = key_of.get(sig)
            if t is None:
                t = key_of[sig] = len(all_cols)
                all_cols.append(row)
                all_len.append(int(p.tr_len[u]))
            ids[u] = t
        remap.append(ids)
    cols = tuple(np.stack([tc[f] for tc in all_cols])
                 for f in range(7))
    tr_len = np.asarray(all_len, dtype=np.int32)
    S = max(p.S for p in plans)
    H = max(p.H for p in plans)
    maxq = max(p.maxq for p in plans)
    out = []
    for p, ids in zip(plans, remap):
        qidx = np.full((p.qidx.shape[0], maxq), -1, dtype=np.int32)
        qidx[:, :p.qidx.shape[1]] = p.qidx
        out.append(dataclasses.replace(
            p, cols=cols, tr_len=tr_len, tid_of=ids[p.tid_of],
            qidx=qidx, S=S, H=H, maxq=maxq))
    return out
