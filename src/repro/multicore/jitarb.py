"""Whole-trace jitted span arbitration: an online serving run as ONE XLA
program.

The incremental client (:class:`repro.multicore.online.OnlineChip`) walks
an arrival trace on the host: every start boundary marks the in-flight
spans dirty, relaxes the share fixed point, and re-simulates dirty
segments -- with ``backend="jax"`` one batched scan per relaxation round.
For very long traces the remaining host work (the boundary event loop and
one device dispatch per round) dominates.  This module lowers that *whole
loop* into a single ``lax.while_loop`` program:

* the arbiter's **settled-prefix cache is a carried array**: ``wsum[e]``
  holds the per-epoch active-weight sums over a *sliding window* of
  ``2 * S`` epochs anchored at ``max(0, boundary - S)`` -- every read and
  write a settle can make lands within ``S`` epochs of its boundary (the
  same span bound that sizes the share window), so the window slides
  forward monotonically, settled epochs spill off the left edge as
  immutable facts, and the carried state is O(S) regardless of trace
  length (100k-1M-request traces fit without an O(horizon) array);
* **retired spans are masked, not pruned**: each core lane carries only
  its *current* segment (a replaced segment's end always precedes every
  later boundary, so it is a settled fact -- the same causality argument
  the host client's retirement rests on), and its contribution lives on
  in the carried prefix;
* per-epoch weight sums are folded in the **host arbiter's span order**
  (start epoch, then core index -- the order ``_pump`` appends spans),
  one masked add per lane, so demand-weighted float weights accumulate
  in exactly the order ``SpanArbiter._rebuild``'s fresh per-epoch fold
  uses and grants stay bit-identical (equal shares reduce to the old
  integer counts, exact in any order);
* the host client's **snapshot cache is a carried array too**: every
  relaxation re-sim records the 15-slot timing carry at each
  ``_BLOCK``-instruction boundary, and later rounds resume from the
  deepest snapshot whose ``last_grant`` precedes the dirty boundary.
  Such a carry is fully determined by grants in the settled prefix
  (``bt <= last_grant`` is a step invariant, and engine-side pipeline
  state depends on the schedule only through grant times), so resuming
  from it is bit-exact -- and each round costs the dirty *suffix*, not
  the whole trace;
* **designs are per-lane data**: the simulate chunk is vmapped with the
  engine scalars and port rates on the lane axis, so heterogeneous core
  mixes (BASE cores next to RASA cores, per-core tiling policies) jit in
  the same executable -- and changing the design never recompiles;
* **admission runs inside the loop**: the serving batcher's reactive
  policies (``occupancy``/``bandwidth``/``predicted``) are replayed as
  carried scalars -- the program interleaves start boundaries with the
  host driver's decision epochs (next arrival, or the chip's next event
  while requests wait), recomputes headroom/occupancy/soonest-free
  placement from the *settled* carried state exactly as the host queries
  it, and records admit epochs -- no host round-trip per batch.  The
  ``fixed`` policy (any ``batch_size``) needs no in-program decisions at
  all: its flush epochs are a closed form of the arrival order, so the
  queues enter fully precomputed.

The outer ``while_loop`` replays the boundary event loop (per-core
candidate = max(queue-head submit epoch, core-free epoch); all cores
sharing the minimal boundary start together), and an inner ``while_loop``
runs the relaxation rounds, each round re-simulating the non-settled
lanes with a block-chunked vmapped
:func:`repro.core.fastsim._sim_chunk_fn` scan.

**Domain.**  The program covers the serving batcher's ``fixed`` (any
batch size), ``occupancy``, ``bandwidth`` and ``predicted`` admission
policies, equal or demand-weighted shares (any ``SharePolicy``: weights
are host-measured per (request shape, core) with the client's own
unthrottled probe), homogeneous or mixed fault-free chips.
:func:`plan_ex` returns a structured gate reason outside the domain (see
``GATE_REASONS``) and callers fall back to the incremental client;
inside it, results are **bit-identical** to the numpy oracle (pinned by
``tests/test_online_jax.py`` and asserted at scale by
``benchmarks/online_scaling.py``):

* the per-instruction scan is the shared ``sim_chunk`` program (bit-exact
  with the numpy token bucket);
* every share is the same expression numpy evaluates
  (``budget * w / wsum[e]``, tails ``budget * w / w_forever`` open and
  ``budget`` closed), weight sums fold in the host's span order, and with
  the power-of-two ``epoch_cycles`` all boundary arithmetic
  (``floor(last_grant / E)``, ``ceil(finish / E)``) is exact;
* admission queries are the host's own expressions: headroom counts
  ``budget / (n_active + k + 1) >= min_share`` terms, ``free_at``
  estimates fold the same per-core cost table in queue order, placement
  ties break on the lowest core index exactly like the host's
  first-minimal ``min``/stable sort;
* skip rules only avoid re-simulating values that could not change
  (settled spans are frozen, resumes replay the settled prefix's exact
  state), so the program walks the *same* end-estimate trajectory to the
  same fixed point as the host relaxation.

Since everything dynamic enters as arrays, arrival traces ``vmap``: an
arrival-rate sweep runs as one device launch (:func:`finish_times_many`,
demonstrated by ``benchmarks/serving_batch.py``).  Shapes are padded to
power-of-two grids (requests, trace rows, queue depth, share window), so
repeated calls with different trace lengths reuse one executable.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

from ..core.fastsim import (_design_arrays, _pow2, has_jax, run_segment)
from ..core.isa import NUM_TREGS
from ..core.tiling import GemmSpec
from ..core.trace import OP_NOP, CompiledTrace, compiled_trace
from .arbiter import MAX_ARBITER_ROUNDS
from .chip import ChipConfig, demands_bandwidth, shared_traffic_bytes, \
    stream_model_params

__all__ = ["plan", "plan_ex", "plan_many", "finish_times",
           "finish_admit_times", "finish_times_many", "Plan",
           "GATE_REASONS"]

#: snapshot granularity of the in-program resume cache (instructions per
#: simulated block); trace columns are padded to a multiple of this
_BLOCK = 64

#: admission policies the program replays in-loop (``fixed`` needs no
#: in-loop decisions; the reactive three do)
MODES = ("fixed", "occupancy", "bandwidth", "predicted")

#: cap on the statically-unrolled admissions per decision epoch (the
#: headroom bound ``floor(budget / min_share)``); configs beyond it gate
_KMAX_CAP = 64

#: every reason :func:`plan_ex` can return (the ``BatchReport.jit_gate``
#: vocabulary); ``None`` means the trace jitted
GATE_REASONS = (
    "no_requests",          # empty trace: nothing to settle
    "no_jax",               # jax is not importable in this environment
    "backend",              # chip.backend != "jax"
    "arbitration",          # only the epoch arbiter is lowered
    "faults_active",        # fault plans replay host-side only
    "admission_policy",     # policy outside MODES (phase_aware, ...)
    "batch_size",           # fixed admission needs batch_size >= 1
    "lookahead",            # predicted admission needs lookahead >= 0
    "epoch_not_pow2",       # exact t/E arithmetic needs 2**k epochs
    "infinite_budget",      # unthrottled chips have no share schedule
    "min_share_out_of_range",  # reactive headroom needs 0 < ms <= budget
    "admission_unroll",     # floor(budget/min_share) > _KMAX_CAP
    "hetero_store_model",   # cores disagree on store-byte charging
    "zero_traffic_segment",  # a request shape with no shared traffic
)


# --------------------------------------------------------------------------
# host-side planning
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """Host-precomputed arrays for one (or many) kernel launches.

    Everything the kernel needs that depends only on the *chip and the
    request shapes* is shared; the per-trace arrays (arrivals, queue
    prefill, shape ids) are what an arrival-rate sweep maps over.  All
    shapes are padded to power-of-two grids so the jitted executable is
    keyed by the grid, not the trace.
    """

    chip: ChipConfig
    cols: tuple                 # 7 stacked trace columns, each [R, L]
    tr_len: np.ndarray          # [R] i32 true (unpadded) trace lengths
    t2l: np.ndarray             # [U, C] i32 trace row per (shape, core)
    wt: np.ndarray              # [U, C] f64 span weight per (shape, core)
    est: np.ndarray             # [U, C] f64 unthrottled cycle estimates
    arrival: np.ndarray         # [N] f64 arrival epochs (sorted; pads inf)
    qidx: np.ndarray            # [C, maxq] i32 queue prefill (fixed mode)
    qsub: np.ndarray            # [C, maxq] f64 submit epochs (fixed mode)
    qtail0: np.ndarray          # [C] i32 initial queue fill (fixed mode)
    tid_of: np.ndarray          # [N] i32 shape id per sorted rank (pads 0)
    order: np.ndarray           # [n_real] caller index per sorted rank
    adm_fixed: np.ndarray | None  # [n_real] fixed-mode admit epochs
    mode: str                   # one of MODES
    S: int                      # share-window epochs (>= max span length)
    maxq: int
    kmax: int                   # per-decision admission unroll
    min_share: float
    lookahead: int
    n_real: int                 # true request count (<= len(arrival))


def _stack_cols(traces: Sequence[CompiledTrace], length: int) -> tuple:
    padded = [t.padded(length) for t in traces]
    return tuple(
        np.stack([(tr.opcode, tr.r_dst, tr.r_a, tr.r_b, tr.nbytes, tr.tm,
                   tr.reusable)[f] for tr in padded])
        for f in range(7))


def _nop_rows(cols: tuple, tr_len: np.ndarray, rows: int
              ) -> tuple[tuple, np.ndarray]:
    """Pad the trace table to ``rows`` with zero-length NOP rows."""
    r, length = cols[0].shape
    if r >= rows:
        return cols, tr_len
    out = []
    for f, c in enumerate(cols):
        pad = np.full((rows - r, length), OP_NOP if f == 0 else 0,
                      dtype=c.dtype)
        out.append(np.concatenate([c, pad], axis=0))
    return tuple(out), np.concatenate(
        [tr_len, np.zeros(rows - r, dtype=np.int32)])


def plan(traffic: Sequence[tuple[int, Sequence[GemmSpec]]],
         chip: ChipConfig, *, policy: str = "fixed", batch_size: int = 1,
         min_share: float | None = None, lookahead: int = 1
         ) -> Plan | None:
    """:func:`plan_ex` without the gate reason (legacy call shape)."""
    return plan_ex(traffic, chip, policy=policy, batch_size=batch_size,
                   min_share=min_share, lookahead=lookahead)[0]


def plan_ex(traffic: Sequence[tuple[int, Sequence[GemmSpec]]],
            chip: ChipConfig, *, policy: str = "fixed",
            batch_size: int = 1, min_share: float | None = None,
            lookahead: int = 1) -> tuple[Plan | None, str | None]:
    """Precompute the kernel inputs for one arrival trace.

    ``traffic`` is ``(arrival_epoch, specs)`` per request, in caller
    order.  Returns ``(Plan, None)`` inside the jitted program's domain
    and ``(None, reason)`` outside it -- the caller then uses the
    incremental client and can surface the reason (see ``GATE_REASONS``);
    raising here would turn a routing decision into an error.
    """
    if not traffic:
        return None, "no_requests"
    if not has_jax():
        return None, "no_jax"
    if chip.backend != "jax":
        return None, "backend"
    if chip.arbitration != "epoch":
        return None, "arbitration"
    if chip.fault_plan is not None and not chip.fault_plan.is_empty:
        return None, "faults_active"
    if policy not in MODES:
        return None, "admission_policy"
    if policy == "fixed" and batch_size < 1:
        return None, "batch_size"
    if policy == "predicted" and lookahead < 0:
        return None, "lookahead"
    E = chip.epoch_cycles
    if not (math.isfinite(E) and E > 0
            and math.log2(E).is_integer()):
        return None, "epoch_not_pow2"
    budget = chip.bw_bytes_per_cycle
    if not math.isfinite(budget):
        return None, "infinite_budget"

    C = chip.n_cores
    N = len(traffic)
    reactive = policy != "fixed"
    if min_share is None:
        min_share = budget / (2.0 * C)
    if reactive and not (0.0 < min_share <= budget):
        return None, "min_share_out_of_range"
    kmax_true = int(budget / min_share) if reactive else 1
    if reactive and min(N, kmax_true) > _KMAX_CAP:
        return None, "admission_unroll"
    params = [stream_model_params(chip, cs.engine)
              for cs in chip.core_specs]
    if len({pp.store_ports is None for pp in params}) != 1:
        # the chunk treats store-byte charging as static: a chip whose
        # engines disagree on it cannot share one program
        return None, "hetero_store_model"

    # trace rows are per (request shape, tiling policy): cores sharing a
    # policy share rows, a mixed chip gets one row per distinct policy
    pgroups: list = []
    pgroup_of = np.zeros(C, dtype=np.int32)
    for c, cs in enumerate(chip.core_specs):
        for gi, g in enumerate(pgroups):
            if g == cs.policy:
                pgroup_of[c] = gi
                break
        else:
            pgroup_of[c] = len(pgroups)
            pgroups.append(cs.policy)

    order_in = sorted(range(N), key=lambda i: traffic[i][0])
    keys: dict[tuple, int] = {}
    shapes: list[tuple] = []
    tid_of = np.zeros(N, dtype=np.int32)
    arrival = np.zeros(N, dtype=np.float64)
    for r, i in enumerate(order_in):
        ep, specs = traffic[i]
        key = tuple(dataclasses.replace(s, name="") for s in specs)
        u = keys.get(key)
        if u is None:
            u = keys[key] = len(shapes)
            shapes.append(key)
        tid_of[r] = u
        arrival[r] = float(ep)

    rows: dict[tuple[int, int], int] = {}
    traces: list[CompiledTrace] = []
    U = len(shapes)
    t2l = np.zeros((U, C), dtype=np.int32)
    for u, key in enumerate(shapes):
        for c in range(C):
            gi = int(pgroup_of[c])
            t = rows.get((u, gi))
            if t is None:
                t = rows[(u, gi)] = len(traces)
                traces.append(compiled_trace(key, pgroups[gi]))
            t2l[u, c] = t
    for tr in traces:
        if len(tr) == 0 or not demands_bandwidth(chip, None, tr):
            return None, "zero_traffic_segment"

    # span weights: the host client measures each admitted segment's
    # unthrottled demand on its core and maps it through the share
    # policy; weight is a pure function of (shape, core), so the probe
    # runs once per table cell and enters the kernel as data
    share_policy = chip.share_policy
    wt = np.ones((U, C), dtype=np.float64)
    if getattr(share_policy, "needs_demand", False):
        cache: dict[tuple, float] = {}
        for u in range(U):
            for c in range(C):
                engine = chip.core_specs[c].engine
                ck = (int(t2l[u, c]), engine)
                d = cache.get(ck)
                if d is None:
                    tr = traces[t2l[u, c]]
                    res, _, _ = run_segment(
                        tr, engine, stream_model_params(chip, engine))
                    traffic_b = shared_traffic_bytes(chip, None, tr)
                    d = cache[ck] = \
                        traffic_b / res.cycles if res.cycles else 0.0
                wt[u, c] = share_policy.weight(d)

    # queued-cost estimates (free_at placement): the host's own cached
    # per-(spec, core-design) estimator, summed per request shape
    est = np.zeros((U, C), dtype=np.float64)
    if reactive:
        from .scheduler import _estimate_cycles
        for u, key in enumerate(shapes):
            for c in range(C):
                est[u, c] = float(sum(_estimate_cycles(s, chip, c)
                                      for s in key))

    # sound per-segment span bound: at most one span per core is active,
    # each weighing at most its core's table max, so every relaxed share
    # is >= budget * w / wf_max -- a segment's epoch count under any
    # reachable schedule is bounded by its constant-floor-share run
    wf_max = float(np.sum(np.max(wt, axis=0)))
    l_max = 0
    lcache: dict[tuple, int] = {}
    for u in range(U):
        for c in range(C):
            engine = chip.core_specs[c].engine
            floor = budget * wt[u, c] / wf_max
            ck = (int(t2l[u, c]), engine, floor)
            n = lcache.get(ck)
            if n is None:
                res, _, _ = run_segment(
                    traces[t2l[u, c]], engine,
                    stream_model_params(chip, engine, (), E, floor))
                n = lcache[ck] = int(res.cycles // E) + 2
            l_max = max(l_max, n)

    # an open span's visible prefix can reach the horizon set by another
    # lane, at most ~2 span lengths past its own start (see module docs)
    S = _pow2(2 * l_max + 4, lo=8)

    # pad every dynamic extent to a power-of-two grid: the executable is
    # keyed by the grid, so nearby trace sizes share one compilation
    Np = _pow2(N, lo=8)
    arrival_p = np.full(Np, np.inf, dtype=np.float64)
    arrival_p[:N] = arrival
    tid_p = np.zeros(Np, dtype=np.int32)
    tid_p[:N] = tid_of

    adm_fixed = None
    if reactive:
        maxq = Np
        qidx = np.zeros((C, maxq), dtype=np.int32)
        qsub = np.zeros((C, maxq), dtype=np.float64)
        qtail0 = np.zeros(C, dtype=np.int32)
        kmax = _pow2(max(1, min(N, kmax_true)), lo=4)
    else:
        # fixed admission is a closed form of the arrival order: rank r
        # goes to core r % C when group r // batch_size flushes -- at the
        # arrival of the group's last member (the drained partial group
        # flushes with the final arrival)
        qlen = np.zeros(C, dtype=np.int32)
        for r in range(N):
            qlen[r % C] += 1
        maxq = _pow2(int(qlen.max()), lo=1)
        qidx = np.zeros((C, maxq), dtype=np.int32)
        qsub = np.zeros((C, maxq), dtype=np.float64)
        qtail0 = qlen
        adm_fixed = np.zeros(N, dtype=np.float64)
        fill = np.zeros(C, dtype=np.int32)
        for r in range(N):
            g = r // batch_size
            adm_fixed[r] = arrival[min((g + 1) * batch_size - 1, N - 1)]
            c = r % C
            qidx[c, fill[c]] = r
            qsub[c, fill[c]] = adm_fixed[r]
            fill[c] += 1
        kmax = 1

    L = -(-max(len(t) for t in traces) // _BLOCK) * _BLOCK
    L = _pow2(L // _BLOCK, lo=1) * _BLOCK
    cols, tr_len = _nop_rows(
        _stack_cols(traces, L),
        np.asarray([len(t) for t in traces], dtype=np.int32),
        _pow2(len(traces), lo=1))
    Up = _pow2(U, lo=1)
    if Up > U:
        t2l = np.concatenate(
            [t2l, np.zeros((Up - U, C), dtype=np.int32)])
        wt = np.concatenate([wt, np.ones((Up - U, C))])
        est = np.concatenate([est, np.zeros((Up - U, C))])
    return Plan(chip=chip, cols=cols, tr_len=tr_len, t2l=t2l, wt=wt,
                est=est, arrival=arrival_p, qidx=qidx, qsub=qsub,
                qtail0=qtail0, tid_of=tid_p,
                order=np.asarray(order_in, dtype=np.int64),
                adm_fixed=adm_fixed, mode=policy, S=S, maxq=maxq,
                kmax=kmax, min_share=float(min_share),
                lookahead=int(lookahead), n_real=N), None


# --------------------------------------------------------------------------
# the program
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _kernel(C: int, N: int, maxq: int, R: int, U: int, L: int, S: int,
            mode: str, charge_store: bool, store_free: bool, kmax: int,
            max_rounds: int):
    """Build (jit, vmapped-jit) of the whole-trace program for one static
    shape signature.  Everything dynamic -- arrivals, queues, trace
    columns, designs, the budget -- is a traced argument, so same-grid
    launches (an arrival sweep, a re-run, a different engine mix) reuse
    the executable."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..core.fastsim import _sim_chunk_fn

    #: per-lane vmap of the simulate chunk: the engine design tuple and
    #: the port rates ride the lane axis (heterogeneous mixes), shares /
    #: schedule bounds as in fastsim's ``_B_CORES`` cores layout
    _B_LANES = (0, 0, None, 0, None, 0, None, None, 0, 0)
    lane_sim = jax.vmap(_sim_chunk_fn(False, False),
                        in_axes=(0, 0, None, (0,) * 8, _B_LANES))
    INF = jnp.inf
    NB = L // _BLOCK
    W = 2 * S
    reactive = mode != "fixed"
    tree = jax.tree_util.tree_map

    def program(cols, tr_len, t2l, wt, est, arrival, qidx0, qsub0, qtail0,
                tid_of, E, budget, burst, inv_load, inv_store, design,
                min_share, lookahead, n_real, packed=True):
        f64 = jnp.float64
        i32 = jnp.int32
        lanes = jnp.arange(C)

        def fresh_carry():
            z = jnp.zeros((C,), f64)
            return (jnp.zeros((C, NUM_TREGS), f64),
                    jnp.full((C,), -1.0, f64), z, z, z,
                    jnp.zeros((C,), bool), z, z,
                    jnp.zeros((C,), jnp.int32), z, z, z, z,
                    jnp.full((C,), burst, f64), z)

        # In the single-trace kernel snapshots live in ONE flat f64 buffer
        # [C, NB+1, D]: a packed 15-element carry per block boundary, so
        # one concatenate + one scatter per simulated block replaces 15 of
        # each -- on CPU the per-block dispatch cost is what the resume
        # cache trades against.  bool/int32 fields roundtrip through f64
        # exactly.  The vmapped kernel keeps the 15-array tuple form:
        # batched scatters into one wide buffer lower to a slower generic
        # scatter than the per-field updates do.
        LG = NUM_TREGS + 11         # packed column of ``last_grant``

        def pack(cy):
            return jnp.concatenate(
                [cy[0]] + [(c if c.dtype == jnp.float64
                            else c.astype(f64))[:, None] for c in cy[1:]],
                axis=1)

        def unpack(p):
            Rg = NUM_TREGS

            def at(i):
                return p[:, Rg + i]

            return (p[:, :Rg], at(0), at(1), at(2), at(3), at(4) != 0.0,
                    at(5), at(6), at(7).astype(jnp.int32), at(8), at(9),
                    at(10), at(11), at(12), at(13))

        def blank_snaps():
            # snapshot slot k of lane l = the carry before block k; slot 0
            # is the fresh segment state, deeper slots start invalid (an
            # inf last_grant never precedes a dirty boundary)
            if packed:
                snaps = jnp.repeat(pack(fresh_carry())[:, None, :],
                                   NB + 1, axis=1)
                return snaps.at[:, 1:, LG].set(INF)
            snaps = tree(
                lambda a: jnp.repeat(a[:, None, ...], NB + 1, axis=1),
                fresh_carry())
            return snaps[:12] + (snaps[12].at[:, 1:].set(INF),) + snaps[13:]

        def reset_snaps(snaps, starts):
            if packed:
                return jnp.where(starts[:, None, None], blank_snaps(),
                                 snaps)
            return tree(
                lambda a, blank: jnp.where(
                    starts[:, None, None] if a.ndim == 3
                    else starts[:, None], blank, a),
                snaps, blank_snaps())

        def snap_lg(snaps):
            return snaps[:, :, LG] if packed else snaps[12]

        def snap_read(snaps, k0):
            if packed:
                return unpack(snaps[lanes, k0])
            return tree(lambda a: a[lanes, k0], snaps)

        def snap_write(snaps, b, act, carry):
            if packed:
                return snaps.at[:, b + 1].set(
                    jnp.where(act[:, None], pack(carry), snaps[:, b + 1]))
            return tree(
                lambda s, c: s.at[:, b + 1].set(
                    jnp.where(act[:, None] if c.ndim == 2 else act,
                              c, s[:, b + 1])),
                snaps, carry)

        def settle(wsum, base, nw, tid, cur, start, ends, lg, te, snaps,
                   d, mxn, p_sh, p_nsh, p_tail):
            """One arbiter settle at boundary ``d``: slide the weight-sum
            window up to ``max(base, d - S)``, zero-fill the idle gap,
            then relax.  Settled epochs spilling off the left edge are
            immutable facts -- no settle reads or writes below
            ``d - S`` (reads span a live span's prefix, writes the
            ``[d, d + S)`` window; both bounded by the span bound S)."""
            base2 = jnp.maximum(base, d - float(S))
            sh = (base2 - base).astype(i32)
            iw = jnp.arange(W, dtype=i32)
            wsum = jnp.where(iw + sh < W,
                             wsum[jnp.clip(iw + sh, 0, W - 1)], 0.0)
            e_abs = base2 + jnp.arange(W, dtype=f64)
            wsum = jnp.where((e_abs >= nw) & (e_abs < d), 0.0, wsum)
            live = tid >= 0
            need = live & jnp.isinf(ends)   # dirty or just-started spans
            tid_s = jnp.maximum(tid, 0)
            row = t2l[tid_s, lanes]
            w_lane = wt[tid_s, lanes]
            lane_cols = tuple(c[row] for c in cols)         # [C, L]
            nblk = (tr_len[row] + (_BLOCK - 1)) // _BLOCK   # [C]
            cutoff = (d - start) * E        # settled-time limit, per lane
            # the host arbiter folds weights over spans in _active order:
            # start epoch, core-index tie-break (the _pump append order)
            perm = jnp.argsort(start * C + lanes.astype(f64))

            def resim(snaps, bucket, sim, fc):
                """Re-simulate the ``sim`` lanes under the current shares.

                A snapshot is reusable when every grant it has absorbed
                lies either in the settled prefix (frozen forever) or
                before the first epoch whose visible share differs from
                the lane's previous sim -- so each lane resumes from its
                deepest such snapshot instead of instruction zero."""
                lim = jnp.maximum(fc * E, cutoff)
                valid = snap_lg(snaps) < lim[:, None]        # [C, NB+1]
                k0 = jnp.max(jnp.where(valid,
                                       jnp.arange(NB + 1, dtype=jnp.int32),
                                       0), axis=1)           # [C]
                blo = jnp.min(jnp.where(sim, k0, NB + 1))
                bhi = jnp.max(jnp.where(sim, nblk, 0))
                carry = snap_read(snaps, k0)

                def block(bs):
                    b, carry, snaps = bs
                    act = sim & (k0 <= b) & (b < nblk)
                    off = b * _BLOCK
                    xs = tuple(
                        lax.dynamic_slice(cc, (jnp.zeros_like(off), off),
                                          (C, _BLOCK))
                        for cc in lane_cols)
                    idx = (off + jnp.arange(_BLOCK)).astype(f64)
                    new = lane_sim(carry, xs, idx, design, bucket)[0]
                    carry = tree(
                        lambda a, n: jnp.where(
                            act[:, None] if n.ndim == 2 else act, n, a),
                        carry, new)
                    snaps = snap_write(snaps, b, act, carry)
                    return b + 1, carry, snaps

                bF, carry, snaps = lax.while_loop(
                    lambda bs: bs[0] < bhi, block, (blo, carry, snaps))
                return carry[7], carry[12], snaps, bF - blo

            def round_body(st):
                (wsum, nw, ends, lg, te, r, _, mxn, snaps, blk,
                 p_sh, p_nsh, p_tail) = st
                closed = live & jnp.isfinite(ends)
                horizon = jnp.maximum(
                    d, jnp.max(jnp.where(closed, ends, d)))
                k = jnp.arange(S, dtype=f64)
                e = d + k                                       # [S]
                hi = jnp.where(jnp.isinf(ends), horizon, ends)  # [C]
                act = (live[:, None] & (start[:, None] <= e[None, :])
                       & (e[None, :] < hi[:, None]))
                open_ = live & jnp.isinf(ends)
                # per-epoch weight sums, folded in the host's span order
                # (masked adds of +0.0 are exact, so dead lanes are
                # order-transparent; unit weights reduce to the integer
                # count and stay exact in any order)
                win = jnp.zeros((S,), f64)
                wf = jnp.asarray(0.0, f64)
                for j in range(C):
                    lane = perm[j]
                    win = win + jnp.where(act[lane], w_lane[lane], 0.0)
                    wf = wf + jnp.where(open_[lane], w_lane[lane], 0.0)
                wsum = lax.dynamic_update_slice(
                    wsum, win, ((d - base2).astype(i32),))
                n_sh = jnp.where(jnp.isinf(ends), horizon - start,
                                 ends - start)
                mxn = jnp.maximum(mxn,
                                  jnp.max(jnp.where(need, n_sh, 0.0)))
                n_sh = jnp.clip(n_sh, 0.0, float(S))
                tail = jnp.where(open_, budget * w_lane / wf, budget)
                lidx = jnp.clip(
                    (start[:, None] - base2).astype(i32)
                    + jnp.arange(S, dtype=i32)[None, :], 0, W - 1)
                shares = budget * w_lane[:, None] / wsum[lidx]  # [C, S]
                bucket = (shares, n_sh, E, tail, burst, n_sh * E,
                          charge_store, store_free, inv_store, inv_load)
                # first epoch whose visible share differs from the lane's
                # previous sim: epochs below it replay identically, so an
                # unchanged lane is skipped outright (the host relaxation's
                # unchanged-visibility skip) and a changed one resumes from
                # its deepest snapshot before the divergence
                m = jnp.minimum(n_sh, p_nsh)
                diff = (k[None, :] < m[:, None]) & (shares != p_sh)
                fc = jnp.min(jnp.where(diff, k[None, :], INF), axis=1)
                cap = jnp.where((n_sh != p_nsh) | (tail != p_tail), m, INF)
                fc = jnp.minimum(fc, cap)
                sim = need & jnp.isfinite(fc)
                te_n, lg_n, snaps, nblks = resim(snaps, bucket, sim, fc)
                te = jnp.where(sim, te_n, te)
                lg = jnp.where(sim, lg_n, lg)
                sel = sim[:, None]
                p_sh = jnp.where(sel, shares, p_sh)
                p_nsh = jnp.where(sim, n_sh, p_nsh)
                p_tail = jnp.where(sim, tail, p_tail)
                e_new = start + jnp.floor(lg / E) + 1.0
                e_new = jnp.where(need, jnp.minimum(e_new, ends), ends)
                conv = jnp.all(e_new == ends)
                return (wsum, horizon, e_new, lg, te, r + 1, conv, mxn,
                        snaps, blk + nblks, p_sh, p_nsh, p_tail)

            st = (wsum, nw, ends, lg, te, jnp.int32(0),
                  jnp.asarray(False), mxn, snaps, jnp.int32(0),
                  p_sh, p_nsh, p_tail)
            st = lax.while_loop(
                lambda s: (~s[6]) & (s[5] < max_rounds), round_body, st)
            return (st[0], base2, st[1], st[2], st[3], st[4], st[7],
                    st[8], st[5], st[9], st[10], st[11], st[12])

        def outer_body(c):
            (qhead, qtail, qidx, qsub, tid, cur, start, ends, lg, te,
             wsum, base, nw, finish, adm_ep, mxn, snaps, n_r, n_b,
             p_sh, p_nsh, p_tail, n_arr, adm, dec_done, t_dec) = c
            has_q = qhead < qtail
            alive = jnp.any(has_q)
            if reactive:
                alive = alive | (adm < n_real)
            slot = jnp.minimum(qhead, maxq - 1)
            nxt_s = jnp.clip(qidx[lanes, slot], 0, N - 1)
            sub = qsub[lanes, slot]
            free = jnp.maximum(start, jnp.ceil((start * E + te) / E))
            free = jnp.where(tid >= 0, free, 0.0)
            b_c = jnp.where(has_q, jnp.maximum(free, sub), INF)
            bstar = jnp.min(b_c)

            def start_step(c):
                """Pump: all cores sharing the minimal boundary start
                their queue heads together, then the arbiter settles."""
                (qhead, qtail, qidx, qsub, tid, cur, start, ends, lg, te,
                 wsum, base, nw, finish, adm_ep, mxn, snaps, n_r, n_b,
                 p_sh, p_nsh, p_tail, n_arr, adm, dec_done, t_dec) = c
                starts = has_q & (b_c == bstar)
                tid2 = jnp.where(starts, tid_of[nxt_s], tid)
                cur2 = jnp.where(starts, nxt_s, cur)
                start2 = jnp.where(starts, bstar, start)
                ends2 = jnp.where(starts, INF, ends)
                lg2 = jnp.where(starts, 0.0, lg)
                te2 = jnp.where(starts, 0.0, te)
                qhead2 = qhead + starts.astype(qhead.dtype)
                snaps2 = reset_snaps(snaps, starts)
                # a fresh span has no previous sim: p_nsh = -1 forces a
                # full first simulation and invalidates old snapshots
                p_nsh2 = jnp.where(starts, -1.0, p_nsh)
                p_tail2 = jnp.where(starts, -1.0, p_tail)
                # the boundary event reopens every span still active here
                ends2 = jnp.where((tid2 >= 0) & (ends2 > bstar), INF,
                                  ends2)
                (wsum2, base2, nw2, ends2, lg2, te2, mxn2, snaps2, dn_r,
                 dn_b, p_sh2, p_nsh2, p_tail2) = settle(
                    wsum, base, nw, tid2, cur2, start2, ends2, lg2, te2,
                    snaps2, bstar, mxn, p_sh, p_nsh2, p_tail2)
                fslot = jnp.where(tid2 >= 0, cur2, N)
                finish2 = finish.at[fslot].set(
                    jnp.where(tid2 >= 0, start2 * E + te2, finish[fslot]))
                return (qhead2, qtail, qidx, qsub, tid2, cur2, start2,
                        ends2, lg2, te2, wsum2, base2, nw2, finish2,
                        adm_ep, mxn2, snaps2, n_r + dn_r, n_b + dn_b,
                        p_sh2, p_nsh2, p_tail2, n_arr, adm, dec_done,
                        t_dec)

            if not reactive:
                new = start_step(c)
                return tree(lambda a, b: jnp.where(alive, a, b), new, c)

            def admit_step(c):
                """The host driver's decision epoch at ``t_dec``: enqueue
                arrivals, admit under the policy, record admit epochs."""
                (qhead, qtail, qidx, qsub, tid, cur, start, ends, lg, te,
                 wsum, base, nw, finish, adm_ep, mxn, snaps, n_r, n_b,
                 p_sh, p_nsh, p_tail, n_arr, adm, dec_done, t_dec) = c
                t = t_dec
                n_arr2 = jnp.searchsorted(arrival, t,
                                          side="right").astype(i32)
                n_wait = n_arr2 - adm
                n_act = jnp.sum(((tid >= 0) & (start <= t)
                                 & (ends > t)).astype(i32))
                kj = jnp.arange(kmax)
                # the host's headroom walk: count k while the projected
                # per-request share stays at or above the floor
                h = jnp.sum(((kj < n_wait)
                             & (budget / (n_act + kj + 1).astype(f64)
                                >= min_share)).astype(i32))
                cap = jnp.minimum(n_wait, h)
                busy = (free > t) | has_q

                def free_at():
                    # the host's free_at_estimate: settled finish of
                    # started work, clamped to now, plus unthrottled cost
                    # estimates folded in queue order
                    fa = jnp.maximum(
                        jnp.where(tid >= 0, start * E + te, 0.0), t * E)
                    depth = qtail - qhead

                    def fold(j, fa):
                        sl = jnp.minimum(qhead + j, maxq - 1)
                        u = tid_of[jnp.clip(qidx[lanes, sl], 0, N - 1)]
                        return fa + jnp.where(j < depth, est[u, lanes],
                                              0.0)

                    return lax.fori_loop(0, jnp.max(depth), fold, fa)

                fa = free_at()
                qidx2, qsub2, qtail2 = qidx, qsub, qtail
                if mode == "occupancy":
                    nfree = jnp.sum((~busy).astype(i32))
                    take = jnp.minimum(cap, nfree)
                    pick = ~busy
                    # rank among the picked cores, ascending core index
                    rank = (jnp.cumsum(pick.astype(i32))
                            - pick.astype(i32)).astype(i32)
                elif mode == "predicted":
                    hz = (t + lookahead) * E
                    elig = fa <= hz
                    take = jnp.minimum(cap, jnp.sum(elig.astype(i32)))
                    pick = elig
                    # the host's stable sort by free_at: rank = count of
                    # eligible cores strictly (fa, index)-before this one
                    before = (elig[None, :]
                              & ((fa[None, :] < fa[:, None])
                                 | ((fa[None, :] == fa[:, None])
                                    & (lanes[None, :] < lanes[:, None]))))
                    rank = jnp.sum(before.astype(i32), axis=1).astype(i32)
                if mode in ("occupancy", "predicted"):
                    sel = pick & (rank < take)
                    col = jnp.minimum(qtail, maxq - 1)
                    qidx2 = qidx.at[lanes, col].set(
                        jnp.where(sel, adm + rank, qidx[lanes, col]))
                    qsub2 = qsub.at[lanes, col].set(
                        jnp.where(sel, t, qsub[lanes, col]))
                    qtail2 = qtail + sel.astype(qtail.dtype)
                else:   # bandwidth: headroom-gated, soonest-free placed
                    take = cap
                    fe = fa
                    for j in range(kmax):
                        on = jnp.asarray(j, i32) < take
                        rank_j = adm + j
                        u_j = tid_of[jnp.clip(rank_j, 0, N - 1)]
                        key = fe + est[u_j]
                        cj = jnp.argmin(key)    # first-minimal, as host
                        fe = jnp.where((lanes == cj) & on, key, fe)
                        colj = jnp.minimum(qtail2[cj], maxq - 1)
                        qidx2 = qidx2.at[cj, colj].set(
                            jnp.where(on, rank_j, qidx2[cj, colj]))
                        qsub2 = qsub2.at[cj, colj].set(
                            jnp.where(on, t, qsub2[cj, colj]))
                        qtail2 = qtail2 + jnp.where((lanes == cj) & on,
                                                    1, 0).astype(
                                                        qtail2.dtype)
                wsl = jnp.where(kj < take, adm + kj, N)
                adm_ep2 = adm_ep.at[wsl].set(t)
                # work conservation: a threshold policy must not starve a
                # waiting request on an idle chip -- the host admits one
                # onto the soonest-free core past the headroom floor
                wc = (take == 0) & (n_wait > 0) & jnp.all(~busy)
                u_wc = tid_of[jnp.clip(adm, 0, N - 1)]
                cw = jnp.argmin(fa + est[u_wc])
                colw = jnp.minimum(qtail2[cw], maxq - 1)
                qidx2 = qidx2.at[cw, colw].set(
                    jnp.where(wc, adm, qidx2[cw, colw]))
                qsub2 = qsub2.at[cw, colw].set(
                    jnp.where(wc, t, qsub2[cw, colw]))
                qtail2 = qtail2 + jnp.where((lanes == cw) & wc,
                                            1, 0).astype(qtail2.dtype)
                adm_ep2 = adm_ep2.at[jnp.where(wc, adm, N)].set(
                    jnp.where(wc, t, adm_ep2[jnp.where(wc, adm, N)]))
                adm2 = (adm + take + wc.astype(i32)).astype(i32)
                # t_dec == dec_done marks "recompute after the pump":
                # the next decision epoch is derived from post-start
                # state, exactly where the host derives it
                return (qhead, qtail2, qidx2, qsub2, tid, cur, start,
                        ends, lg, te, wsum, base, nw, finish, adm_ep2,
                        mxn, snaps, n_r, n_b, p_sh, p_nsh, p_tail,
                        n_arr2, adm2, t, t)

            def resched_step(c):
                """Recompute the next decision epoch from the settled
                post-pump state: the host's candidate list -- the next
                arrival always, the chip's next event only while
                requests wait."""
                (qhead, qtail, qidx, qsub, tid, cur, start, ends, lg, te,
                 wsum, base, nw, finish, adm_ep, mxn, snaps, n_r, n_b,
                 p_sh, p_nsh, p_tail, n_arr, adm, dec_done, t_dec) = c
                cand_arr = jnp.where(
                    n_arr < n_real,
                    arrival[jnp.clip(n_arr, 0, N - 1)], INF)
                f_evt = jnp.where(has_q, jnp.maximum(free, sub), free)
                isev = ((tid >= 0) | has_q) & (f_evt > dec_done)
                evt = jnp.min(jnp.where(isev, f_evt, INF))
                t2 = jnp.minimum(cand_arr,
                                 jnp.where(n_arr > adm, evt, INF))
                # unreachable backstop (an idle chip with waiting work
                # always admits): never spin on an inf decision epoch
                adm2 = jnp.where(jnp.isinf(t2) & (n_arr >= n_real),
                                 n_real, adm).astype(i32)
                return (qhead, qtail, qidx, qsub, tid, cur, start, ends,
                        lg, te, wsum, base, nw, finish, adm_ep, mxn,
                        snaps, n_r, n_b, p_sh, p_nsh, p_tail, n_arr,
                        adm2, dec_done, t2)

            dec_done, t_dec = c[24], c[25]
            new = lax.cond(
                bstar <= t_dec, start_step,
                lambda c: lax.cond(t_dec > dec_done, admit_step,
                                   resched_step, c), c)
            return tree(lambda a, b: jnp.where(alive, a, b), new, c)

        z = jnp.zeros((C,), f64)
        c0 = (jnp.zeros(C, jnp.int32), qtail0.astype(jnp.int32),
              qidx0.astype(jnp.int32), qsub0.astype(f64),
              jnp.full((C,), -1, jnp.int32), jnp.zeros(C, jnp.int32),
              z, jnp.full((C,), -INF, f64), z, z,
              jnp.zeros((W,), f64), jnp.asarray(0.0, f64),
              jnp.asarray(0.0, f64),
              jnp.zeros((N + 1,), f64), jnp.zeros((N + 1,), f64),
              jnp.asarray(0.0, f64), blank_snaps(),
              jnp.int32(0), jnp.int32(0),
              jnp.zeros((C, S), f64), jnp.full((C,), -1.0, f64),
              jnp.full((C,), -1.0, f64),
              jnp.int32(0), jnp.int32(0) if reactive else n_real,
              jnp.asarray(-INF, f64),
              arrival[0] if reactive else jnp.asarray(INF, f64))

        def cond(c):
            alive = jnp.any(c[0] < c[1])
            if reactive:
                alive = alive | (c[23] < n_real)
            return alive

        cF = lax.while_loop(cond, outer_body, c0)
        return cF[13][:N], cF[14][:N], cF[15], cF[17], cF[18]

    one = jax.jit(functools.partial(program, packed=True))
    many = jax.jit(jax.vmap(
        functools.partial(program, packed=False),
        in_axes=((None, None, None, None, None, 0, 0, 0, None, 0)
                 + (None,) * 9)))
    return one, many


def _launch_args(p: Plan):
    params = [stream_model_params(p.chip, cs.engine)
              for cs in p.chip.core_specs]
    store_free = params[0].store_ports is None
    statics = (p.chip.n_cores, len(p.arrival), p.maxq,
               p.cols[0].shape[0], p.t2l.shape[0], p.cols[0].shape[1],
               p.S, p.mode, bool(params[0].charge_store_bytes),
               store_free, p.kmax, MAX_ARBITER_ROUNDS)
    design = _design_arrays([cs.engine for cs in p.chip.core_specs])
    arrays = (np.float64(p.chip.epoch_cycles),
              np.float64(p.chip.bw_bytes_per_cycle),
              np.float64(p.chip.bw_burst_bytes),
              np.asarray([1.0 / pp.load_ports for pp in params]),
              np.asarray([1.0 if pp.store_ports is None
                          else 1.0 / pp.store_ports for pp in params]),
              design, np.float64(p.min_share), np.float64(p.lookahead),
              np.int32(p.n_real))
    return statics, arrays


def _check(p: Plan, mxn: float) -> None:
    if mxn > p.S:
        raise RuntimeError(
            f"jitted arbitration window bound violated (span epochs "
            f"{mxn} vs window {p.S}): the host span bound is unsound "
            f"here")


def finish_admit_times(p: Plan, stats: dict | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Run one planned trace; (finish cycles, admit epochs) in caller
    order.

    When ``stats`` is given, the kernel's relaxation-round and
    simulated-block counters are recorded into it (benchmark
    diagnostics).
    """
    from jax.experimental import enable_x64

    statics, arrays = _launch_args(p)
    fn = _kernel(*statics)[0]
    with enable_x64():
        fin, adm, mxn, n_r, n_b = fn(p.cols, p.tr_len, p.t2l, p.wt,
                                     p.est, p.arrival, p.qidx, p.qsub,
                                     p.qtail0, p.tid_of, *arrays)
        fin = np.asarray(fin)
        adm = np.asarray(adm)
        _check(p, float(mxn))
        if stats is not None:
            stats["rounds"] = int(n_r)
            stats["blocks"] = int(n_b)
    out = np.zeros(p.n_real, dtype=np.float64)
    out[p.order] = fin[:p.n_real]
    adm_out = np.zeros(p.n_real, dtype=np.float64)
    adm_out[p.order] = p.adm_fixed if p.mode == "fixed" \
        else adm[:p.n_real]
    return out, adm_out


def finish_times(p: Plan, stats: dict | None = None) -> np.ndarray:
    """Run one planned trace; absolute finish cycles in caller order."""
    return finish_admit_times(p, stats)[0]


def finish_times_many(plans: Sequence[Plan]) -> list[np.ndarray]:
    """Run a family of same-shape plans (e.g. an arrival-rate sweep) as
    one vmapped launch.  All plans must come from :func:`plan_many`."""
    from jax.experimental import enable_x64

    head = plans[0]
    statics, arrays = _launch_args(head)
    fn = _kernel(*statics)[1]
    with enable_x64():
        fin, _, mxn, _, _ = fn(head.cols, head.tr_len, head.t2l, head.wt,
                               head.est,
                               np.stack([p.arrival for p in plans]),
                               np.stack([p.qidx for p in plans]),
                               np.stack([p.qsub for p in plans]),
                               head.qtail0,
                               np.stack([p.tid_of for p in plans]),
                               *arrays)
        fin = np.asarray(fin)
        for p, x in zip(plans, np.asarray(mxn)):
            _check(p, float(x))
    outs = []
    for v, p in enumerate(plans):
        out = np.zeros(p.n_real, dtype=np.float64)
        out[p.order] = fin[v][:p.n_real]
        outs.append(out)
    return outs


def plan_many(traffics: Sequence[Sequence[tuple[int, Sequence[GemmSpec]]]],
              chip: ChipConfig) -> list[Plan] | None:
    """Plan several ``fixed``-admission arrival traces over the *same*
    request-shape universe so they share one executable (common trace
    table, window and queue bounds).  Returns ``None`` if any variant
    falls outside the domain or the variants disagree on request count."""
    plans = [plan(t, chip) for t in traffics]
    if any(p is None for p in plans) or not plans:
        return None
    if {len(p.arrival) for p in plans} != {len(plans[0].arrival)} \
            or {p.n_real for p in plans} != {plans[0].n_real} \
            or {p.qtail0.tobytes() for p in plans} \
            != {plans[0].qtail0.tobytes()}:
        return None
    C = chip.n_cores
    # unify trace rows by content, then request shapes by their per-core
    # row vector, so every variant indexes one shared table
    row_of: dict[bytes, int] = {}
    all_rows: list[tuple] = []
    all_len: list[int] = []
    L = max(p.cols[0].shape[1] for p in plans)
    shape_of: dict[tuple, int] = {}
    shape_rows: list[tuple] = []
    shape_wt: list[np.ndarray] = []
    shape_est: list[np.ndarray] = []
    remap_u: list[np.ndarray] = []
    for p in plans:
        row_ids = np.zeros(p.cols[0].shape[0], dtype=np.int32)
        for r in range(p.cols[0].shape[0]):
            pad = L - p.cols[0].shape[1]
            row = tuple(
                np.concatenate([c[r], np.full(pad, OP_NOP if f == 0
                                              else 0, dtype=c[r].dtype)])
                for f, c in enumerate(p.cols))
            sig = b"".join(np.ascontiguousarray(a).tobytes()
                           for a in row)
            t = row_of.get(sig)
            if t is None:
                t = row_of[sig] = len(all_rows)
                all_rows.append(row)
                all_len.append(int(p.tr_len[r]))
            row_ids[r] = t
        uids = np.zeros(p.t2l.shape[0], dtype=np.int32)
        for u in range(p.t2l.shape[0]):
            key = tuple(int(row_ids[p.t2l[u, c]]) for c in range(C))
            g = shape_of.get(key)
            if g is None:
                g = shape_of[key] = len(shape_rows)
                shape_rows.append(key)
                shape_wt.append(p.wt[u])
                shape_est.append(p.est[u])
            uids[u] = g
        remap_u.append(uids)
    cols = tuple(np.stack([rw[f] for rw in all_rows]) for f in range(7))
    cols, tr_len = _nop_rows(cols,
                             np.asarray(all_len, dtype=np.int32),
                             _pow2(len(all_rows), lo=1))
    U = _pow2(len(shape_rows), lo=1)
    t2l = np.zeros((U, C), dtype=np.int32)
    wt = np.ones((U, C), dtype=np.float64)
    est = np.zeros((U, C), dtype=np.float64)
    for g, key in enumerate(shape_rows):
        t2l[g] = key
        wt[g] = shape_wt[g]
        est[g] = shape_est[g]
    S = max(p.S for p in plans)
    maxq = max(p.maxq for p in plans)
    out = []
    for p, uids in zip(plans, remap_u):
        qidx = np.zeros((C, maxq), dtype=np.int32)
        qidx[:, :p.qidx.shape[1]] = p.qidx
        qsub = np.zeros((C, maxq), dtype=np.float64)
        qsub[:, :p.qsub.shape[1]] = p.qsub
        out.append(dataclasses.replace(
            p, cols=cols, tr_len=tr_len, t2l=uids[p.t2l], wt=wt, est=est,
            tid_of=uids[p.tid_of], qidx=qidx, qsub=qsub, S=S, maxq=maxq))
    return out
