"""Deterministic fault injection: core failures, thermal throttling and
slow cores, as timed events over the chip simulation.

A production chip loses utilization not only to the overheads the schedule
planned for (fill/drain, bandwidth contention) but to *events it did not*:
cores dropping offline, thermal bandwidth derating, DVFS-throttled cores.
This module is the single description of those events -- a seedable,
deterministic :class:`FaultPlan` attached to
:class:`~repro.multicore.chip.ChipConfig` and honored by both arbitration
clients:

* ``bw_derate(factor, epoch, until)`` scales the shared token-bucket
  budget per epoch through the span arbiter's ``budget_factors`` (see
  :class:`~repro.multicore.arbiter.SpanArbiter`): every active span's
  share in a derated epoch shrinks by the factor, and the dynamic
  arbitration re-balances around the window exactly as it does around
  arrivals and departures.
* ``slow_core(core, factor)`` dilates one core's time base: the core's
  engine retires work at ``factor`` times its nominal rate (DVFS throttle
  model).  Simulated exactly by rescaling the core's visible share
  schedule into its local time base and converting results back.
* ``core_down(core, epoch)`` / ``core_up(core, epoch)`` take a core
  offline at an epoch boundary and back.  In the open-arrival model
  (:class:`~repro.multicore.online.OnlineChip`) a downed core's in-flight
  segment is **preempted at the boundary**: the deterministic
  :func:`repro.core.fastsim.completed_prefix` replay counts how many of
  its instructions had fully retired, the kept prefix is rounded down to
  the ``SimCarry`` snapshot stride (``preemption="resume"``) or discarded
  entirely (``"restart"``), and the remainder is requeued on the
  best surviving core.  Queued work migrates immediately.  Closed-batch
  runs with core events are routed through the online model
  (:func:`faulted_chip_report`).

Every decision is a pure function of the plan and the settled schedule --
no wall clock, no hidden RNG -- so fault runs are bit-reproducible across
the reference/numpy/jax backends (pinned by ``tests/test_faults.py``).
The empty plan is the common case and is zero-cost: every fault hook in
the simulators is gated on ``plan is None``/``plan.is_empty`` and leaves
the fault-free arithmetic untouched.

Work lost to preemption lands in the telemetry's sixth attribution bucket
``fault_lost`` (see :mod:`repro.obs.attribution`); fault instants surface
as markers in the Perfetto export.  See ``docs/resilience.md``.
"""

from __future__ import annotations

import dataclasses
import functools
import random

FAULT_KINDS = ("core_down", "core_up", "bw_derate", "slow_core")

#: what happens to a preempted segment's progress: ``"resume"`` keeps the
#: completed prefix up to the latest ``SimCarry`` snapshot boundary,
#: ``"restart"`` discards it (checkpoint-less hardware).  Migration across
#: heterogeneous designs always restarts -- engine state cannot move
#: between different pipelines.
PREEMPTION_POLICIES = ("resume", "restart")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timed fault event (see the module constructors).

    ``epoch`` is the scheduling epoch at whose boundary the event takes
    effect (before any segment starts at that boundary).  ``until`` bounds
    windowed events (``bw_derate`` requires it; ``slow_core`` treats
    ``None`` as "for the rest of the run").  ``factor`` is the derate /
    speed multiplier in ``(0, 1]``.
    """

    kind: str
    epoch: int
    core: int = -1
    factor: float = 1.0
    until: int | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"available: {FAULT_KINDS}")
        if self.epoch < 0:
            raise ValueError("fault epoch must be >= 0")
        if self.kind in ("core_down", "core_up", "slow_core") \
                and self.core < 0:
            raise ValueError(f"{self.kind} needs a core index")
        if self.kind in ("bw_derate", "slow_core") \
                and not 0.0 < self.factor <= 1.0:
            raise ValueError(f"{self.kind} factor must be in (0, 1] "
                             f"(got {self.factor}): a zero budget or speed "
                             f"would never finish")
        if self.kind == "bw_derate" and self.until is None:
            raise ValueError("bw_derate needs an epoch range: pass until")
        if self.until is not None and self.until <= self.epoch:
            raise ValueError(f"until={self.until} must be > "
                             f"epoch={self.epoch}")

    @property
    def label(self) -> str:
        """Human-readable marker text (Perfetto fault-instant markers)."""
        if self.kind == "core_down":
            return f"core{self.core} down"
        if self.kind == "core_up":
            return f"core{self.core} up"
        if self.kind == "bw_derate":
            return f"bw x{self.factor:g} [{self.epoch},{self.until})"
        return f"core{self.core} x{self.factor:g}"


def core_down(core: int, epoch: int) -> FaultEvent:
    """Core ``core`` goes offline at epoch ``epoch``'s boundary."""
    return FaultEvent("core_down", epoch, core)


def core_up(core: int, epoch: int) -> FaultEvent:
    """Core ``core`` comes back online at epoch ``epoch``'s boundary."""
    return FaultEvent("core_up", epoch, core)


def bw_derate(factor: float, epoch: int, until: int) -> FaultEvent:
    """Thermal throttle: scale the shared budget by ``factor`` over the
    epoch window ``[epoch, until)``.  Overlapping windows compound."""
    return FaultEvent("bw_derate", epoch, factor=factor, until=until)


def slow_core(core: int, factor: float, epoch: int = 0,
              until: int | None = None) -> FaultEvent:
    """DVFS throttle: core ``core`` runs at ``factor`` of nominal speed
    from ``epoch`` on (``until=None``: for the rest of the run).  A
    segment samples its core's speed at its start boundary and holds it
    for its whole run (segment-granular DVFS)."""
    return FaultEvent("slow_core", epoch, core, factor, until)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seedable schedule of fault events.

    Attach to :class:`~repro.multicore.chip.ChipConfig` via its
    ``fault_plan`` field.  ``preemption`` selects what a downed core's
    in-flight segment keeps (see :data:`PREEMPTION_POLICIES`).  Frozen and
    hashable; the empty plan (``FaultPlan()``) is a no-op by construction
    -- every simulator hook is gated on :attr:`is_empty`.
    """

    events: tuple[FaultEvent, ...] = ()
    preemption: str = "resume"

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        if self.preemption not in PREEMPTION_POLICIES:
            raise ValueError(f"unknown preemption policy "
                             f"{self.preemption!r}; available: "
                             f"{PREEMPTION_POLICIES}")

    @property
    def is_empty(self) -> bool:
        return not self.events

    @functools.cached_property
    def core_events(self) -> tuple[FaultEvent, ...]:
        """core_down/core_up events in epoch order (stable: same-epoch
        events apply in plan order)."""
        return tuple(sorted(
            (e for e in self.events if e.kind in ("core_down", "core_up")),
            key=lambda e: e.epoch))

    @property
    def has_core_events(self) -> bool:
        return bool(self.core_events)

    @functools.cached_property
    def _slow_events(self) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind == "slow_core")

    @property
    def has_slow_cores(self) -> bool:
        return bool(self._slow_events)

    @property
    def needs_online(self) -> bool:
        """Does this plan need the open-arrival machinery (preemption /
        migration / timed speed changes)?  Closed-batch runs support
        ``bw_derate`` windows and run-constant ``slow_core`` natively;
        anything event-driven mid-run routes through
        :func:`faulted_chip_report`."""
        return self.has_core_events or any(
            e.epoch > 0 or e.until is not None for e in self._slow_events)

    def budget_factors(self) -> tuple[float, ...]:
        """Per-epoch shared-budget multipliers from the ``bw_derate``
        windows (1.0 outside every window; overlaps compound)."""
        der = [e for e in self.events if e.kind == "bw_derate"]
        if not der:
            return ()
        fac = [1.0] * max(e.until for e in der)
        for e in der:
            for ep in range(e.epoch, e.until):
                fac[ep] *= e.factor
        return tuple(fac)

    def speed_factor(self, core: int, epoch: int) -> float:
        """Core ``core``'s speed multiplier at ``epoch`` (compounded over
        the active ``slow_core`` windows)."""
        f = 1.0
        for e in self._slow_events:
            if (e.core == core and e.epoch <= epoch
                    and (e.until is None or epoch < e.until)):
                f *= e.factor
        return f

    def core_available(self, core: int, epoch: int) -> bool:
        """Is ``core`` online at ``epoch`` (down/up events replayed)?"""
        up = True
        for e in self.core_events:
            if e.epoch > epoch:
                break
            if e.core == core:
                up = e.kind == "core_up"
        return up

    def next_core_event(self, after: int) -> int | None:
        """Earliest core_down/core_up epoch strictly after ``after``."""
        for e in self.core_events:
            if e.epoch > after:
                return e.epoch
        return None


#: the shared no-op plan (what ``ChipConfig.fault_plan=None`` means)
EMPTY_PLAN = FaultPlan()


def random_plan(n_cores: int, *, seed: int = 0, horizon: int = 64,
                n_core_faults: int = 1, down_epochs: int = 8,
                n_derates: int = 0, derate_factor: float = 0.5,
                derate_epochs: int = 8,
                preemption: str = "resume") -> FaultPlan:
    """Seedable random plan generator (the benchmark's fault-rate knob).

    Draws ``n_core_faults`` down/up pairs (each core offline for
    ``down_epochs``) and ``n_derates`` thermal windows uniformly over
    ``[1, horizon)``, all from ``random.Random(seed)`` -- same seed, same
    plan, on every backend and platform.
    """
    rng = random.Random(seed)
    events = []
    for _ in range(n_core_faults):
        c = rng.randrange(n_cores)
        d = rng.randrange(1, max(2, horizon - down_epochs))
        events.append(core_down(c, d))
        events.append(core_up(c, d + down_epochs))
    for _ in range(n_derates):
        s = rng.randrange(1, max(2, horizon - derate_epochs))
        events.append(bw_derate(derate_factor, s, s + derate_epochs))
    return FaultPlan(tuple(events), preemption=preemption)


def faulted_chip_report(shards, chip, workload_name: str, strategy: str,
                        telemetry=None, phase: str = ""):
    """Closed-batch entry point for plans with core events.

    The closed cluster's all-spans-start-at-0 fixed point cannot express
    preemption/migration, so a closed run whose plan ``needs_online`` is
    driven through :class:`~repro.multicore.online.OnlineChip`: every
    shard is submitted to its core at epoch 0, the chip drains through the
    plan's events, and the outcome is assembled into a normal
    :class:`~repro.multicore.chip.ChipReport` (with per-instance
    ``attribution_rows`` carrying the ``fault_lost`` bucket).
    """
    from ..obs.config import OFF
    from .chip import _single_core_cycles, assemble_online_report
    from .online import OnlineChip

    telemetry = telemetry if telemetry is not None else OFF
    sim = OnlineChip(chip, force_history=True)
    for core, shard in enumerate(shards):
        if shard:
            sim.submit(core, tuple(shard))
    sim.drain()
    specs = [s for shard in shards for s in shard]
    return assemble_online_report(
        sim, chip, workload_name, strategy, shards,
        _single_core_cycles(chip, specs), telemetry, phase=phase)
