"""Multi-core RASA CMP: contention-aware chip-level simulation.

The paper evaluates one RASA engine in one CPU core; this subsystem composes
``n_cores`` per-core :class:`~repro.core.timing.PipelineSimulator` instances
into a chip model and answers the next question up the stack: how does a
RASA CMP behave on a model's worth of GEMMs under shared-memory bandwidth
contention?

Layers
------
:mod:`~repro.multicore.arbiter`
    The **single** bandwidth-arbitration implementation: a monotone
    fixed-point relaxation over generic activity spans ``[start, end)``
    with pluggable share policies (equal / demand-weighted) and a
    settled-prefix cache.  The closed batch is the "all spans start at
    epoch 0" special case; the online model staggers the starts.
:mod:`~repro.multicore.chip`
    ``ChipConfig`` (a ``CoreSpec`` per core -- one design replicated or a
    mixed BASE/RASA vector -- x bandwidth budget x arbitration x share
    policy), the ``EpochBandwidthLoadModel`` epoch-sliced token-bucket
    arbiter (default) and the ``SharedBandwidthLoadModel`` static-share
    baseline, both plugged into each core's load/store ports,
    ``CoreCluster`` (the arbiter's closed-batch client: one stream per
    core, re-simulations batched through the fast backends), and
    ``ChipReport`` aggregates (makespan, per-core utilization, bandwidth
    stalls, per-epoch share/active traces, WLBP hit rate,
    speedup/efficiency vs. one core, core designs/weights).
:mod:`~repro.multicore.partition`
    Intra-GEMM parallelism: M-split / N-split / 2D block-cyclic sharding of
    one ``GemmSpec`` into per-core sub-GEMMs (output-space only; K is never
    split, so no cross-core reduction).
:mod:`~repro.multicore.scheduler`
    Inter-GEMM parallelism: static round-robin and dynamic work-queue /
    LPT placement of layer-level GEMM workloads, plus the ``gang``
    scheduler that splits a dominant GEMM across soon-idle cores
    (combined inter+intra parallelism) and ``assign_incremental`` for
    mid-run injection onto already-loaded cores.
:mod:`~repro.multicore.online`
    Open-arrival form of the chip model: segments of scheduled work
    arrive and depart at epoch boundaries while the chip is mid-run -- a
    thin incremental client of the same span arbiter, with retired-span
    pruning for thousand-request serving traces (drives the serving
    batcher in :mod:`repro.serving.simbatch`; see
    ``docs/serving_sim.md``).  ``OnlineChip.snapshot()`` /
    ``OnlineChip.restore()`` checkpoint long runs bit-exactly.
:mod:`~repro.multicore.faults`
    Deterministic fault injection over either client: timed ``core_down``
    / ``core_up`` events (preemption + migration), ``bw_derate`` thermal
    windows (scaled arbiter budgets) and ``slow_core`` DVFS throttles,
    described by a seedable ``FaultPlan`` on ``ChipConfig.fault_plan``
    (see ``docs/resilience.md``).

Modelling assumptions (see ``docs/multicore.md`` for details)
-------------------------------------------------------------
* Cores are homogeneous and private resources (register file, issue port,
  weight-insertion network) are per-core; tile loads *and* ``rasa_ts``
  stores share the chip's memory bandwidth (``store_bytes_shared=False``
  recovers the loads-only accounting).
* Contention is arbitrated in scheduling epochs (``epoch_cycles``): each
  epoch's equal share is recomputed over the cores still drawing on the
  budget, so early finishers return their bandwidth.  Bursts up to
  ``bw_burst_bytes`` pass at full LSQ rate, but unused allowance is capped
  at the burst -- bytes granted per epoch never exceed the epoch's budget
  (plus the burst and one in-flight tile).  ``arbitration="static"`` keeps
  the frozen equal-share model for comparison.
* At ``n_cores=1`` the full budget exceeds one engine's demand by design,
  so the chip model reduces exactly to the single-core simulator.

Entry point: :func:`simulate_chip` -- pass one ``GemmSpec`` (partitioned) or
a list of them (scheduled).
"""

from .arbiter import (MAX_ARBITER_ROUNDS, SHARE_POLICIES, ArbiterTrace,
                      DemandWeightedShare, SharePolicy, Span, SpanArbiter,
                      build_share_schedule, get_share_policy)
from .chip import (ARBITRATIONS, CHIP_BACKENDS, ChipConfig, ChipReport,
                   CoreCluster, CoreSpec, EpochBandwidthLoadModel,
                   SharedBandwidthLoadModel, partitioned_chip_report,
                   simulate_chip)
from .faults import (EMPTY_PLAN, FAULT_KINDS, PREEMPTION_POLICIES,
                     FaultEvent, FaultPlan, bw_derate, core_down, core_up,
                     faulted_chip_report, random_plan, slow_core)
from .online import OnlineChip, OnlineSnapshot, Segment
from .partition import PARTITIONERS, partition_gemm, split_ways
from .scheduler import (SCHEDULERS, assign, assign_incremental,
                        scheduled_chip_report)

__all__ = [
    "ARBITRATIONS", "CHIP_BACKENDS", "ArbiterTrace", "ChipConfig",
    "ChipReport", "CoreCluster", "CoreSpec",
    "EpochBandwidthLoadModel", "SharedBandwidthLoadModel",
    "MAX_ARBITER_ROUNDS", "SHARE_POLICIES", "SharePolicy",
    "DemandWeightedShare", "Span", "SpanArbiter", "get_share_policy",
    "build_share_schedule", "partitioned_chip_report", "simulate_chip",
    "OnlineChip", "OnlineSnapshot", "Segment",
    "EMPTY_PLAN", "FAULT_KINDS", "PREEMPTION_POLICIES", "FaultEvent",
    "FaultPlan", "bw_derate", "core_down", "core_up",
    "faulted_chip_report", "random_plan", "slow_core",
    "PARTITIONERS", "partition_gemm", "split_ways",
    "SCHEDULERS", "assign", "assign_incremental", "scheduled_chip_report",
]
