"""Multi-core RASA CMP: contention-aware chip-level simulation.

The paper evaluates one RASA engine in one CPU core; this subsystem composes
``n_cores`` per-core :class:`~repro.core.timing.PipelineSimulator` instances
into a chip model and answers the next question up the stack: how does a
RASA CMP behave on a model's worth of GEMMs under shared-memory bandwidth
contention?

Layers
------
:mod:`~repro.multicore.chip`
    ``ChipConfig`` (cores x design x bandwidth budget), the
    ``SharedBandwidthLoadModel`` leaky-bucket arbiter plugged into each
    core's load port, ``CoreCluster`` (runs one stream per core), and
    ``ChipReport`` aggregates (makespan, per-core utilization, bandwidth
    stalls, WLBP hit rate, speedup/efficiency vs. one core).
:mod:`~repro.multicore.partition`
    Intra-GEMM parallelism: M-split / N-split / 2D block-cyclic sharding of
    one ``GemmSpec`` into per-core sub-GEMMs (output-space only; K is never
    split, so no cross-core reduction).
:mod:`~repro.multicore.scheduler`
    Inter-GEMM parallelism: static round-robin and dynamic work-queue /
    LPT placement of layer-level GEMM workloads, one GEMM per core at a
    time.

Modelling assumptions (see ``docs/multicore.md`` for details)
-------------------------------------------------------------
* Cores are homogeneous and private resources (register file, issue port,
  weight-insertion network) are per-core; only tile-load bandwidth is shared.
* Contention is static equal-share: active cores each get
  ``bw_bytes_per_cycle / n_active``; bursts up to ``bw_burst_bytes`` pass at
  full LSQ rate.  There is no cycle-by-cycle cross-core arbitration.
* ``rasa_ts`` stores and instruction fetch are not counted against the
  budget (loads dominate: every B panel is re-streamed per C block).
* At ``n_cores=1`` the full budget exceeds one engine's demand by design,
  so the chip model reduces exactly to the single-core simulator.

Entry point: :func:`simulate_chip` -- pass one ``GemmSpec`` (partitioned) or
a list of them (scheduled).
"""

from .chip import (ChipConfig, ChipReport, CoreCluster,
                   SharedBandwidthLoadModel, partitioned_chip_report,
                   simulate_chip)
from .partition import PARTITIONERS, partition_gemm
from .scheduler import SCHEDULERS, assign, scheduled_chip_report

__all__ = [
    "ChipConfig", "ChipReport", "CoreCluster", "SharedBandwidthLoadModel",
    "partitioned_chip_report", "simulate_chip",
    "PARTITIONERS", "partition_gemm",
    "SCHEDULERS", "assign", "scheduled_chip_report",
]
