"""Open-arrival (online) chip simulation: work arrives and departs at
epoch boundaries while the chip is mid-run.

The closed-batch model (:class:`repro.multicore.chip.CoreCluster`) fixes
every core's stream up front and relaxes one share schedule over it.  The
serving question -- how many concurrent requests does the shared memory
system sustain? -- needs the *open* form: requests are injected while other
cores are mid-flight, and a request that drains returns its bandwidth to
the survivors.  :class:`OnlineChip` provides exactly that, as a **thin
incremental client** of the unified span arbiter
(:class:`repro.multicore.arbiter.SpanArbiter` -- the same fixed point the
closed batch uses, with staggered span starts):

* A **segment** (one or more :class:`~repro.core.tiling.GemmSpec` lowered
  back to back -- e.g. one serving request's prefill GEMM plus its decode
  micro-GEMMs) is submitted to a core's FIFO queue at the current epoch.
* A core **starts** its next queued segment at the first epoch boundary at
  which it is free.  Engine and LSQ/bucket state are fresh per segment:
  the chip hands work to cores at scheduling-epoch granularity, and the
  engine synchronizes between requests (different requests share no tile
  registers).  On a heterogeneous chip each segment runs on its core's
  own :class:`~repro.multicore.chip.CoreSpec` engine.
* **Bandwidth** is arbitrated by the span fixed point: epoch *e*'s share
  is recomputed over the segments active in *e* (weighted by the chip's
  ``share_policy``), so arrivals shrink the survivors' shares and
  departures return them.
* **Causality** makes the whole construction incremental: a segment's
  timing depends only on shares in epochs it overlaps, so an event at
  epoch *t* (arrival or start) can change shares only from *t* on --
  everything that finished before *t* is a settled fact.  Arrivals mark
  every in-flight segment dirty and the relaxation re-runs for the dirty
  set alone; the arbiter's **settled-prefix cache** keeps the share
  schedule below ``dirty_from`` verbatim, and segments whose span closed
  at or before the clock are *retired* -- pruned out of the relaxation
  set entirely, their contribution living on in the cached prefix.  This
  is what makes thousand-request serving traces tractable: per-settle
  work scales with the in-flight segments, not the whole history
  (``prefix_cache=False`` keeps the rebuild-from-epoch-0 baseline for
  ``benchmarks/online_scaling.py``).

Backends follow the chip model's contract: ``backend="reference"`` is the
oracle (each re-simulation replays the full stream through
:class:`~repro.core.timing.PipelineSimulator`); ``backend="fast"`` /
``"numpy"`` run the trace-compiled numpy recurrence and *resume* each
re-simulation from the latest :class:`~repro.core.fastsim.SimCarry`
snapshot taken before the first epoch whose share changed, instead of
replaying the prefix.  ``backend="jax"`` batches instead of resuming:
each relaxation round hands *all* of its dirty segments to one vmapped
:func:`~repro.core.fastsim.run_cores` scan (grouped by engine config and
bucket shape, so heterogeneous chips and ``slow_core``-dilated lanes
split into their own lanes automatically) -- one device dispatch per
round in place of one Python token-bucket replay per segment.  Results
are backend-independent and the jax lane is bit-exact with numpy
(``tests/test_online_jax.py`` pins BatchReport equality end to end).

The serving batcher (:mod:`repro.serving.simbatch`) drives this model:
admission policies query :meth:`OnlineChip.core_busy` /
:meth:`OnlineChip.live_share` / :meth:`OnlineChip.free_at_estimate` at
every decision epoch and inject admitted requests with
:meth:`OnlineChip.submit`.

:mod:`repro.multicore.jitarb` mirrors this entire client -- event loop,
admission decisions, demand-weighted shares, heterogeneous lanes,
settled-prefix window -- as ONE jitted ``lax.while_loop`` program on
fault-free chips, bit-identical on its domain (``Plan``-gated; the
incremental client here remains the oracle and the fallback).
"""

from __future__ import annotations

import copy
import dataclasses
import math
from collections import deque
from typing import Sequence

from ..core.fastsim import (SNAP_STRIDE, SimCarry, completed_prefix,
                            run_cores, run_segment)
from ..core.tiling import GemmSpec
from ..core.timing import PipelineSimulator, TimingResult
from ..core.trace import (OP_MM, OP_TL, OP_TS, CompiledTrace, compile_stream,
                          compiled_trace, slice_trace)
from ..obs.config import OFF, TelemetryConfig
from .arbiter import Span, SpanArbiter
from .chip import (ChipConfig, _lower_many, demands_bandwidth,
                   shared_traffic_bytes, stream_model_params)


@dataclasses.dataclass(eq=False)
class Segment:
    """One unit of scheduled work on one core (handle; identity-hashed).

    The segment's activity on the shared budget is its :attr:`span`
    (created when the core picks the segment up); :attr:`start` and
    :attr:`end` expose the span's absolute epochs -- the boundary at which
    the segment started, and the first epoch in which it no longer draws
    on the budget (``None`` while queued / unsettled).
    """

    sid: int
    core: int
    specs: tuple[GemmSpec, ...]
    submit_epoch: int
    demands: bool = True
    weight: float = 1.0
    span: Span | None = dataclasses.field(default=None, repr=False)
    # -- cached simulation state (managed by OnlineChip) --
    stream: tuple | None = dataclasses.field(default=None, repr=False)
    trace: CompiledTrace | None = dataclasses.field(default=None, repr=False)
    result: TimingResult | None = dataclasses.field(default=None, repr=False)
    _snaps: list[SimCarry] = dataclasses.field(default_factory=list,
                                               repr=False)
    # -- fault-injection state (see repro.multicore.faults) --
    #: core speed factor sampled at the start boundary (slow_core events)
    speed: float = 1.0
    #: instruction offset of this instance within the originally submitted
    #: stream (> 0 for a resumed preemption remainder)
    resume_from: int = 0
    #: sid of the preempted instance this segment resumes, if any
    origin_sid: int | None = None
    #: absolute cycles at which this instance was preempted (core_down)
    preempted_at: float | None = None
    #: instructions whose progress survived the preemption (the remainder
    #: resumes after them; 0 under preemption="restart" / migration across
    #: heterogeneous designs)
    kept_instrs: int = 0
    #: chip-cycle FF compute / useful MACs of the kept prefix -- the
    #: telemetry attribution of the preempted instance (fault_lost bucket
    #: absorbs the rest of its busy interval)
    kept_compute: float = 0.0
    kept_macs: float = 0.0

    @property
    def start(self) -> int | None:
        return self.span.start if self.span is not None else None

    @property
    def end(self) -> int | None:
        return self.span.end if self.span is not None else None

    @property
    def macs(self) -> int:
        return sum(s.macs for s in self.specs)


def _first_change(old: tuple, new: tuple) -> int | None:
    """First local epoch at which two visible schedules differ.

    A visible schedule is ``(share_prefix, tail_share)``.  Returns None
    when they are effectively identical; otherwise the earliest epoch any
    arithmetic could diverge -- conservative about prefix-length changes
    (the scheduled-vs-tail code paths are mathematically equal but not
    bit-identical, so a length change dirties everything past the shorter
    prefix).
    """
    (s1, t1), (s2, t2) = old, new
    n = min(len(s1), len(s2))
    for k in range(n):
        if s1[k] != s2[k]:
            return k
    if len(s1) != len(s2) or t1 != t2:
        return n
    return None


class OnlineChip:
    """Event-driven open-arrival chip simulation (see module docs).

    The driver advances time explicitly: :meth:`submit` enqueues work at
    the current epoch, :meth:`advance_to` moves the clock (starting queued
    segments at every intermediate boundary where a core frees up), and
    :meth:`next_event` reports the next epoch at which the chip's state
    changes on its own.  All query methods settle the arbiter fixed point
    lazily first, so observed shares/finish times are always converged.
    """

    def __init__(self, chip: ChipConfig, snap_stride: int = SNAP_STRIDE,
                 prefix_cache: bool = True,
                 telemetry: TelemetryConfig = OFF,
                 force_history: bool = False):
        if chip.arbitration != "epoch":
            raise ValueError("the online model is the epoch arbiter's "
                             "open-arrival form; use arbitration='epoch'")
        if snap_stride < 1:
            raise ValueError("snap_stride must be >= 1")
        self.chip = chip
        self.snap_stride = snap_stride
        #: observability opt-in; when enabled, started segments are kept in
        #: :attr:`history` with their lowered stream / compiled trace so the
        #: telemetry builders can replay them after the run.
        self.telemetry = telemetry
        #: keep :attr:`history` even without telemetry -- the closed-batch
        #: fault router (:func:`repro.multicore.faults.faulted_chip_report`)
        #: assembles its report from the per-segment outcomes post-hoc
        self._keep_history = telemetry.enabled or force_history
        #: every started segment, in start order -- populated only when
        #: history is kept (retirement stays free-to-prune otherwise)
        self.history: list[Segment] = []
        self.epoch = 0
        self._E = chip.epoch_cycles
        self._budget = chip.bw_bytes_per_cycle
        self._ref = chip.backend == "reference"
        #: jax fast lane: settle rounds batch all dirty segments into one
        #: vmapped scan (``_simulate_batch``) instead of per-segment
        #: snapshot-resumed numpy replays.  Bit-exact with numpy.
        self._jax = chip.backend == "jax"
        #: the fault plan driving core_down/up preemption, budget derating
        #: and slow cores; ``None`` when faults are off (the common case:
        #: every fault hook below is gated on it, so an empty plan is
        #: arithmetic-identical to no plan at all)
        plan = chip.fault_plan
        self._plan = plan if plan is not None and not plan.is_empty else None
        self._fault_events = list(self._plan.core_events) if self._plan \
            else []
        self._next_fault = 0
        self._down = [False] * chip.n_cores
        #: (epoch, label) log of applied core events (telemetry markers)
        self.fault_log: list[tuple[int, str]] = []
        self.n_preempted = 0
        self.n_migrated = 0
        #: chip cycles of discarded progress across all preemptions
        self.fault_lost_cycles = 0.0
        #: preempted sid -> the instance that resumed it; holds retired
        #: resume instances strongly so :meth:`final_instance` works after
        #: pruning (empty on fault-free runs)
        self._resume_of: dict[int, Segment] = {}
        #: the unified relaxation engine; ``prefix_cache=False`` keeps the
        #: rebuild-from-epoch-0 baseline (and disables span pruning, which
        #: depends on the settled prefix carrying retired contributions)
        self._arb = SpanArbiter(self._budget, self._E, chip.share_policy,
                                unthrottled_skip=not self._ref,
                                prefix_cache=prefix_cache,
                                budget_factors=self._plan.budget_factors()
                                if self._plan else ())
        self._prune = prefix_cache
        self._queues: list[deque[Segment]] = [deque()
                                              for _ in range(chip.n_cores)]
        #: started, non-retired segments -- the arbiter's relaxation set
        self._active: list[Segment] = []
        #: aggregates over retired (pruned) segments
        self._retired_makespan = 0.0
        self._core_retired_epoch = [0] * chip.n_cores
        self._core_retired_cycles = [0.0] * chip.n_cores
        self.n_retired = 0
        self._next_sid = 0
        self._dirty = False
        self._dirty_from = math.inf     # earliest epoch whose share moved
        #: instrumentation: arbiter settles/rounds and how the fast path
        #: re-simulated (full replays vs. snapshot resumes vs. pure skips).
        self.stats = {"settles": 0, "rounds": 0, "sims_full": 0,
                      "sims_resumed": 0, "instrs_resumed_past": 0,
                      "preempt_replay_instrs": 0}

    # ------------------------------------------------------------ driver
    def submit(self, core: int, specs: Sequence[GemmSpec]) -> Segment:
        """Enqueue a segment on ``core`` at the current epoch.

        The segment starts at the first epoch boundary >= now at which the
        core is free (immediately, if it is free now).
        """
        seg = self._enqueue(core, specs)
        self._pump(self.epoch)
        return seg

    def submit_batch(self, assignments: Sequence[tuple[int, Sequence[GemmSpec]]]
                     ) -> list[Segment]:
        """Enqueue several segments at the current epoch, then start them
        together: one arbiter relaxation for the whole admission batch
        instead of one per :meth:`submit` (the batcher's hot path)."""
        segs = [self._enqueue(core, specs) for core, specs in assignments]
        self._pump(self.epoch)
        return segs

    def _enqueue(self, core: int, specs: Sequence[GemmSpec]) -> Segment:
        specs = tuple(specs)
        if not specs:
            raise ValueError("empty segment")
        if not 0 <= core < self.chip.n_cores:
            raise ValueError(f"core {core} out of range")
        if self._down[core]:
            # submissions blind to the fault state (e.g. a fixed
            # round-robin batcher) are rerouted to the best surviving core;
            # with every core down the work waits for a core_up
            self._settle()
            alt = self._pick_target()
            if alt is not None and alt != core:
                core = alt
                self.n_migrated += 1
        seg = Segment(self._next_sid, core, specs, self.epoch)
        self._next_sid += 1
        core_spec = self.chip.core_specs[core]
        if self._ref:
            seg.stream = tuple(_lower_many(specs, core_spec.policy))
        else:
            seg.trace = compiled_trace(
                tuple(dataclasses.replace(s, name="") for s in specs),
                core_spec.policy)
        seg.demands = demands_bandwidth(self.chip, seg.stream, seg.trace)
        if seg.demands and self.chip.share_policy.needs_demand:
            seg.weight = self.chip.share_policy.weight(self._demand_of(seg))
        self._queues[core].append(seg)
        return seg

    def _demand_of(self, seg: Segment) -> float:
        """Unthrottled bytes/cycle of a segment (the demand policy's
        weight input) -- one extra unthrottled probe per admission."""
        engine = self.chip.core_specs[seg.core].engine
        params = stream_model_params(self.chip, engine)
        if self._ref:
            res = PipelineSimulator(engine,
                                    load_model=params.make_model()) \
                .run(seg.stream)
        else:
            res, _, _ = run_segment(seg.trace, engine, params)
        traffic = shared_traffic_bytes(self.chip, seg.stream, seg.trace)
        return traffic / res.cycles if res.cycles else 0.0

    def advance_to(self, epoch: int) -> None:
        """Move the clock to ``epoch``, starting queued segments at every
        intermediate boundary where their core frees up (in causal order)."""
        if epoch < self.epoch:
            raise ValueError(f"cannot rewind from {self.epoch} to {epoch}")
        self._pump(epoch)
        self.epoch = epoch
        self._retire()

    def next_event(self) -> int | None:
        """Earliest epoch > now at which the chip changes on its own: a
        queued segment starts, or a busy core finishes its started work."""
        self._pump(self.epoch)
        self._settle()
        cands = []
        for c in range(self.chip.n_cores):
            if self._down[c]:
                # nothing can start here until a core_up (which is itself
                # a candidate below); queued work on a fully-down chip
                # must not busy-loop the driver
                continue
            f = self._core_free_epoch(c)
            if self._queues[c]:
                f = max(f, self._queues[c][0].submit_epoch)
            if f > self.epoch:
                cands.append(f)
        if self._next_fault < len(self._fault_events):
            # pending core events change the chip's state on their own
            # (preemption, migration, a downed queue waking up)
            cands.append(self._fault_events[self._next_fault].epoch)
        return min(cands, default=None)

    def drain(self) -> None:
        """Advance until every queue is empty and all work has retired."""
        while True:
            e = self.next_event()
            if e is None:
                return
            self.advance_to(e)

    # ----------------------------------------------- live chip state
    def core_busy(self) -> list[bool]:
        """Is each core occupied (running or queued work) right now?
        Downed cores read as busy -- they cannot take work."""
        self._settle()
        return [self._down[c] or self._core_free_epoch(c) > self.epoch
                or bool(self._queues[c]) for c in range(self.chip.n_cores)]

    def n_active(self) -> int:
        """Segments drawing on the shared budget in the current epoch."""
        self._settle()
        return sum(1 for s in self._active
                   if s.demands and s.start <= self.epoch
                   and (s.end is None or s.end > self.epoch))

    def live_share(self) -> float:
        """Bytes/cycle each active segment is granted in the current epoch
        (under equal shares; the weighted mean share otherwise)."""
        return self._budget / max(1, self.n_active())

    def free_at_estimate(self) -> list[float]:
        """Per-core busy-until estimate (absolute cycles): the settled
        finish of started work plus unthrottled cost estimates of queued
        segments -- the ``free_at`` vector incremental placement wants.
        Queued estimates are costed on each core's own design (mixed
        chips)."""
        from .scheduler import _estimate_cycles
        self._settle()
        now = self.epoch * self._E
        out = []
        for c in range(self.chip.n_cores):
            if self._down[c]:
                out.append(math.inf)
                continue
            t = max((self._finish(s) for s in self._active if s.core == c),
                    default=0.0)
            t = max(t, self._core_retired_cycles[c], now)
            for seg in self._queues[c]:
                t += sum(_estimate_cycles(s, self.chip, c)
                         for s in seg.specs)
            out.append(t)
        return out

    # ----------------------------------------------------- results
    def finish_time(self, seg: Segment) -> float:
        """Absolute retire time (cycles) of a started segment."""
        self._settle()
        if seg.span is None or seg.result is None:
            raise RuntimeError(f"segment {seg.sid} has not started")
        return self._finish(seg)

    def resume_of(self, seg: Segment) -> Segment | None:
        """The instance that resumed ``seg`` after its preemption (None
        for a segment that was never preempted)."""
        return self._resume_of.get(seg.sid)

    def final_instance(self, seg: Segment) -> Segment:
        """Follow preemption-resume chains to the instance that carries
        the logical work submitted as ``seg`` to completion.  Identity on
        fault-free runs; the serving batcher resolves request finish
        times through this."""
        while seg.preempted_at is not None:
            seg = self._resume_of[seg.sid]
        return seg

    @property
    def down_cores(self) -> tuple[bool, ...]:
        """Per-core offline flags under the fault plan (all False without
        one) -- the ``degraded`` admission policy's health signal."""
        return tuple(self._down)

    @property
    def makespan(self) -> float:
        """Latest settled retire time over all started segments."""
        self._settle()
        live = max((self._finish(s) for s in self._active), default=0.0)
        return max(live, self._retired_makespan)

    @property
    def share_trace(self) -> tuple[float, ...]:
        """Converged bytes/cycle per unit weight, per epoch (equal shares:
        the bytes/cycle each active segment receives)."""
        self._settle()
        return self._arb.share_trace

    @property
    def active_trace(self) -> tuple[int, ...]:
        self._settle()
        return self._arb.active_trace

    # --------------------------------------------------- internals
    def _finish(self, seg: Segment) -> float:
        return seg.span.start * self._E + seg.result.cycles

    def _core_free_epoch(self, c: int) -> int:
        """First epoch boundary at which core ``c``'s started work is done
        (requires settled state)."""
        e = self._core_retired_epoch[c]
        for s in self._active:
            if s.core == c:
                e = max(e, s.span.start,
                        math.ceil(self._finish(s) / self._E))
        return e

    def _pump(self, upto: int) -> None:
        """Start queued segments at every boundary <= ``upto`` where their
        core is free, earliest boundary first (ties by core index): a start
        at epoch *b* only changes shares in epochs >= *b*, so processing in
        nondecreasing *b* keeps every earlier decision a settled fact.

        All queue heads sharing the minimal boundary start in one pass
        before re-settling -- same-boundary starts are independent (no
        core's free epoch <= *b* can move on a share change at >= *b*),
        and one relaxation per boundary beats one per segment.
        """
        while True:
            self._settle()
            fault_at = None
            if self._next_fault < len(self._fault_events):
                e = self._fault_events[self._next_fault].epoch
                if e <= upto:
                    fault_at = e
            cands: list[tuple[int, int]] = []
            for c in range(self.chip.n_cores):
                if self._down[c] or not self._queues[c]:
                    continue
                b = max(self._core_free_epoch(c),
                        self._queues[c][0].submit_epoch)
                if b <= upto:
                    cands.append((b, c))
            if fault_at is not None and (
                    not cands or fault_at <= min(b for b, _ in cands)):
                # fault events apply at the boundary *before* any start
                # there: a core_down preempts first, a core_up makes the
                # core a start candidate on the next sweep
                self._process_faults(fault_at)
                continue
            if not cands:
                return
            b_min = min(b for b, _ in cands)
            for b, c in sorted(cands):
                if b != b_min:
                    continue
                seg = self._queues[c].popleft()
                if self._plan is not None:
                    seg.speed = self._plan.speed_factor(c, b_min)
                seg.span = Span(start=b_min,
                                end=None if seg.demands else b_min,
                                demands=seg.demands, weight=seg.weight)
                self._active.append(seg)
                if self._keep_history:
                    self.history.append(seg)
                if seg.demands:
                    self._mark_dirty(b_min)
                else:
                    # zero shared-memory traffic: shares cannot change,
                    # only the new segment itself needs simulating
                    self._dirty = True

    def _process_faults(self, epoch: int) -> None:
        """Apply every core_down/core_up event scheduled at ``epoch``
        (in plan order; the caller guarantees settled state)."""
        while (self._next_fault < len(self._fault_events)
               and self._fault_events[self._next_fault].epoch == epoch):
            ev = self._fault_events[self._next_fault]
            self._next_fault += 1
            self.fault_log.append((epoch, ev.label))
            if ev.kind == "core_down":
                self._core_down(ev.core, epoch)
            else:
                self._down[ev.core] = False

    def _core_down(self, core: int, epoch: int) -> None:
        """Take ``core`` offline: preempt its in-flight segment at this
        boundary and migrate its queue to the surviving cores."""
        self._down[core] = True
        T = epoch * self._E
        changed = False
        for seg in list(self._active):
            if (seg.core == core and seg.preempted_at is None
                    and self._finish(seg) > T):
                changed |= self._preempt(seg, epoch)
        q = self._queues[core]
        if q:
            moved = list(q)
            q.clear()
            for seg in moved:
                self._migrate_queued(seg)
        if changed:
            self._mark_dirty(epoch)

    def _pick_target(self) -> int | None:
        """The best surviving core for displaced work: earliest free, then
        shortest queue, then lowest index (deterministic).  None when every
        core is down."""
        best_key = best = None
        for c in range(self.chip.n_cores):
            if self._down[c]:
                continue
            key = (self._core_free_epoch(c), len(self._queues[c]), c)
            if best_key is None or key < best_key:
                best_key, best = key, c
        return best

    def _preempt(self, seg: Segment, epoch: int) -> bool:
        """Cut a running segment at the ``epoch`` boundary (its core went
        down) and requeue the remainder on the best surviving core.

        The cut is the deterministic :func:`completed_prefix` replay of
        the segment's settled visible schedule: instructions fully retired
        by the boundary survive, rounded down to the ``SimCarry`` snapshot
        stride under ``preemption="resume"`` (state is recovered from the
        latest checkpoint, not from the dying core's registers) or
        discarded entirely under ``"restart"``.  Migration to a different
        core design always restarts -- pipeline state cannot cross
        engines.  Returns True when the preempted span's activity shrank
        (the caller re-relaxes from ``epoch``).
        """
        span = seg.span
        engine = self.chip.core_specs[seg.core].engine
        T = epoch * self._E
        f = seg.speed
        prefix, tail = span._vis if span._vis is not None \
            else ((), math.inf)
        if f != 1.0:
            params = stream_model_params(self.chip, engine,
                                         tuple(s / f for s in prefix),
                                         self._E * f, tail / f)
        else:
            params = stream_model_params(self.chip, engine, prefix,
                                         self._E, tail)
        trace = seg.trace if seg.trace is not None \
            else compile_stream(seg.stream)
        limit = (T - span.start * self._E) * f
        # resume the cut replay from the segment's latest checkpoint whose
        # completions all land at or before the boundary (recorded under
        # the same settled schedule ``params`` was built from) -- repeated
        # preemptions then replay only the work past the last snapshot
        # instead of the whole segment history each time
        cut_carry = None
        for c in seg._snaps:
            if c.t_end <= limit and (cut_carry is None
                                     or c.i > cut_carry.i):
                cut_carry = c
        n_done = completed_prefix(trace, engine, params, limit,
                                  carry=cut_carry)
        self.stats["preempt_replay_instrs"] += \
            n_done - (cut_carry.i if cut_carry else 0)
        target = self._pick_target()
        if target is None:
            target = seg.core        # all cores down: wait for a core_up
        same_design = (self.chip.core_specs[target]
                       == self.chip.core_specs[seg.core])
        keep = 0
        if self._plan.preemption == "resume" and same_design:
            keep = (n_done // self.snap_stride) * self.snap_stride

        # the preempted instance: busy from its start to the boundary,
        # credited with the kept prefix's compute/MACs; the rest of its
        # busy interval is lost work (the fault_lost attribution bucket)
        op = trace.opcode[:keep]
        kept_macs = float(trace.macs[:keep].sum())
        kept_compute = float(trace.tm[:keep].sum()) / f
        busy = T - span.start * self._E
        seg.result = TimingResult(
            cycles=busy, n_mm=int((op == OP_MM).sum()),
            n_tl=int((op == OP_TL).sum()), n_ts=int((op == OP_TS).sum()),
            wl_skips=int(trace.reusable[:keep].sum()) if engine.wlbp else 0,
            useful_macs=kept_macs,
            peak_macs_per_cycle=engine.peak_macs_per_cycle,
            bw_stall_cycles=0.0, schedules=None)
        seg.preempted_at = T
        seg.kept_instrs = keep
        seg.kept_compute = kept_compute
        seg.kept_macs = kept_macs
        self.n_preempted += 1
        self.fault_lost_cycles += busy - kept_compute

        # the remainder: a fresh segment submitted at the fault boundary
        new = Segment(self._next_sid, target, seg.specs, epoch)
        self._next_sid += 1
        new.origin_sid = seg.sid
        new.resume_from = seg.resume_from + keep
        if same_design:
            if keep:
                if self._ref:
                    new.stream = seg.stream[keep:]
                else:
                    new.trace = slice_trace(seg.trace, keep)
            else:
                new.stream = seg.stream
                new.trace = seg.trace
        else:
            policy = self.chip.core_specs[target].policy
            if self._ref:
                new.stream = tuple(_lower_many(seg.specs, policy))
            else:
                new.trace = compiled_trace(
                    tuple(dataclasses.replace(s, name="")
                          for s in seg.specs), policy)
        new.demands = demands_bandwidth(self.chip, new.stream, new.trace)
        if new.demands and self.chip.share_policy.needs_demand:
            new.weight = self.chip.share_policy.weight(self._demand_of(new))
        self._queues[target].append(new)
        self._resume_of[seg.sid] = new
        if target != seg.core:
            self.n_migrated += 1

        # freeze the preempted span at the boundary.  last_grant is pinned
        # so the arbiter's convergence recompute (start + last_grant//E + 1)
        # lands exactly back on the truncated end -- the span is a settled
        # fact from here on and is never re-simulated.
        if span.end is None or span.end > epoch:
            span.end = epoch
            span.last_grant = max(0.0, (epoch - span.start - 1) * self._E)
            return seg.demands
        return False

    def _migrate_queued(self, seg: Segment) -> None:
        """Move a queued (not yet started) segment off a downed core."""
        target = self._pick_target()
        if target is None or target == seg.core:
            # every core down: leave it queued until a core_up
            self._queues[seg.core].append(seg)
            return
        if (self.chip.core_specs[target]
                != self.chip.core_specs[seg.core]):
            # different design: the queued lowering is invalid there
            policy = self.chip.core_specs[target].policy
            if self._ref:
                seg.stream = tuple(_lower_many(seg.specs, policy))
                seg.trace = None
            else:
                seg.trace = compiled_trace(
                    tuple(dataclasses.replace(s, name="")
                          for s in seg.specs), policy)
                seg.stream = None
            seg.core = target
            seg.demands = demands_bandwidth(self.chip, seg.stream,
                                            seg.trace)
            seg.weight = 1.0
            if seg.demands and self.chip.share_policy.needs_demand:
                seg.weight = self.chip.share_policy.weight(
                    self._demand_of(seg))
        else:
            seg.core = target
        self._queues[target].append(seg)
        self.n_migrated += 1

    def _retire(self) -> None:
        """Prune segments that are facts out of the relaxation set.

        Events only ever occur at epochs >= ``self.epoch`` (``_pump``
        processes intermediate boundaries before the clock moves), so a
        segment whose activity span closed at or before now can never be
        marked dirty again: its result stands, its contribution to the
        share schedule lives on in the arbiter's settled prefix, and its
        snapshots, lowered stream/trace reference and span bookkeeping are
        dead weight over a long serving run.  Per-core/chip maxima are
        folded into scalar aggregates so queries stay O(in-flight).

        With ``prefix_cache=False`` (the benchmark baseline) nothing is
        pruned: the rebuild-from-0 arbiter re-derives every epoch from the
        full span set, so every span must stay in it.
        """
        if not self._prune:
            return
        keep: list[Segment] = []
        for s in self._active:
            if s.end is None or s.end > self.epoch:
                keep.append(s)
                continue
            f = self._finish(s)
            c = s.core
            self._retired_makespan = max(self._retired_makespan, f)
            self._core_retired_cycles[c] = max(self._core_retired_cycles[c],
                                               f)
            self._core_retired_epoch[c] = max(
                self._core_retired_epoch[c], s.span.start,
                math.ceil(f / self._E))
            self.n_retired += 1
            s._snaps = []
            if not self._keep_history:
                # telemetry replays retired segments post-hoc, so the
                # lowered stream / compiled trace must survive retirement
                s.stream = s.trace = None
        self._active = keep

    def _mark_dirty(self, from_epoch: int) -> None:
        """An event at ``from_epoch`` invalidates every segment still
        active there: back to 'active indefinitely' for the relaxation."""
        self._dirty = True
        self._dirty_from = min(self._dirty_from, from_epoch)
        for s in self._active:
            if s.demands and (s.end is None or s.end > from_epoch):
                s.span.end = None

    def _settle(self) -> None:
        """Relax the share schedule to its fixed point (the thin client).

        All relaxation logic -- schedule building, skip rules, monotone
        convergence, the settled-prefix cache -- lives in
        :class:`SpanArbiter`; this method only maps spans back to segments
        and runs their (resumable) re-simulations.
        """
        if not self._dirty:
            return
        self.stats["settles"] += 1
        segs = self._active
        spans = [s.span for s in segs]
        if math.isinf(self._dirty_from):
            # no share moved (non-demanding starts only): keep the whole
            # settled schedule, just simulate the new segments
            dirty_from = self._arb.settled_horizon
        else:
            dirty_from = int(self._dirty_from)

        if self._jax:
            def simulate(jobs):
                self._simulate_batch(segs, jobs)
        else:
            def simulate(jobs):
                for i, prefix, tail in jobs:
                    self._simulate(segs[i], (prefix, tail))

        # The settle is transactional: if relax (or a simulate callback)
        # raises, the arbiter's rebuilt suffix and every span/segment it
        # touched are restored, and the dirty marker survives -- so a
        # retry sees exactly the pre-settle state instead of a half
        # rebuilt schedule disagreeing with a cleared marker.
        arb = self._arb
        d0 = dirty_from if arb.prefix_cache else 0
        saved_w, saved_n = arb._wsum[d0:], arb._nact[d0:]
        saved_stamp = arb._stamp
        saved = [(s.span.end, s.span.last_grant, s.span.throttled,
                  s.span._vis, s.span._stamp, s.result, s._snaps)
                 for s in segs]
        try:
            trace = arb.relax(spans, simulate, dirty_from=dirty_from,
                              collect_trace=False)
        except BaseException:
            del arb._wsum[d0:]
            arb._wsum.extend(saved_w)
            del arb._nact[d0:]
            arb._nact.extend(saved_n)
            arb._stamp = saved_stamp
            for s, (end, lg, th, vis, stamp, res, snaps) in zip(segs, saved):
                s.span.end = end
                s.span.last_grant = lg
                s.span.throttled = th
                s.span._vis = vis
                s.span._stamp = stamp
                s.result = res
                s._snaps = snaps
            raise
        self.stats["rounds"] += trace.rounds
        self._dirty = False
        self._dirty_from = math.inf

    def _simulate(self, seg: Segment, vis: tuple) -> None:
        """(Re-)simulate one segment under its visible schedule.

        The reference oracle replays the full stream; the fast path
        resumes from the latest snapshot whose horizon precedes the first
        changed epoch (snapshots before it stay valid, ones after it are
        discarded and re-recorded).  ``seg.span._vis`` still holds the
        *previous* visible schedule here -- the arbiter updates it only
        after the simulation batch returns.

        A slowed core (``slow_core`` fault) is simulated in its own
        dilated time base: chip epoch ``E`` spans ``E * speed`` local
        engine cycles, so the visible chip-cycle schedule maps to local
        shares ``s / speed`` over local epochs ``E * speed``, and the
        local results map back by ``1 / speed``.  Exact: the recurrence is
        positively homogeneous in the time unit.
        """
        if seg.preempted_at is not None:
            # a preempted instance's truncated result is a settled fact
            # (its span can never rejoin the relaxation)
            return
        prefix, tail = vis
        engine = self.chip.core_specs[seg.core].engine
        f = seg.speed
        if f != 1.0:
            params = stream_model_params(self.chip, engine,
                                         tuple(s / f for s in prefix),
                                         self._E * f, tail / f)
        else:
            params = stream_model_params(self.chip, engine, prefix,
                                         self._E, tail)
        if self._ref:
            model = params.make_model()
            res = PipelineSimulator(engine,
                                    load_model=model).run(seg.stream)
            last_grant = model.last_grant
            self.stats["sims_full"] += 1
        else:
            carry = None
            old_vis = seg.span._vis
            if old_vis is not None and seg._snaps:
                x = _first_change(old_vis, vis)
                if x is not None:
                    boundary = x * self._E * f if f != 1.0 else x * self._E
                    for c in seg._snaps:
                        if c.horizon <= boundary:
                            carry = c
                        else:
                            break
            res, last_grant, snaps = run_segment(
                seg.trace, engine, params, carry=carry,
                snap_stride=self.snap_stride)
            if carry is None:
                seg._snaps = snaps
                self.stats["sims_full"] += 1
            else:
                # snaps now leads with the carry-in itself (the boundary
                # snapshot), so keep strictly-earlier checkpoints only
                seg._snaps = [c for c in seg._snaps
                              if c.i < carry.i] + snaps
                self.stats["sims_resumed"] += 1
                self.stats["instrs_resumed_past"] += carry.i
        if f != 1.0:
            res = dataclasses.replace(
                res, cycles=res.cycles / f,
                bw_stall_cycles=res.bw_stall_cycles / f)
            last_grant = last_grant / f
        seg.result = res
        seg.span.last_grant = last_grant
        seg.span.throttled = res.bw_stall_cycles != 0.0

    def _simulate_batch(self, segs: list[Segment], jobs) -> None:
        """One relaxation round's re-simulations as a single batched call.

        The jax lane of :meth:`_settle`: every dirty bucket-throttled
        segment in the round becomes one lane of a vmapped
        :func:`run_cores` scan.  ``run_cores`` groups lanes by engine
        config and bucket shape, so heterogeneous chips and slow-core
        dilated time bases (``E * speed`` epochs) land in their own
        compiled executables without special-casing here.  Lanes whose
        visible schedule reduces to the unthrottled port model -- the
        non-demanding segments, each simulated exactly once -- keep the
        host path: they cannot amortize a separate port-model compile.

        Snapshot checkpoints are not recorded on this path (the batch
        re-simulates from scratch every round, which is exactly what the
        vmapped scan is fast at); a later preemption of a jax-simulated
        segment falls back to the full ``completed_prefix`` replay.
        """
        batch: list[tuple[Segment, object, object, float]] = []
        for i, prefix, tail in jobs:
            seg = segs[i]
            if seg.preempted_at is not None:
                # settled fact, same as the host path
                continue
            engine = self.chip.core_specs[seg.core].engine
            f = seg.speed
            if f != 1.0:
                params = stream_model_params(self.chip, engine,
                                             tuple(s / f for s in prefix),
                                             self._E * f, tail / f)
            else:
                params = stream_model_params(self.chip, engine, prefix,
                                             self._E, tail)
            if params.is_port_model:
                self._simulate(seg, (prefix, tail))
                continue
            batch.append((seg, engine, params, f))
        if not batch:
            return
        out = run_cores([seg.trace for seg, _, _, _ in batch],
                        [engine for _, engine, _, _ in batch],
                        [params for _, _, params, _ in batch],
                        backend="jax")
        for (seg, _, _, f), (res, last_grant) in zip(batch, out):
            if f != 1.0:
                res = dataclasses.replace(
                    res, cycles=res.cycles / f,
                    bw_stall_cycles=res.bw_stall_cycles / f)
                last_grant = last_grant / f
            seg.result = res
            seg.span.last_grant = last_grant
            seg.span.throttled = res.bw_stall_cycles != 0.0
            seg._snaps = []
            self.stats["sims_full"] += 1

    # ------------------------------------------------ checkpoint/resume
    def snapshot(self) -> "OnlineSnapshot":
        """Checkpoint the complete simulation state (see
        :class:`OnlineSnapshot`).

        The arbiter is settled first, so the captured state is a fixed
        point: dirty flags need not be stored, and a restored chip resumes
        with exactly the settled prefix, span ends, ``SimCarry`` snapshot
        lists and fault bookkeeping of the original -- continuing a
        restored run is bit-identical to never having checkpointed
        (pinned by ``tests/test_faults.py``).  The snapshot owns deep
        copies of all mutable state (further simulation on ``self`` cannot
        corrupt it) and shares the immutable heavyweights (compiled
        traces, lowered streams, results, carries).
        """
        self._pump(self.epoch)
        self._settle()
        state = dict(
            epoch=self.epoch,
            queues=[list(q) for q in self._queues],
            active=list(self._active),
            history=list(self.history),
            retired_makespan=self._retired_makespan,
            core_retired_epoch=list(self._core_retired_epoch),
            core_retired_cycles=list(self._core_retired_cycles),
            n_retired=self.n_retired,
            next_sid=self._next_sid,
            stats=dict(self.stats),
            wsum=list(self._arb._wsum),
            nact=list(self._arb._nact),
            stamp=self._arb._stamp,
            rounds_total=self._arb.rounds_total,
            next_fault=self._next_fault,
            resume_of=dict(self._resume_of),
            down=list(self._down),
            fault_log=list(self.fault_log),
            n_preempted=self.n_preempted,
            n_migrated=self.n_migrated,
            fault_lost_cycles=self.fault_lost_cycles,
        )
        return OnlineSnapshot(self.chip, self.snap_stride, self._prune,
                              self.telemetry, self._keep_history,
                              _copy_state(state))

    @classmethod
    def restore(cls, snap: "OnlineSnapshot") -> "OnlineChip":
        """Rebuild a chip from a checkpoint (the snapshot stays usable:
        restoring twice yields two independent simulations)."""
        sim = cls(snap.chip, snap.snap_stride, snap.prefix_cache,
                  snap.telemetry, force_history=snap.force_history)
        st = _copy_state(snap.state)
        sim.epoch = st["epoch"]
        sim._queues = [deque(q) for q in st["queues"]]
        sim._active = st["active"]
        sim.history = st["history"]
        sim._retired_makespan = st["retired_makespan"]
        sim._core_retired_epoch = st["core_retired_epoch"]
        sim._core_retired_cycles = st["core_retired_cycles"]
        sim.n_retired = st["n_retired"]
        sim._next_sid = st["next_sid"]
        sim.stats = st["stats"]
        sim._arb._wsum = st["wsum"]
        sim._arb._nact = st["nact"]
        sim._arb._stamp = st["stamp"]
        sim._arb.rounds_total = st["rounds_total"]
        sim._next_fault = st["next_fault"]
        sim._resume_of = st["resume_of"]
        sim._down = st["down"]
        sim.fault_log = st["fault_log"]
        sim.n_preempted = st["n_preempted"]
        sim.n_migrated = st["n_migrated"]
        sim.fault_lost_cycles = st["fault_lost_cycles"]
        return sim


@dataclasses.dataclass(frozen=True)
class OnlineSnapshot:
    """A picklable checkpoint of an :class:`OnlineChip` mid-run.

    Produced by :meth:`OnlineChip.snapshot`, consumed by
    :meth:`OnlineChip.restore`.  ``state`` holds deep copies of the
    mutable simulation state (segments, spans, queues, the arbiter's
    settled prefix, fault bookkeeping) with immutable members shared;
    everything inside is plain dataclasses / numpy arrays, so the whole
    object round-trips through ``pickle`` for on-disk checkpoints of
    long serving runs (``benchmarks/online_scaling.py --resume``).
    """

    chip: ChipConfig
    snap_stride: int
    prefix_cache: bool
    telemetry: TelemetryConfig
    force_history: bool
    state: dict


def _copy_state(state: dict) -> dict:
    """Deep-copy a snapshot state dict in one pass (preserving the
    aliasing between ``active``/``history``/queues and their spans) while
    sharing the immutable heavyweights: compiled traces, lowered streams,
    specs, results and ``SimCarry`` checkpoints are seeded into the memo
    so ``deepcopy`` reuses them instead of duplicating megabytes of
    arrays."""
    memo: dict = {}

    def pin(obj) -> None:
        if obj is not None:
            memo[id(obj)] = obj

    segs: set[Segment] = set(state["active"])
    segs.update(state["history"])
    segs.update(state["resume_of"].values())
    for q in state["queues"]:
        segs.update(q)
    for seg in segs:
        pin(seg.specs)
        pin(seg.stream)
        pin(seg.trace)
        pin(seg.result)
        for c in seg._snaps:
            pin(c)
    return copy.deepcopy(state, memo)
