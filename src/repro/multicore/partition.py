"""GEMM partitioners: shard one GemmSpec into per-core sub-GEMMs.

The output-space strategies shard the C space only -- every core runs an
independent ``C_i += A_i @ B_i`` lowered by the unmodified register-aware
tiler.  The unit of distribution is the hardware tile (``TILE_M`` rows x
``TILE_N`` cols): edge tiles go to whichever core owns them, so shard dims
track the exact row/col extents and the simulated FF stages of edge tiles
stay exact.

Strategies (``PARTITIONERS``):

  m_split  -- contiguous blocks of tile-rows (classic batch/row parallelism;
              every core re-streams all of B).
  n_split  -- contiguous blocks of tile-cols (every core re-streams all of A;
              weight-register reuse per core is unchanged).
  block2d  -- block-cyclic over a pm x pn core grid chosen to minimize the
              per-core tile count; core (i, j) owns tile-rows i, i+pm, ...
              and tile-cols j, j+pn, ...  The cyclically gathered tiles are
              modelled as one dense sub-GEMM per core (tile counts -- the
              quantity the cycle model sees -- are identical).
  k_split  -- contiguous blocks of tile-*depths*: core *i* computes the full
              [M, N] partial product over its K-chunk, and the core owning
              the largest chunk (core 0) additionally runs the cross-core
              reduction (:class:`repro.core.tiling.ReduceSpec`) that merges
              the ``w`` partials.  This is the only axis on which a decode
              GEMM (M = 1..16, a single tile row) can occupy more than one
              core -- and it is never a free lunch: the reduction's
              ``(w + 1) * M * N * 4`` bytes of fp32 partial traffic are
              charged against the shared bandwidth budget through the same
              arbiters as every tile load.  Timing note: the merge is
              modelled *in-stream* on the hosting core, which is exact when
              the K-chunks are symmetric (equal-share peers finish their
              identical partials simultaneously, so the host starts merging
              right when the last partial lands) and conservative-to-
              approximate when edge tiles skew the chunks.

Partitioners are core-design agnostic: shards are plain ``GemmSpec``s
(plus the one ``ReduceSpec`` of a K-split), so they flow unchanged onto
heterogeneous chips (each core lowers its shard under its own
:class:`~repro.multicore.chip.CoreSpec`); balancing a split *across* a
BASE/RASA mix is the scheduler's job (``gang`` costs every shard on its
target core).
"""

from __future__ import annotations

from ..core.isa import TILE_K, TILE_M, TILE_N
from ..core.tiling import GemmSpec, ReduceSpec

PARTITIONERS = ("m_split", "n_split", "block2d", "k_split")


def _chunk_extents(n_items: int, full: int, tile: int, n_chunks: int) -> list[int]:
    """Split ``n_items`` tiles (covering ``full`` rows/cols of size ``tile``)
    into ``n_chunks`` balanced contiguous chunks; return element extents."""
    base, extra = divmod(n_items, n_chunks)
    extents, t0 = [], 0
    for i in range(n_chunks):
        t1 = t0 + base + (1 if i < extra else 0)
        extents.append(max(0, min(t1 * tile, full) - t0 * tile))
        t0 = t1
    return extents


def _cyclic_extents(n_items: int, full: int, tile: int, n_ways: int) -> list[int]:
    """Element extents when tiles are dealt cyclically across ``n_ways``."""
    extents = [0] * n_ways
    for t in range(n_items):
        extents[t % n_ways] += min(tile, full - t * tile)
    return extents


def _best_grid(n_cores: int, mt: int, nt: int) -> tuple[int, int]:
    """Factor ``n_cores`` into (pm, pn) minimizing the per-core tile count,
    tie-breaking toward a square grid."""
    best = None
    for pm in range(1, n_cores + 1):
        if n_cores % pm:
            continue
        pn = n_cores // pm
        per_core = -(-mt // pm) * -(-nt // pn)
        key = (per_core, abs(pm - pn))
        if best is None or key < best[0]:
            best = (key, (pm, pn))
    return best[1]


def split_ways(spec: GemmSpec, ways: int, strategy: str = "m_split",
               tile_m: int = TILE_M, tile_n: int = TILE_N) -> list[GemmSpec]:
    """The non-empty shards of ``spec`` split ``ways`` ways.

    Gang-scheduling helper: unlike :func:`partition_gemm` this drops empty
    shards (a gang never occupies a core it has no tiles for) and returns a
    flat list.  ``ways=1`` returns ``[spec]`` unchanged, so a gang of one is
    exactly the whole-GEMM placement.  Output-space strategies only: a
    K-split's reduction must ride the shard that hosts it, which the flat
    one-spec-per-core gang contract cannot express -- use
    :func:`partition_gemm` (``partitioned_chip_report``) for K-splits.
    """
    if strategy == "k_split":
        raise ValueError("k_split cannot gang-split: the reduction is tied "
                         "to its host shard; use partition_gemm instead")
    if ways == 1:
        return [spec]
    return [s for shard in partition_gemm(spec, ways, strategy,
                                          tile_m=tile_m, tile_n=tile_n)
            for s in shard]


def partition_gemm(spec: GemmSpec, n_cores: int, strategy: str = "m_split",
                   tile_m: int = TILE_M, tile_n: int = TILE_N
                   ) -> list[list]:
    """Shard ``spec`` across ``n_cores``; returns one shard list per core.

    Cores whose share of the tile grid is empty (more cores than tiles along
    the split axis) receive an empty list and sit idle.  Shards are
    ``GemmSpec``s; a ``k_split`` across >= 2 live chunks appends the
    :class:`~repro.core.tiling.ReduceSpec` merging the partials to core 0's
    list.
    """
    if n_cores < 1:
        raise ValueError("n_cores must be >= 1")
    if strategy not in PARTITIONERS:
        raise ValueError(f"unknown partitioner {strategy!r}; "
                         f"available: {PARTITIONERS}")
    mt, kt, nt = spec.tiles(tile_m=tile_m, tile_n=tile_n)

    if strategy == "k_split":
        extents = _chunk_extents(kt, spec.K, TILE_K, n_cores)
        out = [[GemmSpec(f"{spec.name}@c{core}", M=spec.M, K=k, N=spec.N)]
               if k > 0 else []
               for core, (k) in enumerate(extents)]
        live = sum(1 for k in extents if k > 0)
        if live > 1:
            # core 0 owns the largest K-chunk (contiguous chunking hands
            # extras to early cores), so the merge rides its stream
            out[0].append(ReduceSpec(f"{spec.name}@reduce",
                                     M=spec.M, N=spec.N, ways=live))
        return out

    if strategy == "m_split":
        shards = [(m, spec.N) for m in _chunk_extents(mt, spec.M, tile_m, n_cores)]
    elif strategy == "n_split":
        shards = [(spec.M, n) for n in _chunk_extents(nt, spec.N, tile_n, n_cores)]
    else:  # block2d
        pm, pn = _best_grid(n_cores, mt, nt)
        rows = _cyclic_extents(mt, spec.M, tile_m, pm)
        cols = _cyclic_extents(nt, spec.N, tile_n, pn)
        shards = [(rows[i], cols[j]) for i in range(pm) for j in range(pn)]

    out: list[list[GemmSpec]] = []
    for core, (m, n) in enumerate(shards):
        if m > 0 and n > 0:
            out.append([GemmSpec(f"{spec.name}@c{core}", M=m, K=spec.K, N=n)])
        else:
            out.append([])
    return out
