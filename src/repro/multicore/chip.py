"""Chip-level composition: N per-core engines + a shared-memory model.

A ``ChipConfig`` instantiates any :data:`repro.core.designs.DESIGNS` engine
in every core and throttles the cores' aggregate tile-load traffic against a
global bytes/cycle budget.  Contention is modelled statically: each *active*
core (one with instructions to run) gets an equal ``bw_bytes_per_cycle /
n_active`` share enforced by a leaky-bucket :class:`SharedBandwidthLoadModel`
-- bursts up to ``bw_burst_bytes`` ride the core's LSQ at full port rate, but
the sustained byte rate cannot exceed the share, and the excess wait is
accounted as bandwidth-stall cycles.  See ``docs/multicore.md`` for the
assumptions and their rationale.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

from ..core.designs import EngineConfig, get_design
from ..core.isa import Instr
from ..core.tiling import ALG1_POLICY, GemmSpec, RegPolicy, lower_gemm
from ..core.timing import LoadStreamModel, PipelineSimulator, TimingResult
from .partition import partition_gemm


class SharedBandwidthLoadModel(LoadStreamModel):
    """Leaky-bucket arbiter: per-core load ports + a bytes/cycle budget.

    A load of ``n_bytes`` requested at ``t`` may start once (i) a load port
    slot is free (``load_ports`` per cycle, as in the unthrottled model) and
    (ii) cumulative bytes fit under ``share * t + burst``.  Any extra wait
    imposed by (ii) is reported as bandwidth stall.  With ``share == inf``
    this reduces exactly to the base port model.
    """

    def __init__(self, load_ports: int, bytes_per_cycle: float,
                 burst_bytes: float = 16384.0):
        self.bytes_per_cycle = bytes_per_cycle
        self.burst_bytes = burst_bytes
        super().__init__(load_ports)

    def reset(self) -> None:
        super().reset()
        self._bytes = 0.0

    def acquire(self, t_request: float, n_bytes: int) -> tuple[float, float]:
        port_start = max(t_request, self._next_free)
        if math.isinf(self.bytes_per_cycle):
            t_bw = 0.0
        else:
            t_bw = (self._bytes + n_bytes - self.burst_bytes) / self.bytes_per_cycle
        start = max(port_start, t_bw)
        self._bytes += n_bytes
        self._next_free = start + 1.0 / self.load_ports
        return start, start - port_start


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    """A CMP of ``n_cores`` identical RASA-equipped cores.

    ``bw_bytes_per_cycle`` is the chip-wide tile-load budget in bytes per
    *engine* cycle; the default 256 B/cyc corresponds to 128 GB/s at the
    paper's 500 MHz engine clock -- ample for one core (so ``n_cores=1``
    reduces exactly to the single-core simulator) but binding for several
    aggressive engines.  Use ``math.inf`` for a contention-free chip.
    """

    n_cores: int = 4
    design: str = "RASA-DMDB-WLS"
    bw_bytes_per_cycle: float = 256.0
    bw_burst_bytes: float = 16384.0
    policy: RegPolicy = ALG1_POLICY

    def __post_init__(self):
        if self.n_cores < 1:
            raise ValueError("need at least one core")
        if not self.bw_bytes_per_cycle > 0:
            raise ValueError("bw_bytes_per_cycle must be > 0 (use math.inf "
                             "for a contention-free chip)")
        if self.bw_burst_bytes < 0:
            raise ValueError("bw_burst_bytes must be >= 0")

    @property
    def engine(self) -> EngineConfig:
        return get_design(self.design)


@dataclasses.dataclass(frozen=True)
class ChipReport:
    """Chip-level aggregate of one multi-core run (cf. core.SimReport)."""

    design: str
    workload: str
    strategy: str                       # partitioner or scheduler used
    n_cores: int
    cycles: float                       # makespan: max over per-core cycles
    single_core_cycles: float           # same work, one core, full bandwidth
    per_core_cycles: tuple[float, ...]
    per_core_utilization: tuple[float, ...]
    utilization: float                  # chip-wide incl. idle cores/tails
    #: cycles added by bandwidth contention, summed over cores: each core's
    #: throttled runtime minus the same stream run with infinite bandwidth.
    bw_stall_cycles: float
    n_mm: int
    wl_skips: int
    macs: int
    per_core_gemms: tuple[tuple[str, ...], ...] = ()

    @property
    def speedup(self) -> float:
        return self.single_core_cycles / self.cycles if self.cycles else 0.0

    @property
    def efficiency(self) -> float:
        """Parallel efficiency vs. the single-core run (1.0 = linear)."""
        return self.speedup / self.n_cores

    @property
    def bw_stall_share(self) -> float:
        """Share of aggregate core-cycles lost waiting on shared bandwidth."""
        busy = sum(self.per_core_cycles)
        return self.bw_stall_cycles / busy if busy else 0.0

    @property
    def wlbp_rate(self) -> float:
        return self.wl_skips / self.n_mm if self.n_mm else 0.0


class CoreCluster:
    """Runs one instruction stream per core under the shared-memory model."""

    def __init__(self, chip: ChipConfig):
        self.chip = chip

    def run_streams(self, streams: Sequence[Sequence[Instr]]
                    ) -> tuple[list[TimingResult], list[float]]:
        """Simulate every core's stream under its bandwidth share.

        Returns ``(results, contention_stalls)`` where ``contention_stalls[i]``
        is how many cycles core *i* lost to the shared-bandwidth throttle
        (its throttled runtime minus its unthrottled runtime -- 0 whenever
        the budget does not bind).
        """
        cfg = self.chip.engine
        n_active = sum(1 for s in streams if s) or 1
        share = self.chip.bw_bytes_per_cycle / n_active
        results, stalls = [], []
        for stream in streams:
            model = SharedBandwidthLoadModel(cfg.load_ports, share,
                                             self.chip.bw_burst_bytes)
            res = PipelineSimulator(cfg, load_model=model).run(stream)
            if res.load_stall_cycles == 0.0:
                # the arbiter never delayed a load: the run is identical to
                # an unthrottled one, so skip the reference re-simulation.
                stall = 0.0
            else:
                free = PipelineSimulator(cfg).run(stream)
                stall = max(0.0, res.cycles - free.cycles)
            results.append(res)
            stalls.append(stall)
        return results, stalls


def _lower_many(specs: Sequence[GemmSpec], policy: RegPolicy) -> list[Instr]:
    stream: list[Instr] = []
    for spec in specs:
        stream.extend(lower_gemm(spec, policy))
    return stream


def _aggregate(chip: ChipConfig, workload_name: str, strategy: str,
               shards: Sequence[Sequence[GemmSpec]],
               results: Sequence[TimingResult], stalls: Sequence[float],
               single_core_cycles: float) -> ChipReport:
    cycles = max((r.cycles for r in results), default=0.0)
    peak = chip.engine.peak_macs_per_cycle
    chip_util = (sum(r.useful_macs for r in results)
                 / (cycles * peak * chip.n_cores)) if cycles else 0.0
    return ChipReport(
        design=chip.engine.name,
        workload=workload_name,
        strategy=strategy,
        n_cores=chip.n_cores,
        cycles=cycles,
        single_core_cycles=single_core_cycles,
        per_core_cycles=tuple(r.cycles for r in results),
        per_core_utilization=tuple(r.utilization for r in results),
        utilization=chip_util,
        bw_stall_cycles=sum(stalls),
        n_mm=sum(r.n_mm for r in results),
        wl_skips=sum(r.wl_skips for r in results),
        macs=sum(int(s.macs) for shard in shards for s in shard),
        per_core_gemms=tuple(tuple(s.name for s in shard) for shard in shards),
    )


@functools.lru_cache(maxsize=1024)
def _single_core_cycles_cached(chip: ChipConfig,
                               specs: tuple[GemmSpec, ...]) -> float:
    cfg = chip.engine
    model = SharedBandwidthLoadModel(cfg.load_ports, chip.bw_bytes_per_cycle,
                                     chip.bw_burst_bytes)
    sim = PipelineSimulator(cfg, load_model=model)
    return sim.run(_lower_many(specs, chip.policy)).cycles


def _single_core_cycles(chip: ChipConfig, specs: Sequence[GemmSpec]) -> float:
    """Reference: all work on one core with the full bandwidth budget."""
    return _single_core_cycles_cached(dataclasses.replace(chip, n_cores=1),
                                      tuple(specs))


def partitioned_chip_report(spec: GemmSpec, chip: ChipConfig,
                            strategy: str = "m_split") -> ChipReport:
    """Shard one GEMM across the chip's cores and report scaling."""
    shards = partition_gemm(spec, chip.n_cores, strategy)
    streams = [_lower_many(shard, chip.policy) for shard in shards]
    results, stalls = CoreCluster(chip).run_streams(streams)
    return _aggregate(chip, spec.name, strategy, shards, results, stalls,
                      _single_core_cycles(chip, [spec]))


def simulate_chip(workload, chip: ChipConfig | None = None, *,
                  partition: str = "m_split",
                  scheduler: str = "work_queue", **chip_kwargs) -> ChipReport:
    """Chip-level analogue of :func:`repro.core.simulate`.

    ``workload`` is either one :class:`GemmSpec` -- partitioned across cores
    with ``partition`` -- or a sequence of specs, scheduled whole-GEMM-per-
    core with ``scheduler`` (see :mod:`repro.multicore.scheduler`).  Extra
    keyword arguments construct the :class:`ChipConfig` when none is given.
    """
    if chip is None:
        chip = ChipConfig(**chip_kwargs)
    elif chip_kwargs:
        raise TypeError(f"pass either a ChipConfig or config kwargs, not "
                        f"both: {sorted(chip_kwargs)}")
    if isinstance(workload, GemmSpec):
        return partitioned_chip_report(workload, chip, partition)
    from .scheduler import scheduled_chip_report
    return scheduled_chip_report(list(workload), chip, scheduler)
