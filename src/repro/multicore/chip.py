"""Chip-level composition: N per-core engines + a shared-memory model.

A ``ChipConfig`` instantiates a :data:`repro.core.designs.DESIGNS` engine
in every core -- one design replicated, or a mixed BASE/RASA vector of
:class:`CoreSpec` -- and throttles the cores' aggregate tile traffic
against a global bytes/cycle budget.  Two arbitration models are
available:

``arbitration="epoch"`` (default)
    Time is divided into scheduling epochs of ``epoch_cycles`` engine
    cycles.  Within each epoch every core still drawing on the memory
    system gets a share of ``bw_bytes_per_cycle`` (equal by default;
    ``share_policy="demand"`` weights shares by measured bytes/cycle
    demand); a core that drains its traffic early *returns its share*, so
    the survivors' shares grow epoch by epoch.  The per-core share
    schedule is found by the monotone fixed-point relaxation of
    :class:`repro.multicore.arbiter.SpanArbiter` -- the **single**
    implementation shared with the open-arrival model
    (:mod:`repro.multicore.online`); the closed batch is its "all spans
    start at epoch 0" special case -- and enforced per core by a
    token-bucket :class:`EpochBandwidthLoadModel`.  The resulting
    per-epoch share/active traces are reported on :class:`ChipReport`.

``arbitration="static"``
    The frozen-share model, kept as the comparison baseline: each active
    core gets ``bw_bytes_per_cycle / n_active`` for the entire run
    (:class:`SharedBandwidthLoadModel`, the same token bucket with a
    constant share).  This over-penalizes long-running cores on skewed
    workloads -- bandwidth freed by early finishers is never
    redistributed.  Always equal-share: it predates (and baselines) the
    share policies.

In both models bursts up to ``bw_burst_bytes`` ride the core's LSQ at full
port rate, the excess wait is accounted as bandwidth-stall cycles, and --
unless ``store_bytes_shared=False`` -- ``rasa_ts`` store traffic is charged
against the same budget and serialized on the engine's store port.  See
``docs/multicore.md`` for the assumptions and their rationale.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

from ..core.designs import EngineConfig, get_design
from ..core.fastsim import StreamModelParams, run_cores
from ..core.isa import Instr, Op, tile_bytes
from ..core.tiling import (ALG1_POLICY, GemmSpec, RegPolicy, lowered_stream)
from ..core.timing import LoadStreamModel, PipelineSimulator, TimingResult
from ..core.trace import (OP_MM, OP_TL, OP_TS, CompiledTrace, compile_stream,
                          compiled_trace)
from ..obs.config import OFF, TelemetryConfig
from .arbiter import (ArbiterTrace, SharePolicy, Span, SpanArbiter,
                      get_share_policy)
from .faults import FaultPlan
from .partition import partition_gemm

ARBITRATIONS = ("epoch", "static")

#: chip-level simulation backends: the reference Python loop, or the
#: trace-compiled fast backends of :mod:`repro.core.fastsim` ("fast" picks
#: jax when available and worthwhile, numpy otherwise).
CHIP_BACKENDS = ("reference", "fast", "numpy", "jax")


def stream_model_params(chip: "ChipConfig", engine: EngineConfig,
                        shares: Sequence[float] = (),
                        epoch_cycles: float = math.inf,
                        tail: float = math.inf) -> StreamModelParams:
    """The chip's arbiter as fast-backend parameters for one core's
    ``engine`` (default: the unthrottled port model).  Shared by the
    closed-batch cluster and the online model."""
    store_ports = engine.store_ports if chip.store_bytes_shared else None
    return StreamModelParams(
        engine.load_ports, store_ports, tuple(shares),
        epoch_cycles, tail, chip.bw_burst_bytes, chip.store_bytes_shared)


def demands_bandwidth(chip: "ChipConfig", stream: Sequence[Instr] | None,
                      trace: CompiledTrace | None = None) -> bool:
    """Does this stream put any traffic on the shared memory system?"""
    charge_stores = chip.store_bytes_shared
    if trace is not None:
        return trace.n_tl > 0 or (charge_stores and trace.n_ts > 0)
    return any(ins.op is Op.TL or (charge_stores and ins.op is Op.TS)
               for ins in stream)


def shared_traffic_bytes(chip: "ChipConfig",
                         stream: Sequence[Instr] | None,
                         trace: CompiledTrace | None = None) -> float:
    """Total bytes this stream puts on the shared memory system (tile
    loads, plus ``rasa_ts`` stores when they are charged) -- the numerator
    of the demand-weighted share policy's bytes/cycle measurement."""
    if trace is not None:
        total = float(trace.nbytes[trace.opcode == OP_TL].sum())
        if chip.store_bytes_shared:
            total += float(trace.nbytes[trace.opcode == OP_TS].sum())
        return total
    total = 0.0
    for ins in stream:
        if ins.op is Op.TL or (chip.store_bytes_shared and ins.op is Op.TS):
            total += tile_bytes(ins)
    return total


class EpochBandwidthLoadModel(LoadStreamModel):
    """Token-bucket arbiter under a piecewise-constant share schedule.

    ``shares[e]`` is this core's bytes/cycle allowance during epoch ``e``
    (the interval ``[e * epoch_cycles, (e+1) * epoch_cycles)``); epochs past
    the end of the schedule run at ``tail_share`` (the cluster passes the
    full chip budget there: by construction every other core has drained by
    then).  Unused allowance accumulates only up to ``burst_bytes`` -- a core
    cannot bank unbounded credit and replay it later -- which is what makes
    the per-epoch conservation property hold:

        bytes granted per epoch  <=  share * epoch_cycles + burst_bytes
                                     + one in-flight tile

    (the tile term covers the single grant that straddles the epoch edge;
    asserted by ``tests/test_multicore.py``).  A request larger than the
    bucket capacity is granted once the bucket is full and leaves the token
    count negative (debt repaid by subsequent refill), so any tile size
    works with any ``burst_bytes`` including 0.
    """

    def __init__(self, load_ports: int, shares: Sequence[float],
                 epoch_cycles: float, tail_share: float,
                 burst_bytes: float = 16384.0,
                 store_ports: int | None = None,
                 charge_store_bytes: bool = False,
                 record_grants: bool = False):
        if epoch_cycles <= 0:
            raise ValueError("epoch_cycles must be > 0")
        self.shares = tuple(shares)
        self.epoch_cycles = epoch_cycles
        self.tail_share = tail_share
        self._schedule_end = len(self.shares) * epoch_cycles if shares else 0.0
        self.burst_bytes = burst_bytes
        self.charge_store_bytes = charge_store_bytes
        self.record_grants = record_grants
        super().__init__(load_ports, store_ports)

    def reset(self) -> None:
        super().reset()
        self._tokens = self.burst_bytes
        self._t = 0.0           # bucket time: refills are settled up to here
        #: (start, n_bytes) of every granted access, when record_grants.
        self.grants: list[tuple[float, int]] = []

    def _share_at(self, t: float) -> float:
        e = int(t // self.epoch_cycles)
        return self.shares[e] if e < len(self.shares) else self.tail_share

    def _advance(self, t: float) -> None:
        """Settle refills from the bucket time up to ``t`` (capped)."""
        while self._t < t:
            rate = self._share_at(self._t)
            if self._t >= self._schedule_end:
                step_end = t        # constant tail rate: one jump
            else:
                e_end = ((int(self._t // self.epoch_cycles) + 1)
                         * self.epoch_cycles)
                step_end = min(t, e_end)
            if math.isinf(rate):
                self._tokens = self.burst_bytes
            else:
                self._tokens = min(self.burst_bytes,
                                   self._tokens + rate * (step_end - self._t))
            self._t = step_end

    def _grant(self, t_earliest: float, n_bytes: int) -> float:
        """Earliest start >= ``t_earliest`` at which ``n_bytes`` is granted,
        consuming the tokens.  Requests behind the bucket time (out-of-order
        stores, whose ready times are not monotone in issue order) are
        served from the current bucket state without rewinding it."""
        self._advance(t_earliest)
        need = min(float(n_bytes), self.burst_bytes)
        if self._tokens >= need:
            start = t_earliest
        else:
            t, tokens = self._t, self._tokens
            schedule_end = self._schedule_end
            while True:
                rate = self._share_at(t)
                if math.isinf(rate):
                    start = t
                    break
                if rate <= 0.0 and t >= schedule_end:
                    raise RuntimeError("tail share must be > 0: request can "
                                       "never be granted")
                e_end = (int(t // self.epoch_cycles) + 1) * self.epoch_cycles
                if rate > 0.0:
                    t_hit = t + (need - tokens) / rate
                    if t_hit <= e_end or t >= schedule_end:
                        start = t_hit
                        break
                    tokens += rate * (e_end - t)
                t = e_end
            start = max(start, t_earliest)
        self._advance(start)
        self._tokens -= n_bytes
        if self.record_grants:
            self.grants.append((start, n_bytes))
        return start

    def acquire(self, t_request: float, n_bytes: int) -> tuple[float, float]:
        port_start = max(t_request, self._next_free)
        start = self._grant(port_start, n_bytes)
        self._next_free = start + 1.0 / self.load_ports
        self.last_grant = max(self.last_grant, start)
        return start, start - port_start

    def acquire_store(self, t_request: float, n_bytes: int) -> tuple[float, float]:
        if self.store_ports is None:
            return t_request, 0.0
        port_start = max(t_request, self._store_next_free)
        if self.charge_store_bytes:
            start = self._grant(port_start, n_bytes)
        else:
            start = port_start
        self._store_next_free = start + 1.0 / self.store_ports
        self.last_grant = max(self.last_grant, start)
        return start, start - port_start


class SharedBandwidthLoadModel(EpochBandwidthLoadModel):
    """Constant-share token bucket: the ``arbitration="static"`` model.

    The frozen-share baseline: one share for the whole run, i.e. an
    :class:`EpochBandwidthLoadModel` with an empty schedule and
    ``tail_share=bytes_per_cycle``.  Sharing the exact bucket semantics with
    the epoch model matters: the dynamic schedule's shares dominate the
    static share pointwise in time, so with identical bucket mechanics the
    dynamic makespan provably never exceeds the static one.  A load of
    ``n_bytes`` requested at ``t`` may start once (i) a load port slot is
    free and (ii) ``n_bytes`` tokens are available (refill ``share`` per
    cycle, capped at ``burst_bytes``).  With ``share == inf`` this reduces
    exactly to the base port model.
    """

    def __init__(self, load_ports: int, bytes_per_cycle: float,
                 burst_bytes: float = 16384.0,
                 store_ports: int | None = None,
                 charge_store_bytes: bool = False):
        self.bytes_per_cycle = bytes_per_cycle
        super().__init__(load_ports, shares=(), epoch_cycles=math.inf,
                         tail_share=bytes_per_cycle, burst_bytes=burst_bytes,
                         store_ports=store_ports,
                         charge_store_bytes=charge_store_bytes)


@dataclasses.dataclass(frozen=True)
class CoreSpec:
    """One core's configuration in a (possibly mixed) chip.

    The unit of heterogeneity: a :class:`ChipConfig` carries one
    ``CoreSpec`` per core, so BASE and RASA(-DM/-WLBP/...) cores can share
    one chip and flow together through the partitioners, both arbiters,
    all simulation backends, and :class:`ChipReport`.
    """

    design: str
    policy: RegPolicy = ALG1_POLICY

    @property
    def engine(self) -> EngineConfig:
        return get_design(self.design)


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    """A CMP of RASA-equipped cores sharing one memory system.

    By default all ``n_cores`` cores replicate ``design``/``policy``; pass
    ``cores`` -- a tuple of :class:`CoreSpec` (or design-name strings) --
    for a heterogeneous mix, in which case ``cores`` is authoritative:
    ``n_cores`` is derived from it (or must match it if given) and
    ``design``/``policy`` only serve as defaults for string entries.

    ``bw_bytes_per_cycle`` is the chip-wide tile-traffic budget in bytes per
    *engine* cycle; the default 256 B/cyc corresponds to 128 GB/s at the
    paper's 500 MHz engine clock -- ample for one core (so ``n_cores=1``
    reduces exactly to the single-core simulator) but binding for several
    aggressive engines.  Use ``math.inf`` for a contention-free chip.

    ``arbitration`` selects the contention model (``"epoch"`` dynamic
    time-sliced shares recomputed every ``epoch_cycles``; ``"static"`` the
    frozen equal-share baseline).  ``share_policy`` selects how the epoch
    arbiter splits each epoch's budget over the active cores (``"equal"``
    or ``"demand"``; see :mod:`repro.multicore.arbiter`).
    ``store_bytes_shared=False`` recovers the PR-1 loads-only accounting
    where ``rasa_ts`` stores are free.
    """

    n_cores: int | None = None
    design: str = "RASA-DMDB-WLS"
    bw_bytes_per_cycle: float = 256.0
    bw_burst_bytes: float = 16384.0
    policy: RegPolicy = ALG1_POLICY
    arbitration: str = "epoch"
    epoch_cycles: float = 1024.0
    store_bytes_shared: bool = True
    #: simulation backend (see :data:`CHIP_BACKENDS`); "reference" keeps the
    #: per-core Python loop as the exactness oracle.
    backend: str = "fast"
    #: epoch-share policy (see :data:`repro.multicore.arbiter.
    #: SHARE_POLICIES`); normalized to a SharePolicy instance.
    share_policy: str | SharePolicy = "equal"
    #: per-core design vector; ``None`` replicates ``design``/``policy``.
    cores: tuple | None = None
    #: deterministic fault-event schedule
    #: (:class:`repro.multicore.faults.FaultPlan`); ``None`` -- the default
    #: and the common case -- is a pristine chip and costs nothing.
    fault_plan: FaultPlan | None = None

    def __post_init__(self):
        if self.backend not in CHIP_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"available: {CHIP_BACKENDS}")
        if not self.bw_bytes_per_cycle > 0:
            raise ValueError("bw_bytes_per_cycle must be > 0 (use math.inf "
                             "for a contention-free chip)")
        if self.bw_burst_bytes < 0:
            raise ValueError("bw_burst_bytes must be >= 0")
        if self.arbitration not in ARBITRATIONS:
            raise ValueError(f"unknown arbitration {self.arbitration!r}; "
                             f"available: {ARBITRATIONS}")
        if not self.epoch_cycles > 0:
            raise ValueError("epoch_cycles must be > 0")
        object.__setattr__(self, "share_policy",
                           get_share_policy(self.share_policy))
        if self.cores is None:
            # the field stays None so dataclasses.replace(design=...) or
            # replace(n_cores=...) re-derives the replicated vector; the
            # resolved form is the core_specs property
            n = 4 if self.n_cores is None else self.n_cores
        else:
            cores = tuple(CoreSpec(c, self.policy) if isinstance(c, str)
                          else c for c in self.cores)
            if not cores:
                raise ValueError("need at least one core")
            n = len(cores) if self.n_cores is None else self.n_cores
            if n != len(cores):
                raise ValueError(f"n_cores={n} does not match "
                                 f"len(cores)={len(cores)}")
            object.__setattr__(self, "cores", cores)
        if n < 1:
            raise ValueError("need at least one core")
        object.__setattr__(self, "n_cores", n)
        for spec in self.core_specs:
            spec.engine             # fail fast on unknown design names
        plan = self.fault_plan
        if plan is not None and plan.is_empty:
            object.__setattr__(self, "fault_plan", None)
            plan = None
        if plan is not None:
            if self.arbitration != "epoch":
                raise ValueError(
                    "fault_plan requires arbitration='epoch': the span "
                    "arbiter is where faults are injected")
            for e in plan.events:
                if e.core >= n:
                    raise ValueError(f"fault event {e.label!r} names "
                                     f"core {e.core} on a {n}-core chip")

    @property
    def core_specs(self) -> tuple[CoreSpec, ...]:
        """The resolved per-core vector: ``cores`` as given, or
        ``design``/``policy`` replicated ``n_cores`` times."""
        if self.cores is not None:
            return self.cores
        cached = self.__dict__.get("_core_specs")
        if cached is None:
            cached = (CoreSpec(self.design, self.policy),) * self.n_cores
            object.__setattr__(self, "_core_specs", cached)
        return cached

    @property
    def homogeneous(self) -> bool:
        specs = self.core_specs
        return all(spec == specs[0] for spec in specs)

    @property
    def engine(self) -> EngineConfig:
        """The chip's engine when every core shares one design.

        Heterogeneous chips have no single engine -- use
        :meth:`core_engine` there; raising here catches call sites that
        silently assumed homogeneity.
        """
        designs = {spec.design for spec in self.core_specs}
        if len(designs) > 1:
            raise ValueError("heterogeneous chip has no single engine; "
                             "use core_engine(core)")
        return self.core_specs[0].engine

    def core_engine(self, core: int) -> EngineConfig:
        return self.core_specs[core].engine

    @property
    def design_name(self) -> str:
        """Report label: the engine name, or a mix summary."""
        if len({spec.design for spec in self.core_specs}) == 1:
            return self.core_specs[0].engine.name
        runs: list[list] = []
        for spec in self.core_specs:
            if runs and runs[-1][0] == spec.design:
                runs[-1][1] += 1
            else:
                runs.append([spec.design, 1])
        return "mixed[" + "+".join(f"{d}x{k}" if k > 1 else d
                                   for d, k in runs) + "]"

    @property
    def store_ports(self) -> int | None:
        """Store-port count handed to the arbiter models (None = stores
        free, the loads-only accounting switch).  Homogeneous chips only;
        per-core form: :meth:`store_ports_for`."""
        return self.engine.store_ports if self.store_bytes_shared else None

    def store_ports_for(self, core: int) -> int | None:
        return self.core_specs[core].engine.store_ports \
            if self.store_bytes_shared else None

    def single_core(self, core: int = 0) -> "ChipConfig":
        """The one-core chip running this chip's ``core`` spec (the
        reference configuration speedups are measured against)."""
        spec = self.core_specs[core]
        # the reference is always a pristine core: faults measure *loss*
        # against the fault-free single-core run
        return dataclasses.replace(self, n_cores=1, cores=(spec,),
                                   design=spec.design, policy=spec.policy,
                                   fault_plan=None)


@dataclasses.dataclass(frozen=True)
class ChipReport:
    """Chip-level aggregate of one multi-core run (cf. core.SimReport)."""

    design: str
    workload: str
    strategy: str                       # partitioner or scheduler used
    n_cores: int
    cycles: float                       # makespan: max over per-core cycles
    single_core_cycles: float           # same work, one core, full bandwidth
    per_core_cycles: tuple[float, ...]
    per_core_utilization: tuple[float, ...]
    utilization: float                  # chip-wide incl. idle cores/tails
    #: cycles added by bandwidth contention, summed over cores: each core's
    #: throttled runtime minus the same stream run with infinite bandwidth.
    bw_stall_cycles: float
    n_mm: int
    wl_skips: int
    macs: int
    per_core_gemms: tuple[tuple[str, ...], ...] = ()
    #: contention model that produced this report ("epoch" or "static")
    arbitration: str = "static"
    #: scheduling-epoch length in engine cycles (0 for the static model)
    epoch_cycles: float = 0.0
    #: bytes/cycle granted per unit arbitration weight, per epoch (equal
    #: shares: exactly the bytes/cycle each active core receives; static:
    #: one entry covering the whole run).  Core *i* receives
    #: ``share_trace[e] * core_weights[i]``.
    share_trace: tuple[float, ...] = ()
    #: cores still drawing on the shared budget, per epoch
    active_trace: tuple[int, ...] = ()
    #: relaxation rounds the epoch arbiter needed (1 for static)
    arb_rounds: int = 1
    #: per relaxation round, cores skipped because their visible share
    #: schedule was unchanged (see :class:`repro.multicore.arbiter.
    #: ArbiterTrace`)
    arb_skipped: tuple[int, ...] = ()
    #: per-core design names (the CoreSpec vector; all equal on a
    #: homogeneous chip)
    core_designs: tuple[str, ...] = ()
    #: epoch-share policy of the arbiter ("equal" or "demand")
    share_policy: str = "equal"
    #: per-core arbitration weights (all 1 under equal shares)
    core_weights: tuple[float, ...] = ()
    #: per-core FF feed cycles (sum of ``tm``) -- the compute-bucket
    #: numerator of the stall attribution
    per_core_compute_cycles: tuple[float, ...] = ()
    #: per-core end-to-end bandwidth-stall cycles (the summands of
    #: :attr:`bw_stall_cycles`)
    per_core_bw_stall_cycles: tuple[float, ...] = ()
    #: per-instance attribution rows (fault runs only): the exact
    #: ``(core, submit, start, finish, compute, bw_stall[, fault_lost])``
    #: tuples handed to :func:`repro.obs.attribution.attribute_segments`.
    #: Empty on fault-free reports, where the per-core vectors above are
    #: the rows.
    attribution_rows: tuple = ()
    #: segments preempted at a core_down boundary
    n_preemptions: int = 0
    #: segments moved off their submitted core (queued or preempted)
    n_migrations: int = 0
    #: busy cycles discarded by preemption (work done but not kept --
    #: the ``fault_lost`` attribution bucket)
    fault_lost_cycles: float = 0.0
    #: fault instants of the run's plan, as ``(epoch, label)`` -- the
    #: Perfetto export renders them as instant markers
    fault_log: tuple[tuple[int, str], ...] = ()
    #: full timeline telemetry (:class:`repro.obs.timeline.ChipTelemetry`);
    #: populated only when the run was made with
    #: ``TelemetryConfig(enabled=True)``.  Identity-compared: two
    #: telemetry-carrying reports never compare equal.
    telemetry: object | None = None
    #: inference phase of the workload ("prefill" / "decode" for compiled
    #: model workloads, "" for hand-written spec lists)
    phase: str = ""

    @property
    def attribution(self):
        """Stall-cycle bucket decomposition of the run
        (:class:`repro.obs.attribution.StallAttribution`), or ``None``
        on reports that predate the per-core compute fields."""
        from ..obs.attribution import attribute_segments
        if self.attribution_rows:
            return attribute_segments(self.n_cores, self.cycles,
                                      self.attribution_rows)
        if not self.per_core_compute_cycles:
            return None
        rows = [(i, 0.0, 0.0, self.per_core_cycles[i],
                 self.per_core_compute_cycles[i],
                 self.per_core_bw_stall_cycles[i])
                for i in range(self.n_cores)]
        return attribute_segments(self.n_cores, self.cycles, rows)

    @property
    def speedup(self) -> float:
        return self.single_core_cycles / self.cycles if self.cycles else 0.0

    @property
    def efficiency(self) -> float:
        """Parallel efficiency vs. the single-core run (1.0 = linear)."""
        return self.speedup / self.n_cores

    @property
    def occupied_core_cycles(self) -> float:
        """Aggregate occupied core-cycles: makespan x cores that ran work.

        A core that drained early still *occupies* its slot until the chip
        finishes (nothing else can be placed on it within this run), so this
        -- not ``sum(per_core_cycles)`` -- is the denominator against which
        chip-level overheads are meaningfully normalized.
        """
        active = sum(1 for c in self.per_core_cycles if c > 0)
        return self.cycles * active

    @property
    def bw_stall_share(self) -> float:
        """Share of occupied core-cycles (makespan x active cores) lost
        waiting on shared bandwidth.

        Defined against :attr:`occupied_core_cycles` rather than
        ``sum(per_core_cycles)``: mixing drained-early cores' short runtimes
        into the denominator would inflate the apparent stall share on
        skewed workloads.
        """
        occupied = self.occupied_core_cycles
        return self.bw_stall_cycles / occupied if occupied else 0.0

    @property
    def wlbp_rate(self) -> float:
        return self.wl_skips / self.n_mm if self.n_mm else 0.0


class CoreCluster:
    """Runs one instruction stream per core under the shared-memory model.

    The epoch arbitration itself lives in
    :class:`repro.multicore.arbiter.SpanArbiter`; this class is its
    closed-batch client -- it owns the per-core streams/traces, batches
    the arbiter's re-simulation requests through the fast backends, and
    measures contention stalls.
    """

    def __init__(self, chip: ChipConfig):
        self.chip = chip
        plan = chip.fault_plan
        #: per-core speed factors (run-constant ``slow_core`` dilation;
        #: None -- no plan / no slow cores -- keeps every path untouched).
        #: The closed batch samples speeds at epoch 0 and holds them; plans
        #: with timed speed changes route through the online model
        #: (``FaultPlan.needs_online``).
        self._speed: tuple[float, ...] | None = None
        if plan is not None and plan.has_slow_cores:
            self._speed = tuple(plan.speed_factor(c, 0)
                                for c in range(chip.n_cores))
        self._budget_factors = plan.budget_factors() if plan is not None \
            else ()
        #: per-core arbitration weights of the last run (all 1 for equal)
        self.core_weights: tuple[float, ...] = ()
        # -- retained state of the last run_streams call; the telemetry
        # builders (repro.obs.timeline) read these to replay the run.
        self.last_results: list[TimingResult] = []
        self.last_stalls: list[float] = []
        #: per-core stream-model parameters of each core's *final*
        #: simulation -- for the epoch arbiter, the exact visible schedule
        #: (``Span._vis``) the fixed point settled on, so a replay under
        #: them reproduces the run bit for bit.
        self.last_params: list[StreamModelParams] = []
        self.last_streams: Sequence[Sequence[Instr]] | None = None
        self.last_traces: Sequence[CompiledTrace] | None = None

    def run_streams(self, streams: Sequence[Sequence[Instr]] | None,
                    traces: Sequence[CompiledTrace] | None = None
                    ) -> tuple[list[TimingResult], list[float],
                               ArbiterTrace | None]:
        """Simulate every core's stream under the chip's arbitration model.

        Returns ``(results, contention_stalls, trace)`` where
        ``contention_stalls[i]`` is how many cycles core *i* lost to the
        shared-bandwidth throttle (its throttled runtime minus its
        unthrottled runtime -- 0 whenever the budget does not bind) and
        ``trace`` is the per-epoch :class:`ArbiterTrace` (None only when
        there is nothing to arbitrate).

        With a fast backend, ``traces`` (the compiled form) may be passed
        instead of / alongside ``streams``; entry points pass the cached
        traces so the per-round simulations never re-lower anything.
        """
        if self.chip.backend == "reference":
            if streams is None:
                raise ValueError("backend='reference' needs instruction "
                                 "streams")
            traces = None
        elif traces is None:
            if streams is None:
                raise ValueError("need streams or compiled traces")
            traces = [compile_stream(s) for s in streams]
        self.last_streams = streams
        self.last_traces = traces
        if self.chip.arbitration == "static":
            return self._run_static(streams, traces)
        return self._run_epoch(streams, traces)

    # -- shared helpers ----------------------------------------------------
    def _params(self, core: int, shares: Sequence[float] = (),
                epoch_cycles: float = math.inf,
                tail: float = math.inf) -> StreamModelParams:
        if self._speed is not None:
            f = self._speed[core]
            if f != 1.0:
                # dilate into the slow core's local time base: the local
                # clock ticks at f x the chip rate, so one local cycle
                # spans 1/f chip cycles (shares scale by 1/f) and an epoch
                # of E chip cycles holds E*f local cycles.  _sim_round
                # converts the local-time results back (divide by f).
                shares = tuple(s / f for s in shares)
                epoch_cycles = epoch_cycles * f
                tail = tail / f
        return stream_model_params(self.chip, self.chip.core_specs[core].engine,
                                   shares, epoch_cycles, tail)

    def _sim_round(self, idxs: Sequence[int], streams, traces,
                   params: Sequence[StreamModelParams]
                   ) -> list[tuple[TimingResult, float]]:
        """Simulate the given cores (by index) under their arbiter
        parameters, returning ``(TimingResult, last_grant)`` per core.

        ``streams``/``traces`` are parallel to ``idxs``.  Cores that share
        a compiled trace, an engine config *and* identical arbiter
        parameters (symmetric shards under equal shares) are simulated
        once and fan the result out -- results are deterministic in
        (trace, engine, params).
        """
        cfgs = [self.chip.core_specs[i].engine for i in idxs]
        if self.chip.backend == "reference":
            out = []
            for cfg, stream, p in zip(cfgs, streams, params):
                model = p.make_model()
                res = PipelineSimulator(cfg, load_model=model).run(stream)
                out.append((res, model.last_grant))
            return self._descale(idxs, out)
        slot: dict[tuple, int] = {}
        todo_t, todo_c, todo_p = [], [], []
        lanes = []
        for t, c, p in zip(traces, cfgs, params):
            # CompiledTrace is identity-hashed (eq=False), so this
            # deduplicates same-object traces; keying on the trace itself
            # (not id()) keeps a strong reference alive for the dict's
            # lifetime so a recycled id can never alias two traces.
            key = (t, c, p)
            if key not in slot:
                slot[key] = len(todo_t)
                todo_t.append(t)
                todo_c.append(c)
                todo_p.append(p)
            lanes.append(slot[key])
        uniq = run_cores(todo_t, todo_c, todo_p, backend=self.chip.backend)
        return self._descale(idxs, [uniq[k] for k in lanes])

    def _descale(self, idxs: Sequence[int],
                 outs: list[tuple[TimingResult, float]]
                 ) -> list[tuple[TimingResult, float]]:
        """Convert slow cores' local-time results back to chip time (see
        ``_params``); the identity whenever no core is slowed."""
        if self._speed is None:
            return outs
        scaled = []
        for i, (res, lg) in zip(idxs, outs):
            f = self._speed[i]
            if f != 1.0:
                res = dataclasses.replace(
                    res, cycles=res.cycles / f,
                    bw_stall_cycles=res.bw_stall_cycles / f)
                lg = lg / f
            scaled.append((res, lg))
        return scaled

    def _demands_bandwidth(self, stream: Sequence[Instr] | None,
                           trace: CompiledTrace | None = None) -> bool:
        """Does this core put any traffic on the shared memory system?"""
        return demands_bandwidth(self.chip, stream, trace)

    def _demand_vector(self, streams, traces) -> list[bool]:
        n = len(traces if traces is not None else streams)
        return [self._demands_bandwidth(streams[i] if streams else None,
                                        traces[i] if traces else None)
                for i in range(n)]

    def _demand_weights(self, streams, traces, demand,
                        unthrottled: dict[int, TimingResult]
                        ) -> list[float]:
        """Per-core arbitration weights for the chip's share policy.

        Equal shares weigh every core 1 with no extra work; the demand
        policy measures each demanding core's unthrottled bytes/cycle
        (one batched unthrottled round, reused as the contention-stall
        baseline via ``unthrottled``).
        """
        n = len(demand)
        policy = self.chip.share_policy
        if not policy.needs_demand:
            return [1.0] * n
        idxs = [i for i in range(n) if demand[i]]
        weights = [1.0] * n
        if not idxs:
            return weights
        outs = self._sim_round(
            idxs, [streams[i] for i in idxs] if streams else None,
            [traces[i] for i in idxs] if traces else None,
            [self._params(i) for i in idxs])
        for i, (res, _) in zip(idxs, outs):
            unthrottled[i] = res
            traffic = shared_traffic_bytes(
                self.chip, streams[i] if streams else None,
                traces[i] if traces else None)
            weights[i] = policy.weight(traffic / res.cycles
                                       if res.cycles else 0.0)
        return weights

    def _contention_stalls(self, streams, traces,
                           results: Sequence[TimingResult],
                           unthrottled: dict[int, TimingResult] | None = None
                           ) -> list[float]:
        """End-to-end cycles each core lost to the bandwidth throttle.

        Cores whose arbiter never delayed an access ran identically to an
        unthrottled core, so only the stalled subset is re-simulated --
        batched through the fast backend when one is selected, and reusing
        any ``unthrottled`` baselines already measured (demand weighing).
        """
        stalls = [0.0] * len(results)
        pre = unthrottled or {}
        for i, base in pre.items():
            if results[i].bw_stall_cycles != 0.0:
                stalls[i] = max(0.0, results[i].cycles - base.cycles)
        idxs = [i for i, r in enumerate(results)
                if r.bw_stall_cycles != 0.0 and i not in pre]
        if not idxs:
            return stalls
        outs = self._sim_round(
            idxs, [streams[i] for i in idxs] if streams else None,
            [traces[i] for i in idxs] if traces else None,
            [self._params(i) for i in idxs])
        for i, (res, _) in zip(idxs, outs):
            stalls[i] = max(0.0, results[i].cycles - res.cycles)
        return stalls

    # -- static equal shares (PR-1 baseline) -------------------------------
    def _run_static(self, streams, traces):
        chip = self.chip
        demand = self._demand_vector(streams, traces)
        n_active = sum(demand) or 1
        share = chip.bw_bytes_per_cycle / n_active
        idxs = list(range(len(demand)))
        params = [self._params(i, tail=share) for i in idxs]
        results = [r for r, _ in self._sim_round(idxs, streams, traces,
                                                 params)]
        stalls = self._contention_stalls(streams, traces, results)
        self.core_weights = (1.0,) * len(demand)
        self.last_results = results
        self.last_stalls = stalls
        self.last_params = params
        trace = ArbiterTrace(epoch_cycles=0.0, shares=(share,),
                             n_active=(n_active,), rounds=1)
        return results, stalls, trace

    # -- epoch-based dynamic arbitration -----------------------------------
    def _run_epoch(self, streams, traces):
        """The closed batch as the arbiter's "all spans start at 0" case.

        The relaxation itself -- schedule building, skip rules,
        convergence -- lives in :class:`SpanArbiter`; this method only
        owns the per-core inputs and batches the re-simulation requests.
        """
        chip = self.chip
        E = chip.epoch_cycles
        demand = self._demand_vector(streams, traces)
        n = len(demand)
        unthrottled: dict[int, TimingResult] = {}
        weights = self._demand_weights(streams, traces, demand, unthrottled)
        spans = [Span(start=0, end=None if d else 0, demands=d, weight=w)
                 for d, w in zip(demand, weights)]
        results: list[TimingResult | None] = [None] * n

        def simulate(jobs):
            idxs = [i for i, _, _ in jobs]
            params = [self._params(i, prefix, E, tail)
                      for i, prefix, tail in jobs]
            outs = self._sim_round(
                idxs, [streams[i] for i in idxs] if streams else None,
                [traces[i] for i in idxs] if traces else None, params)
            for (i, _, _), (res, lg) in zip(jobs, outs):
                results[i] = res
                spans[i].last_grant = lg
                spans[i].throttled = res.bw_stall_cycles != 0.0

        arb = SpanArbiter(chip.bw_bytes_per_cycle, E, chip.share_policy,
                          oracle=chip.backend == "reference",
                          budget_factors=self._budget_factors)
        trace = arb.relax(spans, simulate)
        self.core_weights = tuple(weights)
        stalls = self._contention_stalls(streams, traces, results,
                                         unthrottled)
        self.last_results = list(results)
        self.last_stalls = stalls
        self.last_params = [
            self._params(i, s._vis[0], E, s._vis[1])
            if s._vis is not None else self._params(i)
            for i, s in enumerate(spans)]
        return results, stalls, trace


def _lower_many(specs: Sequence[GemmSpec], policy: RegPolicy) -> list[Instr]:
    stream: list[Instr] = []
    for spec in specs:
        stream.extend(lowered_stream(spec, policy))
    return stream


def _streams_traces(chip: ChipConfig, shards: Sequence[Sequence[GemmSpec]]):
    """Per-core simulator inputs: instruction streams for the reference
    backend, cached compiled traces for the fast backends (which then never
    materialize ``Instr`` lists at all).

    Trace cache keys drop the spec names: lowering depends only on the
    dims, so the equal-dim shards a symmetric partitioner emits ("x@c0",
    "x@c1", ...) share one compiled trace -- and, downstream, one
    simulation per arbiter round (see ``CoreCluster._sim_round``).
    Lowering runs under each core's own register policy.
    """
    if chip.backend == "reference":
        return [_lower_many(shard, chip.core_specs[i].policy)
                for i, shard in enumerate(shards)], None
    return None, [
        compiled_trace(tuple(dataclasses.replace(s, name="")
                             for s in shard), chip.core_specs[i].policy)
        for i, shard in enumerate(shards)]


def _compute_cycles_vec(streams, traces,
                        n_cores: int) -> tuple[float, ...]:
    """Per-core FF feed cycles (sum of ``tm``) from whichever simulator
    input the run used -- a vectorized sum over the cached trace arrays,
    or one attribute pass over the already-lowered reference stream."""
    out = []
    for i in range(n_cores):
        if traces is not None:
            t = traces[i]
            out.append(float(t.tm[t.opcode == OP_MM].sum()))
        elif streams is not None:
            out.append(float(sum(ins.tm for ins in streams[i]
                                 if ins.op is Op.MM)))
        else:
            out.append(0.0)
    return tuple(out)


def _aggregate(chip: ChipConfig, workload_name: str, strategy: str,
               shards: Sequence[Sequence[GemmSpec]],
               results: Sequence[TimingResult], stalls: Sequence[float],
               single_core_cycles: float,
               trace: ArbiterTrace | None = None,
               core_weights: tuple[float, ...] = (), *,
               streams=None, traces=None, phase: str = "") -> ChipReport:
    compute = _compute_cycles_vec(streams, traces, chip.n_cores)
    plan = chip.fault_plan
    if plan is not None and plan.has_slow_cores:
        # a slowed core's FF feed cycles dilate with its clock, keeping
        # compute + stalls <= busy in chip time (attribution conservation)
        compute = tuple(c / plan.speed_factor(i, 0)
                        for i, c in enumerate(compute))
    cycles = max((r.cycles for r in results), default=0.0)
    peak = sum(spec.engine.peak_macs_per_cycle for spec in chip.core_specs)
    chip_util = (sum(r.useful_macs for r in results)
                 / (cycles * peak)) if cycles else 0.0
    return ChipReport(
        design=chip.design_name,
        workload=workload_name,
        strategy=strategy,
        n_cores=chip.n_cores,
        cycles=cycles,
        single_core_cycles=single_core_cycles,
        per_core_cycles=tuple(r.cycles for r in results),
        per_core_utilization=tuple(r.utilization for r in results),
        utilization=chip_util,
        bw_stall_cycles=sum(stalls),
        n_mm=sum(r.n_mm for r in results),
        wl_skips=sum(r.wl_skips for r in results),
        macs=sum(int(s.macs) for shard in shards for s in shard),
        per_core_gemms=tuple(tuple(s.name for s in shard) for shard in shards),
        arbitration=chip.arbitration,
        epoch_cycles=trace.epoch_cycles if trace else 0.0,
        share_trace=trace.shares if trace else (),
        active_trace=trace.n_active if trace else (),
        arb_rounds=trace.rounds if trace else 1,
        arb_skipped=trace.skipped if trace else (),
        core_designs=tuple(spec.design for spec in chip.core_specs),
        # static arbitration is the frozen *equal*-share baseline
        # regardless of the configured policy (see _run_static)
        share_policy=chip.share_policy.name
        if chip.arbitration == "epoch" else "equal",
        core_weights=tuple(core_weights),
        per_core_compute_cycles=compute,
        per_core_bw_stall_cycles=tuple(stalls),
        fault_log=tuple((e.epoch, e.label) for e in plan.events)
        if plan is not None else (),
        phase=phase,
    )


@functools.lru_cache(maxsize=1024)
def _single_core_cycles_cached(chip: ChipConfig,
                               specs: tuple[GemmSpec, ...]) -> float:
    spec0 = chip.core_specs[0]
    cfg = spec0.engine
    params = StreamModelParams(
        cfg.load_ports, chip.store_ports_for(0), (), math.inf,
        chip.bw_bytes_per_cycle, chip.bw_burst_bytes,
        chip.store_bytes_shared)
    if chip.backend == "reference":
        sim = PipelineSimulator(cfg, load_model=params.make_model())
        return sim.run(_lower_many(specs, spec0.policy)).cycles
    trace = compiled_trace(tuple(dataclasses.replace(s, name="")
                                 for s in specs), spec0.policy)
    return run_cores([trace], cfg, [params],
                     backend=chip.backend)[0][0].cycles


def _single_core_cycles(chip: ChipConfig, specs: Sequence[GemmSpec]) -> float:
    """Reference: all work on one core with the full bandwidth budget.

    Mixed chips are referenced against their core-0 spec (document the
    mix you compare against by ordering ``cores`` accordingly).
    """
    return _single_core_cycles_cached(chip.single_core(), tuple(specs))


def _attach_telemetry(report: ChipReport, cluster: CoreCluster,
                      shards, telemetry: TelemetryConfig) -> ChipReport:
    if not telemetry.enabled:
        return report
    from ..obs.timeline import build_chip_telemetry
    return dataclasses.replace(
        report, telemetry=build_chip_telemetry(cluster, shards, report,
                                               telemetry))


def _seg_compute_cycles(seg) -> float:
    """One online segment's FF feed cycles in chip time (preempted
    instances are credited with their kept prefix only)."""
    if seg.preempted_at is not None:
        return seg.kept_compute
    if seg.trace is not None:
        t = seg.trace
        return float(t.tm[t.opcode == OP_MM].sum()) / seg.speed
    if seg.stream is not None:
        return float(sum(ins.tm for ins in seg.stream
                         if ins.op is Op.MM)) / seg.speed
    return 0.0


def assemble_online_report(sim, chip: ChipConfig, workload_name: str,
                           strategy: str,
                           shards: Sequence[Sequence[GemmSpec]],
                           single_core_cycles: float,
                           telemetry: TelemetryConfig = OFF,
                           phase: str = "") -> ChipReport:
    """A :class:`ChipReport` from a drained :class:`OnlineChip` history.

    The closed-batch assembly path for fault plans that need the online
    machinery (:func:`repro.multicore.faults.faulted_chip_report`).  The
    per-instance outcomes become :attr:`ChipReport.attribution_rows` --
    a preempted instance is busy from its start to the fault boundary,
    credited with its kept prefix's compute and charged the rest to the
    ``fault_lost`` bucket; its resumed remainder is a row of its own.
    Per-instance bandwidth stalls follow the closed cluster's end-to-end
    definition (throttled minus unthrottled makespan, clamped so
    fill/drain stays non-negative), measured with one unthrottled re-sim
    per distinct trace.
    """
    from ..core.fastsim import run_segment

    E = chip.epoch_cycles
    n = chip.n_cores
    segs = sim.history
    cycles = sim.makespan
    per_core = [0.0] * n
    per_stall = [0.0] * n
    per_compute = [0.0] * n
    per_macs = [0.0] * n
    unthrottled: dict[tuple, float] = {}
    rows = []
    for seg in segs:
        c = seg.core
        finish = seg.span.start * E + seg.result.cycles
        per_core[c] = max(per_core[c], finish)
        comp = _seg_compute_cycles(seg)
        per_compute[c] += comp
        per_macs[c] += seg.result.useful_macs
        if seg.preempted_at is not None:
            lost = max(0.0, seg.result.cycles - comp)
            bw = 0.0
        else:
            lost = 0.0
            bw = 0.0
            if seg.result.bw_stall_cycles != 0.0:
                engine = chip.core_specs[c].engine
                trace = seg.trace if seg.trace is not None \
                    else compile_stream(seg.stream)
                key = (trace, engine.name)
                base = unthrottled.get(key)
                if base is None:
                    base = run_segment(
                        trace, engine,
                        stream_model_params(chip, engine))[0].cycles
                    unthrottled[key] = base
                busy = seg.result.cycles
                bw = min(max(0.0, busy - base / seg.speed),
                         max(0.0, busy - comp))
        per_stall[c] += bw
        rows.append((c, seg.submit_epoch * E, seg.span.start * E, finish,
                     comp, bw, lost))
    peak = sum(spec.engine.peak_macs_per_cycle for spec in chip.core_specs)
    util = [per_macs[c] / (per_core[c]
                           * chip.core_specs[c].engine.peak_macs_per_cycle)
            if per_core[c] else 0.0 for c in range(n)]
    plan = chip.fault_plan
    report = ChipReport(
        design=chip.design_name,
        workload=workload_name,
        strategy=strategy,
        n_cores=n,
        cycles=cycles,
        single_core_cycles=single_core_cycles,
        per_core_cycles=tuple(per_core),
        per_core_utilization=tuple(util),
        utilization=sum(per_macs) / (cycles * peak) if cycles else 0.0,
        bw_stall_cycles=sum(per_stall),
        n_mm=sum(s.result.n_mm for s in segs),
        wl_skips=sum(s.result.wl_skips for s in segs),
        macs=sum(int(s.macs) for shard in shards for s in shard),
        per_core_gemms=tuple(tuple(s.name for s in shard)
                             for shard in shards),
        arbitration=chip.arbitration,
        epoch_cycles=E,
        share_trace=sim.share_trace,
        active_trace=sim.active_trace,
        arb_rounds=sim.stats["rounds"],
        core_designs=tuple(spec.design for spec in chip.core_specs),
        share_policy=chip.share_policy.name,
        per_core_compute_cycles=tuple(per_compute),
        per_core_bw_stall_cycles=tuple(per_stall),
        attribution_rows=tuple(rows),
        n_preemptions=sim.n_preempted,
        n_migrations=sim.n_migrated,
        fault_lost_cycles=sim.fault_lost_cycles,
        fault_log=tuple((e.epoch, e.label) for e in plan.events)
        if plan is not None else (),
        phase=phase,
    )
    if telemetry.enabled:
        from ..obs.timeline import build_online_telemetry
        report = dataclasses.replace(
            report, telemetry=build_online_telemetry(sim, telemetry))
    return report


def partitioned_chip_report(spec: GemmSpec, chip: ChipConfig,
                            strategy: str = "m_split",
                            telemetry: TelemetryConfig = OFF) -> ChipReport:
    """Shard one GEMM across the chip's cores and report scaling."""
    shards = partition_gemm(spec, chip.n_cores, strategy)
    if chip.fault_plan is not None and chip.fault_plan.needs_online:
        from .faults import faulted_chip_report
        return faulted_chip_report(shards, chip, spec.name, strategy,
                                   telemetry)
    streams, traces = _streams_traces(chip, shards)
    cluster = CoreCluster(chip)
    results, stalls, trace = cluster.run_streams(streams, traces)
    report = _aggregate(chip, spec.name, strategy, shards, results, stalls,
                        _single_core_cycles(chip, [spec]), trace,
                        cluster.core_weights, streams=streams, traces=traces)
    return _attach_telemetry(report, cluster, shards, telemetry)


def simulate_chip(workload, chip: ChipConfig | None = None, *,
                  partition: str = "m_split",
                  scheduler: str = "work_queue",
                  telemetry: TelemetryConfig = OFF,
                  **chip_kwargs) -> ChipReport:
    """Chip-level analogue of :func:`repro.core.simulate`.

    ``workload`` is one :class:`GemmSpec` -- partitioned across cores with
    ``partition`` -- a compiled model :class:`repro.workload.Workload` --
    scheduled with ``scheduler`` over its atomic placement units -- or a
    sequence of specs, scheduled with ``scheduler`` (see
    :mod:`repro.multicore.scheduler`; the ``gang``/``gang_refine``
    schedulers also use ``partition`` to split dominant GEMMs across idle
    cores).  Extra keyword arguments construct the :class:`ChipConfig` when
    none is given.  ``telemetry=TelemetryConfig(enabled=True)`` attaches a
    full :class:`repro.obs.timeline.ChipTelemetry` to the report.
    """
    if chip is None:
        chip = ChipConfig(**chip_kwargs)
    elif chip_kwargs:
        raise TypeError(f"pass either a ChipConfig or config kwargs, not "
                        f"both: {sorted(chip_kwargs)}")
    if isinstance(workload, GemmSpec):
        return partitioned_chip_report(workload, chip, partition, telemetry)
    from ..workload.compile import Workload
    if isinstance(workload, Workload):
        from .scheduler import scheduled_workload_report
        return scheduled_workload_report(workload, chip, scheduler,
                                         partition=partition,
                                         telemetry=telemetry)
    from .scheduler import scheduled_chip_report
    return scheduled_chip_report(list(workload), chip, scheduler,
                                 partition=partition, telemetry=telemetry)
