"""Multi-GEMM workload scheduling across the chip's cores.

One model layer = one GEMM; a workload is the list of layer GEMMs (e.g.
``repro.core.workloads.TABLE_I`` values or the per-layer traces derived from
``repro.configs``).  The scheduler decides the GEMM -> core placement:

  round_robin -- static: GEMM ``i`` goes to core ``i % n_cores``, blind to
                 cost.  The baseline every dynamic policy must beat.
  work_queue  -- dynamic: GEMMs are pulled from a single queue by whichever
                 core *completes them* first (deterministic work-stealing
                 under the cost model).  Costs are estimated with the
                 unthrottled single-engine simulator (cached), then the
                 final placement is re-simulated under the shared-bandwidth
                 model.
  lpt         -- work_queue with GEMMs sorted longest-first (classic LPT
                 bound); better balance when the workload is skewed but
                 ignores submission order.
  gang        -- lpt that may *split* a GEMM instead of placing it whole:
                 for each GEMM (longest first) it considers every gang
                 width ``w`` in 1..n_cores, shards the GEMM ``w`` ways with
                 :func:`repro.multicore.partition.split_ways`, places the
                 shards on the ``w`` soonest-free cores, and keeps the
                 width with the earliest estimated completion; the split
                 schedule is used only if it beats the whole-GEMM LPT
                 schedule's estimated makespan (splitting re-streams
                 operands, so it must pay for itself).  This is the
                 combined partition x schedule policy: a dominant GEMM
                 that would leave cores idle under whole-GEMM LPT gets
                 gang-split across them.

All cost estimates are **per (GEMM, core)**: on a heterogeneous chip
(mixed :class:`~repro.multicore.chip.CoreSpec` vector) each candidate
placement is costed on the target core's own design, so the dynamic
schedulers route reuse-friendly (WLBP-favoring) GEMMs to the RASA cores
that finish them first and leave BASE cores the work they are least bad
at.  On a homogeneous chip every estimate is core-independent and the
placements reduce exactly to the classic free-at rules (the tests pin
this).

The first three place each GEMM whole on a single core (layer-level
parallelism); only ``gang`` combines inter- and intra-GEMM parallelism.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from ..core.simulator import _simulate_cached
from ..core.tiling import GemmSpec
from ..obs.config import OFF, TelemetryConfig
from .chip import (ChipConfig, ChipReport, CoreCluster, _aggregate,
                   _attach_telemetry, _single_core_cycles, _streams_traces)
from .partition import split_ways

SCHEDULERS = ("round_robin", "work_queue", "lpt", "gang")


def _estimate_cycles(spec: GemmSpec, chip: ChipConfig, core: int = 0) -> float:
    # cost depends only on the dims, but the lru_cache key includes the
    # name -- canonicalize it so equal-dim shards ("x@c0", "x@c1", ...)
    # and repeated layers hit one cache entry instead of re-simulating.
    # Estimates run on the chip's backend: results are backend-independent
    # (see docs/performance.md), so gang's many split_ways probes get the
    # fast path too.  The estimate is per *core*: a mixed chip costs each
    # candidate placement on the target core's own design/policy.
    spec = dataclasses.replace(spec, name="")
    core_spec = chip.core_specs[core]
    return _simulate_cached(spec, core_spec.design, core_spec.policy,
                            chip.backend).cycles


def _workload_cycles(spec: GemmSpec, chip: ChipConfig) -> float:
    """Core-independent size of a GEMM: its best-core estimate.

    The LPT/gang orderings need one scalar per GEMM; on a homogeneous chip
    this equals the (only) per-core estimate, on a mixed chip it is the
    cost on the core that runs the GEMM fastest.
    """
    return min(_estimate_cycles(spec, chip, c) for c in range(chip.n_cores))


def assign_round_robin(specs: list[GemmSpec], n_cores: int) -> list[list[GemmSpec]]:
    out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    for i, spec in enumerate(specs):
        out[i % n_cores].append(spec)
    return out


def assign_work_queue(specs: list[GemmSpec], n_cores: int, chip: ChipConfig,
                      longest_first: bool = False) -> list[list[GemmSpec]]:
    order = specs
    if longest_first:
        order = sorted(specs, key=lambda s: -_workload_cycles(s, chip))
    out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    free_at = [0.0] * n_cores
    for spec in order:
        # earliest *completion*, not earliest free slot: on a mixed chip a
        # busier RASA core can still finish a reuse-friendly GEMM first
        core = min(range(n_cores),
                   key=lambda c: free_at[c] + _estimate_cycles(spec, chip, c))
        out[core].append(spec)
        free_at[core] += _estimate_cycles(spec, chip, core)
    return out


def assign_gang(specs: list[GemmSpec], chip: ChipConfig,
                partition: str = "m_split") -> list[list[GemmSpec]]:
    """LPT with gang splitting: shard GEMMs across soon-free cores when the
    whole-GEMM schedule would leave cores idle under a dominant GEMM.

    Two candidate schedules are built deterministically and the one with
    the smaller estimated makespan wins (ties go to whole-GEMM placement,
    since splitting re-streams operands and so must pay for itself):

    * the plain whole-GEMM LPT schedule;
    * a greedy gang schedule: GEMMs longest-first, each placed at the gang
      width ``w`` in 1..n_cores whose sharded placement (longest shards on
      the soonest-free cores, each shard costed on its target core)
      completes earliest.

    On a balanced workload the greedy splitter serializes gangs and loses,
    so gang placement degenerates to LPT exactly; on a skewed one the
    dominant GEMM is split across the cores LPT would have idled.  With
    ``n_cores == 1`` this is the whole workload, in submission order, on
    core 0 -- the single-core reduction the tests pin down.
    """
    n_cores = chip.n_cores
    if n_cores == 1:
        return [list(specs)]
    est = lambda s, c: _estimate_cycles(s, chip, c)

    whole = assign_work_queue(specs, n_cores, chip, longest_first=True)
    whole_makespan = max(sum(est(s, c) for s in core)
                         for c, core in enumerate(whole))

    order = sorted(specs, key=lambda s: -_workload_cycles(s, chip))
    gang: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    free_at = [0.0] * n_cores
    for spec in order:
        best: tuple[float, int] | None = None
        best_placement: list[tuple[int, GemmSpec]] = []
        for w in range(1, n_cores + 1):
            shards = split_ways(spec, w, partition)
            if len(shards) < w:
                continue            # more gang slots than tiles at this width
            cores = sorted(range(n_cores), key=lambda c: free_at[c])[:w]
            shards = sorted(shards, key=lambda s: -_workload_cycles(s, chip))
            placement = list(zip(cores, shards))
            completion = max(free_at[c] + est(s, c) for c, s in placement)
            if best is None or (completion, w) < best:
                best = (completion, w)
                best_placement = placement
        for core, shard in best_placement:
            gang[core].append(shard)
            free_at[core] += est(shard, core)
    return gang if max(free_at) < whole_makespan else whole


def assign_incremental(items: Sequence, chip: ChipConfig,
                       free_at: Sequence[float]) -> list[list]:
    """Place *new* work onto already-loaded cores without reshuffling.

    The online form of ``work_queue``: ``free_at[c]`` is core *c*'s current
    busy-until estimate (e.g. :meth:`repro.multicore.online.OnlineChip.
    free_at_estimate`); each item goes, in submission order, to the core
    that *completes* it soonest (its backlog plus the item's unthrottled
    cost on that core's design), and the estimate is advanced accordingly.
    An item is either one :class:`GemmSpec` or a sequence of them that must
    land on a single core as a unit (a serving request's prefill + decode
    chain); items are returned as given, so the caller can map them back.
    Only the per-core *additions* are returned -- the caller owns the
    existing placement.  With ``n_cores == 1`` (and any ``free_at``) this
    is all items, in submission order, on core 0 -- the single-core
    reduction the tests pin down.
    """
    if len(free_at) != chip.n_cores:
        raise ValueError(f"need one free_at entry per core, got "
                         f"{len(free_at)} for {chip.n_cores} cores")
    out: list[list] = [[] for _ in range(chip.n_cores)]
    free = list(free_at)
    for item in items:
        specs = (item,) if isinstance(item, GemmSpec) else tuple(item)
        cost = lambda c: sum(_estimate_cycles(s, chip, c) for s in specs)
        core = min(range(chip.n_cores), key=lambda c: free[c] + cost(c))
        out[core].append(item)
        free[core] += cost(core)
    return out


def assign(specs: list[GemmSpec], chip: ChipConfig,
           scheduler: str = "work_queue",
           partition: str = "m_split") -> list[list[GemmSpec]]:
    if scheduler == "round_robin":
        return assign_round_robin(specs, chip.n_cores)
    if scheduler == "work_queue":
        return assign_work_queue(specs, chip.n_cores, chip)
    if scheduler == "lpt":
        return assign_work_queue(specs, chip.n_cores, chip, longest_first=True)
    if scheduler == "gang":
        return assign_gang(specs, chip, partition)
    raise ValueError(f"unknown scheduler {scheduler!r}; available: {SCHEDULERS}")


def scheduled_chip_report(specs: list[GemmSpec], chip: ChipConfig,
                          scheduler: str = "work_queue",
                          partition: str = "m_split",
                          telemetry: TelemetryConfig = OFF) -> ChipReport:
    """Place ``specs`` on cores, simulate each core's concatenated stream
    under the shared-bandwidth model, and aggregate chip-level results.

    ``partition`` selects the sharding strategy the ``gang`` scheduler uses
    when it splits a GEMM (ignored by the whole-GEMM schedulers).
    """
    if not specs:
        raise ValueError("empty workload")
    shards = assign(specs, chip, scheduler, partition)
    streams, traces = _streams_traces(chip, shards)
    cluster = CoreCluster(chip)
    results, stalls, trace = cluster.run_streams(streams, traces)
    name = f"{specs[0].name}+{len(specs) - 1}" if len(specs) > 1 else specs[0].name
    report = _aggregate(chip, name, scheduler, shards, results, stalls,
                        _single_core_cycles(chip, specs), trace,
                        cluster.core_weights, streams=streams, traces=traces)
    return _attach_telemetry(report, cluster, shards, telemetry)
