"""Multi-GEMM workload scheduling across the chip's cores.

One model layer = one GEMM; a workload is the list of layer GEMMs (e.g.
``repro.core.workloads.TABLE_I`` values or the per-layer traces derived from
``repro.configs``).  The scheduler decides the GEMM -> core placement:

  round_robin -- static: GEMM ``i`` goes to core ``i % n_cores``, blind to
                 cost.  The baseline every dynamic policy must beat.
  work_queue  -- dynamic: GEMMs are pulled from a single queue by whichever
                 core *completes them* first (deterministic work-stealing
                 under the cost model).  Costs are estimated with the
                 unthrottled single-engine simulator (cached), then the
                 final placement is re-simulated under the shared-bandwidth
                 model.
  lpt         -- work_queue with GEMMs sorted longest-first (classic LPT
                 bound); better balance when the workload is skewed but
                 ignores submission order.
  gang        -- lpt that may *split* a GEMM instead of placing it whole:
                 for each GEMM (longest first) it considers every gang
                 width ``w`` in 1..n_cores, shards the GEMM ``w`` ways with
                 :func:`repro.multicore.partition.split_ways`, places the
                 shards on the ``w`` soonest-free cores, and keeps the
                 width with the earliest estimated completion; the split
                 schedule is used only if it beats the whole-GEMM LPT
                 schedule's estimated makespan (splitting re-streams
                 operands, so it must pay for itself).  This is the
                 combined partition x schedule policy: a dominant GEMM
                 that would leave cores idle under whole-GEMM LPT gets
                 gang-split across them.
  gang_refine -- gang followed by malleable-width refinement: the greedy
                 width vector is hill-climbed (grow/shrink one GEMM's gang
                 by a core per round, keep the best improving move) until
                 the estimated makespan stops improving.  Greedy widths
                 are myopic -- chosen against the free-at state at
                 placement time -- so refinement wins when a later GEMM
                 strands an earlier width choice (the pinned skewed-
                 workload case in the tests).

Workload-level scheduling (:func:`scheduled_workload_report`) goes through
:func:`assign_units`: the items are a compiled model's *placement units*
(:meth:`repro.workload.Workload.units`), so a MoE expert's GEMM pair lands
on one core atomically while distinct experts spread across cores --
expert parallelism as a scheduling consequence, not a special case.

All cost estimates are **per (GEMM, core)**: on a heterogeneous chip
(mixed :class:`~repro.multicore.chip.CoreSpec` vector) each candidate
placement is costed on the target core's own design, so the dynamic
schedulers route reuse-friendly (WLBP-favoring) GEMMs to the RASA cores
that finish them first and leave BASE cores the work they are least bad
at.  On a homogeneous chip every estimate is core-independent and the
placements reduce exactly to the classic free-at rules (the tests pin
this).

The first three place each GEMM whole on a single core (layer-level
parallelism); only ``gang`` combines inter- and intra-GEMM parallelism.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from ..core.simulator import _simulate_cached
from ..core.tiling import GemmSpec
from ..obs.config import OFF, TelemetryConfig
from .chip import (ChipConfig, ChipReport, CoreCluster, _aggregate,
                   _attach_telemetry, _single_core_cycles, _streams_traces)
from .partition import split_ways

SCHEDULERS = ("round_robin", "work_queue", "lpt", "gang", "gang_refine")


def _estimate_cycles(spec: GemmSpec, chip: ChipConfig, core: int = 0) -> float:
    # cost depends only on the dims, but the lru_cache key includes the
    # name -- canonicalize it so equal-dim shards ("x@c0", "x@c1", ...)
    # and repeated layers hit one cache entry instead of re-simulating.
    # Estimates run on the chip's backend: results are backend-independent
    # (see docs/performance.md), so gang's many split_ways probes get the
    # fast path too.  The estimate is per *core*: a mixed chip costs each
    # candidate placement on the target core's own design/policy.
    spec = dataclasses.replace(spec, name="")
    core_spec = chip.core_specs[core]
    return _simulate_cached(spec, core_spec.design, core_spec.policy,
                            chip.backend).cycles


def _workload_cycles(spec: GemmSpec, chip: ChipConfig) -> float:
    """Core-independent size of a GEMM: its best-core estimate.

    The LPT/gang orderings need one scalar per GEMM; on a homogeneous chip
    this equals the (only) per-core estimate, on a mixed chip it is the
    cost on the core that runs the GEMM fastest.
    """
    return min(_estimate_cycles(spec, chip, c) for c in range(chip.n_cores))


def assign_round_robin(specs: list[GemmSpec], n_cores: int) -> list[list[GemmSpec]]:
    out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    for i, spec in enumerate(specs):
        out[i % n_cores].append(spec)
    return out


def assign_work_queue(specs: list[GemmSpec], n_cores: int, chip: ChipConfig,
                      longest_first: bool = False) -> list[list[GemmSpec]]:
    order = specs
    if longest_first:
        order = sorted(specs, key=lambda s: -_workload_cycles(s, chip))
    out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    free_at = [0.0] * n_cores
    for spec in order:
        # earliest *completion*, not earliest free slot: on a mixed chip a
        # busier RASA core can still finish a reuse-friendly GEMM first
        core = min(range(n_cores),
                   key=lambda c: free_at[c] + _estimate_cycles(spec, chip, c))
        out[core].append(spec)
        free_at[core] += _estimate_cycles(spec, chip, core)
    return out


def assign_gang(specs: list[GemmSpec], chip: ChipConfig,
                partition: str = "m_split") -> list[list[GemmSpec]]:
    """LPT with gang splitting: shard GEMMs across soon-free cores when the
    whole-GEMM schedule would leave cores idle under a dominant GEMM.

    Two candidate schedules are built deterministically and the one with
    the smaller estimated makespan wins (ties go to whole-GEMM placement,
    since splitting re-streams operands and so must pay for itself):

    * the plain whole-GEMM LPT schedule;
    * a greedy gang schedule: GEMMs longest-first, each placed at the gang
      width ``w`` in 1..n_cores whose sharded placement (longest shards on
      the soonest-free cores, each shard costed on its target core)
      completes earliest.

    On a balanced workload the greedy splitter serializes gangs and loses,
    so gang placement degenerates to LPT exactly; on a skewed one the
    dominant GEMM is split across the cores LPT would have idled.  With
    ``n_cores == 1`` this is the whole workload, in submission order, on
    core 0 -- the single-core reduction the tests pin down.
    """
    n_cores = chip.n_cores
    if n_cores == 1:
        return [list(specs)]
    est = lambda s, c: _estimate_cycles(s, chip, c)

    whole = assign_work_queue(specs, n_cores, chip, longest_first=True)
    whole_makespan = max(sum(est(s, c) for s in core)
                         for c, core in enumerate(whole))

    order = sorted(specs, key=lambda s: -_workload_cycles(s, chip))
    gang: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    free_at = [0.0] * n_cores
    for spec in order:
        best: tuple[float, int] | None = None
        best_placement: list[tuple[int, GemmSpec]] = []
        for w in range(1, n_cores + 1):
            shards = split_ways(spec, w, partition)
            if len(shards) < w:
                continue            # more gang slots than tiles at this width
            cores = sorted(range(n_cores), key=lambda c: free_at[c])[:w]
            shards = sorted(shards, key=lambda s: -_workload_cycles(s, chip))
            placement = list(zip(cores, shards))
            completion = max(free_at[c] + est(s, c) for c, s in placement)
            if best is None or (completion, w) < best:
                best = (completion, w)
                best_placement = placement
        for core, shard in best_placement:
            gang[core].append(shard)
            free_at[core] += est(shard, core)
    return gang if max(free_at) < whole_makespan else whole


def assign_incremental(items: Sequence, chip: ChipConfig,
                       free_at: Sequence[float]) -> list[list]:
    """Place *new* work onto already-loaded cores without reshuffling.

    The online form of ``work_queue``: ``free_at[c]`` is core *c*'s current
    busy-until estimate (e.g. :meth:`repro.multicore.online.OnlineChip.
    free_at_estimate`); each item goes, in submission order, to the core
    that *completes* it soonest (its backlog plus the item's unthrottled
    cost on that core's design), and the estimate is advanced accordingly.
    An item is either one :class:`GemmSpec` or a sequence of them that must
    land on a single core as a unit (a serving request's prefill + decode
    chain); items are returned as given, so the caller can map them back.
    Only the per-core *additions* are returned -- the caller owns the
    existing placement.  With ``n_cores == 1`` (and any ``free_at``) this
    is all items, in submission order, on core 0 -- the single-core
    reduction the tests pin down.
    """
    if len(free_at) != chip.n_cores:
        raise ValueError(f"need one free_at entry per core, got "
                         f"{len(free_at)} for {chip.n_cores} cores")
    out: list[list] = [[] for _ in range(chip.n_cores)]
    free = list(free_at)
    for item in items:
        specs = (item,) if isinstance(item, GemmSpec) else tuple(item)
        cost = lambda c: sum(_estimate_cycles(s, chip, c) for s in specs)
        core = min(range(chip.n_cores), key=lambda c: free[c] + cost(c))
        out[core].append(item)
        free[core] += cost(core)
    return out


def _unit_cost(unit: tuple, chip: ChipConfig, core: int) -> float:
    """Cost of placing one atomic unit (a spec tuple) on ``core``."""
    return sum(_estimate_cycles(s, chip, core) for s in unit)


def _unit_size(unit: tuple, chip: ChipConfig) -> float:
    return min(_unit_cost(unit, chip, c) for c in range(chip.n_cores))


def _unit_shards(unit: tuple, w: int, partition: str) -> list[tuple] | None:
    """The gang shards of a unit at width ``w``, each itself a unit.

    Multi-spec units (MoE placement groups) are atomic: only width 1 is
    valid.  Returns ``None`` when the unit cannot occupy exactly ``w``
    cores at this width (more gang slots than tiles, or an atomic group).
    """
    if w == 1:
        return [unit]
    if len(unit) != 1:
        return None
    shards = split_ways(unit[0], w, partition)
    return [(s,) for s in shards] if len(shards) == w else None


def _gang_place(order: list[tuple], widths: list[int], chip: ChipConfig,
                partition: str) -> tuple[list[list[tuple]], list[float]] | None:
    """Replay the deterministic gang placement at fixed per-unit widths.

    Same placement rule as the greedy search (shards longest-first onto
    the soonest-free cores); ``None`` if any width is invalid for its
    unit.  This is the evaluation oracle the refinement hill-climb uses.
    """
    placed: list[list[tuple]] = [[] for _ in range(chip.n_cores)]
    free_at = [0.0] * chip.n_cores
    for unit, w in zip(order, widths):
        shards = _unit_shards(unit, w, partition)
        if shards is None:
            return None
        cores = sorted(range(chip.n_cores), key=lambda c: free_at[c])[:len(shards)]
        shards = sorted(shards, key=lambda u: -_unit_size(u, chip))
        for core, shard in zip(cores, shards):
            placed[core].append(shard)
            free_at[core] += _unit_cost(shard, chip, core)
    return placed, free_at


def _gang_greedy_widths(order: list[tuple], chip: ChipConfig,
                        partition: str) -> list[int]:
    """Per-unit gang widths chosen greedily (earliest estimated completion
    given the placements made so far) -- the width vector ``gang`` commits
    to and ``gang_refine`` starts from."""
    n_cores = chip.n_cores
    free_at = [0.0] * n_cores
    widths: list[int] = []
    for unit in order:
        best: tuple[float, int] | None = None
        best_placement: list[tuple[int, tuple]] = []
        for w in range(1, n_cores + 1):
            shards = _unit_shards(unit, w, partition)
            if shards is None:
                continue
            cores = sorted(range(n_cores), key=lambda c: free_at[c])[:w]
            shards = sorted(shards, key=lambda u: -_unit_size(u, chip))
            placement = list(zip(cores, shards))
            completion = max(free_at[c] + _unit_cost(u, chip, c)
                             for c, u in placement)
            if best is None or (completion, w) < best:
                best = (completion, w)
                best_placement = placement
        widths.append(best[1])
        for core, shard in best_placement:
            free_at[core] += _unit_cost(shard, chip, core)
    return widths


def assign_units(units: Sequence[tuple], chip: ChipConfig,
                 scheduler: str = "work_queue",
                 partition: str = "m_split",
                 refine_rounds: int = 64) -> list[list[GemmSpec]]:
    """Place atomic *units* (spec tuples) on cores; returns per-core specs.

    The unit-level generalization of :func:`assign`: a unit's specs always
    land on one core together (a :meth:`repro.workload.Workload.units` MoE
    placement group, or a singleton GEMM).  The whole-unit schedulers are
    the classic rules on unit costs; ``gang`` may split *singleton* units
    across cores exactly as the flat scheduler does, and ``gang_refine``
    additionally revisits the greedy per-GEMM gang widths after placement:
    a hill-climb shrinks/grows one unit's width (+-1) per round, keeping
    the move that most improves the estimated makespan, until a fixpoint
    (malleable-width gangs; the greedy width choice is myopic -- made
    against the free-at state *at placement time* -- so a later, larger
    unit can strand the width committed for an earlier one).

    Both gang variants keep their schedule only if it beats the whole-unit
    LPT makespan, and fall back to LPT otherwise -- splitting re-streams
    operands, so it must pay for itself.
    """
    units = [tuple(u) for u in units]
    n_cores = chip.n_cores
    if scheduler == "round_robin":
        out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
        for i, unit in enumerate(units):
            out[i % n_cores].extend(unit)
        return out
    if scheduler in ("work_queue", "lpt"):
        order = units
        if scheduler == "lpt":
            order = sorted(units, key=lambda u: -_unit_size(u, chip))
        out = [[] for _ in range(n_cores)]
        free_at = [0.0] * n_cores
        for unit in order:
            core = min(range(n_cores),
                       key=lambda c: free_at[c] + _unit_cost(unit, chip, c))
            out[core].extend(unit)
            free_at[core] += _unit_cost(unit, chip, core)
        return out
    if scheduler not in ("gang", "gang_refine"):
        raise ValueError(f"unknown scheduler {scheduler!r}; "
                         f"available: {SCHEDULERS}")

    whole = assign_units(units, chip, "lpt")
    whole_makespan = max(
        (sum(_estimate_cycles(s, chip, c) for s in core_specs)
         for c, core_specs in enumerate(whole) if core_specs), default=0.0)
    if n_cores == 1:
        return [[s for u in units for s in u]]

    order = sorted(units, key=lambda u: -_unit_size(u, chip))
    widths = _gang_greedy_widths(order, chip, partition)
    placed, free_at = _gang_place(order, widths, chip, partition)

    if scheduler == "gang_refine":
        best_span = max(free_at)
        for _ in range(refine_rounds):
            move: tuple[float, int, int] | None = None
            for i, w in enumerate(widths):
                for cand in (w - 1, w + 1):
                    if not 1 <= cand <= n_cores:
                        continue
                    trial = widths[:i] + [cand] + widths[i + 1:]
                    res = _gang_place(order, trial, chip, partition)
                    if res is None:
                        continue
                    span = max(res[1])
                    if span < best_span and (move is None or span < move[0]):
                        move = (span, i, cand)
            if move is None:
                break
            best_span, i, cand = move
            widths[i] = cand
        placed, free_at = _gang_place(order, widths, chip, partition)

    if max(free_at) < whole_makespan:
        return [[s for unit in core_units for s in unit]
                for core_units in placed]
    return whole


def assign(specs: list[GemmSpec], chip: ChipConfig,
           scheduler: str = "work_queue",
           partition: str = "m_split") -> list[list[GemmSpec]]:
    if scheduler == "round_robin":
        return assign_round_robin(specs, chip.n_cores)
    if scheduler == "work_queue":
        return assign_work_queue(specs, chip.n_cores, chip)
    if scheduler == "lpt":
        return assign_work_queue(specs, chip.n_cores, chip, longest_first=True)
    if scheduler == "gang":
        return assign_gang(specs, chip, partition)
    if scheduler == "gang_refine":
        return assign_units([(s,) for s in specs], chip, "gang_refine",
                            partition)
    raise ValueError(f"unknown scheduler {scheduler!r}; available: {SCHEDULERS}")


def scheduled_chip_report(specs: list[GemmSpec], chip: ChipConfig,
                          scheduler: str = "work_queue",
                          partition: str = "m_split",
                          telemetry: TelemetryConfig = OFF) -> ChipReport:
    """Place ``specs`` on cores, simulate each core's concatenated stream
    under the shared-bandwidth model, and aggregate chip-level results.

    ``partition`` selects the sharding strategy the ``gang`` scheduler uses
    when it splits a GEMM (ignored by the whole-GEMM schedulers).
    """
    if not specs:
        raise ValueError("empty workload")
    shards = assign(specs, chip, scheduler, partition)
    name = f"{specs[0].name}+{len(specs) - 1}" if len(specs) > 1 else specs[0].name
    if chip.fault_plan is not None and chip.fault_plan.needs_online:
        from .faults import faulted_chip_report
        return faulted_chip_report(shards, chip, name, scheduler, telemetry)
    streams, traces = _streams_traces(chip, shards)
    cluster = CoreCluster(chip)
    results, stalls, trace = cluster.run_streams(streams, traces)
    report = _aggregate(chip, name, scheduler, shards, results, stalls,
                        _single_core_cycles(chip, specs), trace,
                        cluster.core_weights, streams=streams, traces=traces)
    return _attach_telemetry(report, cluster, shards, telemetry)


def scheduled_workload_report(workload, chip: ChipConfig,
                              scheduler: str = "work_queue",
                              partition: str = "m_split",
                              telemetry: TelemetryConfig = OFF) -> ChipReport:
    """Place a compiled :class:`repro.workload.Workload` on the chip.

    Placement respects the workload's atomic units (MoE expert groups land
    whole); the report carries the workload's phase so downstream consumers
    can tell a prefill makespan from a decode one.
    """
    units = workload.units()
    if not units:
        raise ValueError("empty workload")
    shards = assign_units(units, chip, scheduler, partition)
    if chip.fault_plan is not None and chip.fault_plan.needs_online:
        from .faults import faulted_chip_report
        return faulted_chip_report(shards, chip, workload.name, scheduler,
                                   telemetry, phase=workload.phase)
    streams, traces = _streams_traces(chip, shards)
    cluster = CoreCluster(chip)
    results, stalls, trace = cluster.run_streams(streams, traces)
    report = _aggregate(chip, workload.name, scheduler, shards, results,
                        stalls, _single_core_cycles(chip, workload.specs),
                        trace, cluster.core_weights, streams=streams,
                        traces=traces, phase=workload.phase)
    return _attach_telemetry(report, cluster, shards, telemetry)
