"""Multi-GEMM workload scheduling across the chip's cores.

One model layer = one GEMM; a workload is the list of layer GEMMs (e.g.
``repro.core.workloads.TABLE_I`` values or the per-layer traces derived from
``repro.configs``).  Each GEMM runs whole on a single core (layer-level
parallelism -- intra-GEMM partitioning is :mod:`repro.multicore.partition`'s
job); the scheduler decides the GEMM -> core placement:

  round_robin -- static: GEMM ``i`` goes to core ``i % n_cores``, blind to
                 cost.  The baseline every dynamic policy must beat.
  work_queue  -- dynamic: GEMMs are pulled from a single queue by whichever
                 core frees up first (deterministic work-stealing under the
                 cost model).  Costs are estimated with the unthrottled
                 single-engine simulator (cached), then the final placement
                 is re-simulated under the shared-bandwidth model.
  lpt         -- work_queue with GEMMs sorted longest-first (classic LPT
                 bound); better balance when the workload is skewed but
                 ignores submission order.
"""

from __future__ import annotations

from ..core.simulator import _simulate_cached
from ..core.tiling import GemmSpec
from .chip import (ChipConfig, ChipReport, CoreCluster, _aggregate,
                   _lower_many, _single_core_cycles)

SCHEDULERS = ("round_robin", "work_queue", "lpt")


def _estimate_cycles(spec: GemmSpec, chip: ChipConfig) -> float:
    return _simulate_cached(spec, chip.engine.name, chip.policy).cycles


def assign_round_robin(specs: list[GemmSpec], n_cores: int) -> list[list[GemmSpec]]:
    out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    for i, spec in enumerate(specs):
        out[i % n_cores].append(spec)
    return out


def assign_work_queue(specs: list[GemmSpec], n_cores: int, chip: ChipConfig,
                      longest_first: bool = False) -> list[list[GemmSpec]]:
    order = specs
    if longest_first:
        order = sorted(specs, key=lambda s: -_estimate_cycles(s, chip))
    out: list[list[GemmSpec]] = [[] for _ in range(n_cores)]
    free_at = [0.0] * n_cores
    for spec in order:
        core = min(range(n_cores), key=lambda c: free_at[c])
        out[core].append(spec)
        free_at[core] += _estimate_cycles(spec, chip)
    return out


def assign(specs: list[GemmSpec], chip: ChipConfig,
           scheduler: str = "work_queue") -> list[list[GemmSpec]]:
    if scheduler == "round_robin":
        return assign_round_robin(specs, chip.n_cores)
    if scheduler == "work_queue":
        return assign_work_queue(specs, chip.n_cores, chip)
    if scheduler == "lpt":
        return assign_work_queue(specs, chip.n_cores, chip, longest_first=True)
    raise ValueError(f"unknown scheduler {scheduler!r}; available: {SCHEDULERS}")


def scheduled_chip_report(specs: list[GemmSpec], chip: ChipConfig,
                          scheduler: str = "work_queue") -> ChipReport:
    """Place ``specs`` on cores, simulate each core's concatenated stream
    under the shared-bandwidth model, and aggregate chip-level results."""
    if not specs:
        raise ValueError("empty workload")
    shards = assign(specs, chip, scheduler)
    streams = [_lower_many(shard, chip.policy) for shard in shards]
    results, stalls = CoreCluster(chip).run_streams(streams)
    name = f"{specs[0].name}+{len(specs) - 1}" if len(specs) > 1 else specs[0].name
    return _aggregate(chip, name, scheduler, shards, results, stalls,
                      _single_core_cycles(chip, specs))
