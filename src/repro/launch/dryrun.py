import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the two
lines above execute before any other import -- jax locks the device count on
first init -- and must never leak into tests/benches (which want 1 device).

Per cell this produces, from the compiled artifact:
  * memory_analysis()  -- per-device bytes: proves the cell fits HBM;
  * cost_analysis()    -- per-device FLOPs / bytes accessed;
  * the optimized HLO  -- collective ops + operand bytes (roofline comm term);
and stores everything in benchmarks/results/dryrun/<cell>.json, which
EXPERIMENTS.md §Dry-run / §Roofline and the perf loop read.

cost_analysis on this JAX counts a scan body ONCE (verified empirically),
so for roofline FLOPs we additionally compile layer-UNROLLED reduced-depth
variants (n_layers = 0 and 1 group) and combine analytically:
total = embed_head + n_groups * per_group.  The full-depth scanned compile
is still what proves memory + sharding.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _cell_path(arch: str, shape: str, multi_pod: bool) -> Path:
    pod = "pod2" if multi_pod else "pod1"
    return RESULTS_DIR / f"{arch}__{shape}__{pod}.json"


def build_step(cfg, shape_kind: str, seq_len: int, batch: int, ctx):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings,
    donate_argnums) for the cell's step function."""
    from ..configs import input_specs
    from ..models import build_model
    from ..serving.engine import _params_shardings, decode_state_shardings
    from ..training.step import (batch_shardings, build_train_step,
                                 init_train_state, state_shardings)
    from ..optim.adamw import AdamWState
    from ..training.step import TrainState

    api = build_model(cfg)
    params_shapes = jax.eval_shape(api.init, jax.random.key(0))

    if shape_kind == "train":
        from ..optim.adamw import adamw_init
        opt_shapes = jax.eval_shape(
            lambda p: adamw_init(p, cfg.parallel.opt_state_dtype),
            params_shapes)
        state_shapes = TrainState(params=params_shapes, opt=opt_shapes,
                                  step=jax.ShapeDtypeStruct((), jnp.int32))
        specs = input_specs(cfg, "train_4k", seq_len=seq_len,
                            global_batch=batch)
        step = build_train_step(api)
        st_sh = state_shardings(api, state_shapes, ctx)
        b_sh = batch_shardings(api, specs, ctx)
        return (step, (state_shapes, specs), (st_sh, b_sh), (0,))

    if shape_kind == "prefill":
        from ..configs import input_specs as ispec
        specs = ispec(cfg, "prefill_32k", seq_len=seq_len, global_batch=batch)
        state_shapes = jax.eval_shape(
            lambda: api.init_decode_state(batch, max_seq=seq_len))
        params_sh = _params_shardings(api, ctx)
        st_sh = decode_state_shardings(api, state_shapes, ctx)
        from ..distributed.sharding import activation_spec
        from jax.sharding import NamedSharding
        tok_sh = NamedSharding(ctx.mesh, activation_spec("tokens", ctx))
        fn = lambda params, tokens, state: api.prefill(params, tokens, state)
        return (fn, (params_shapes, specs["tokens"], state_shapes),
                (params_sh, tok_sh, st_sh), (2,))

    # decode: cache of length seq_len, one new token
    specs = None
    from ..configs import input_specs as ispec
    shape_name = "long_500k" if seq_len >= 500_000 else "decode_32k"
    specs = ispec(cfg, shape_name, seq_len=seq_len, global_batch=batch)
    state_shapes = jax.eval_shape(
        lambda: api.init_decode_state(batch, max_seq=seq_len))
    params_sh = _params_shardings(api, ctx)
    st_sh = decode_state_shardings(api, state_shapes, ctx)
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ctx.dp_axes
    tok_spec = P(dp if batch % 16 == 0 else None)
    if cfg.model.family == "audio":
        tok_spec = P(dp if batch % 16 == 0 else None, None)
    tok_sh = NamedSharding(ctx.mesh, tok_spec)
    fn = lambda params, token, state: api.decode_step(params, token, state)
    return (fn, (params_shapes, specs["token"], state_shapes),
            (params_sh, tok_sh, st_sh), (2,))


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in optimized HLO."""
    import re
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "f64": 8, "s64": 8, "pred": 1,
                   "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    out = {}
    pattern = re.compile(
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?[^=]*=\s*((?:\([^)]*\)|\S+))")
    for m in re.finditer(
            r"^\s*\S+\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?!-done)", hlo_text, re.M):
        shapes_str, op = m.group(1), m.group(2)
        total = 0
        for t, dims in re.findall(r"(\w+)\[([\d,]*)\]", shapes_str):
            if t not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dtype_bytes[t]
        key = op
        out[key] = out.get(key, 0) + total
        out[f"{key}_count"] = out.get(f"{key}_count", 0) + 1
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, force: bool = False,
             reduced_depth: int | None = None) -> dict:
    from ..config import SHAPES
    from ..configs import cell_applicable, get_config
    from ..distributed.sharding import mesh_context
    from .mesh import make_production_mesh

    suffix = "" if reduced_depth is None else f"__d{reduced_depth}"
    path = _cell_path(arch, shape, multi_pod)
    path = path.with_name(path.stem + suffix + ".json")
    if path.exists() and not force:
        return json.loads(path.read_text())

    ok, why = cell_applicable(arch, shape)
    if not ok:
        result = {"arch": arch, "shape": shape, "skipped": True, "reason": why}
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(result, indent=2))
        return result

    seq_len, batch, kind = SHAPES[shape]
    cfg = get_config(arch)
    if multi_pod:
        cfg = dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel, pods=2))
    if shape == "long_500k":
        cfg = dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel,
                                              sequence_parallel_decode=True))
    if reduced_depth is not None:
        # unrolled python-loop layers AND unrolled inner chunk loops
        # (attention/CE/SSD) so cost_analysis counts every op -- scan
        # bodies are counted once; see module docstring
        # NOTE: SSD keeps its production chunk (the chunk size changes the
        # algorithm's FLOPs) -- its chunk scan is unrolled instead.
        cfg = dataclasses.replace(
            cfg,
            model=dataclasses.replace(cfg.model, n_layers=reduced_depth),
            parallel=dataclasses.replace(cfg.parallel, scan_layers=False),
            engine=dataclasses.replace(cfg.engine,
                                       attn_q_chunk=seq_len,
                                       attn_kv_chunk=seq_len,
                                       ce_chunk=seq_len,
                                       unroll_ssd=True))

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh_context(mesh, cfg.parallel) as ctx:
        fn, args, shardings, donate = build_step(cfg, kind, seq_len, batch, ctx)
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = parse_collectives(hlo)

    n_dev = 512 if multi_pod else 256
    unit = 1
    if get_config(arch).model.family == "hybrid":
        unit = get_config(arch).model.hybrid.attn_every
    result = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "unit_layers": unit,
        "total_layers": get_config(arch).model.n_layers,
        "mesh": [2, 16, 16] if multi_pod else [16, 16],
        "devices": n_dev,
        "kind": kind, "seq_len": seq_len, "batch": batch,
        "reduced_depth": reduced_depth,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost_per_device": {
            "flops": cost.get("flops", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "collectives_per_device_bytes": colls,
        "hlo_bytes": len(hlo),
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(result, indent=2))
    return result


def run_layer_costs(arch: str, shape: str, force: bool = False) -> None:
    """Reduced-depth UNROLLED compiles (depth 0 and one unit) on the
    single-pod mesh -- the roofline's accurate per-layer cost source."""
    from ..configs import get_config
    unit = 1
    if get_config(arch).model.family == "hybrid":
        unit = get_config(arch).model.hybrid.attn_every
    for depth in (0, unit):
        run_cell(arch, shape, multi_pod=False, force=force,
                 reduced_depth=depth)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--layer-costs", action="store_true",
                    help="also compile reduced-depth unrolled variants "
                         "(roofline per-layer costs)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from ..configs import ARCH_NAMES, SHAPES

    cells = []
    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
        try:
            r = run_cell(arch, shape, mp, force=args.force)
            if r.get("skipped"):
                print(f"[skip] {tag}: {r['reason']}")
            else:
                mem_gb = r["memory"]["peak_bytes_per_device"] / 2**30
                print(f"[ ok ] {tag}: peak {mem_gb:.2f} GiB/dev, "
                      f"compile {r.get('compile_s', '?')}s "
                      f"(flops/dev {r['cost_per_device']['flops']:.3g})")
            if args.layer_costs and not mp and not r.get("skipped"):
                run_layer_costs(arch, shape, force=args.force)
                print(f"[ ok ] {tag}: layer-cost artifacts written")
        except Exception as e:
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
