"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state -- smoke tests and benches see the real device
count, only dryrun.py forces 512 host devices.
"""

from __future__ import annotations

import jax

from ..config import ParallelConfig


def _auto_mesh(shape, axes):
    """jax.make_mesh with Auto axis types; older jax (< 0.6, no
    jax.sharding.AxisType) builds auto-sharded meshes unconditionally."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_mesh_for(parallel: ParallelConfig):
    """Mesh matching a ParallelConfig (used by elastic restart to rebuild a
    smaller mesh after node loss)."""
    if parallel.pods > 1:
        shape = (parallel.pods, parallel.data, parallel.model)
        axes = ("pod", "data", "model")
    else:
        shape = (parallel.data, parallel.model)
        axes = ("data", "model")
    return _auto_mesh(shape, axes)


def make_host_mesh(max_devices: int | None = None):
    """Best-effort mesh over whatever devices exist (CPU smoke runs: 1
    device -> 1x1 mesh).  Used by examples and integration tests."""
    n = len(jax.devices()) if max_devices is None else min(
        max_devices, len(jax.devices()))
    model = 1
    for m in (4, 2, 1):
        if n % m == 0 and n >= m:
            model = m
            break
    return _auto_mesh((n // model, model), ("data", "model"))


#: XLA flags a real TPU launch would set for compute/comm overlap (no-ops on
#: CPU; documented in DESIGN.md §5 -- the launch scripts export these).
TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
)
