"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Prefill + batched greedy decode through the ServeSession; production meshes
use the same jit_prefill/jit_decode_step wrappers with KV-cache shardings
(sequence-parallel flash decode for 500k contexts; serving/sp_decode.py).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..distributed.sharding import mesh_context
from ..models import build_model
from ..serving import ServeSession
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    api = build_model(cfg)

    with mesh_context(mesh, cfg.parallel):
        params = api.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        if cfg.model.family == "audio":
            prompts = jax.numpy.asarray(rng.integers(
                0, cfg.model.vocab,
                (args.batch, args.prompt_len, cfg.model.n_codebooks)),
                jax.numpy.int32)
        else:
            prompts = jax.numpy.asarray(rng.integers(
                0, cfg.model.vocab, (args.batch, args.prompt_len)),
                jax.numpy.int32)
        session = ServeSession(api, params,
                               max_seq=args.prompt_len + args.steps + 8)
        t0 = time.perf_counter()
        out = session.generate(prompts, args.steps)
        dt = time.perf_counter() - t0
        print(f"[serve] {args.batch} seqs x {args.steps} tokens in {dt:.2f}s "
              f"({args.batch*args.steps/dt:.1f} tok/s); "
              f"sample: {np.asarray(out[0])[:8].tolist()}")
    return out


if __name__ == "__main__":
    main()
