"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires config -> mesh -> sharded train step -> fault-tolerant loop.  On the
CPU container this runs reduced configs end-to-end (see examples/train_lm.py
for the 100M-scale run); on a TPU pod the same entry point scales out --
set ``TPU_PERF_FLAGS`` (mesh.py) in the launch environment for
compute/comm overlap.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..config import TrainConfig
from ..configs import get_config
from ..data import SyntheticLMDataset
from ..distributed.sharding import mesh_context
from ..models import build_model
from ..training import LoopConfig, TrainLoop, init_train_state
from ..training.step import jit_train_step, state_shardings
from .mesh import make_host_mesh, make_mesh_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 production mesh (TPU pods)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, train=TrainConfig(
        global_batch=args.global_batch, seq_len=args.seq_len,
        lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir))

    mesh = (make_mesh_for(cfg.parallel) if args.production_mesh
            else make_host_mesh())
    api = build_model(cfg)
    data = SyntheticLMDataset(cfg.model, seq_len=args.seq_len,
                              global_batch=args.global_batch)

    with mesh_context(mesh, cfg.parallel) as ctx:
        state = init_train_state(api, jax.random.key(cfg.train.seed))
        from ..configs import input_specs
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in data.batch(0).items()}
        specs = {k: jax.ShapeDtypeStruct(np.asarray(v).shape,
                                         np.asarray(v).dtype)
                 for k, v in data.batch(0).items()}
        step_fn = jit_train_step(api, state, specs, ctx)
        st_sh = state_shardings(api, state, ctx)

        loop = TrainLoop(
            step_fn=step_fn, state=state,
            batch_fn=lambda s: data.batch(s),
            cfg=LoopConfig(total_steps=args.steps,
                           checkpoint_every=args.checkpoint_every,
                           checkpoint_dir=args.checkpoint_dir,
                           handle_sigterm=True),
            state_shardings=st_sh)
        final = loop.run()
        losses = [m["loss"] for m in loop.metrics_history]
        print(f"[train] done: {len(losses)} steps, "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
              f"stragglers flagged: {loop.straggler.flagged}")
    return final


if __name__ == "__main__":
    main()
