"""RASA core: the paper's contribution, reproduced.

- :mod:`repro.core.isa`       -- AMX-like tile ISA + register file w/ dirty bits
- :mod:`repro.core.designs`   -- baseline + 7 RASA designs (Control x Data)
- :mod:`repro.core.timing`    -- cycle-level sub-stage pipeline model
- :mod:`repro.core.tiling`    -- register-aware GEMM lowering (Algorithm 1)
- :mod:`repro.core.engine`    -- functional (numerics) execution
- :mod:`repro.core.workloads` -- Table I layer set
- :mod:`repro.core.area`      -- area/power/energy model (published constants)
- :mod:`repro.core.simulator` -- evaluation driver
"""

from .designs import DESIGNS, EngineConfig, get_design
from .isa import (NUM_TREGS, TILE_K, TILE_M, TILE_N, Instr, Op,
                  TileRegisterFile, count_ops, tile_bytes, validate_stream)
from .simulator import SimReport, normalized_runtime, simulate, sweep_designs
from .tiling import (ALG1_POLICY, MAX_REUSE_POLICY, GemmSpec, RegPolicy,
                     lower_gemm, stream_stats)
from .timing import (LoadStreamModel, PipelineSimulator, TimingResult,
                     serial_mm_latency, steady_state_interval)
from .workloads import TABLE_I, batch_sweep

__all__ = [
    "DESIGNS", "EngineConfig", "get_design",
    "NUM_TREGS", "TILE_K", "TILE_M", "TILE_N", "Instr", "Op",
    "TileRegisterFile", "count_ops", "tile_bytes", "validate_stream",
    "SimReport", "normalized_runtime", "simulate", "sweep_designs",
    "ALG1_POLICY", "MAX_REUSE_POLICY", "GemmSpec", "RegPolicy",
    "lower_gemm", "stream_stats",
    "LoadStreamModel", "PipelineSimulator", "TimingResult",
    "serial_mm_latency", "steady_state_interval", "TABLE_I", "batch_sweep",
]
