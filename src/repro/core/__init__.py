"""RASA core: the paper's contribution, reproduced.

- :mod:`repro.core.isa`       -- AMX-like tile ISA + register file w/ dirty bits
- :mod:`repro.core.designs`   -- baseline + 7 RASA designs (Control x Data)
- :mod:`repro.core.timing`    -- cycle-level sub-stage pipeline model
- :mod:`repro.core.tiling`    -- register-aware GEMM lowering (Algorithm 1)
- :mod:`repro.core.engine`    -- functional (numerics) execution
- :mod:`repro.core.workloads` -- Table I layer set
- :mod:`repro.core.area`      -- area/power/energy model (published constants)
- :mod:`repro.core.trace`     -- SoA trace compilation (cached lowering)
- :mod:`repro.core.fastsim`   -- numpy/jax scan backends over compiled traces
- :mod:`repro.core.simulator` -- evaluation driver (backend dispatch)
"""

from .designs import DESIGNS, EngineConfig, get_design
from .fastsim import StreamModelParams
from .isa import (NUM_TREGS, TILE_K, TILE_M, TILE_N, Instr, Op,
                  TileRegisterFile, count_ops, tile_bytes, validate_stream)
from .simulator import (BACKENDS, SimReport, normalized_runtime, simulate,
                        sweep_designs, sweep_workload)
from .tiling import (ALG1_POLICY, MAX_REUSE_POLICY, GemmSpec, RegPolicy,
                     lower_gemm, lowered_stream, stream_stats)
from .timing import (LoadStreamModel, PipelineSimulator, TimingResult,
                     serial_mm_latency, steady_state_interval)
from .trace import CompiledTrace, compile_stream, compiled_trace, gemm_trace
from .workloads import TABLE_I, batch_sweep

__all__ = [
    "DESIGNS", "EngineConfig", "get_design",
    "NUM_TREGS", "TILE_K", "TILE_M", "TILE_N", "Instr", "Op",
    "TileRegisterFile", "count_ops", "tile_bytes", "validate_stream",
    "BACKENDS", "SimReport", "normalized_runtime", "simulate",
    "sweep_designs", "sweep_workload",
    "ALG1_POLICY", "MAX_REUSE_POLICY", "GemmSpec", "RegPolicy",
    "lower_gemm", "lowered_stream", "stream_stats",
    "LoadStreamModel", "PipelineSimulator", "TimingResult",
    "serial_mm_latency", "steady_state_interval",
    "CompiledTrace", "StreamModelParams", "compile_stream", "compiled_trace",
    "gemm_trace", "TABLE_I", "batch_sweep",
]
