"""Functional execution of RASA instruction streams (numerics oracle).

Executes a lowered instruction stream against real matrices with the
engine's mixed-precision semantics -- bf16 operands, fp32 accumulation --
exactly as the paper's PEs do ("BF16 in, FP32 out", §IV-B) and as the TPU
MXU does.  Used by tests to prove that ``tiling.lower_gemm`` is a correct
compiler for every register policy and edge-tile case, and by the examples
to show bit-equivalence with the Pallas kernels' reference.
"""

from __future__ import annotations

import math

import numpy as np
import ml_dtypes

from .isa import NUM_TREGS, TILE_K, TILE_M, TILE_N, Instr, Op
from .tiling import GemmSpec, RegPolicy, lower_gemm

BF16 = ml_dtypes.bfloat16


class FunctionalEngine:
    """Executes rasa_tl / rasa_mm / rasa_ts against numpy tile storage."""

    def __init__(self, a: np.ndarray, b: np.ndarray, c: np.ndarray,
                 tile_m: int = TILE_M, tile_k: int = TILE_K, tile_n: int = TILE_N):
        self.tile_m, self.tile_k, self.tile_n = tile_m, tile_k, tile_n
        self.a = np.asarray(a, dtype=BF16)
        self.b = np.asarray(b, dtype=BF16)
        self.c = np.asarray(c, dtype=np.float32).copy()
        self.tregs: list[np.ndarray | None] = [None] * NUM_TREGS

    # -- tile address helpers ------------------------------------------------
    def _slice(self, mat: str, addr: tuple) -> tuple:
        _, i, j = addr
        if mat == "A":
            return (slice(i * self.tile_m, (i + 1) * self.tile_m),
                    slice(j * self.tile_k, (j + 1) * self.tile_k))
        if mat == "B":
            return (slice(i * self.tile_k, (i + 1) * self.tile_k),
                    slice(j * self.tile_n, (j + 1) * self.tile_n))
        return (slice(i * self.tile_m, (i + 1) * self.tile_m),
                slice(j * self.tile_n, (j + 1) * self.tile_n))

    def execute(self, ins: Instr) -> None:
        if ins.op is Op.TL:
            mat = ins.addr[0]                          # type: ignore[index]
            src = {"A": self.a, "B": self.b, "C": self.c}[mat]
            self.tregs[ins.dst] = src[self._slice(mat, ins.addr)].copy()  # type: ignore
        elif ins.op is Op.TS:
            self.c[self._slice("C", ins.addr)] = self.tregs[ins.src1]     # type: ignore
        else:  # MM: C += A @ B with bf16 multiply, fp32 accumulate
            a = self.tregs[ins.src1].astype(np.float32)   # type: ignore[union-attr]
            b = self.tregs[ins.src2].astype(np.float32)   # type: ignore[union-attr]
            c = self.tregs[ins.dst].astype(np.float32)    # type: ignore[union-attr]
            self.tregs[ins.dst] = c + a @ b


def run_gemm(a: np.ndarray, b: np.ndarray, c: np.ndarray,
             policy: RegPolicy | None = None) -> np.ndarray:
    """Lower + functionally execute C += A @ B; returns the resulting C."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    spec = GemmSpec("run", m, k, n)
    # pad to tile multiples so tile slicing is uniform; strip afterwards.
    mt, kt, nt = spec.tiles()
    ap = np.zeros((mt * TILE_M, kt * TILE_K), np.float32); ap[:m, :k] = a
    bp = np.zeros((kt * TILE_K, nt * TILE_N), np.float32); bp[:k, :n] = b
    cp = np.zeros((mt * TILE_M, nt * TILE_N), np.float32); cp[:m, :n] = c
    eng = FunctionalEngine(ap, bp, cp)
    for ins in lower_gemm(spec, policy or RegPolicy()):
        eng.execute(ins)
    return eng.c[:m, :n]


def reference_gemm(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    """Mixed-precision reference: bf16-rounded operands, fp32 accumulate."""
    a16 = np.asarray(a, dtype=BF16).astype(np.float32)
    b16 = np.asarray(b, dtype=BF16).astype(np.float32)
    return np.asarray(c, np.float32) + a16 @ b16


def simulate_chip(workload, chip=None, **kwargs):
    """Chip-level (multi-core) simulation entry point.

    Convenience re-export: delegates to :func:`repro.multicore.simulate_chip`
    (imported lazily so ``repro.core`` stays dependency-free of the chip
    layer).  ``workload`` is a single :class:`~repro.core.tiling.GemmSpec`
    (partitioned across cores) or a sequence of them (scheduled across
    cores); see :mod:`repro.multicore` for the knobs.
    """
    from ..multicore import simulate_chip as _simulate_chip
    return _simulate_chip(workload, chip, **kwargs)
