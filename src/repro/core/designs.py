"""The eight evaluated designs (paper §V): baseline + seven RASA variants.

Naming follows the paper: RASA-Control optimizations {PIPE, WLBP, WLS} and
RASA-Data optimizations {DB, DM, DMDB}.  WLS requires a double weight buffer
(DB); DM halves the rows of the array and puts two multipliers in each PE
("for fair comparisons, we use the same number of multipliers in all systolic
arrays": 32x16x1 == 16x16x2).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Physical + scheduling configuration of the matrix engine."""

    name: str
    rows: int = 32               # physical PE rows (the T_K direction)
    cols: int = 16               # physical PE cols (the T_N direction)
    macs_per_pe: int = 1         # 2 with DM
    pipe: bool = False           # PIPE: overlap next WL with previous DR
    wlbp: bool = False           # skip WL on clean weight-register reuse
    wls: bool = False            # prefetch WL into shadow buffer (needs DB)
    double_buffer: bool = False  # DB: shadow weight buffer + links
    #: engine clock (paper: 500 MHz) and host core clock (2 GHz, 4-wide)
    engine_clock_hz: float = 500e6
    core_clock_hz: float = 2e9
    core_issue_width: int = 4
    #: tile-load latency in *engine* cycles (cold); paper assumes the memory
    #: system never throttles throughput, so this only delays true deps.
    load_latency: int = 5
    #: number of in-flight tile loads the LSQ sustains per engine cycle
    load_ports: int = 2
    #: tile stores retired per engine cycle once store traffic is modelled
    #: (the chip-level arbiter serializes ``rasa_ts`` on this; the paper's
    #: single-core model leaves stores free -- see LoadStreamModel).
    store_ports: int = 1

    def __post_init__(self):
        if self.wls and not self.double_buffer:
            raise ValueError("WLS requires a double (shadow) weight buffer [paper §IV-B]")

    # -- derived stage latencies (engine cycles) ---------------------------
    @property
    def wl_cycles(self) -> int:
        """Weight Load: stream `rows` weight rows top->bottom.  With DM the
        array has half the rows (each PE buffers two weights fed over the
        doubled links), so WL shortens accordingly."""
        return self.rows

    @property
    def fs_cycles(self) -> int:
        return self.rows - 1

    @property
    def dr_cycles(self) -> int:
        # DM adds a merge row of adders at the bottom: +1 drain cycle.
        return self.cols + (1 if self.macs_per_pe == 2 else 0)

    def ff_cycles(self, tm: int) -> int:
        return tm

    def serial_latency(self, tm: int) -> int:
        """BASE occupancy of one rasa_mm: WL + FF + FS + DR.

        For the paper's 32x16 / T_M=16 configuration this is 95 cycles
        ("L_baseline = 95"), i.e. Eq. (1) in its non-overlapped '-1' form --
        see DESIGN.md §1.
        """
        return self.wl_cycles + self.ff_cycles(tm) + self.fs_cycles + self.dr_cycles

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.rows * self.cols * self.macs_per_pe


def _mk(name: str, *, dm: bool = False, db: bool = False, pipe: bool = False,
        wlbp: bool = False, wls: bool = False) -> EngineConfig:
    return EngineConfig(
        name=name,
        rows=16 if dm else 32,
        cols=16,
        macs_per_pe=2 if dm else 1,
        pipe=pipe or wlbp or wls,   # WLBP/WLS subsume basic pipelining
        wlbp=wlbp,
        wls=wls,
        double_buffer=db,
    )


#: Baseline + the seven RASA designs evaluated in Fig. 5.
DESIGNS: dict[str, EngineConfig] = {
    "BASE":           _mk("BASE"),
    "RASA-PIPE":      _mk("RASA-PIPE", pipe=True),
    "RASA-WLBP":      _mk("RASA-WLBP", wlbp=True),
    "RASA-DB-WLS":    _mk("RASA-DB-WLS", db=True, wls=True, wlbp=True),
    "RASA-DM-PIPE":   _mk("RASA-DM-PIPE", dm=True, pipe=True),
    "RASA-DM-WLBP":   _mk("RASA-DM-WLBP", dm=True, wlbp=True),
    "RASA-DMDB-WLS":  _mk("RASA-DMDB-WLS", dm=True, db=True, wls=True, wlbp=True),
    # DB alone enables WLS-less double buffering; included for the PPA study.
    "RASA-DB-WLBP":   _mk("RASA-DB-WLBP", db=True, wlbp=True),
}


def get_design(name: str) -> EngineConfig:
    try:
        return DESIGNS[name]
    except KeyError:
        raise KeyError(f"unknown design {name!r}; available: {sorted(DESIGNS)}") from None
