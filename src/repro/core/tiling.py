"""Register-aware GEMM -> RASA instruction-stream lowering (the "compiler").

This reproduces the software layer the paper relies on (LIBXSMM-generated
AMX microkernels, Algorithm 1): a tiled GEMM

    C[M,N] += A[M,K] @ B[K,N]      (bf16 inputs, fp32 accumulation)

is lowered into ``rasa_tl`` / ``rasa_mm`` / ``rasa_ts`` over the eight tile
registers.  The *register allocation policy* determines the weight-register
reuse pattern that RASA-WLBP exploits, and the spacing between ``rasa_mm``
that accumulate into the same C register (a true dependency through the
array) -- hence "register-aware".

Policy := (mc, nc, a_regs, b_regs): an mc x nc block of C tiles stays
resident in registers while K streams; A tiles cycle through ``a_regs``
registers and B tiles through ``b_regs``.  Algorithm 1 in the paper is
(mc=2, nc=2, a_regs=2, b_regs=2).  The inner rasa_mm order is n-outer /
m-inner so that the B register is reused for (mc-1) consecutive rasa_mm
out of every mc (WLBP hit rate = (mc-1)/mc within a k-step).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterator

from .isa import (NUM_TREGS, TILE_K, TILE_M, TILE_N, Instr, Op)


@dataclasses.dataclass(frozen=True)
class RegPolicy:
    mc: int = 2        # C-block tiles in M
    nc: int = 2        # C-block tiles in N
    a_regs: int = 2
    b_regs: int = 2
    #: emit loads of C before accumulation (beta=1) and stores after.
    load_c: bool = True
    #: rasa_mm order within a k-step: "n_outer" (Algorithm 1; B register is
    #: reused (mc-1)/mc of the time) or "m_outer" (B changes every rasa_mm --
    #: the reuse-hostile order; used to bracket trace-level reuse rates).
    mm_order: str = "n_outer"
    #: pad edge tiles to the full 16x32x16 (LIBXSMM/paper behaviour: batch
    #: 1..16 all cost the same -- Fig. 7).  False = AMX-tilecfg-style exact
    #: tiles whose FF stage shortens; a beyond-paper optimization.
    pad_tiles: bool = True

    def __post_init__(self):
        need = self.mc * self.nc + self.a_regs + self.b_regs
        if need > NUM_TREGS:
            raise ValueError(
                f"policy needs {need} tile registers > {NUM_TREGS} available")
        if self.a_regs < 1 or self.b_regs < 1:
            raise ValueError("need at least one A and one B register")

    @property
    def c_base(self) -> int:
        return 0

    @property
    def a_base(self) -> int:
        return self.mc * self.nc

    @property
    def b_base(self) -> int:
        return self.mc * self.nc + self.a_regs


#: the paper's Algorithm-1 policy
ALG1_POLICY = RegPolicy(mc=2, nc=2, a_regs=2, b_regs=2)
#: reuse-maximizing policy found by the design-space benchmark (mc=5 keeps
#: five consecutive rasa_mm on one weight register: WLBP hit rate 4/5)
MAX_REUSE_POLICY = RegPolicy(mc=5, nc=1, a_regs=2, b_regs=1)
#: reuse-hostile order: the B register changes on every rasa_mm (WLBP never
#: fires).  Together with ALG1_POLICY this brackets the effective reuse rate
#: of the paper's LIBXSMM traces (see EXPERIMENTS.md §Fig5).
LOW_REUSE_POLICY = RegPolicy(mc=2, nc=2, a_regs=2, b_regs=2, mm_order="m_outer")


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    name: str
    M: int
    K: int
    N: int

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def tiles(self, tile_m: int = TILE_M, tile_k: int = TILE_K,
              tile_n: int = TILE_N) -> tuple[int, int, int]:
        return (math.ceil(self.M / tile_m),
                math.ceil(self.K / tile_k),
                math.ceil(self.N / tile_n))


@dataclasses.dataclass(frozen=True)
class ReduceSpec:
    """Cross-core reduction of ``ways`` partial C matrices into one.

    Emitted by the K-split partitioner: each of the ``ways`` K-shards
    produces a full [M, N] fp32 partial, and the hosting core merges them
    with element-wise adds.  The merge runs on the core's vector unit, not
    the systolic array, so a ``ReduceSpec`` lowers to a pure memory stream
    -- ``ways`` ``rasa_tl`` loads plus one ``rasa_ts`` store per C tile, no
    ``rasa_mm`` -- and its cost is the load/store port time plus whatever
    the shared-bandwidth arbiter charges for the (ways + 1) x M x N x 4
    bytes of reduction traffic.  ``macs`` is 0: a reduction adds no
    multiply work, so MAC conservation across a K-split holds exactly.
    """

    name: str
    M: int
    N: int
    ways: int

    def __post_init__(self):
        if self.ways < 2:
            raise ValueError("a reduction needs at least 2 partials")

    @property
    def macs(self) -> int:
        return 0

    @property
    def flops(self) -> int:
        #: element-wise adds of the merge ((ways - 1) per C element)
        return (self.ways - 1) * self.M * self.N

    @property
    def bytes_moved(self) -> int:
        """fp32 traffic of the merge: ``ways`` partials in, one result out."""
        return (self.ways + 1) * self.M * self.N * 4

    def tiles(self, tile_m: int = TILE_M, tile_k: int = TILE_K,
              tile_n: int = TILE_N) -> tuple[int, int, int]:
        """C-tile grid as an ``(mt, kt, nt)`` triple; ``kt`` is 0 so the
        ``mt * kt * nt`` rasa_mm cache guards see a reduction's true MM
        count (zero)."""
        return (math.ceil(self.M / tile_m), 0, math.ceil(self.N / tile_n))


def lower_reduce(spec: ReduceSpec, policy: RegPolicy = ALG1_POLICY,
                 tile_m: int = TILE_M, tile_n: int = TILE_N
                 ) -> Iterator[Instr]:
    """Yield the memory stream of a cross-core partial-sum reduction.

    Per C tile: ``rasa_tl`` each of the ``ways`` fp32 partials into
    rotating registers, then ``rasa_ts`` the merged tile.  The partial for
    way ``p`` of tile (mi, ni) is addressed ``("C", mi, ni, p)`` -- a C-kind
    tile, so :func:`repro.core.isa.tile_bytes` charges ``tm * tn * 4``
    bytes, the rate the bandwidth arbiters throttle.  Edge-tile extents
    follow ``policy.pad_tiles`` exactly like :func:`lower_gemm`.
    """
    mt, _, nt = spec.tiles(tile_m=tile_m, tile_n=tile_n)

    def dim(i, full, tile):
        if policy.pad_tiles:
            return tile
        return min(tile, full - i * tile)

    for ni in range(nt):
        for mi in range(mt):
            tm = dim(mi, spec.M, tile_m)
            tn = dim(ni, spec.N, tile_n)
            for p in range(spec.ways):
                yield Instr(Op.TL, dst=p % NUM_TREGS,
                            addr=("C", mi, ni, p), tm=tm, tn=tn)
            yield Instr(Op.TS, src1=0, addr=("C", mi, ni), tm=tm, tn=tn)


def lower_gemm(spec: GemmSpec, policy: RegPolicy = ALG1_POLICY,
               tile_m: int = TILE_M, tile_k: int = TILE_K,
               tile_n: int = TILE_N) -> Iterator[Instr]:
    """Yield the RASA instruction stream for one GEMM.

    Loop nest (LIBXSMM-style, C-block resident):

        for n_blk:                      # steps of nc tiles
          for m_blk:                    # steps of mc tiles
            rasa_tl C[mi,ni] ...        # mc*nc loads (if beta=1)
            for k:                      # K tiles stream
              rasa_tl A[mi,k], B[k,ni]  # as registers cycle
              for ni: for mi:           # n-outer/m-inner => B reuse
                rasa_mm C[mi,ni], A[mi], B[ni]
            rasa_ts C[mi,ni] ...
    """
    mt, kt, nt = spec.tiles(tile_m, tile_k, tile_n)

    def dim(i, t, full, tile):
        """tile-i extent along a dimension: full tile when padding (the
        hardware streams every configured register row), exact otherwise."""
        if policy.pad_tiles:
            return tile
        return min(tile, full - i * tile)

    for n0 in range(0, nt, policy.nc):
        ncur = min(policy.nc, nt - n0)
        for m0 in range(0, mt, policy.mc):
            mcur = min(policy.mc, mt - m0)
            # --- load the C block ------------------------------------------
            if policy.load_c:
                for ni in range(ncur):
                    for mi in range(mcur):
                        yield Instr(Op.TL, dst=policy.c_base + ni * policy.mc + mi,
                                    addr=("C", m0 + mi, n0 + ni),
                                    tm=dim(m0 + mi, mt, spec.M, tile_m),
                                    tn=dim(n0 + ni, nt, spec.N, tile_n))
            # --- stream K ---------------------------------------------------
            for k in range(kt):
                tk = dim(k, kt, spec.K, tile_k)
                preload_a = policy.a_regs >= mcur
                preload_b = policy.b_regs >= ncur
                if preload_a:
                    # all A tiles for this k fit; load once up front
                    for mi in range(mcur):
                        yield Instr(Op.TL, dst=policy.a_base + mi % policy.a_regs,
                                    addr=("A", m0 + mi, k),
                                    tm=dim(m0 + mi, mt, spec.M, tile_m), tk=tk)
                if policy.mm_order == "m_outer" and preload_b:
                    for ni in range(ncur):
                        yield Instr(Op.TL, dst=policy.b_base + ni % policy.b_regs,
                                    addr=("B", k, n0 + ni),
                                    tk=tk, tn=dim(n0 + ni, nt, spec.N, tile_n))

                if policy.mm_order == "n_outer":
                    order = [(mi, ni) for ni in range(ncur) for mi in range(mcur)]
                else:
                    order = [(mi, ni) for mi in range(mcur) for ni in range(ncur)]

                last_b_loaded: int | None = None
                for mi, ni in order:
                    a_reg = policy.a_base + mi % policy.a_regs
                    b_reg = policy.b_base + ni % policy.b_regs
                    # just-in-time (re)loads under register pressure / order
                    need_b = ((policy.mm_order == "n_outer" and mi == order[0][0]
                               and last_b_loaded != ni)
                              or (policy.mm_order == "m_outer" and not preload_b))
                    if need_b:
                        yield Instr(Op.TL, dst=b_reg, addr=("B", k, n0 + ni),
                                    tk=tk, tn=dim(n0 + ni, nt, spec.N, tile_n))
                        last_b_loaded = ni
                    if not preload_a:
                        yield Instr(Op.TL, dst=a_reg, addr=("A", m0 + mi, k),
                                    tm=dim(m0 + mi, mt, spec.M, tile_m), tk=tk)
                    yield Instr(
                        Op.MM,
                        dst=policy.c_base + ni * policy.mc + mi,
                        src1=a_reg, src2=b_reg,
                        tm=dim(m0 + mi, mt, spec.M, tile_m),
                        tk=tk,
                        tn=dim(n0 + ni, nt, spec.N, tile_n))
            # --- store the C block -----------------------------------------
            for ni in range(ncur):
                for mi in range(mcur):
                    yield Instr(Op.TS, src1=policy.c_base + ni * policy.mc + mi,
                                addr=("C", m0 + mi, n0 + ni),
                                tm=dim(m0 + mi, mt, spec.M, tile_m),
                                tn=dim(n0 + ni, nt, spec.N, tile_n))


#: streams whose rasa_mm count exceeds this are not memoized -- a cached
#: million-``Instr`` list would pin hundreds of MB for a stream that is
#: cheaper to regenerate (the compact SoA form in ``repro.core.trace`` has
#: its own, much denser cache).
_STREAM_CACHE_MAX_MM = 150_000


def lower_spec(spec, policy: RegPolicy = ALG1_POLICY) -> Iterator[Instr]:
    """Lower one workload op: a :class:`GemmSpec` through
    :func:`lower_gemm`, a :class:`ReduceSpec` through
    :func:`lower_reduce`."""
    if isinstance(spec, ReduceSpec):
        return lower_reduce(spec, policy)
    return lower_gemm(spec, policy)


@functools.lru_cache(maxsize=256)
def _lowered_stream_cached(spec,
                           policy: RegPolicy) -> tuple[Instr, ...]:
    return tuple(lower_spec(spec, policy))


def lowered_stream(spec,
                   policy: RegPolicy = ALG1_POLICY) -> tuple[Instr, ...]:
    """Memoized :func:`lower_spec`: one lowering per ``(spec, policy)``.

    Design sweeps, scheduler cost probes and arbiter relaxation rounds all
    re-simulate the same stream; lowering it once per key removes the
    biggest constant factor from those loops.  Very large streams (see
    ``_STREAM_CACHE_MAX_MM``) are regenerated instead of cached.
    """
    mt, kt, nt = spec.tiles()
    # GEMMs are guarded by their rasa_mm count; reductions (kt == 0, no
    # rasa_mm at all) by their C-tile count, the driver of stream length.
    if mt * (kt or 1) * nt > _STREAM_CACHE_MAX_MM:
        return tuple(lower_spec(spec, policy))
    return _lowered_stream_cached(spec, policy)


def stream_stats(spec: GemmSpec, policy: RegPolicy = ALG1_POLICY) -> dict:
    """Static properties of the lowered stream (no timing)."""
    n_tl = n_ts = n_mm = 0
    reuse = 0
    last_b: tuple | None = None
    b_contents: dict[int, tuple] = {}
    for ins in lower_gemm(spec, policy):
        if ins.op is Op.TL:
            n_tl += 1
            b_contents[ins.dst] = ins.addr  # type: ignore[index]
            if last_b is not None and ins.dst == last_b[0]:
                last_b = None  # weight register overwritten
        elif ins.op is Op.TS:
            n_ts += 1
        else:
            n_mm += 1
            key = (ins.src2, b_contents.get(ins.src2))
            if last_b == key:
                reuse += 1
            last_b = key
    return {"tl": n_tl, "ts": n_ts, "mm": n_mm,
            "wlbp_hits": reuse,
            "wlbp_rate": reuse / max(n_mm, 1)}
