"""Area / power / energy-efficiency model (paper §V, Fig. 6).

We cannot re-run Synopsys DC + Cadence Innovus on Nangate-15nm here, so the
published physical-design measurements are model *constants*, and the derived
quantities (PPA, energy-efficiency ratios) are produced by the same
arithmetic the paper uses:

  * baseline 32x16 array = 0.7 % of an Intel Skylake GT2 4C die;
  * area overhead over baseline: DB +3.1 %, DM +2.6 %, DMDB +5.5 %;
  * "RASA-Control + RASA-Data ... total 0.847 mm^2" => baseline
    ~= 0.847 / 1.055 = 0.803 mm^2 (consistent with 0.7 % of ~115 mm^2);
  * energy efficiency ~= speedup / (power ratio), power ~ area at iso-activity,
    which reproduces the paper's 4.38x / 2.19x / 4.59x from its own runtime
    numbers (validated in tests/test_area.py).
"""

from __future__ import annotations

BASELINE_AREA_MM2 = 0.847 / 1.055          # ~0.803 mm^2 (32x16 PEs)
SKYLAKE_GT2_4C_DIE_MM2 = BASELINE_AREA_MM2 / 0.007

#: multiplicative area overhead of the RASA-Data options over baseline
AREA_OVERHEAD = {
    "baseline": 1.0,
    "DB": 1.031,
    "DM": 1.026,
    "DMDB": 1.055,
}

#: dirty bits for WLBP: 8 bits -- negligible, modelled as zero area.

def data_opt_of(design_name: str) -> str:
    if "DMDB" in design_name:
        return "DMDB"
    if "DM" in design_name:
        return "DM"
    if "DB" in design_name:
        return "DB"
    return "baseline"


def area_mm2(design_name: str) -> float:
    return BASELINE_AREA_MM2 * AREA_OVERHEAD[data_opt_of(design_name)]


def perf_per_area(design_name: str, speedup: float) -> float:
    """Performance-per-area normalized to the baseline (Fig. 6)."""
    return speedup / AREA_OVERHEAD[data_opt_of(design_name)]


def energy_efficiency(design_name: str, speedup: float) -> float:
    """ops/J vs baseline at iso-activity: speedup / power-ratio, power ~ area.

    With the paper's own speedups (DB-WLS 1/(1-0.781), DM-WLBP 1/(1-0.555),
    DMDB-WLS 1/(1-0.792)) this yields 4.43x / 2.19x / 4.56x vs the published
    4.38x / 2.19x / 4.59x -- within 1.2 %.
    """
    return speedup / AREA_OVERHEAD[data_opt_of(design_name)]


#: published validation targets (paper §V)
PAPER_RUNTIME_REDUCTION = {
    "RASA-PIPE": 0.157,
    "RASA-WLBP": 0.309,
    "RASA-DB-WLS": 0.781,
    "RASA-DM-WLBP": 0.555,
    "RASA-DMDB-WLS": 0.792,
}
PAPER_ENERGY_EFFICIENCY = {"DB": 4.38, "DM": 2.19, "DMDB": 4.59}
PAPER_BEST_NORMALIZED_RUNTIME = 16 / 95    # DMDB-WLS steady-state bound
