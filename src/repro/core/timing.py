"""Cycle-level timing model of the RASA matrix engine (paper §IV-B, Fig. 4).

The execution of one ``rasa_mm`` on the weight-stationary array is divided
into four sub-stages (durations in engine cycles, array of ``rows x cols``):

  WL  (Weight Load)   rows          stream B top->bottom
  FF  (Feed First)    tm            feed A/C until the first array row is done
  FS  (Feed Second)   rows - 1      drain the feed skew through lower rows
  DR  (Drain)         cols (+1 DM)  eject remaining outputs (+ DM merge row)

Scheduling rules per design (cf. DESIGN.md §1 for the validation targets):

  BASE   : fully serial -- WL_i >= DR_end_{i-1}.
  PIPE   : WL_i overlaps the previous DR -- WL_i >= FS_end_{i-1}.
  WLBP   : if the B register is reused & clean (dirty bit), skip WL and let
           FF_i overlap the previous FS/DR -- FF_i >= FF_end_{i-1}.
  WLS+DB : WL_i streams into the shadow buffer behind the previous
           instruction's compute wavefront (extra per-PE links); effectively
           hidden whenever the array is still busy, so FF_i >= FF_end_{i-1}.
           A cold WL (idle array) still pays the full `rows` cycles.

True data dependencies are honoured through register ready-times: a tile
load's consumer waits `load_latency`; an ``rasa_mm`` accumulating into the
same C register as a previous ``rasa_mm`` must wait for that instruction's
DR to complete (C streams through the array) -- this is why Algorithm 1 in
the paper round-robins four C registers, and it is what makes the register
*allocation* policy performance-relevant ("register-aware").
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .designs import EngineConfig
from .isa import Instr, Op, TileRegisterFile, tile_bytes


class LoadStreamModel:
    """Reusable stream-timing hook: arbitrates tile load *and* store slots.

    The default model reproduces the paper's idealized LSQ -- ``load_ports``
    tile loads sustained per engine cycle, never bandwidth-limited ("the
    memory system never throttles throughput"), and ``rasa_ts`` stores
    retiring for free (``store_ports=None``).  Subclasses may impose an
    aggregate, possibly *time-varying* bandwidth budget and serialize stores
    on dedicated ports (see :mod:`repro.multicore`); the simulator calls
    :meth:`acquire` once per ``rasa_tl`` and :meth:`acquire_store` once per
    ``rasa_ts``, both in issue order, and :meth:`reset` at the start of every
    :meth:`PipelineSimulator.run`.

    ``last_grant`` records the start time of the latest memory access the
    model has granted; chip-level arbiters use it to decide until when a
    core keeps drawing on the shared budget (its *activity* horizon).
    """

    def __init__(self, load_ports: int, store_ports: int | None = None):
        self.load_ports = load_ports
        #: stores per cycle the store path sustains; ``None`` keeps the
        #: paper's loads-only model where stores never serialize.
        self.store_ports = store_ports
        self.reset()

    def reset(self) -> None:
        self._next_free = 0.0
        self._store_next_free = 0.0
        self.last_grant = 0.0

    def acquire(self, t_request: float, n_bytes: int) -> tuple[float, float]:
        """Claim a load slot for ``n_bytes`` requested at ``t_request``.

        Returns ``(t_start, bw_stall)``: when the load actually starts and
        how many cycles of that wait are attributable to bandwidth throttling
        (always 0 for the unthrottled port model).
        """
        start = max(t_request, self._next_free)
        self._next_free = start + 1.0 / self.load_ports
        self.last_grant = max(self.last_grant, start)
        return start, 0.0

    def acquire_store(self, t_request: float, n_bytes: int) -> tuple[float, float]:
        """Claim a store slot; same contract as :meth:`acquire`.

        With ``store_ports=None`` stores are free (no serialization, no
        bytes) -- the paper's idealized model.
        """
        if self.store_ports is None:
            return t_request, 0.0
        start = max(t_request, self._store_next_free)
        self._store_next_free = start + 1.0 / self.store_ports
        self.last_grant = max(self.last_grant, start)
        return start, 0.0


@dataclasses.dataclass
class MMSchedule:
    index: int
    wl_start: float
    wl_skipped: bool
    ff_start: float
    ff_end: float
    fs_end: float
    dr_end: float


@dataclasses.dataclass
class TimingResult:
    cycles: float                      # engine cycles until everything retires
    n_mm: int
    n_tl: int
    n_ts: int
    wl_skips: int                      # WLBP hits
    useful_macs: float                 # sum(tm*tk*tn) over mm instructions
    peak_macs_per_cycle: int
    #: cumulative load/store-start delay imposed by the bandwidth arbiter.
    #: This counts delays the pipeline may absorb (loads run far ahead of
    #: their consumers); the end-to-end cost of contention is
    #: ``ChipReport.bw_stall_cycles`` in :mod:`repro.multicore`.  Zero here
    #: guarantees the run is identical to an unthrottled one.
    bw_stall_cycles: float = 0.0
    schedules: list[MMSchedule] | None = None

    @property
    def load_stall_cycles(self) -> float:
        """Deprecated alias of :attr:`bw_stall_cycles` (pre-PR-6 name)."""
        return self.bw_stall_cycles

    @property
    def utilization(self) -> float:
        """Average MAC-unit utilization (useful MACs / peak MAC slots)."""
        if self.cycles <= 0:
            return 0.0
        return self.useful_macs / (self.cycles * self.peak_macs_per_cycle)

    def runtime_seconds(self, clock_hz: float) -> float:
        """Wall time at the given engine clock (cycles are clock-agnostic)."""
        return self.cycles / clock_hz


class PipelineSimulator:
    """In-order issue, cycle-level sub-stage pipeline simulator."""

    def __init__(self, config: EngineConfig, keep_schedules: bool = False,
                 load_model: LoadStreamModel | None = None):
        self.cfg = config
        self.keep_schedules = keep_schedules
        #: stream-timing hook for tile loads; reset at the start of each run.
        self.load_model = load_model or LoadStreamModel(config.load_ports)

    def run(self, stream: Sequence[Instr]) -> TimingResult:
        cfg = self.cfg
        wl = cfg.wl_cycles
        fs = cfg.fs_cycles
        dr = cfg.dr_cycles
        # core->engine issue bandwidth: instructions issued per engine cycle.
        issue_per_cycle = cfg.core_issue_width * (cfg.core_clock_hz / cfg.engine_clock_hz)
        load_lat = float(cfg.load_latency)
        load_model = self.load_model
        load_model.reset()

        regfile = TileRegisterFile()
        reg_ready = [0.0] * len(regfile.regs)

        # previous MM stage times (engine constraints are chained through these)
        p_ff_start = -1.0
        p_ff_end = 0.0
        p_fs_end = 0.0
        p_dr_end = 0.0
        have_prev = False
        # the weight-insertion network is a single resource: real WLs are
        # serialized on it (monotonic), independent of WLBP skips in between.
        wl_port_free = 0.0

        t_end = 0.0
        n_mm = n_tl = n_ts = wl_skips = 0
        useful = 0.0
        bw_stall = 0.0
        schedules: list[MMSchedule] = [] if self.keep_schedules else None  # type: ignore

        for idx, ins in enumerate(stream):
            t_issue = idx / issue_per_cycle

            if ins.op is Op.TL:
                n_tl += 1
                start, stall = load_model.acquire(t_issue, tile_bytes(ins))
                bw_stall += stall
                done = start + load_lat
                regfile.write(ins.dst, ins.addr)       # type: ignore[arg-type]
                reg_ready[ins.dst] = done              # type: ignore[index]
                t_end = max(t_end, done)
                continue

            if ins.op is Op.TS:
                n_ts += 1
                t_avail = max(t_issue, reg_ready[ins.src1])    # type: ignore[index]
                start, stall = load_model.acquire_store(t_avail, tile_bytes(ins))
                bw_stall += stall
                t_end = max(t_end, start + 1.0)
                continue

            # ---- rasa_mm ---------------------------------------------------
            n_mm += 1
            c, a, b = ins.dst, ins.src1, ins.src2
            t_ready_ac = max(t_issue, reg_ready[a], reg_ready[c])  # type: ignore[index]
            t_ready_b = max(t_issue, reg_ready[b])                 # type: ignore[index]

            reuse = cfg.wlbp and regfile.mm_weight_reusable(b)     # type: ignore[arg-type]

            if reuse:
                wl_start = t_ready_b
                wl_skipped = True
                ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0)
            elif cfg.wls:
                # prefetch into shadow buffer; hidden behind an active array
                wl_start = max(t_ready_b,
                               p_ff_start if have_prev else 0.0,
                               wl_port_free)
                hidden = have_prev and wl_start <= p_fs_end
                weights_ready = (wl_start + 1.0) if hidden else (wl_start + wl)
                wl_skipped = False
                ff_start = max(t_ready_ac,
                               p_ff_end if have_prev else 0.0,
                               weights_ready)
            elif cfg.pipe:
                wl_start = max(t_ready_b, p_fs_end if have_prev else 0.0,
                               wl_port_free)
                wl_skipped = False
                ff_start = max(t_ready_ac, wl_start + wl,
                               p_dr_end if have_prev else 0.0)
            else:  # BASE
                wl_start = max(t_ready_b, p_dr_end if have_prev else 0.0,
                               wl_port_free)
                wl_skipped = False
                ff_start = max(t_ready_ac, wl_start + wl)

            if reuse:
                wl_skips += 1
            else:
                regfile.latch_weights(b)               # type: ignore[arg-type]

            ff_end = ff_start + cfg.ff_cycles(ins.tm)
            fs_end = ff_end + fs
            dr_end = fs_end + dr

            # C register is rewritten by this MM; ready when fully drained.
            regfile.write(c, ("mm-out", idx))          # type: ignore[arg-type]
            reg_ready[c] = dr_end                      # type: ignore[index]
            # writing C does not disturb the latched weights; re-mark B latched
            regfile.latch_weights(b)                   # type: ignore[arg-type]

            useful += ins.tm * ins.tk * ins.tn
            t_end = max(t_end, dr_end)

            if self.keep_schedules:
                schedules.append(MMSchedule(idx, wl_start, wl_skipped,
                                            ff_start, ff_end, fs_end, dr_end))

            if not wl_skipped:
                wl_port_free = wl_start + wl
            p_ff_start, p_ff_end, p_fs_end, p_dr_end = ff_start, ff_end, fs_end, dr_end
            have_prev = True

        return TimingResult(
            cycles=t_end,
            n_mm=n_mm, n_tl=n_tl, n_ts=n_ts,
            wl_skips=wl_skips,
            useful_macs=useful,
            peak_macs_per_cycle=cfg.peak_macs_per_cycle,
            bw_stall_cycles=bw_stall,
            schedules=schedules,
        )


def serial_mm_latency(rows: int, cols: int, tm: int) -> int:
    """Closed form used by Fig. 2: WL + FF + FS + DR = 2*rows + tm + cols - 1."""
    return 2 * rows + tm + cols - 1


def steady_state_interval(cfg: EngineConfig, tm: int, weight_reused: bool) -> float:
    """Analytic issue-to-issue interval of back-to-back rasa_mm (for tests
    and napkin math; the simulator must agree on ideal streams)."""
    if cfg.wlbp and weight_reused:
        return tm
    if cfg.wls:
        # the shadow buffer hides WL behind compute, but the single weight
        # insertion network still serializes *fresh* weight sets: one WL
        # (`rows` cycles) per rasa_mm floors the interval.
        return max(tm, cfg.wl_cycles)
    if cfg.pipe:
        # WL overlaps the previous DR, but FF still waits for both this WL
        # and the previous drain: whichever is longer paces the pipeline
        # (DR > WL only with DM's +1 merge-row cycle).
        return max(cfg.wl_cycles, cfg.dr_cycles) + tm + cfg.fs_cycles
    return cfg.serial_latency(tm)
