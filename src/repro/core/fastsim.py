"""Fast simulation backends over :class:`repro.core.trace.CompiledTrace`.

The reference :class:`repro.core.timing.PipelineSimulator` is the exactness
oracle: a pure-Python per-instruction loop over ``Instr`` objects.  Its
scheduling recurrence, however, is a small fixed-size carry -- eight
register ready-times, the previous instruction's four sub-stage times, the
WL-port/LSQ-port free times and the token-bucket state -- which makes it
exactly a ``jax.lax.scan`` step.  This module implements that step twice:

``numpy``
    A Python loop over the compiled SoA trace that calls the *same*
    ``LoadStreamModel`` objects as the reference simulator.  Bit-exact with
    the reference by construction (identical arithmetic in identical
    order); 3-6x faster because the per-instruction ``Instr``/
    ``TileRegisterFile`` bookkeeping is precompiled away.  This is the
    fallback when jax is unavailable or the stream is too short to amortize
    a compile.

``jax``
    ``jax.lax.scan`` over the trace arrays, ``vmap``-batched over designs
    (one trace, eight engine configs -- the ``sweep_designs`` fast path) or
    over cores (one config, N per-core traces under a shared epoch-share
    schedule -- the ``multicore`` arbiter fast path).  Runs in float64 via
    the scoped ``jax.experimental.enable_x64`` context so the global jax
    configuration is untouched; agrees with the reference to well below
    the 1e-6 relative parity bound (see ``tests/test_fastsim.py``).

The load/store arbitration of *both* the paper's idealized port model and
the chip-level token buckets is expressed by one parameter set,
:class:`StreamModelParams`: an empty share schedule with an infinite tail
share reduces exactly to the unthrottled port model (the same reduction
``SharedBandwidthLoadModel`` documents).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

from .designs import EngineConfig
from .isa import NUM_TREGS
from .timing import LoadStreamModel, TimingResult
from .trace import OP_END, OP_MM, OP_TL, OP_TS, CompiledTrace

#: below this many total instructions (batch x length) the scan's compile +
#: dispatch overhead beats the win, and ``backend="fast"`` stays on numpy.
FAST_JAX_MIN_INSTRS = 32768

#: per-core batches (each lane its own trace: gather-bound scan step) need
#: far more work before the jax path beats the inlined numpy loop.
FAST_JAX_MIN_CORES_INSTRS = 4_000_000

_BACKENDS = ("fast", "numpy", "jax")


@functools.lru_cache(maxsize=1)
def has_jax() -> bool:
    try:
        import jax  # noqa: F401
        from jax.experimental import enable_x64  # noqa: F401
    except Exception:
        return False
    return True


def resolve_backend(backend: str, n_instrs: int) -> str:
    """Map a requested backend to a concrete one (``numpy`` or ``jax``).

    ``fast`` auto-selects: jax when it is importable *and* the batch is
    large enough (>= ``FAST_JAX_MIN_INSTRS`` instructions) to amortize
    compilation; numpy otherwise.
    """
    if backend == "numpy":
        return "numpy"
    if backend == "jax":
        if not has_jax():
            raise RuntimeError("backend='jax' requested but jax is not "
                               "importable; use backend='numpy' or 'fast'")
        return "jax"
    if backend == "fast":
        return "jax" if has_jax() and n_instrs >= FAST_JAX_MIN_INSTRS \
            else "numpy"
    raise ValueError(f"unknown backend {backend!r}; available: {_BACKENDS} "
                     f"(plus 'reference' at the simulator facade)")


# --------------------------------------------------------------------------
# load/store stream-model parameters
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamModelParams:
    """Declarative form of a :class:`LoadStreamModel` for the fast backends.

    The defaults describe the paper's idealized LSQ (``load_ports`` loads
    per cycle, free stores, no bandwidth cap): an empty epoch schedule whose
    infinite ``tail_share`` never throttles.  Chip-level arbiters fill in
    the token-bucket fields (cf. ``repro.multicore.chip``).
    """

    load_ports: int
    store_ports: int | None = None
    shares: tuple[float, ...] = ()
    epoch_cycles: float = math.inf
    tail_share: float = math.inf
    burst_bytes: float = 0.0
    charge_store_bytes: bool = False

    def __post_init__(self):
        if not self.epoch_cycles > 0:
            raise ValueError("epoch_cycles must be > 0")
        if not self.tail_share > 0:
            raise ValueError("tail_share must be > 0 (requests past the "
                             "schedule could never be granted)")

    @property
    def is_port_model(self) -> bool:
        return not self.shares and math.isinf(self.tail_share)

    @classmethod
    def for_config(cls, cfg: EngineConfig) -> "StreamModelParams":
        return cls(load_ports=cfg.load_ports)

    @classmethod
    def from_model(cls, model: LoadStreamModel) -> "StreamModelParams | None":
        """Extract parameters from a live model, or None when the model is a
        custom subclass whose semantics the fast backends cannot replicate
        (callers then fall back to the reference simulator)."""
        if type(model) is LoadStreamModel:
            return cls(model.load_ports, model.store_ports)
        try:
            from ..multicore.chip import EpochBandwidthLoadModel
        except ImportError:                              # pragma: no cover
            return None
        cls_ = type(model)
        untouched = all(
            getattr(cls_, m) is getattr(EpochBandwidthLoadModel, m)
            for m in ("acquire", "acquire_store", "reset", "_grant",
                      "_advance", "_share_at"))
        if (isinstance(model, EpochBandwidthLoadModel) and untouched
                and not model.record_grants):
            return cls(model.load_ports, model.store_ports,
                       tuple(model.shares), model.epoch_cycles,
                       model.tail_share, model.burst_bytes,
                       model.charge_store_bytes)
        return None

    def make_model(self) -> LoadStreamModel:
        """Instantiate the live model these parameters describe (the numpy
        backend runs the recurrence against real model objects so it stays
        bit-exact with the reference simulator)."""
        if self.is_port_model:
            return LoadStreamModel(self.load_ports, self.store_ports)
        from ..multicore.chip import EpochBandwidthLoadModel
        return EpochBandwidthLoadModel(
            self.load_ports, self.shares, self.epoch_cycles, self.tail_share,
            burst_bytes=self.burst_bytes, store_ports=self.store_ports,
            charge_store_bytes=self.charge_store_bytes)

    @property
    def schedule_end(self) -> float:
        return len(self.shares) * self.epoch_cycles if self.shares else 0.0


def _result(trace: CompiledTrace, cfg: EngineConfig, t_end: float,
            wl_skips: int, bw_stall: float) -> TimingResult:
    return TimingResult(
        cycles=float(t_end), n_mm=trace.n_mm, n_tl=trace.n_tl,
        n_ts=trace.n_ts, wl_skips=int(wl_skips),
        useful_macs=trace.useful_macs,
        peak_macs_per_cycle=cfg.peak_macs_per_cycle,
        bw_stall_cycles=float(bw_stall), schedules=None)


# --------------------------------------------------------------------------
# numpy backend: SoA loop against live LoadStreamModel objects
# --------------------------------------------------------------------------

def run_trace_numpy(trace: CompiledTrace, cfg: EngineConfig,
                    load_model: LoadStreamModel | None = None) -> TimingResult:
    """Run the scheduling recurrence over a compiled trace.

    Mirrors ``PipelineSimulator.run`` statement for statement (same
    arithmetic, same order, same model calls) -- the dirty-bit bookkeeping
    is the only thing replaced, by the trace's precompiled ``reusable``
    bits.  Bit-exact with the reference.
    """
    wl = cfg.wl_cycles
    fs = cfg.fs_cycles
    dr = cfg.dr_cycles
    issue_per_cycle = cfg.core_issue_width * (cfg.core_clock_hz
                                              / cfg.engine_clock_hz)
    load_lat = float(cfg.load_latency)
    model = load_model or LoadStreamModel(cfg.load_ports)
    model.reset()
    acquire = model.acquire
    acquire_store = model.acquire_store
    wlbp, wls, pipe = cfg.wlbp, cfg.wls, cfg.pipe

    op = trace.opcode.tolist()
    rd = trace.r_dst.tolist()
    ra = trace.r_a.tolist()
    rb = trace.r_b.tolist()
    nb = trace.nbytes.tolist()
    tms = trace.tm.tolist()
    reus = trace.reusable.tolist()

    reg_ready = [0.0] * NUM_TREGS
    p_ff_start = -1.0
    p_ff_end = p_fs_end = p_dr_end = 0.0
    have_prev = False
    wl_port_free = 0.0
    t_end = 0.0
    wl_skips = 0
    bw_stall = 0.0

    for i in range(len(op)):
        o = op[i]
        t_issue = i / issue_per_cycle

        if o == OP_TL:
            start, stall = acquire(t_issue, nb[i])
            bw_stall += stall
            done = start + load_lat
            reg_ready[rd[i]] = done
            if done > t_end:
                t_end = done
            continue

        if o == OP_TS:
            r = reg_ready[ra[i]]
            t_avail = t_issue if t_issue > r else r
            start, stall = acquire_store(t_avail, nb[i])
            bw_stall += stall
            e = start + 1.0
            if e > t_end:
                t_end = e
            continue

        if o != OP_MM:          # OP_NOP padding
            continue

        c, a, b = rd[i], ra[i], rb[i]
        t_ready_ac = max(t_issue, reg_ready[a], reg_ready[c])
        t_ready_b = max(t_issue, reg_ready[b])
        reuse = wlbp and reus[i]

        if reuse:
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0)
            wl_skips += 1
        elif wls:
            wl_start = max(t_ready_b, p_ff_start if have_prev else 0.0,
                           wl_port_free)
            hidden = have_prev and wl_start <= p_fs_end
            weights_ready = (wl_start + 1.0) if hidden else (wl_start + wl)
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0,
                           weights_ready)
            wl_port_free = wl_start + wl
        elif pipe:
            wl_start = max(t_ready_b, p_fs_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl,
                           p_dr_end if have_prev else 0.0)
            wl_port_free = wl_start + wl
        else:  # BASE
            wl_start = max(t_ready_b, p_dr_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl)
            wl_port_free = wl_start + wl

        ff_end = ff_start + tms[i]
        fs_end = ff_end + fs
        dr_end = fs_end + dr
        reg_ready[c] = dr_end
        if dr_end > t_end:
            t_end = dr_end
        p_ff_start, p_ff_end, p_fs_end, p_dr_end = (ff_start, ff_end,
                                                    fs_end, dr_end)
        have_prev = True

    res = _result(trace, cfg, t_end, wl_skips, bw_stall)
    return res


@dataclasses.dataclass(frozen=True)
class SimCarry:
    """Resumable snapshot of the inlined numpy recurrence.

    Captures the complete per-core simulator state after the first ``i``
    instructions of a trace: register ready-times, the previous ``rasa_mm``
    sub-stage times, port/bucket state and the running aggregates.  The
    future of the recurrence depends on the past *only* through this state,
    so re-simulation may resume here instead of replaying the prefix --
    provided the arbiter's share schedule is unchanged on
    ``[0, self.horizon)``: every epoch the first ``i`` instructions could
    observe lies strictly below the horizon (grant walks never look past
    the epoch containing the granted start).

    The online chip model (:mod:`repro.multicore.online`) snapshots every
    ``SNAP_STRIDE`` instructions and, when an arrival changes the schedule
    from epoch ``x`` on, resumes each in-flight core from its latest
    snapshot with ``horizon <= x * epoch_cycles``.
    """

    i: int                          # instructions consumed (resume index)
    reg_ready: tuple[float, ...]
    p_ff_start: float
    p_ff_end: float
    p_fs_end: float
    p_dr_end: float
    have_prev: bool
    wl_port_free: float
    t_end: float
    wl_skips: int
    bw_stall: float
    next_free: float
    store_next: float
    last_grant: float
    tokens: float
    bt: float

    @property
    def horizon(self) -> float:
        """Latest point in time this state depends on (see class docs)."""
        return max(self.t_end, self.bt, self.next_free, self.store_next,
                   self.wl_port_free, self.last_grant, self.p_dr_end,
                   max(self.reg_ready))


#: snapshot cadence of :func:`run_segment` (instructions between carries);
#: power of two so the per-instruction check stays a single compare.
SNAP_STRIDE = 4096


def _run_numpy_params(trace: CompiledTrace, cfg: EngineConfig,
                      params: StreamModelParams
                      ) -> tuple[TimingResult, float]:
    """The numpy loop with the stream-model arithmetic inlined.

    Identical statement order and float operations as
    :func:`run_trace_numpy` driving a live ``LoadStreamModel`` /
    ``EpochBandwidthLoadModel`` (bit-exact; pinned by the parity suite),
    but without the per-access method-call chain -- the dominant cost of
    bandwidth-throttled runs.  Returns ``(result, last_grant)``.
    """
    res, lg, _ = run_segment(trace, cfg, params)
    return res, lg


def run_segment(trace: CompiledTrace, cfg: EngineConfig,
                params: StreamModelParams,
                carry: SimCarry | None = None,
                snap_stride: int | None = None
                ) -> tuple[TimingResult, float, list[SimCarry]]:
    """Resumable form of the inlined numpy loop.

    With ``carry`` given, simulation resumes at instruction ``carry.i``
    from the saved state instead of replaying the prefix -- exact whenever
    ``params``'s share schedule agrees with the schedule the carry was
    produced under on ``[0, carry.horizon)`` (see :class:`SimCarry`).
    With ``snap_stride`` set, a snapshot is recorded every that many
    instructions; the returned list is ordered by instruction index.
    Returns ``(result, last_grant, snapshots)``.
    """
    wl = cfg.wl_cycles
    fs = cfg.fs_cycles
    dr = cfg.dr_cycles
    issue_per_cycle = cfg.core_issue_width * (cfg.core_clock_hz
                                              / cfg.engine_clock_hz)
    load_lat = float(cfg.load_latency)
    wlbp, wls, pipe = cfg.wlbp, cfg.wls, cfg.pipe

    port = params.is_port_model
    inv_load = 1.0 / params.load_ports
    store_free = params.store_ports is None
    inv_store = 1.0 / params.store_ports if not store_free else 0.0
    charge = params.charge_store_bytes and not port
    shares = list(params.shares)
    n_sh = len(shares)
    E = params.epoch_cycles
    sched_end = params.schedule_end
    tail = params.tail_share
    burst = params.burst_bytes
    tokens = burst
    bt = 0.0

    def grant(tokens, bt, t_earliest, n_bytes):
        # == EpochBandwidthLoadModel._grant (with _advance inlined)
        while bt < t_earliest:
            rate = shares[int(bt // E)] if bt // E < n_sh else tail
            if bt >= sched_end:
                step_end = t_earliest
            else:
                e_end = (int(bt // E) + 1) * E
                step_end = t_earliest if t_earliest < e_end else e_end
            if math.isinf(rate):
                tokens = burst
            else:
                tokens = tokens + rate * (step_end - bt)
                if tokens > burst:
                    tokens = burst
            bt = step_end
        need = n_bytes if n_bytes < burst else burst
        if tokens >= need:
            start = t_earliest
        else:
            t, tk = bt, tokens
            while True:
                rate = shares[int(t // E)] if t // E < n_sh else tail
                if math.isinf(rate):
                    start = t
                    break
                if rate <= 0.0 and t >= sched_end:
                    raise RuntimeError("tail share must be > 0: request can "
                                       "never be granted")
                e_end = (int(t // E) + 1) * E
                if rate > 0.0:
                    t_hit = t + (need - tk) / rate
                    if t_hit <= e_end or t >= sched_end:
                        start = t_hit
                        break
                    tk += rate * (e_end - t)
                t = e_end
            if start < t_earliest:
                start = t_earliest
        while bt < start:
            rate = shares[int(bt // E)] if bt // E < n_sh else tail
            if bt >= sched_end:
                step_end = start
            else:
                e_end = (int(bt // E) + 1) * E
                step_end = start if start < e_end else e_end
            if math.isinf(rate):
                tokens = burst
            else:
                tokens = tokens + rate * (step_end - bt)
                if tokens > burst:
                    tokens = burst
            bt = step_end
        return start, tokens - n_bytes, bt

    op = trace.opcode.tolist()
    rd = trace.r_dst.tolist()
    ra = trace.r_a.tolist()
    rb = trace.r_b.tolist()
    nb = trace.nbytes.tolist()
    tms = trace.tm.tolist()
    reus = trace.reusable.tolist()

    if carry is None:
        i0 = 0
        reg_ready = [0.0] * NUM_TREGS
        p_ff_start = -1.0
        p_ff_end = p_fs_end = p_dr_end = 0.0
        have_prev = False
        wl_port_free = 0.0
        t_end = 0.0
        wl_skips = 0
        bw_stall = 0.0
        next_free = store_next = 0.0
        last_grant = 0.0
    else:
        i0 = carry.i
        reg_ready = list(carry.reg_ready)
        p_ff_start = carry.p_ff_start
        p_ff_end = carry.p_ff_end
        p_fs_end = carry.p_fs_end
        p_dr_end = carry.p_dr_end
        have_prev = carry.have_prev
        wl_port_free = carry.wl_port_free
        t_end = carry.t_end
        wl_skips = carry.wl_skips
        bw_stall = carry.bw_stall
        next_free = carry.next_free
        store_next = carry.store_next
        last_grant = carry.last_grant
        tokens = carry.tokens
        bt = carry.bt

    snaps: list[SimCarry] = []
    next_snap = len(op) + 1
    if snap_stride is not None:
        next_snap = (i0 // snap_stride + 1) * snap_stride
        if carry is not None:
            # the boundary snapshot: a resumed run re-emits its carry-in,
            # so the returned list is self-contained -- the state at i0
            # is recorded even when resuming exactly on a stride boundary
            # (callers that re-seed from returned snaps would otherwise
            # lose the i0 checkpoint and replay up to a full stride)
            snaps.append(carry)

    for i in range(i0, len(op)):
        if i == next_snap:
            snaps.append(SimCarry(
                i, tuple(reg_ready), p_ff_start, p_ff_end, p_fs_end,
                p_dr_end, have_prev, wl_port_free, t_end, wl_skips,
                bw_stall, next_free, store_next, last_grant, tokens, bt))
            next_snap += snap_stride
        o = op[i]
        t_issue = i / issue_per_cycle

        if o == OP_TL:
            port_start = t_issue if t_issue > next_free else next_free
            if port:
                start = port_start
            else:
                start, tokens, bt = grant(tokens, bt, port_start, nb[i])
                bw_stall += start - port_start
            next_free = start + inv_load
            if start > last_grant:
                last_grant = start
            done = start + load_lat
            reg_ready[rd[i]] = done
            if done > t_end:
                t_end = done
            continue

        if o == OP_TS:
            r = reg_ready[ra[i]]
            t_avail = t_issue if t_issue > r else r
            if store_free:
                e = t_avail + 1.0
            else:
                port_start = t_avail if t_avail > store_next else store_next
                if charge:
                    start, tokens, bt = grant(tokens, bt, port_start, nb[i])
                    bw_stall += start - port_start
                else:
                    start = port_start
                store_next = start + inv_store
                if start > last_grant:
                    last_grant = start
                e = start + 1.0
            if e > t_end:
                t_end = e
            continue

        if o != OP_MM:          # OP_NOP padding
            continue

        c, a, b = rd[i], ra[i], rb[i]
        t_ready_ac = max(t_issue, reg_ready[a], reg_ready[c])
        t_ready_b = max(t_issue, reg_ready[b])
        reuse = wlbp and reus[i]

        if reuse:
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0)
            wl_skips += 1
        elif wls:
            wl_start = max(t_ready_b, p_ff_start if have_prev else 0.0,
                           wl_port_free)
            hidden = have_prev and wl_start <= p_fs_end
            weights_ready = (wl_start + 1.0) if hidden else (wl_start + wl)
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0,
                           weights_ready)
            wl_port_free = wl_start + wl
        elif pipe:
            wl_start = max(t_ready_b, p_fs_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl,
                           p_dr_end if have_prev else 0.0)
            wl_port_free = wl_start + wl
        else:  # BASE
            wl_start = max(t_ready_b, p_dr_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl)
            wl_port_free = wl_start + wl

        ff_end = ff_start + tms[i]
        fs_end = ff_end + fs
        dr_end = fs_end + dr
        reg_ready[c] = dr_end
        if dr_end > t_end:
            t_end = dr_end
        p_ff_start, p_ff_end, p_fs_end, p_dr_end = (ff_start, ff_end,
                                                    fs_end, dr_end)
        have_prev = True

    return _result(trace, cfg, t_end, wl_skips, bw_stall), last_grant, snaps


def completed_prefix(trace: CompiledTrace, cfg: EngineConfig,
                     params: StreamModelParams, limit: float,
                     carry: SimCarry | None = None) -> int:
    """How many leading instructions of ``trace`` have fully retired by
    time ``limit`` (engine-local cycles) under ``params``'s schedule.

    This is the deterministic preemption replay
    (:mod:`repro.multicore.faults`): when a core goes down at an epoch
    boundary, the surviving prefix of its in-flight segment is exactly the
    instructions whose *completion* -- load data arrival for ``rasa_tl``,
    store retire for ``rasa_ts``, drain end for ``rasa_mm`` -- lands at or
    before the boundary.  The loop mirrors :func:`run_segment` statement
    for statement (same arithmetic, same order, so the cut index is
    bit-identical on every backend) and stops at the first instruction
    that completes after ``limit``: returns ``k`` such that instructions
    ``[0, k)`` are done and instruction ``k`` is not.

    ``carry`` resumes the replay from a :class:`SimCarry` recorded by
    :func:`run_segment` under the *same* ``params`` schedule.  Valid only
    when ``carry.t_end <= limit``: ``t_end`` is the max completion time
    over instructions ``[0, carry.i)``, so none of them can be the first
    violator and the cut from ``carry.i`` on is bit-identical to the
    full replay -- repeated preemptions of one segment then replay only
    the work past its latest checkpoint instead of its whole history.
    """
    if carry is not None and carry.t_end > limit:
        raise ValueError("completed_prefix carry is past the limit: an "
                         "instruction before carry.i may be the cut")
    wl = cfg.wl_cycles
    fs = cfg.fs_cycles
    dr = cfg.dr_cycles
    issue_per_cycle = cfg.core_issue_width * (cfg.core_clock_hz
                                              / cfg.engine_clock_hz)
    load_lat = float(cfg.load_latency)
    wlbp, wls, pipe = cfg.wlbp, cfg.wls, cfg.pipe

    port = params.is_port_model
    inv_load = 1.0 / params.load_ports
    store_free = params.store_ports is None
    inv_store = 1.0 / params.store_ports if not store_free else 0.0
    charge = params.charge_store_bytes and not port
    shares = list(params.shares)
    n_sh = len(shares)
    E = params.epoch_cycles
    sched_end = params.schedule_end
    tail = params.tail_share
    burst = params.burst_bytes
    tokens = burst
    bt = 0.0

    def grant(tokens, bt, t_earliest, n_bytes):
        # == run_segment's inlined EpochBandwidthLoadModel._grant
        while bt < t_earliest:
            rate = shares[int(bt // E)] if bt // E < n_sh else tail
            if bt >= sched_end:
                step_end = t_earliest
            else:
                e_end = (int(bt // E) + 1) * E
                step_end = t_earliest if t_earliest < e_end else e_end
            if math.isinf(rate):
                tokens = burst
            else:
                tokens = tokens + rate * (step_end - bt)
                if tokens > burst:
                    tokens = burst
            bt = step_end
        need = n_bytes if n_bytes < burst else burst
        if tokens >= need:
            start = t_earliest
        else:
            t, tk = bt, tokens
            while True:
                rate = shares[int(t // E)] if t // E < n_sh else tail
                if math.isinf(rate):
                    start = t
                    break
                if rate <= 0.0 and t >= sched_end:
                    raise RuntimeError("tail share must be > 0: request can "
                                       "never be granted")
                e_end = (int(t // E) + 1) * E
                if rate > 0.0:
                    t_hit = t + (need - tk) / rate
                    if t_hit <= e_end or t >= sched_end:
                        start = t_hit
                        break
                    tk += rate * (e_end - t)
                t = e_end
            if start < t_earliest:
                start = t_earliest
        while bt < start:
            rate = shares[int(bt // E)] if bt // E < n_sh else tail
            if bt >= sched_end:
                step_end = start
            else:
                e_end = (int(bt // E) + 1) * E
                step_end = start if start < e_end else e_end
            if math.isinf(rate):
                tokens = burst
            else:
                tokens = tokens + rate * (step_end - bt)
                if tokens > burst:
                    tokens = burst
            bt = step_end
        return start, tokens - n_bytes, bt

    op = trace.opcode.tolist()
    rd = trace.r_dst.tolist()
    ra = trace.r_a.tolist()
    rb = trace.r_b.tolist()
    nb = trace.nbytes.tolist()
    tms = trace.tm.tolist()
    reus = trace.reusable.tolist()

    if carry is None:
        i0 = 0
        reg_ready = [0.0] * NUM_TREGS
        p_ff_start = -1.0
        p_ff_end = p_fs_end = p_dr_end = 0.0
        have_prev = False
        wl_port_free = 0.0
        next_free = store_next = 0.0
    else:
        i0 = carry.i
        reg_ready = list(carry.reg_ready)
        p_ff_start = carry.p_ff_start
        p_ff_end = carry.p_ff_end
        p_fs_end = carry.p_fs_end
        p_dr_end = carry.p_dr_end
        have_prev = carry.have_prev
        wl_port_free = carry.wl_port_free
        next_free = carry.next_free
        store_next = carry.store_next
        tokens = carry.tokens
        bt = carry.bt

    for i in range(i0, len(op)):
        o = op[i]
        t_issue = i / issue_per_cycle

        if o == OP_TL:
            port_start = t_issue if t_issue > next_free else next_free
            if port:
                start = port_start
            else:
                start, tokens, bt = grant(tokens, bt, port_start, nb[i])
            next_free = start + inv_load
            done = start + load_lat
            if done > limit:
                return i
            reg_ready[rd[i]] = done
            continue

        if o == OP_TS:
            r = reg_ready[ra[i]]
            t_avail = t_issue if t_issue > r else r
            if store_free:
                e = t_avail + 1.0
            else:
                port_start = t_avail if t_avail > store_next else store_next
                if charge:
                    start, tokens, bt = grant(tokens, bt, port_start, nb[i])
                else:
                    start = port_start
                store_next = start + inv_store
                e = start + 1.0
            if e > limit:
                return i
            continue

        if o != OP_MM:          # OP_NOP padding: retires instantly
            continue

        c, a, b = rd[i], ra[i], rb[i]
        t_ready_ac = max(t_issue, reg_ready[a], reg_ready[c])
        t_ready_b = max(t_issue, reg_ready[b])
        reuse = wlbp and reus[i]

        if reuse:
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0)
        elif wls:
            wl_start = max(t_ready_b, p_ff_start if have_prev else 0.0,
                           wl_port_free)
            hidden = have_prev and wl_start <= p_fs_end
            weights_ready = (wl_start + 1.0) if hidden else (wl_start + wl)
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0,
                           weights_ready)
            wl_port_free = wl_start + wl
        elif pipe:
            wl_start = max(t_ready_b, p_fs_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl,
                           p_dr_end if have_prev else 0.0)
            wl_port_free = wl_start + wl
        else:  # BASE
            wl_start = max(t_ready_b, p_dr_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl)
            wl_port_free = wl_start + wl

        ff_end = ff_start + tms[i]
        fs_end = ff_end + fs
        dr_end = fs_end + dr
        if dr_end > limit:
            return i
        reg_ready[c] = dr_end
        p_ff_start, p_ff_end, p_fs_end, p_dr_end = (ff_start, ff_end,
                                                    fs_end, dr_end)
        have_prev = True

    return len(op)


# --------------------------------------------------------------------------
# jax backend: lax.scan step, vmapped over designs or cores
# --------------------------------------------------------------------------

def _pow2(n: int, lo: int = 16) -> int:
    return max(lo, 1 << max(0, (n - 1)).bit_length())


#: the jax backend scans fixed-size chunks and threads the carry between
#: them, so changing stream lengths never retrigger XLA compilation -- one
#: compile per (vmap layout, port/bucket variant, batch size, share-pad).
CHUNK = 16384


@functools.lru_cache(maxsize=8)
def _sim_chunk_fn(port_model: bool, emit_ends: bool = False):
    """Build the raw (unjitted) per-instruction scan program.

    Returns ``sim_chunk(carry, xs, idx, design, bucket)``: one
    ``lax.scan`` over a chunk of compiled-trace columns, threading the
    15-slot timing carry.  :func:`_jax_fns` wraps it in the two jitted
    vmap layouts; :mod:`repro.multicore.jitarb` embeds it directly inside
    its whole-trace arbitration program (vmapping and jitting itself), so
    the scheduling arithmetic lives in exactly one place.
    """
    import jax.numpy as jnp
    from jax import lax

    def f64(v):
        return jnp.asarray(v, dtype=jnp.float64)

    def sim_chunk(carry0, xs, idx, design, bucket):
        (wl, fs, dr, issue, load_lat, wlbp, wls, pipe) = design
        (shares, n_shares, E, tail, burst, sched_end, charge_store,
         store_free, inv_store, inv_load) = bucket
        S = shares.shape[0]
        # XLA:CPU contracts ``tk + rate * dt`` into a fused multiply-add
        # (one rounding), while the numpy/reference token bucket rounds the
        # product first -- a 1-ulp drift that breaks oracle parity.  A
        # select on a runtime-only predicate pins the product: neither the
        # HLO simplifier (the predicate is unknown) nor LLVM's instruction
        # selector (the add's operand is a select, not the multiply) can
        # re-fuse it.
        rt_true = E == E

        def unfused(x):
            return lax.select(rt_true, x, jnp.zeros_like(x))

        def share_at(t):
            e = jnp.floor(t / E)
            i = jnp.clip(e, 0.0, S - 1.0).astype(jnp.int32)
            return jnp.where(e < n_shares, shares[i], tail)

        def advance(tokens, bt, t):
            def cond(s):
                return s[1] < t

            def body(s):
                tk, b = s
                rate = share_at(b)
                e_end = (jnp.floor(b / E) + 1.0) * E
                step_end = jnp.where(b >= sched_end, t,
                                     jnp.minimum(t, e_end))
                tk = jnp.where(jnp.isinf(rate), burst,
                               jnp.minimum(burst,
                                           tk + unfused(rate
                                                        * (step_end - b))))
                return tk, step_end

            # a saturated bucket stays saturated: every refill step clamps
            # ``min(burst, burst + rate*dt)`` with rate >= 0 back to burst,
            # so jump straight to ``t`` without walking the epochs
            bt = jnp.where(tokens >= burst, jnp.maximum(bt, t), bt)
            return lax.while_loop(cond, body, (tokens, bt))

        def grant_bucket(tokens, bt, t_earliest, n_bytes, want):
            # ``want=False`` pins every walk to its start (zero iterations)
            # so ops that discard the grant -- rasa_mm, uncharged stores --
            # don't spin the bucket up to their issue time for nothing.
            # Wanting lanes see bit-identical arithmetic either way.
            tokens, bt = advance(tokens, bt,
                                 jnp.where(want, t_earliest, bt))
            need = jnp.minimum(n_bytes, burst)

            def cond(s):
                return ~s[3]

            def body(s):
                t, tk, start, done = s
                rate = share_at(t)
                infr = jnp.isinf(rate)
                e_end = (jnp.floor(t / E) + 1.0) * E
                t_hit = t + (need - tk) / rate
                hit = (rate > 0.0) & ((t_hit <= e_end) | (t >= sched_end))
                dead = ~infr & (rate <= 0.0) & (t >= sched_end)
                fin = infr | hit | dead
                start2 = jnp.where(infr, t,
                                   jnp.where(dead, jnp.inf, t_hit))
                tk2 = jnp.where(rate > 0.0,
                                tk + unfused(rate * (e_end - t)), tk)
                return (jnp.where(fin, t, e_end), jnp.where(fin, tk, tk2),
                        jnp.where(fin, start2, start), fin)

            # when the bucket already covers the request the walk's result
            # is discarded below -- don't spin it
            walked = lax.while_loop(
                cond, body, (bt, tokens, f64(0.0),
                             ~want | (tokens >= need)))[2]
            start = jnp.where(tokens >= need, t_earliest,
                              jnp.maximum(walked, t_earliest))
            start = jnp.where(want, start, bt)
            tokens, bt = advance(tokens, bt, start)
            return start, tokens - n_bytes, bt

        def grant_port(tokens, bt, t_earliest, n_bytes, want):
            # infinite tail share, empty schedule: every request is granted
            # the moment the port frees up, the bucket state is inert.
            return t_earliest, tokens, bt

        grant = grant_port if port_model else grant_bucket

        def step(carry, x):
            (reg_ready, pffs, pffe, pfse, pdre, have_prev, wlfree, t_end,
             wl_skips, bw_stall, next_free, snext, last_grant,
             tokens, bt) = carry
            # pre-step outputs: at an OP_END marker these are the results of
            # the lane's just-finished packed segment
            emit = (t_end, wl_skips, bw_stall, last_grant) if emit_ends \
                else None
            op, rdst, ra, rb, nb, tm_i, reus, i = x
            t_issue = i / issue
            is_tl = op == OP_TL
            is_ts = op == OP_TS
            is_mm = op == OP_MM

            rr_rd = reg_ready[rdst]
            rr_ra = reg_ready[ra]
            rr_rb = reg_ready[rb]

            # ---- memory path (TL / TS share one masked grant) -------------
            port_start_tl = jnp.maximum(t_issue, next_free)
            t_avail = jnp.maximum(t_issue, rr_ra)
            port_start_ts = jnp.maximum(t_avail, snext)
            req = jnp.where(is_tl, port_start_tl, port_start_ts)
            do_grant = is_tl | (is_ts & jnp.logical_and(
                charge_store, jnp.logical_not(store_free)))
            gstart, gtokens, gbt = grant(tokens, bt, req, nb, do_grant)
            tokens = jnp.where(do_grant, gtokens, tokens)
            bt = jnp.where(do_grant, gbt, bt)
            start_mem = jnp.where(do_grant, gstart, req)
            done_tl = start_mem + load_lat
            next_free = jnp.where(is_tl, start_mem + inv_load, next_free)
            ts_tracked = is_ts & ~store_free
            snext = jnp.where(ts_tracked, start_mem + inv_store, snext)
            start_ts = jnp.where(store_free, t_avail, start_mem)
            stall = jnp.where(
                is_tl, start_mem - port_start_tl,
                jnp.where(ts_tracked, start_mem - port_start_ts, 0.0))
            bw_stall = bw_stall + stall
            last_grant = jnp.where(is_tl | ts_tracked,
                                   jnp.maximum(last_grant, start_mem),
                                   last_grant)

            # ---- rasa_mm scheduling rules ---------------------------------
            t_ready_ac = jnp.maximum(t_issue, jnp.maximum(rr_ra, rr_rd))
            t_ready_b = jnp.maximum(t_issue, rr_rb)
            reuse = wlbp & reus
            pffs_e = jnp.where(have_prev, pffs, 0.0)
            pffe_e = jnp.where(have_prev, pffe, 0.0)
            pfse_e = jnp.where(have_prev, pfse, 0.0)
            pdre_e = jnp.where(have_prev, pdre, 0.0)

            ff_reuse = jnp.maximum(t_ready_ac, pffe_e)

            wls_wl = jnp.maximum(jnp.maximum(t_ready_b, pffs_e), wlfree)
            hidden = have_prev & (wls_wl <= pfse)
            w_ready = jnp.where(hidden, wls_wl + 1.0, wls_wl + wl)
            ff_wls = jnp.maximum(jnp.maximum(t_ready_ac, pffe_e), w_ready)

            pipe_wl = jnp.maximum(jnp.maximum(t_ready_b, pfse_e), wlfree)
            ff_pipe = jnp.maximum(jnp.maximum(t_ready_ac, pipe_wl + wl),
                                  pdre_e)

            base_wl = jnp.maximum(jnp.maximum(t_ready_b, pdre_e), wlfree)
            ff_base = jnp.maximum(t_ready_ac, base_wl + wl)

            wl_start = jnp.where(wls, wls_wl,
                                 jnp.where(pipe, pipe_wl, base_wl))
            ff_start = jnp.where(
                reuse, ff_reuse,
                jnp.where(wls, ff_wls, jnp.where(pipe, ff_pipe, ff_base)))

            ff_end = ff_start + tm_i
            fs_end = ff_end + fs
            dr_end = fs_end + dr

            # ---- merge ----------------------------------------------------
            new_reg = jnp.where(is_tl, done_tl, dr_end)
            writes = is_tl | is_mm
            reg_ready = reg_ready.at[rdst].set(
                jnp.where(writes, new_reg, rr_rd))
            contrib = jnp.where(
                is_tl, done_tl,
                jnp.where(is_ts, start_ts + 1.0,
                          jnp.where(is_mm, dr_end, -jnp.inf)))
            t_end = jnp.maximum(t_end, contrib)
            pffs = jnp.where(is_mm, ff_start, pffs)
            pffe = jnp.where(is_mm, ff_end, pffe)
            pfse = jnp.where(is_mm, fs_end, pfse)
            pdre = jnp.where(is_mm, dr_end, pdre)
            have_prev = have_prev | is_mm
            wlfree = jnp.where(is_mm & ~reuse, wl_start + wl, wlfree)
            wl_skips = wl_skips + (is_mm & reuse).astype(jnp.int32)

            new_carry = (reg_ready, pffs, pffe, pfse, pdre, have_prev,
                         wlfree, t_end, wl_skips, bw_stall, next_free,
                         snext, last_grant, tokens, bt)
            if emit_ends:
                # OP_END: reset the lane for its next packed segment
                is_end = op == OP_END

                def rst(val, init):
                    return jnp.where(is_end, init, val)

                new_carry = (jnp.where(is_end, 0.0, reg_ready),
                             rst(pffs, -1.0), rst(pffe, 0.0), rst(pfse, 0.0),
                             rst(pdre, 0.0), rst(have_prev, False),
                             rst(wlfree, 0.0), rst(t_end, 0.0),
                             rst(wl_skips, 0), rst(bw_stall, 0.0),
                             rst(next_free, 0.0), rst(snext, 0.0),
                             rst(last_grant, 0.0), rst(tokens, burst),
                             rst(bt, 0.0))
            return new_carry, emit

        final, ys = lax.scan(step, carry0, (xs[0], xs[1], xs[2], xs[3],
                                            xs[4], xs[5], xs[6], idx),
                             unroll=8)
        return final, ys

    return sim_chunk


#: bucket in_axes of the two vmap layouts below (and of
#: ``multicore.jitarb``'s in-program lane vmap ``_B_LANES``, which
#: extends ``_B_CORES`` by also mapping inv_load / inv_store per lane so
#: heterogeneous core mixes trace through one program): the cores layout
#: maps shares / n_shares / tail / sched_end per lane, everything else
#: is shared.
_B_SWEEP = ((None,) * 9) + (0,)          # bucket: inv_load per design
_B_CORES = (0, 0, None, 0, None, 0) + ((None,) * 4)


@functools.lru_cache(maxsize=8)
def _jax_fns(port_model: bool, emit_ends: bool = False):
    import jax

    sim_chunk = _sim_chunk_fn(port_model, emit_ends)
    # two vmap layouts: `sweep` shares one trace across design lanes (the
    # shared xs keeps every per-step op a cheap scalar-indexed slice);
    # `cores` gives each lane its own trace under one shared design --
    # with the share schedule per lane (shares / n_shares / tail /
    # sched_end), which is what weighted epoch arbitration produces.
    sweep = jax.jit(jax.vmap(sim_chunk, in_axes=(0, None, None, 0, _B_SWEEP)))
    cores = jax.jit(jax.vmap(sim_chunk, in_axes=(0, 0, None, None, _B_CORES)))
    return sweep, cores


#: carry slots read back after the last chunk (see ``sim_chunk``):
#: t_end, wl_skips, bw_stall, last_grant.
_OUT_SLOTS = (7, 8, 9, 12)


def _init_carry(n_lanes: int, burst: float):
    import jax.numpy as jnp
    f = np.float64
    z = np.zeros(n_lanes, dtype=f)
    return (jnp.asarray(np.zeros((n_lanes, NUM_TREGS), dtype=f)),
            jnp.asarray(np.full(n_lanes, -1.0, dtype=f)), jnp.asarray(z),
            jnp.asarray(z), jnp.asarray(z),
            jnp.asarray(np.zeros(n_lanes, dtype=bool)), jnp.asarray(z),
            jnp.asarray(z), jnp.asarray(np.zeros(n_lanes, dtype=np.int32)),
            jnp.asarray(z), jnp.asarray(z), jnp.asarray(z), jnp.asarray(z),
            jnp.asarray(np.full(n_lanes, burst, dtype=f)), jnp.asarray(z))


def _run_chunked(fn, carry, trace_chunks, idx_chunks, design, bucket,
                 pick=None):
    """Thread the batched carry through one jitted chunk call per chunk.

    ``pick`` (one int array per chunk) selects per-step emission positions
    to keep -- the OP_END markers of a packed stream.  Only those slices are
    retained (lazily), so the chunk chain stays async and the full [B, L]
    emission buffers are never materialized on the host.
    """
    kept = []
    for k, (xs, idx) in enumerate(zip(trace_chunks, idx_chunks)):
        carry, ys = fn(carry, xs, idx, design, bucket)
        if pick is not None and len(pick[k]):
            kept.append(tuple(y[..., pick[k]] for y in ys))
    outs = [np.asarray(carry[s]) for s in _OUT_SLOTS]
    if pick is None:
        return outs
    if not kept:
        empty = np.zeros((0,))
        return outs, [empty] * len(_OUT_SLOTS)
    cat = [np.concatenate([np.asarray(y[k]) for y in kept], axis=-1)
           for k in range(len(_OUT_SLOTS))]
    return outs, cat


def _xs_arrays(trace: CompiledTrace):
    return (trace.opcode, trace.r_dst, trace.r_a, trace.r_b, trace.nbytes,
            trace.tm, trace.reusable)


def _empty_trace() -> CompiledTrace:
    i32, f = np.int32, np.float64
    z = np.zeros(0, dtype=i32)
    return CompiledTrace(opcode=z, r_dst=z, r_a=z, r_b=z,
                         nbytes=np.zeros(0, dtype=f),
                         tm=np.zeros(0, dtype=f), macs=np.zeros(0, dtype=f),
                         reusable=np.zeros(0, dtype=bool),
                         n_tl=0, n_ts=0, n_mm=0, useful_macs=0.0)


def _chunk_single(trace: CompiledTrace, idx: np.ndarray | None = None):
    """Chunk one trace: list of per-chunk xs tuples + f64 index arrays.

    ``idx`` overrides the instruction-index array (packed streams restart
    issue indices per segment); by default it is ``arange(len)``.
    """
    n_chunks = max(1, -(-len(trace) // CHUNK))
    L = n_chunks * CHUNK
    padded = trace.padded(L)
    arrays = _xs_arrays(padded)
    if idx is not None:
        idx_full = np.zeros(L, dtype=np.float64)
        idx_full[:len(idx)] = idx
    chunks, idxs = [], []
    for k in range(n_chunks):
        sl = slice(k * CHUNK, (k + 1) * CHUNK)
        chunks.append(tuple(a[sl] for a in arrays))
        idxs.append(np.arange(sl.start, sl.stop, dtype=np.float64)
                    if idx is None else idx_full[sl])
    return chunks, idxs


def _chunk_batch(traces: Sequence[CompiledTrace]):
    """Chunk a batch of traces to a common length: xs leaves are [B, CHUNK]."""
    n_chunks = max(1, -(-max(len(t) for t in traces) // CHUNK))
    padded = [t.padded(n_chunks * CHUNK) for t in traces]
    per_trace = [_xs_arrays(t) for t in padded]
    chunks, idxs = [], []
    for k in range(n_chunks):
        sl = slice(k * CHUNK, (k + 1) * CHUNK)
        chunks.append(tuple(np.stack([arrs[f][sl] for arrs in per_trace])
                            for f in range(7)))
        idxs.append(np.arange(sl.start, sl.stop, dtype=np.float64))
    return chunks, idxs


def _design_arrays(cfgs: Sequence[EngineConfig]):
    f = np.float64
    return (np.array([c.wl_cycles for c in cfgs], dtype=f),
            np.array([c.fs_cycles for c in cfgs], dtype=f),
            np.array([c.dr_cycles for c in cfgs], dtype=f),
            np.array([c.core_issue_width * (c.core_clock_hz
                                            / c.engine_clock_hz)
                      for c in cfgs], dtype=f),
            np.array([float(c.load_latency) for c in cfgs], dtype=f),
            np.array([c.wlbp for c in cfgs], dtype=bool),
            np.array([c.wls for c in cfgs], dtype=bool),
            np.array([c.pipe for c in cfgs], dtype=bool))


def _design_scalars(cfg: EngineConfig):
    return (np.float64(cfg.wl_cycles), np.float64(cfg.fs_cycles),
            np.float64(cfg.dr_cycles),
            np.float64(cfg.core_issue_width * (cfg.core_clock_hz
                                               / cfg.engine_clock_hz)),
            np.float64(cfg.load_latency), bool(cfg.wlbp), bool(cfg.wls),
            bool(cfg.pipe))


def _bucket_arrays(params: StreamModelParams, inv_load, tail,
                   pad_to: int | None = None):
    """The bucket tuple consumed by ``sim_chunk`` -- the single place its
    field order lives; ``inv_load`` is an array for design sweeps,
    ``tail`` an array for core batches.  ``pad_to`` overrides the share
    padding (per-lane stacking needs a common length)."""
    S = pad_to if pad_to is not None else _pow2(max(1, len(params.shares)),
                                                lo=4)
    shares = np.zeros(S, dtype=np.float64)
    if params.shares:
        shares[:len(params.shares)] = params.shares
    store_free = params.store_ports is None
    inv_store = 1.0 / params.store_ports if not store_free else 1.0
    return (shares, np.float64(len(params.shares)),
            np.float64(params.epoch_cycles), tail,
            np.float64(params.burst_bytes), np.float64(params.schedule_end),
            bool(params.charge_store_bytes), bool(store_free),
            np.float64(inv_store), inv_load)


#: bucket fields the cores layout maps per lane (must mirror the
#: ``_B_CORES`` in_axes in ``_jax_fns``): shares, n_shares, tail,
#: sched_end.
_BUCKET_LANE_FIELDS = (0, 1, 3, 5)


def _bucket_arrays_per_lane(params_list: Sequence[StreamModelParams],
                            inv_load):
    """Stack per-lane bucket rows for the cores layout.

    Each lane's row is built by :func:`_bucket_arrays` (so the field
    layout lives once); the fields ``_B_CORES`` vmaps are stacked, the
    rest come from lane 0 (``run_cores`` groups lanes so they agree).
    """
    S = _pow2(max(1, max(len(p.shares) for p in params_list)), lo=4)
    rows = [_bucket_arrays(p, inv_load, np.float64(p.tail_share), pad_to=S)
            for p in params_list]
    return tuple(
        np.stack([row[k] for row in rows]) if k in _BUCKET_LANE_FIELDS
        else rows[0][k]
        for k in range(len(rows[0])))


# --------------------------------------------------------------------------
# MM-only port-model path: compile the memory behaviour into the trace
# --------------------------------------------------------------------------
#
# Under the paper's idealized port model the tile-load stream never couples
# back into the compute recurrence: TL grant times are the running-max
# recurrence  start_k = max(t_issue_k, start_{k-1} + 1/ports),  solvable in
# closed form (max-accumulate) with numpy, and a free store's finish time is
# max(t_issue, producer's DR end) + 1 where the producer of the stored
# register is statically known.  Only the rasa_mm scheduling recurrence is
# genuinely sequential -- so the scan runs over MM rows alone (roughly half
# the stream) with a step that has no arbiter state at all.  This is the
# design-sweep fast path; the token-bucket models keep the full-stream scan.

@dataclasses.dataclass(frozen=True, eq=False)
class _MMAnalysis:
    """Design-independent static analysis of a trace's dataflow."""

    mm_pos: np.ndarray      # [n_mm] stream position (issue index)
    c: np.ndarray           # int32 register ids
    a: np.ndarray
    b: np.ndarray
    #: per-operand last-writer kind: 0 = never written, 1 = TL, 2 = MM
    a_kind: np.ndarray
    b_kind: np.ndarray
    c_kind: np.ndarray
    #: TL ordinal of the writer when kind == 1
    a_tl: np.ndarray
    b_tl: np.ndarray
    c_tl: np.ndarray
    reusable: np.ndarray
    tm: np.ndarray
    #: max stream position of free stores whose producer is MM m (-1: none)
    ts_max_pos: np.ndarray  # [n_mm]
    tl_pos: np.ndarray      # [n_tl] stream positions of TLs
    #: free stores with a static (TL / never-written) source: position,
    #: kind and TL ordinal
    ts_const_pos: np.ndarray
    ts_const_kind: np.ndarray
    ts_const_tl: np.ndarray


def _resolve_writers(wr_pos: dict[int, np.ndarray], is_tl: np.ndarray,
                     tl_ord: np.ndarray, mm_ord: np.ndarray,
                     read_pos: np.ndarray, read_reg: np.ndarray):
    """Last writer strictly before each read: (kind, tl ordinal, mm ordinal).

    kind: 0 = never written, 1 = TL, 2 = MM.
    """
    kind = np.zeros(len(read_pos), dtype=np.int8)
    tl_i = np.zeros(len(read_pos), dtype=np.int32)
    mm_i = np.zeros(len(read_pos), dtype=np.int32)
    for reg, wpos in wr_pos.items():
        mask = read_reg == reg
        if not mask.any() or not len(wpos):
            continue
        k = np.searchsorted(wpos, read_pos[mask], side="left") - 1
        wj = wpos[np.clip(k, 0, None)]
        has = k >= 0
        w_is_tl = is_tl[wj]
        kind[mask] = np.where(has, np.where(w_is_tl, 1, 2), 0)
        tl_i[mask] = np.where(has & w_is_tl, tl_ord[wj], 0)
        mm_i[mask] = np.where(has & ~w_is_tl, mm_ord[wj], 0)
    return kind, tl_i, mm_i


_MM_CACHE = None  # type: ignore[assignment]


def _mm_analysis(trace: CompiledTrace) -> _MMAnalysis:
    global _MM_CACHE
    if _MM_CACHE is None:
        import weakref
        _MM_CACHE = weakref.WeakKeyDictionary()
    hit = _MM_CACHE.get(trace)
    if hit is not None:
        return hit
    op = trace.opcode
    is_tl = op == OP_TL
    is_ts = op == OP_TS
    is_mm = op == OP_MM
    pos = np.arange(len(op), dtype=np.int64)
    tl_ord = (np.cumsum(is_tl) - 1).astype(np.int32)
    mm_ord = (np.cumsum(is_mm) - 1).astype(np.int32)
    writes = is_tl | is_mm
    wr_pos = {reg: pos[writes & (trace.r_dst == reg)]
              for reg in range(NUM_TREGS)}

    mm_pos = pos[is_mm]
    c = trace.r_dst[is_mm]
    a = trace.r_a[is_mm]
    b = trace.r_b[is_mm]
    a_kind, a_tl, _ = _resolve_writers(wr_pos, is_tl, tl_ord, mm_ord,
                                       mm_pos, a)
    b_kind, b_tl, _ = _resolve_writers(wr_pos, is_tl, tl_ord, mm_ord,
                                       mm_pos, b)
    c_kind, c_tl, _ = _resolve_writers(wr_pos, is_tl, tl_ord, mm_ord,
                                       mm_pos, c)

    ts_pos = pos[is_ts]
    ts_src = trace.r_a[is_ts]
    t_kind, t_tl, t_mm = _resolve_writers(wr_pos, is_tl, tl_ord, mm_ord,
                                          ts_pos, ts_src)
    n_mm = int(is_mm.sum())
    ts_max_pos = np.full(n_mm, -1, dtype=np.int64)
    dyn = t_kind == 2
    if dyn.any():
        np.maximum.at(ts_max_pos, t_mm[dyn], ts_pos[dyn])
    out = _MMAnalysis(
        mm_pos=mm_pos, c=c, a=a, b=b,
        a_kind=a_kind, b_kind=b_kind, c_kind=c_kind,
        a_tl=a_tl, b_tl=b_tl, c_tl=c_tl,
        reusable=trace.reusable[is_mm], tm=trace.tm[is_mm],
        ts_max_pos=ts_max_pos, tl_pos=pos[is_tl],
        ts_const_pos=ts_pos[~dyn], ts_const_kind=t_kind[~dyn],
        ts_const_tl=t_tl[~dyn])
    _MM_CACHE[trace] = out
    return out


@functools.lru_cache(maxsize=1)
def _jax_mm_fn():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def sim_chunk(carry0, xs, design):
        (wl, fs, dr, wlbp, wls, pipe) = design

        def step(carry, x):
            (reg_ready, pffs, pffe, pfse, pdre, have_prev, wlfree, t_end,
             wl_skips) = carry
            (valid, c, a, b, a_dyn, b_dyn, c_dyn, a_const, b_const, c_const,
             reus, tm_i, t_issue, ts_mask, ts_issue) = x
            ra_v = jnp.where(a_dyn, reg_ready[a], a_const)
            rb_v = jnp.where(b_dyn, reg_ready[b], b_const)
            rc_v = jnp.where(c_dyn, reg_ready[c], c_const)
            t_ready_ac = jnp.maximum(t_issue, jnp.maximum(ra_v, rc_v))
            t_ready_b = jnp.maximum(t_issue, rb_v)
            reuse = wlbp & reus
            pffs_e = jnp.where(have_prev, pffs, 0.0)
            pffe_e = jnp.where(have_prev, pffe, 0.0)
            pfse_e = jnp.where(have_prev, pfse, 0.0)
            pdre_e = jnp.where(have_prev, pdre, 0.0)

            ff_reuse = jnp.maximum(t_ready_ac, pffe_e)
            wls_wl = jnp.maximum(jnp.maximum(t_ready_b, pffs_e), wlfree)
            hidden = have_prev & (wls_wl <= pfse)
            w_ready = jnp.where(hidden, wls_wl + 1.0, wls_wl + wl)
            ff_wls = jnp.maximum(jnp.maximum(t_ready_ac, pffe_e), w_ready)
            pipe_wl = jnp.maximum(jnp.maximum(t_ready_b, pfse_e), wlfree)
            ff_pipe = jnp.maximum(jnp.maximum(t_ready_ac, pipe_wl + wl),
                                  pdre_e)
            base_wl = jnp.maximum(jnp.maximum(t_ready_b, pdre_e), wlfree)
            ff_base = jnp.maximum(t_ready_ac, base_wl + wl)
            wl_start = jnp.where(wls, wls_wl,
                                 jnp.where(pipe, pipe_wl, base_wl))
            ff_start = jnp.where(
                reuse, ff_reuse,
                jnp.where(wls, ff_wls, jnp.where(pipe, ff_pipe, ff_base)))

            ff_end = ff_start + tm_i
            fs_end = ff_end + fs
            dr_end = fs_end + dr
            ts_c = jnp.where(ts_mask, jnp.maximum(ts_issue, dr_end) + 1.0,
                             -jnp.inf)
            upd = (reg_ready.at[c].set(jnp.where(valid, dr_end,
                                                 reg_ready[c])),
                   jnp.where(valid, ff_start, pffs),
                   jnp.where(valid, ff_end, pffe),
                   jnp.where(valid, fs_end, pfse),
                   jnp.where(valid, dr_end, pdre),
                   have_prev | valid,
                   jnp.where(valid & ~reuse, wl_start + wl, wlfree),
                   jnp.where(valid,
                             jnp.maximum(t_end, jnp.maximum(dr_end, ts_c)),
                             t_end),
                   wl_skips + (valid & reuse).astype(jnp.int32))
            return upd, None

        final, _ = lax.scan(step, carry0, xs, unroll=8)
        return final

    _DESIGN_AXES = (0, 0, 0, 0, 0, 0)
    return jax.jit(jax.vmap(sim_chunk, in_axes=(0, None, _DESIGN_AXES)))


def _mm_init_carry(n_lanes: int):
    import jax.numpy as jnp
    f = np.float64
    z = np.zeros(n_lanes, dtype=f)
    return (jnp.asarray(np.zeros((n_lanes, NUM_TREGS), dtype=f)),
            jnp.asarray(np.full(n_lanes, -1.0, dtype=f)), jnp.asarray(z),
            jnp.asarray(z), jnp.asarray(z),
            jnp.asarray(np.zeros(n_lanes, dtype=bool)), jnp.asarray(z),
            jnp.asarray(z), jnp.asarray(np.zeros(n_lanes, dtype=np.int32)))


def _load_sig(cfg: EngineConfig, params: StreamModelParams | None):
    ports = params.load_ports if params is not None else cfg.load_ports
    issue = cfg.core_issue_width * (cfg.core_clock_hz / cfg.engine_clock_hz)
    return (issue, ports, float(cfg.load_latency))


def _port_static(ana: _MMAnalysis, sig) -> tuple[np.ndarray, float]:
    """Per load-signature: TL done times + the static part of ``cycles``."""
    issue, ports, load_lat = sig
    inv = 1.0 / ports
    t_issue_tl = ana.tl_pos / issue
    if len(t_issue_tl):
        drift = np.arange(len(t_issue_tl), dtype=np.float64) * inv
        start = np.maximum.accumulate(t_issue_tl - drift) + drift
        done_tl = start + load_lat
        static_end = float(done_tl.max())
    else:
        done_tl = np.zeros(0, dtype=np.float64)
        static_end = 0.0
    if len(ana.ts_const_pos):
        ready = np.where(ana.ts_const_kind == 1,
                         done_tl[ana.ts_const_tl] if len(done_tl)
                         else 0.0, 0.0)
        contrib = np.maximum(ana.ts_const_pos / issue, ready) + 1.0
        static_end = max(static_end, float(contrib.max()))
    return done_tl, static_end


def _sweep_port_mm(trace: CompiledTrace, cfgs: Sequence[EngineConfig],
                   params: StreamModelParams | None) -> list[TimingResult]:
    """The MM-only jax sweep (see section comment above)."""
    from jax.experimental import enable_x64
    ana = _mm_analysis(trace)
    n_mm = len(ana.mm_pos)
    results: list[TimingResult | None] = [None] * len(cfgs)
    groups: dict[tuple, list[int]] = {}
    for j, cfg in enumerate(cfgs):
        groups.setdefault(_load_sig(cfg, params), []).append(j)
    fn = _jax_mm_fn()
    for sig, members in groups.items():
        done_tl, static_end = _port_static(ana, sig)
        if n_mm == 0:
            for j in members:
                results[j] = _result(trace, cfgs[j], static_end, 0, 0.0)
            continue
        issue = sig[0]

        def const_of(kind, tl_idx):
            if len(done_tl):
                v = done_tl[tl_idx]
            else:
                v = np.zeros(len(tl_idx), dtype=np.float64)
            return np.where(kind == 1, v, 0.0)

        n_chunks = -(-n_mm // CHUNK)
        L = n_chunks * CHUNK
        pad = L - n_mm

        def padded(arr, fill=0):
            return np.concatenate(
                [arr, np.full(pad, fill, dtype=arr.dtype)])

        f64 = np.float64
        cols = (padded(np.ones(n_mm, dtype=bool)),
                padded(ana.c), padded(ana.a), padded(ana.b),
                padded(ana.a_kind == 2), padded(ana.b_kind == 2),
                padded(ana.c_kind == 2),
                padded(const_of(ana.a_kind, ana.a_tl).astype(f64)),
                padded(const_of(ana.b_kind, ana.b_tl).astype(f64)),
                padded(const_of(ana.c_kind, ana.c_tl).astype(f64)),
                padded(ana.reusable), padded(ana.tm),
                padded((ana.mm_pos / issue).astype(f64)),
                padded(ana.ts_max_pos >= 0),
                padded(np.where(ana.ts_max_pos >= 0,
                                ana.ts_max_pos / issue, 0.0).astype(f64)))
        mem_cfgs = [cfgs[j] for j in members]
        B = _pow2(len(mem_cfgs), lo=1)
        mem_cfgs = mem_cfgs + [mem_cfgs[-1]] * (B - len(mem_cfgs))
        d = _design_arrays(mem_cfgs)
        design = (d[0], d[1], d[2], d[5], d[6], d[7])   # wl fs dr wlbp wls pipe
        with enable_x64():
            carry = _mm_init_carry(B)
            for k in range(n_chunks):
                sl = slice(k * CHUNK, (k + 1) * CHUNK)
                carry = fn(carry, tuple(col[sl] for col in cols), design)
            t_end = np.asarray(carry[7])
            skips = np.asarray(carry[8])
        for bi, j in enumerate(members):
            results[j] = _result(trace, cfgs[j],
                                 max(float(t_end[bi]), static_end),
                                 int(skips[bi]), 0.0)
    return results  # type: ignore[return-value]


def sweep_trace(trace: CompiledTrace, cfgs: Sequence[EngineConfig],
                params: StreamModelParams | None = None,
                backend: str = "fast") -> list[TimingResult]:
    """Simulate one compiled trace under many engine configs at once.

    With ``params=None`` each config gets the paper's idealized port model
    (``LoadStreamModel(cfg.load_ports)``); an explicit ``params`` applies
    to every config.
    """
    if not cfgs:
        return []
    # a single design lane cannot amortize the vmapped scan: "fast" keeps
    # one-off simulations on the numpy loop (explicit "jax" still honored)
    work = len(trace) * len(cfgs) if len(cfgs) > 1 else 0
    concrete = resolve_backend(backend, work)
    if concrete == "numpy":
        return [_run_numpy_params(
                    trace, cfg,
                    params or StreamModelParams.for_config(cfg))[0]
                for cfg in cfgs]

    from jax.experimental import enable_x64
    base = params or StreamModelParams(load_ports=1)
    if base.is_port_model and base.store_ports is None:
        return _sweep_port_mm(trace, cfgs, params)
    sweep_fn = _jax_fns(base.is_port_model)[0]
    # pad the design batch to a power of two so neighbourhood sweeps of any
    # size reuse the same compiled executable
    n = len(cfgs)
    cfgs_p = list(cfgs) + [cfgs[-1]] * (_pow2(n, lo=1) - n)
    chunks, idxs = _chunk_single(trace)
    inv_load = np.array(
        [1.0 / (params.load_ports if params is not None else c.load_ports)
         for c in cfgs_p], dtype=np.float64)
    bucket = _bucket_arrays(base, inv_load, np.float64(base.tail_share))
    with enable_x64():
        carry = _init_carry(len(cfgs_p), base.burst_bytes)
        t_end, skips, stall, _ = _run_chunked(
            sweep_fn, carry, chunks, idxs, _design_arrays(cfgs_p), bucket)
    return [_result(trace, cfg, t_end[b], skips[b], stall[b])
            for b, cfg in enumerate(cfgs)]


def run_cores(traces: Sequence[CompiledTrace],
              cfg: EngineConfig | Sequence[EngineConfig],
              params: Sequence[StreamModelParams],
              backend: str = "fast") -> list[tuple[TimingResult, float]]:
    """Simulate one trace per core.

    ``cfg`` is one engine config shared by every core, or one per core
    (heterogeneous chips).  ``params[i]`` describes core *i*'s arbiter;
    schedules may differ per core in both ``shares`` and ``tail_share`` --
    exactly what weighted epoch arbitration produces.  Returns
    ``(TimingResult, last_grant)`` per core; ``last_grant`` is the activity
    horizon the chip-level relaxation reads back.
    """
    if len(traces) != len(params):
        raise ValueError("need one StreamModelParams per trace")
    if not traces:
        return []
    cfgs = [cfg] * len(traces) if isinstance(cfg, EngineConfig) else list(cfg)
    if len(cfgs) != len(traces):
        raise ValueError("need one EngineConfig per trace (or a single "
                         "shared one)")
    # a vmapped call can only span batch-compatible lanes -- same engine
    # config and bucket *shape* (port vs. bucket model, epoch length,
    # burst, store accounting); shares/tails vary per lane.
    groups: dict[tuple, list[int]] = {}
    for i, (c, p) in enumerate(zip(cfgs, params)):
        key = (c, p.is_port_model, p.epoch_cycles, p.burst_bytes,
               p.charge_store_bytes, p.load_ports, p.store_ports)
        groups.setdefault(key, []).append(i)
    out: list[tuple[TimingResult, float] | None] = [None] * len(traces)
    for idxs in groups.values():
        # the per-core layout cannot share instruction arrays across
        # lanes, so its scan step is gather-bound and only beats the
        # inlined numpy loop on large batches -- "fast" stays on numpy
        # below that scale (and always for one lane, which cannot
        # amortize the vmap at all).  Resolved per *group*: a mixed chip
        # whose cores split into small per-design groups must not pay one
        # unamortized vmapped scan per group.
        total = sum(len(traces[i]) for i in idxs) if len(idxs) > 1 else 0
        concrete = resolve_backend(
            backend, total if total >= FAST_JAX_MIN_CORES_INSTRS else 0)
        if concrete == "numpy":
            for i in idxs:
                out[i] = _run_numpy_params(traces[i], cfgs[i], params[i])
        else:
            res = _run_cores_jax([traces[i] for i in idxs], cfgs[idxs[0]],
                                 [params[i] for i in idxs])
            for i, r in zip(idxs, res):
                out[i] = r
    return out  # type: ignore[return-value]


def _run_cores_jax(traces: Sequence[CompiledTrace], cfg: EngineConfig,
                   params: Sequence[StreamModelParams]
                   ) -> list[tuple[TimingResult, float]]:
    """The jax cores layout for one batch-compatible lane group."""
    from jax.experimental import enable_x64
    head = params[0]
    cores_fn = _jax_fns(head.is_port_model)[1]
    n = len(traces)
    lanes = list(traces) + [_empty_trace()] * (_pow2(n, lo=1) - n)
    pad_p = list(params) + [head] * (len(lanes) - n)
    bucket = _bucket_arrays_per_lane(pad_p,
                                     np.float64(1.0 / head.load_ports))
    chunks, idxs = _chunk_batch(lanes)
    with enable_x64():
        carry = _init_carry(len(lanes), head.burst_bytes)
        t_end, skips, stall, lg = _run_chunked(
            cores_fn, carry, chunks, idxs, _design_scalars(cfg), bucket)
    return [(_result(traces[b], cfg, t_end[b], skips[b], stall[b]),
             float(lg[b])) for b in range(n)]


def _pack_lane(segs: Sequence[CompiledTrace]
               ) -> tuple[CompiledTrace, np.ndarray, list[int]]:
    """Concatenate segment traces with OP_END markers after each.

    Returns the packed trace, the per-instruction *segment-local* index
    array (issue times restart per segment), and the marker positions at
    which the lane's per-segment results are emitted.
    """
    fields: dict[str, list[np.ndarray]] = {k: [] for k in
                                           ("opcode", "r_dst", "r_a", "r_b",
                                            "nbytes", "tm", "macs",
                                            "reusable")}
    idx_parts: list[np.ndarray] = []
    ends: list[int] = []
    pos = 0
    for t in segs:
        for k in fields:
            fields[k].append(getattr(t, k))
        idx_parts.append(np.arange(len(t), dtype=np.float64))
        pos += len(t)
        ends.append(pos)
        pos += 1
        fields["opcode"].append(np.array([OP_END], dtype=np.int32))
        for k in ("r_dst", "r_a", "r_b"):
            fields[k].append(np.zeros(1, dtype=np.int32))
        for k in ("nbytes", "tm", "macs"):
            fields[k].append(np.zeros(1, dtype=np.float64))
        fields["reusable"].append(np.zeros(1, dtype=bool))
        idx_parts.append(np.zeros(1, dtype=np.float64))
    cat = {k: np.concatenate(v) for k, v in fields.items()}
    packed = CompiledTrace(**cat, n_tl=sum(t.n_tl for t in segs),
                           n_ts=sum(t.n_ts for t in segs),
                           n_mm=sum(t.n_mm for t in segs),
                           useful_macs=sum(t.useful_macs for t in segs))
    return packed, np.concatenate(idx_parts), ends


def sweep_traces(traces: Sequence[CompiledTrace],
                 cfgs: Sequence[EngineConfig],
                 params: StreamModelParams | None = None,
                 backend: str = "fast") -> list[list[TimingResult]]:
    """Simulate the full (trace x config) grid: ``out[i][j]`` is trace *i*
    under config *j*.

    The jax path packs all traces back to back into *one* shared stream
    (OP_END markers emit each segment's results and reset the lane state),
    vmapped over the design configs only.  Sharing the instruction arrays
    across lanes keeps every per-step op a scalar-indexed slice -- the
    highest-throughput layout for multi-GEMM design sweeps.
    """
    if not traces or not cfgs:
        return [[] for _ in traces]
    total = sum(len(t) for t in traces) * len(cfgs)
    concrete = resolve_backend(backend, total)
    if concrete == "numpy":
        return [[_run_numpy_params(
                    t, cfg, params or StreamModelParams.for_config(cfg))[0]
                 for cfg in cfgs] for t in traces]

    from jax.experimental import enable_x64
    base = params or StreamModelParams(load_ports=1)
    if base.is_port_model and base.store_ports is None:
        return [_sweep_port_mm(t, cfgs, params) for t in traces]
    sweep_fn = _jax_fns(base.is_port_model, emit_ends=True)[0]
    packed, idx, ends = _pack_lane(traces)
    chunks, idxs = _chunk_single(packed, idx)
    pick = [np.array([p - k * CHUNK for p in ends
                      if k * CHUNK <= p < (k + 1) * CHUNK], dtype=np.int64)
            for k in range(len(chunks))]
    # segment s of the packed stream is traces[s]; its result sits at the
    # s-th kept emission (picks are chunk-ordered = position-ordered)
    n = len(cfgs)
    cfgs_p = list(cfgs) + [cfgs[-1]] * (_pow2(n, lo=1) - n)
    inv_load = np.array(
        [1.0 / (params.load_ports if params is not None else c.load_ports)
         for c in cfgs_p], dtype=np.float64)
    bucket = _bucket_arrays(base, inv_load, np.float64(base.tail_share))
    with enable_x64():
        carry = _init_carry(len(cfgs_p), base.burst_bytes)
        _, ys = _run_chunked(sweep_fn, carry, chunks, idxs,
                             _design_arrays(cfgs_p), bucket, pick=pick)
    t_end, skips, stall, _ = ys
    return [[_result(traces[s], cfgs[j], t_end[j][s], skips[j][s],
                     stall[j][s]) for j in range(n)]
            for s in range(len(traces))]
