"""RASA instruction set + architectural tile-register file.

The paper (§IV-A) assumes an AMX-inspired ISA:

* eight architectural tile registers ``treg0-7``, each 16 rows x 64 B (1 KB);
* ``rasa_tl  treg, ptr``   -- load a tile from memory into a register;
* ``rasa_ts  ptr, treg``   -- store a tile register back to memory;
* ``rasa_mm  tC, tA, tB``  -- C[16x16,fp32] += A[16x32,bf16] @ B[32x16,bf16].

A bf16 tile register holds 16 rows x 32 cols (64 B of bf16 per row); an fp32
tile register holds 16 x 16.  The matrix engine is a weight-stationary
systolic array of ``rows x cols`` PEs (32x16 baseline; 16x16 with the DM
optimization), so one ``rasa_mm`` maps T_M=16, T_K=32, T_N=16.

Each tile register carries a *dirty bit* (paper §IV-B, WLBP): set on any
write (``rasa_tl`` or being an ``rasa_mm`` destination), cleared when the
register's content is latched into the array as the stationary operand.  A
subsequent ``rasa_mm`` whose B register is clean may skip its WL stage.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Iterator, Sequence

NUM_TREGS = 8
TREG_ROWS = 16          # rows per tile register
TREG_ROW_BYTES = 64     # bytes per row
TREG_BYTES = TREG_ROWS * TREG_ROW_BYTES

# Logical tile dims of one rasa_mm at bf16-in/fp32-out (AMX-style).
TILE_M = 16             # rows of A / C
TILE_K = 32             # cols of A / rows of B  (bf16: 64B row = 32 elements)
TILE_N = 16             # cols of B / C          (fp32: 64B row = 16 elements)


class Op(enum.Enum):
    TL = "rasa_tl"
    TS = "rasa_ts"
    MM = "rasa_mm"


@dataclasses.dataclass(frozen=True)
class Instr:
    """One RASA instruction.

    ``addr`` is an abstract tile identifier (matrix name, tile row, tile col)
    used both by the functional engine to fetch operand data and by the
    timing model to attribute memory traffic.  For MM: dst/src1/src2 are
    (C, A, B) register ids.  ``tm/tk/tn`` give the *valid* sub-tile dims so
    edge tiles of a GEMM are modelled and executed exactly.
    """

    op: Op
    dst: int | None = None            # treg id (TL, MM) -- None for TS
    src1: int | None = None           # A treg (MM) / treg to store (TS)
    src2: int | None = None           # B treg (MM)
    addr: tuple | None = None         # abstract memory tile id (TL / TS)
    tm: int = TILE_M
    tk: int = TILE_K
    tn: int = TILE_N

    def __post_init__(self):
        if self.op is Op.MM:
            assert self.dst is not None and self.src1 is not None and self.src2 is not None
        elif self.op is Op.TL:
            assert self.dst is not None and self.addr is not None
        elif self.op is Op.TS:
            assert self.src1 is not None and self.addr is not None

    def __str__(self) -> str:  # pragma: no cover - debug aid
        if self.op is Op.TL:
            return f"rasa_tl  treg{self.dst}, {self.addr}"
        if self.op is Op.TS:
            return f"rasa_ts  {self.addr}, treg{self.src1}"
        return f"rasa_mm  treg{self.dst}, treg{self.src1}, treg{self.src2}"


def tile_bytes(ins: Instr) -> int:
    """Memory traffic of one tile access: bf16 A/B operands, fp32 C tiles.

    Used by bandwidth-aware load models (``addr[0]`` names the matrix).
    """
    mat = ins.addr[0] if ins.addr else "C"
    if mat == "A":
        return ins.tm * ins.tk * 2
    if mat == "B":
        return ins.tk * ins.tn * 2
    return ins.tm * ins.tn * 4


@dataclasses.dataclass
class TregState:
    """Architectural state of one tile register as seen by the scheduler."""

    #: abstract id of the value currently held (None = undefined)
    value: tuple | None = None
    #: dirty bit -- set on write, cleared when latched as stationary operand
    dirty: bool = True
    #: generation counter; bumped on every write (disambiguates reuse checks)
    generation: int = 0


class TileRegisterFile:
    """Tracks register contents + dirty bits for WLBP reuse detection.

    This mirrors the microarchitectural bookkeeping the paper adds: one dirty
    bit per register (8 bits total).  The *timing* model queries
    :meth:`mm_weight_reusable` at rename time; the *functional* engine keeps
    its own data copies (see ``engine.py``).
    """

    def __init__(self, num_regs: int = NUM_TREGS):
        self.regs = [TregState() for _ in range(num_regs)]
        #: (reg id, generation) of the weights currently latched in the array
        self._latched: tuple[int, int] | None = None

    def write(self, reg: int, value: tuple | None) -> None:
        st = self.regs[reg]
        st.value = value
        st.dirty = True
        st.generation += 1

    def mm_weight_reusable(self, b_reg: int) -> bool:
        """True iff this MM's B register equals the latched weights and has
        not been written since they were latched (clean dirty bit)."""
        if self._latched is None:
            return False
        reg, gen = self._latched
        return reg == b_reg and self.regs[b_reg].generation == gen

    def latch_weights(self, b_reg: int) -> None:
        self.regs[b_reg].dirty = False
        self._latched = (b_reg, self.regs[b_reg].generation)

    def invalidate_latch(self) -> None:
        self._latched = None


def validate_stream(stream: Iterable[Instr]) -> None:
    """Static sanity checks on an instruction stream (used by tests)."""
    defined: set[int] = set()
    for i, ins in enumerate(stream):
        if ins.op is Op.TL:
            defined.add(ins.dst)  # type: ignore[arg-type]
        elif ins.op is Op.MM:
            for r, role in ((ins.dst, "C"), (ins.src1, "A"), (ins.src2, "B")):
                if r not in defined:
                    raise ValueError(f"instr {i}: {role} register treg{r} used before defined")
        elif ins.op is Op.TS:
            if ins.src1 not in defined:
                raise ValueError(f"instr {i}: stored register treg{ins.src1} undefined")


def count_ops(stream: Sequence[Instr]) -> dict[str, int]:
    out = {"tl": 0, "ts": 0, "mm": 0}
    for ins in stream:
        out[{Op.TL: "tl", Op.TS: "ts", Op.MM: "mm"}[ins.op]] += 1
    return out


def mm_instrs(stream: Iterable[Instr]) -> Iterator[Instr]:
    return (i for i in stream if i.op is Op.MM)
