"""High-level driver: workload -> lowered stream -> timing, per design.

This is the reproduction of the paper's evaluation flow (LIBXSMM trace ->
MacSim), minus the parts we rebuild analytically (see DESIGN.md §3): the
GEMM is lowered by ``tiling.lower_gemm`` (the LIBXSMM-equivalent microkernel
generator) and timed by ``timing.PipelineSimulator`` (the MacSim-equivalent
matrix-engine model).
"""

from __future__ import annotations

import dataclasses
import functools

from .designs import DESIGNS, EngineConfig, get_design
from .timing import LoadStreamModel, PipelineSimulator, TimingResult
from .tiling import ALG1_POLICY, GemmSpec, RegPolicy, lower_gemm


@dataclasses.dataclass(frozen=True)
class SimReport:
    design: str
    workload: str
    cycles: float
    n_mm: int
    n_tl: int
    n_ts: int
    wl_skips: int
    utilization: float
    runtime_s: float
    macs: int
    #: see TimingResult.load_stall_cycles -- arbiter delay, not end-to-end.
    load_stall_cycles: float = 0.0

    @property
    def macs_per_cycle(self) -> float:
        return self.macs / self.cycles if self.cycles else 0.0


def simulate(spec: GemmSpec, design: str | EngineConfig,
             policy: RegPolicy = ALG1_POLICY,
             load_model: LoadStreamModel | None = None) -> SimReport:
    cfg = get_design(design) if isinstance(design, str) else design
    sim = PipelineSimulator(cfg, load_model=load_model)
    res: TimingResult = sim.run(list(lower_gemm(spec, policy)))
    return SimReport(
        design=cfg.name,
        workload=spec.name,
        cycles=res.cycles,
        n_mm=res.n_mm, n_tl=res.n_tl, n_ts=res.n_ts,
        wl_skips=res.wl_skips,
        utilization=res.utilization,
        runtime_s=res.cycles / cfg.engine_clock_hz,
        macs=spec.macs,
        load_stall_cycles=res.load_stall_cycles,
    )


@functools.lru_cache(maxsize=4096)
def _simulate_cached(spec: GemmSpec, design: str, policy: RegPolicy) -> SimReport:
    return simulate(spec, design, policy)


def normalized_runtime(spec: GemmSpec, design: str,
                       policy: RegPolicy = ALG1_POLICY,
                       baseline: str = "BASE") -> float:
    """Runtime normalized to the BASE design (paper Fig. 5 / Fig. 7 y-axis)."""
    base = _simulate_cached(spec, baseline, policy)
    d = _simulate_cached(spec, design, policy)
    return d.cycles / base.cycles


def sweep_designs(spec: GemmSpec, designs: list[str] | None = None,
                  policy: RegPolicy = ALG1_POLICY) -> dict[str, SimReport]:
    return {name: _simulate_cached(spec, name, policy)
            for name in (designs or list(DESIGNS))}
