"""High-level driver: workload -> lowered stream -> timing, per design.

This is the reproduction of the paper's evaluation flow (LIBXSMM trace ->
MacSim), minus the parts we rebuild analytically (see DESIGN.md §3): the
GEMM is lowered by ``tiling.lower_gemm`` (the LIBXSMM-equivalent microkernel
generator) and timed by ``timing.PipelineSimulator`` (the MacSim-equivalent
matrix-engine model).

Every entry point takes a ``backend``:

``"reference"`` (default)
    The pure-Python :class:`PipelineSimulator` -- the exactness oracle.
``"fast"``
    Trace-compiled (:mod:`repro.core.trace`) and run by
    :mod:`repro.core.fastsim`: the jax ``lax.scan`` backend when jax is
    importable and the batch is large enough to amortize compilation, the
    bit-exact numpy SoA loop otherwise.
``"numpy"`` / ``"jax"``
    Force a specific fast backend.

A custom ``load_model`` whose parameters the fast backends cannot express
(see :meth:`repro.core.fastsim.StreamModelParams.from_model`) silently
falls back to the reference simulator, so ``backend="fast"`` is always
safe to request.
"""

from __future__ import annotations

import dataclasses
import functools

from . import fastsim
from .designs import DESIGNS, EngineConfig, get_design
from .timing import LoadStreamModel, PipelineSimulator, TimingResult
from .tiling import ALG1_POLICY, GemmSpec, RegPolicy, lowered_stream
from .trace import gemm_trace

BACKENDS = ("reference", "fast", "numpy", "jax")


@dataclasses.dataclass(frozen=True)
class SimReport:
    design: str
    workload: str
    cycles: float
    n_mm: int
    n_tl: int
    n_ts: int
    wl_skips: int
    utilization: float
    runtime_s: float
    macs: int
    #: see TimingResult.bw_stall_cycles -- arbiter delay, not end-to-end.
    bw_stall_cycles: float = 0.0

    @property
    def load_stall_cycles(self) -> float:
        """Deprecated alias of :attr:`bw_stall_cycles` (pre-PR-6 name)."""
        return self.bw_stall_cycles

    @property
    def macs_per_cycle(self) -> float:
        return self.macs / self.cycles if self.cycles else 0.0


def _to_report(spec: GemmSpec, cfg: EngineConfig,
               res: TimingResult) -> SimReport:
    return SimReport(
        design=cfg.name,
        workload=spec.name,
        cycles=res.cycles,
        n_mm=res.n_mm, n_tl=res.n_tl, n_ts=res.n_ts,
        wl_skips=res.wl_skips,
        utilization=res.utilization,
        runtime_s=res.cycles / cfg.engine_clock_hz,
        macs=spec.macs,
        bw_stall_cycles=res.bw_stall_cycles,
    )


def _fast_params(cfg: EngineConfig, load_model: LoadStreamModel | None
                 ) -> fastsim.StreamModelParams | None:
    if load_model is None:
        return fastsim.StreamModelParams.for_config(cfg)
    return fastsim.StreamModelParams.from_model(load_model)


def simulate(spec: GemmSpec, design: str | EngineConfig,
             policy: RegPolicy = ALG1_POLICY,
             load_model: LoadStreamModel | None = None,
             backend: str = "reference") -> SimReport:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"available: {BACKENDS}")
    cfg = get_design(design) if isinstance(design, str) else design
    if backend != "reference":
        params = _fast_params(cfg, load_model)
        if params is not None:
            trace = gemm_trace(spec, policy)
            res = fastsim.sweep_trace(trace, [cfg], params, backend)[0]
            return _to_report(spec, cfg, res)
        # an exotic load model: only the reference loop knows its semantics
    sim = PipelineSimulator(cfg, load_model=load_model)
    res: TimingResult = sim.run(lowered_stream(spec, policy))
    return _to_report(spec, cfg, res)


@functools.lru_cache(maxsize=4096)
def _simulate_cached(spec: GemmSpec, design: str | EngineConfig,
                     policy: RegPolicy,
                     backend: str = "reference") -> SimReport:
    """Memoized :func:`simulate`.

    ``design`` may be a name from :data:`DESIGNS` *or* any frozen custom
    :class:`EngineConfig` (hashable), so design-space searches probing
    perturbed configs hit the cache instead of re-simulating every probe.
    """
    return simulate(spec, design, policy, backend=backend)


def normalized_runtime(spec: GemmSpec, design: str | EngineConfig,
                       policy: RegPolicy = ALG1_POLICY,
                       baseline: str = "BASE",
                       backend: str = "reference") -> float:
    """Runtime normalized to the BASE design (paper Fig. 5 / Fig. 7 y-axis)."""
    base = _simulate_cached(spec, baseline, policy, backend)
    d = _simulate_cached(spec, design, policy, backend)
    return d.cycles / base.cycles


def _as_configs(designs) -> list[EngineConfig]:
    cfgs = [get_design(d) if isinstance(d, str) else d
            for d in (designs or list(DESIGNS))]
    names = [c.name for c in cfgs]
    if len(set(names)) != len(names):
        raise ValueError(f"design names must be unique, got {names}")
    return cfgs


def sweep_designs(spec: GemmSpec, designs: list | None = None,
                  policy: RegPolicy = ALG1_POLICY,
                  backend: str = "reference") -> dict[str, SimReport]:
    """Simulate one GEMM under many designs (names or custom configs).

    The fast backends compile the stream to a trace once and batch all
    designs through a single vmapped scan.
    """
    cfgs = _as_configs(designs)
    if backend == "reference":
        entries = list(designs or list(DESIGNS))
        return {cfg.name: _simulate_cached(spec, entry, policy)
                for entry, cfg in zip(entries, cfgs)}
    trace = gemm_trace(spec, policy)
    results = fastsim.sweep_trace(trace, cfgs, backend=backend)
    return {cfg.name: _to_report(spec, cfg, res)
            for cfg, res in zip(cfgs, results)}


def sweep_workload(specs: list[GemmSpec], designs: list | None = None,
                   policy: RegPolicy = ALG1_POLICY,
                   backend: str = "reference") -> list[dict[str, SimReport]]:
    """Simulate every (GEMM, design) pair of a workload.

    Returns one ``{design name: SimReport}`` dict per spec, in order.  The
    fast backends pack the whole grid into batched scan lanes (grouped by
    stream length), which is the highest-throughput way to run multi-GEMM
    design sweeps.
    """
    cfgs = _as_configs(designs)
    if backend == "reference":
        return [sweep_designs(spec, designs, policy) for spec in specs]
    traces = [gemm_trace(spec, policy) for spec in specs]
    grid = fastsim.sweep_traces(traces, cfgs, backend=backend)
    return [{cfg.name: _to_report(spec, cfg, res)
             for cfg, res in zip(cfgs, row)}
            for spec, row in zip(specs, grid)]
