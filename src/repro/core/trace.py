"""Trace compilation: lower an instruction stream once into a cached
structure-of-arrays ``CompiledTrace``.

Every simulation backend consumes the same per-instruction facts -- opcode,
register ids, valid tile dims, tile bytes -- but the reference
:class:`repro.core.timing.PipelineSimulator` re-derives them from ``Instr``
dataclasses on every run (attribute access, ``tile_bytes`` calls, dirty-bit
bookkeeping through :class:`repro.core.isa.TileRegisterFile`).  A
``CompiledTrace`` hoists all of it into flat numpy arrays so that the fast
backends (:mod:`repro.core.fastsim`) touch only scalars inside the timing
recurrence, and a ``jax.lax.scan`` can consume the arrays directly.

The key observation that makes the weight-reuse (WLBP) decision compilable:
the dirty-bit state the reference simulator tracks at *run* time is a pure
function of the instruction sequence -- timing never feeds back into it.
``rasa_tl`` bumps the destination register's generation; every ``rasa_mm``
bumps its C register's generation and then latches ``(B, gen(B))``.  So the
per-``rasa_mm`` "B register clean and still latched" bit is precomputed here
by replaying exactly that bookkeeping (see ``test_fastsim`` for the parity
suite that pins this against the runtime ``TileRegisterFile``).

Traces are cached per ``(specs, policy)`` -- the lowering and the replay are
paid once per workload, not once per design x arbiter round x probe.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence

import numpy as np

from .isa import NUM_TREGS, Instr, Op, tile_bytes
from .tiling import GemmSpec, RegPolicy, lowered_stream

#: fastsim opcode encoding.  ``NOP`` pads batched traces to a common length
#: and leaves every piece of simulator state untouched; ``END`` is a
#: segment separator for lane-packed batches (emit the lane's results, then
#: reset the simulator state for the next packed stream).
OP_TL, OP_TS, OP_MM, OP_NOP, OP_END = 0, 1, 2, 3, 4


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledTrace:
    """Structure-of-arrays form of one instruction stream.

    All arrays have length ``len(self)``; entries of fields that do not
    apply to an opcode (e.g. ``r_b`` of a ``rasa_tl``) are zero.
    Identity-hashed (``eq=False``): derived analyses are cached per trace
    object (see ``fastsim._mm_analysis``).
    """

    #: OP_TL / OP_TS / OP_MM (OP_NOP only appears in padded traces)
    opcode: np.ndarray          # int32
    #: destination register: C for MM, dst for TL (0 for TS)
    r_dst: np.ndarray           # int32
    #: first source: A for MM, the stored register for TS (0 for TL)
    r_a: np.ndarray             # int32
    #: second source: B for MM (0 otherwise)
    r_b: np.ndarray             # int32
    #: memory traffic of TL/TS accesses (:func:`repro.core.isa.tile_bytes`)
    nbytes: np.ndarray          # float64
    #: valid tile rows of an MM (drives the FF stage length)
    tm: np.ndarray              # float64
    #: useful MACs of an MM (tm*tk*tn; 0 otherwise)
    macs: np.ndarray            # float64
    #: static WLBP-reusability of an MM's B register (see module docstring)
    reusable: np.ndarray        # bool
    n_tl: int
    n_ts: int
    n_mm: int
    useful_macs: float

    def __len__(self) -> int:
        return int(self.opcode.shape[0])

    def padded(self, length: int) -> "CompiledTrace":
        """Return a copy padded with NOPs to ``length`` instructions."""
        n = len(self)
        if length < n:
            raise ValueError(f"cannot pad length-{n} trace to {length}")
        if length == n:
            return self
        pad = length - n

        def ext(a: np.ndarray, fill=0) -> np.ndarray:
            return np.concatenate([a, np.full(pad, fill, dtype=a.dtype)])

        return dataclasses.replace(
            self, opcode=ext(self.opcode, OP_NOP), r_dst=ext(self.r_dst),
            r_a=ext(self.r_a), r_b=ext(self.r_b), nbytes=ext(self.nbytes),
            tm=ext(self.tm), macs=ext(self.macs), reusable=ext(self.reusable))


def slice_trace(trace: CompiledTrace, k: int) -> CompiledTrace:
    """The trace of instructions ``[k, len(trace))`` as a fresh stream.

    Equivalent to ``compile_stream(stream[k:])`` (pinned by
    ``tests/test_faults.py``) but built from array slices -- the preemption
    remainder of a long segment must not pay a full re-lowering.  The only
    per-instruction fact that depends on the cut is the first in-slice
    ``rasa_mm``'s WLBP reusability: its predecessor MM is gone, so the
    fresh engine's weight latch is empty and it must reload
    (``reusable=False``).  Every later MM compares against an in-slice
    predecessor with identical writes in between, so its bit is unchanged.
    """
    n = len(trace)
    if not 0 <= k <= n:
        raise ValueError(f"slice index {k} out of range for length-{n} trace")
    if k == 0:
        return trace
    opcode = trace.opcode[k:]
    tm = trace.tm[k:]
    macs = trace.macs[k:]
    reusable = trace.reusable[k:]
    is_mm = opcode == OP_MM
    mm_idx = np.flatnonzero(is_mm)
    if len(mm_idx) and reusable[mm_idx[0]]:
        reusable = reusable.copy()
        reusable[mm_idx[0]] = False
    return CompiledTrace(
        opcode=opcode, r_dst=trace.r_dst[k:], r_a=trace.r_a[k:],
        r_b=trace.r_b[k:], nbytes=trace.nbytes[k:], tm=tm, macs=macs,
        reusable=reusable,
        n_tl=int((opcode == OP_TL).sum()), n_ts=int((opcode == OP_TS).sum()),
        n_mm=int(is_mm.sum()), useful_macs=float(macs.sum()),
    )


_OP_CODE = {Op.TL: OP_TL, Op.TS: OP_TS, Op.MM: OP_MM}
_MAT_CODE = {"A": 0, "B": 1}                 # everything else is a C tile


def compile_stream(stream: Iterable[Instr]) -> CompiledTrace:
    """Lower an instruction stream into its :class:`CompiledTrace`.

    Field extraction and the dirty-bit replay are vectorized; the replay
    mirrors ``PipelineSimulator.run``'s event order exactly: an MM's reuse
    check reads generations *before* its own C write, and the latch is
    taken *after* it -- so ``reusable[k]`` holds iff MM ``k`` names the same
    B register as MM ``k-1`` and no write touched that register strictly
    between the two (MM ``k-1``'s own C write included in the baseline).
    """
    instrs = stream if isinstance(stream, (list, tuple)) else list(stream)
    n = len(instrs)
    f64, i32 = np.float64, np.int32
    opcode = np.fromiter((_OP_CODE[i.op] for i in instrs), i32, n)
    dst = np.fromiter(((i.dst or 0) for i in instrs), i32, n)
    src1 = np.fromiter(((i.src1 or 0) for i in instrs), i32, n)
    src2 = np.fromiter(((i.src2 or 0) for i in instrs), i32, n)
    tm = np.fromiter((i.tm for i in instrs), f64, n)
    tk = np.fromiter((i.tk for i in instrs), f64, n)
    tn = np.fromiter((i.tn for i in instrs), f64, n)
    mat = np.fromiter((_MAT_CODE.get(i.addr[0] if i.addr else "C", 2)
                       for i in instrs), i32, n)
    is_tl = opcode == OP_TL
    is_ts = opcode == OP_TS
    is_mm = opcode == OP_MM

    # tile_bytes: bf16 A (tm*tk*2) / bf16 B (tk*tn*2) / fp32 C (tm*tn*4)
    nbytes = np.where(mat == 0, tm * tk * 2.0,
                      np.where(mat == 1, tk * tn * 2.0, tm * tn * 4.0))
    nbytes = np.where(is_tl | is_ts, nbytes, 0.0)
    macs = np.where(is_mm, tm * tk * tn, 0.0)

    # WLBP reuse replay (see docstring): per B register, count writes
    # strictly before each of the two probe positions with searchsorted.
    reusable = np.zeros(n, dtype=bool)
    if is_mm.any():
        pos = np.arange(n, dtype=np.int64)
        writes = is_tl | is_mm
        mm_pos = pos[is_mm]
        mm_b = src2[is_mm]
        ok = np.zeros(len(mm_pos), dtype=bool)
        same_b = np.zeros(len(mm_pos), dtype=bool)
        same_b[1:] = mm_b[1:] == mm_b[:-1]
        for reg in np.unique(mm_b):
            wpos = pos[writes & (dst == reg)]
            sel = np.flatnonzero(mm_b == reg)
            sel = sel[sel > 0]
            if not len(sel):
                continue
            before_k = np.searchsorted(wpos, mm_pos[sel])
            after_prev = np.searchsorted(wpos, mm_pos[sel - 1] + 1)
            ok[sel] = before_k == after_prev
        reusable[is_mm] = same_b & ok

    return CompiledTrace(
        opcode=opcode,
        r_dst=np.where(is_tl | is_mm, dst, 0).astype(i32),
        r_a=np.where(is_mm | is_ts, src1, 0).astype(i32),
        r_b=np.where(is_mm, src2, 0).astype(i32),
        nbytes=nbytes.astype(f64),
        tm=np.where(is_mm, tm, 0.0).astype(f64),
        macs=macs.astype(f64),
        reusable=reusable,
        n_tl=int(is_tl.sum()), n_ts=int(is_ts.sum()),
        n_mm=int(is_mm.sum()), useful_macs=float(macs.sum()),
    )


def _chain(specs: Sequence[GemmSpec], policy: RegPolicy) -> Iterable[Instr]:
    for spec in specs:
        yield from lowered_stream(spec, policy)


#: workloads above this many rasa_mm are compiled fresh instead of cached
#: (the SoA arrays are ~42 B/instr; a handful of multi-million-instruction
#: traces would otherwise pin GBs across a long sweep).
_TRACE_CACHE_MAX_MM = 600_000


@functools.lru_cache(maxsize=64)
def _compiled_trace_cached(specs: tuple[GemmSpec, ...],
                           policy: RegPolicy) -> CompiledTrace:
    return compile_stream(_chain(specs, policy))


def compiled_trace(specs: tuple[GemmSpec, ...],
                   policy: RegPolicy) -> CompiledTrace:
    """The cached ``CompiledTrace`` of ``specs`` lowered back to back.

    Register/dirty-bit state deliberately carries across GEMM boundaries,
    exactly as the reference simulator sees the concatenated stream.
    """
    mm = sum(m * k * n for m, k, n in (s.tiles() for s in specs))
    if mm > _TRACE_CACHE_MAX_MM:
        return compile_stream(_chain(specs, policy))
    return _compiled_trace_cached(specs, policy)


def gemm_trace(spec: GemmSpec, policy: RegPolicy) -> CompiledTrace:
    """Cached trace of a single GEMM (the ``simulate()`` fast path)."""
    return compiled_trace((spec,), policy)
