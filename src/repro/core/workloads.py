"""Paper Table I: the nine MLPerf-derived layers used in the evaluation.

Convolutions are lowered to GEMM exactly as LIBXSMM does (im2col view):
M = batch * out_x * out_y, K = in_channels * R * S, N = filters.  FC layers:
M = batch, K = NIN, N = NON.  (Paper notation: N=batch, K=filters, C=input
channels, X/Y input dims, R/S filter dims.)
"""

from __future__ import annotations

import dataclasses

from .tiling import GemmSpec


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    batch: int
    filters: int
    channels: int
    x: int
    y: int
    r: int
    s: int
    stride: int = 1

    def to_gemm(self) -> GemmSpec:
        # ResNet 3x3 layers use 'same' padding -> output dims == input dims
        # for stride 1 (the paper's layers are all stride 1).
        out_x = self.x // self.stride
        out_y = self.y // self.stride
        return GemmSpec(self.name,
                        M=self.batch * out_x * out_y,
                        K=self.channels * self.r * self.s,
                        N=self.filters)


@dataclasses.dataclass(frozen=True)
class FCSpec:
    name: str
    batch: int
    nin: int
    non: int

    def to_gemm(self) -> GemmSpec:
        return GemmSpec(self.name, M=self.batch, K=self.nin, N=self.non)


#: Table I, verbatim.
TABLE_I: dict[str, GemmSpec] = {
    "ResNet50-1": ConvSpec("ResNet50-1", 32, 64, 64, 56, 56, 1, 1).to_gemm(),
    "ResNet50-2": ConvSpec("ResNet50-2", 32, 64, 64, 56, 56, 3, 3).to_gemm(),
    "ResNet50-3": ConvSpec("ResNet50-3", 32, 512, 1024, 14, 14, 1, 1).to_gemm(),
    "DLRM-1": FCSpec("DLRM-1", 512, 1024, 1024).to_gemm(),
    "DLRM-2": FCSpec("DLRM-2", 512, 1024, 64).to_gemm(),
    "DLRM-3": FCSpec("DLRM-3", 512, 2048, 2048).to_gemm(),
    "BERT-1": FCSpec("BERT-1", 256, 768, 768).to_gemm(),
    "BERT-2": FCSpec("BERT-2", 256, 3072, 768).to_gemm(),
    "BERT-3": FCSpec("BERT-3", 256, 768, 3072).to_gemm(),
}

#: Fig. 7 sweeps batch size on an FC layer (we use DLRM-1 dims as the base).
def batch_sweep(nin: int = 1024, non: int = 1024,
                batches: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128,
                                            256, 512, 1024, 2048)) -> dict[int, GemmSpec]:
    return {b: GemmSpec(f"FC-b{b}", M=b, K=nin, N=non) for b in batches}
