"""ModelConfig -> chip Workload compilation (the workload frontend).

This is the missing layer between the ten model configs under
:mod:`repro.configs` and the chip model: it turns any
:class:`repro.config.ModelConfig` plus an inference point ``(batch, seq,
phase)`` into a :class:`Workload` -- the per-layer GEMM stream the
multi-core schedulers, both arbiter clients, and the serving batcher eat.

Phase semantics
---------------
``phase="prefill"``
    All ``batch * seq`` prompt tokens flow through every projection, so
    projection GEMMs carry ``M = batch * seq``; SSM blocks run the chunked
    SSD scan (see below).
``phase="decode"``
    One new token per sequence: projection GEMMs carry ``M = batch`` (the
    small-M regime the paper's register-aware techniques target), ``seq``
    is the KV/context length (it sizes the optional attention-score GEMMs
    and the SSD recurrent state reads), and SSM blocks run the O(1)
    recurrent update.

Lowering per block
------------------
* Attention: fused ``qkv`` ([d, (h + 2*kv) * hd]) and ``wo`` ([h * hd, d]);
  with ``CompileOptions.attention_scores`` also the ``QK^T`` / ``PV`` score
  GEMMs, folded along M over the ``batch * n_heads`` instances (a
  block-diagonal approximation: MAC-exact, reuse-approximate).
* Dense FFN: ``swiglu``/``geglu`` emit gate + up + down (one fused
  [d, 2*d_ff] gate-up GEMM under ``fuse_gate_up``); other activations
  emit up + down.
* MoE: balanced ("uniform") routing over ``n_active = min(n_experts,
  routed_tokens, max_experts)`` experts, ``ceil(routed_tokens /
  n_active)`` tokens each, where ``routed_tokens = M * top_k``.  Each
  modeled expert's GEMM pair is one *placement group* (``L{i}.e{j}``):
  schedulers place a group atomically on one core, so distinct experts
  spreading over cores is exactly expert parallelism.
* SSM (Mamba2): ``in_proj`` / ``out_proj`` projections plus the SSD core
  costed via the :mod:`repro.kernels.ssd_chunk` decomposition -- per
  (batch, head, chunk) the chunked scan is four matmuls (``cb = C @ B^T``,
  intra-chunk ``y = w @ xdt``, inter-chunk ``y += C @ state``, and the
  state update), folded along M over their instances; decode degenerates
  to the recurrent ``y = C @ state`` read plus the rank-1 state update.

Dedup / caching
---------------
Spec names are canonical per *block kind*, not per layer index
(``gemma-2b.attn.qkv``, never ``...L17.qkv``), so the ``n_layers``
repetitions of a layer produce literally equal ``GemmSpec``s: the lowering
cache (:func:`repro.core.tiling.lowered_stream`), the trace compiler
(:func:`repro.core.trace.compiled_trace`) and the scheduler's cost cache
all compile a repeated layer once.  :class:`WorkloadOp` carries the layer
index separately for reporting.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from ..config import ModelConfig
from ..core.tiling import GemmSpec

PHASES = ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Explicit knobs of the compile layer.

    ``dim_cap`` caps every GEMM dimension (the LLM-projection benchmark's
    heuristic, now a first-class option: relative BASE -> RASA behaviour in
    the small-M regime is insensitive to K/N beyond a few thousand, while
    simulation cost is not).  ``max_layers`` lowers only the first L layers
    (the workload records the full depth for scaled reporting).
    ``max_experts`` caps the modeled expert-parallel width per MoE layer;
    the routed token count is conserved, so capped experts are fewer but
    proportionally fatter.  ``attention_scores`` adds the ``QK^T`` / ``PV``
    GEMMs; ``include_head`` appends the LM head(s) (``n_codebooks`` of
    them for audio models).
    """

    dim_cap: int | None = None
    max_layers: int | None = None
    max_experts: int | None = None
    attention_scores: bool = False
    include_head: bool = False

    def cap(self, dim: int) -> int:
        return max(1, min(dim, self.dim_cap)) if self.dim_cap else dim


#: default options: the uncapped, projection-only lowering
DEFAULT_OPTIONS = CompileOptions()


@dataclasses.dataclass(frozen=True)
class WorkloadOp:
    """One GEMM of a compiled workload, with its provenance.

    ``group`` is the placement-group key (MoE expert-parallel hint): ops
    sharing a group must land on one core as a unit; ``None`` ops are
    free-standing.
    """

    spec: GemmSpec
    layer: int                      # layer index (-1 for the LM head)
    block: str                      # attn | ffn | moe | ssm | head
    group: str | None = None


@dataclasses.dataclass(frozen=True)
class Workload:
    """A compiled model inference point: the chip-schedulable GEMM stream."""

    name: str
    arch: str
    phase: str
    batch: int
    seq: int
    #: layers actually lowered (== n_layers unless max_layers cut the stack)
    layers_modeled: int
    #: the model's full depth, for scaled single-core projections
    n_layers: int
    ops: tuple[WorkloadOp, ...]

    @property
    def specs(self) -> tuple[GemmSpec, ...]:
        return tuple(op.spec for op in self.ops)

    @property
    def macs(self) -> int:
        return sum(op.spec.macs for op in self.ops)

    def units(self) -> list[tuple[GemmSpec, ...]]:
        """Scheduler items: placement groups as atomic spec tuples.

        Consecutive ops sharing a ``group`` key collapse into one unit (a
        MoE expert's GEMM pair); ungrouped ops are singleton units.  Order
        follows the op stream, so single-core placement preserves the
        layer order exactly.
        """
        units: list[tuple[GemmSpec, ...]] = []
        open_key: str | None = None
        for op in self.ops:
            if op.group is not None and op.group == open_key:
                units[-1] = units[-1] + (op.spec,)
            else:
                units.append((op.spec,))
                open_key = op.group
        return units

    def unique_specs(self) -> list[tuple[GemmSpec, int]]:
        """The distinct GEMMs with multiplicities (the dedup view: repeated
        layers share canonically-named, literally equal specs)."""
        counts: dict[GemmSpec, int] = {}
        for op in self.ops:
            counts[op.spec] = counts.get(op.spec, 0) + 1
        return list(counts.items())


def _resolve_model(model) -> tuple[ModelConfig, str]:
    if isinstance(model, ModelConfig):
        return model, model.name
    from ..configs import get_config
    return get_config(model).model, model


def _attention_ops(m: ModelConfig, arch: str, layer: int, m_tokens: int,
                   batch: int, seq: int, phase: str, o: CompileOptions
                   ) -> Iterable[WorkloadOp]:
    d, hd = o.cap(m.d_model), m.resolved_head_dim
    n_qkv = o.cap((m.n_heads + 2 * m.n_kv_heads) * hd)
    mk = lambda op, M, K, N: WorkloadOp(
        GemmSpec(f"{arch}.attn.{op}", M, K, N), layer, "attn")
    yield mk("qkv", m_tokens, d, n_qkv)
    if o.attention_scores:
        # per-(batch, head) score/context GEMMs folded along M; decode has
        # one query row per instance, prefill a full seq x seq block
        q_rows = seq if phase == "prefill" else 1
        M = o.cap(batch * m.n_heads * q_rows)
        kv = o.cap(seq)
        yield mk("scores", M, hd, kv)
        yield mk("context", M, kv, hd)
    yield mk("wo", m_tokens, o.cap(m.n_heads * hd), d)


def _ffn_ops(m: ModelConfig, arch: str, layer: int, m_tokens: int,
             o: CompileOptions) -> Iterable[WorkloadOp]:
    d, ff = o.cap(m.d_model), o.cap(m.d_ff)
    mk = lambda op, M, K, N: WorkloadOp(
        GemmSpec(f"{arch}.ffn.{op}", M, K, N), layer, "ffn")
    if m.act in ("swiglu", "geglu"):
        if m.fuse_gate_up:
            yield mk("gate_up", m_tokens, d, o.cap(2 * m.d_ff))
        else:
            yield mk("gate", m_tokens, d, ff)
            yield mk("up", m_tokens, d, ff)
    else:
        yield mk("up", m_tokens, d, ff)
    yield mk("down", m_tokens, ff, d)


def _moe_ops(m: ModelConfig, arch: str, layer: int, m_tokens: int,
             o: CompileOptions) -> Iterable[WorkloadOp]:
    moe = m.moe
    assert moe is not None
    d, ffe = o.cap(m.d_model), o.cap(moe.d_ff_expert)
    routed = m_tokens * moe.top_k
    n_active = min(moe.n_experts, routed)
    if o.max_experts:
        n_active = min(n_active, o.max_experts)
    m_e = math.ceil(routed / n_active)
    for e in range(n_active):
        group = f"L{layer}.e{e}"
        mk = lambda op, M, K, N: WorkloadOp(
            GemmSpec(f"{arch}.moe.{op}", M, K, N), layer, "moe", group)
        if m.act in ("swiglu", "geglu") and not m.fuse_gate_up:
            yield mk("gate", m_e, d, ffe)
        yield mk("up", m_e, d, ffe)
        yield mk("down", m_e, ffe, d)


def _ssm_ops(m: ModelConfig, arch: str, layer: int, m_tokens: int,
             batch: int, seq: int, phase: str, o: CompileOptions
             ) -> Iterable[WorkloadOp]:
    s = m.ssm
    assert s is not None
    d = o.cap(m.d_model)
    di = s.expand * m.d_model
    h = di // s.head_dim
    P, N = s.head_dim, s.d_state
    n_in = o.cap(2 * di + 2 * s.n_groups * N + h)
    mk = lambda op, M, K, Nn: WorkloadOp(
        GemmSpec(f"{arch}.ssm.{op}", M, K, Nn), layer, "ssm")
    yield mk("in_proj", m_tokens, d, n_in)
    if phase == "prefill":
        # chunked SSD (Dao & Gu): per (batch, head, chunk) four matmuls,
        # folded along M over their instances (MAC-exact)
        q = min(s.chunk, seq)
        nc = math.ceil(seq / q)
        rows = o.cap(batch * h * nc * q)
        yield mk("ssd.cb", rows, N, o.cap(q))          # C @ B^T
        yield mk("ssd.intra", rows, o.cap(q), P)       # w @ xdt
        yield mk("ssd.inter", rows, N, P)              # C @ state
        yield mk("ssd.state", o.cap(batch * h * nc * N), o.cap(q), P)
    else:
        # recurrent step: y = C @ state per (batch, head), plus the rank-1
        # state update outer(B, x * dt)
        yield mk("ssd.out", o.cap(batch * h), N, P)
        yield mk("ssd.state", o.cap(batch * h * N), 1, P)
    yield mk("out_proj", m_tokens, o.cap(di), d)


def layer_ops(model, layer: int, *, batch: int, seq: int,
              phase: str = "decode",
              options: CompileOptions = DEFAULT_OPTIONS
              ) -> list[WorkloadOp]:
    """The GEMM ops of one layer at one inference point.

    Hybrid models (Zamba2-style) interleave: every layer runs the SSM
    block, and layers at the shared-attention stride additionally run the
    attention + FFN block.
    """
    m, arch = _resolve_model(model)
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; available: {PHASES}")
    m_tokens = batch * seq if phase == "prefill" else batch
    m_tokens = options.cap(m_tokens)
    out: list[WorkloadOp] = []
    attn_layer = m.n_heads > 0
    if m.hybrid is not None:
        attn_layer = m.n_heads > 0 and layer % m.hybrid.attn_every == 0
    if m.ssm is not None:
        out += _ssm_ops(m, arch, layer, m_tokens, batch, seq, phase, options)
    if attn_layer:
        out += _attention_ops(m, arch, layer, m_tokens, batch, seq, phase,
                              options)
        if m.moe is not None:
            out += _moe_ops(m, arch, layer, m_tokens, options)
        elif m.d_ff:
            out += _ffn_ops(m, arch, layer, m_tokens, options)
    return out


def compile_workload(model, *, batch: int, seq: int,
                     phase: str = "decode",
                     options: CompileOptions = DEFAULT_OPTIONS) -> Workload:
    """Compile ``model`` at ``(batch, seq, phase)`` into a :class:`Workload`.

    ``model`` is a :class:`repro.config.ModelConfig` or an arch name from
    :data:`repro.configs.ARCH_NAMES`.  The resulting op stream is
    layer-ordered; repeated layers share canonically-named specs, so the
    trace compiler lowers each distinct shape once no matter the depth.
    """
    m, arch = _resolve_model(model)
    if batch < 1 or seq < 1:
        raise ValueError("batch and seq must be >= 1")
    n = m.n_layers
    modeled = min(n, options.max_layers) if options.max_layers else n
    ops: list[WorkloadOp] = []
    for layer in range(modeled):
        ops += layer_ops(m, layer, batch=batch, seq=seq, phase=phase,
                         options=options)
    if options.include_head:
        m_tokens = options.cap(batch * seq if phase == "prefill" else batch)
        for cb in range(m.n_codebooks):
            ops.append(WorkloadOp(
                GemmSpec(f"{arch}.head", m_tokens,
                         options.cap(m.d_model), options.cap(m.vocab)),
                -1, "head"))
    if not ops:
        raise ValueError(f"{arch}: no GEMMs lowered -- "
                         f"check the model's block configuration")
    return Workload(
        name=f"{arch}/{phase}[b{batch},s{seq}]",
        arch=arch, phase=phase, batch=batch, seq=seq,
        layers_modeled=modeled, n_layers=n, ops=tuple(ops))
