"""Real-model workload frontend: compile ``repro.configs`` models into
chip-schedulable :class:`Workload`s (see :mod:`repro.workload.compile`)."""

from .compile import (
    DEFAULT_OPTIONS,
    PHASES,
    CompileOptions,
    Workload,
    WorkloadOp,
    compile_workload,
    layer_ops,
)

__all__ = [
    "DEFAULT_OPTIONS",
    "PHASES",
    "CompileOptions",
    "Workload",
    "WorkloadOp",
    "compile_workload",
    "layer_ops",
]
