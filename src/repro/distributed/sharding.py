"""Logical-axis sharding rules -> PartitionSpecs (DP / FSDP / TP / SP / EP).

Strategy (DESIGN.md §5):
  * batch (DP)            over ("pod", "data")  [multi-pod] or ("data",)
  * parameter storage     FSDP over the data axes (d_model-ish dims)
  * tensor parallel (TP)  over "model" (heads / ff / vocab dims)
  * sequence parallel     over "data" for the 500k KV cache (decode)
  * experts               TP within each expert (expert dim replicated --
                          8 and 40 experts don't divide the 16-wide model
                          axis; see DESIGN.md §5)

Parameter specs are derived from leaf *names*: every module names its
parameters from a fixed vocabulary (wq, wo, w_up, experts_w1, ...).  Stacked
per-layer parameters carry a leading layer dim (spec gets a leading None).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import ParallelConfig

_STATE = threading.local()


@dataclasses.dataclass
class MeshContext:
    mesh: Mesh
    parallel: ParallelConfig

    @property
    def fsdp_axes(self):
        if not self.parallel.fsdp:
            return None
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names) or None

    @property
    def dp_axes(self):
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)


def current_ctx() -> MeshContext | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, parallel: ParallelConfig):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh, parallel)
    try:
        with mesh:
            yield _STATE.ctx
    finally:
        _STATE.ctx = prev


# ---------------------------------------------------------------- parameters

def _param_rules(fsdp) -> dict[str, P]:
    """leaf-name -> PartitionSpec (without the stacked-layer leading dim)."""
    f = fsdp  # None (replicated storage) or axis tuple
    return {
        # embeddings / head
        "embedding": P("model", f),          # [V, D]
        "lm_head": P(f, "model"),            # [D, V] (or [D, cb*V])
        "patch_proj": P(f, "model"),         # vlm stub frontend
        # attention
        "wq": P(f, "model"),                 # [D, H*hd]
        "wk": P(f, "model"),
        "wv": P(f, "model"),
        "wo": P("model", f),                 # [H*hd, D]
        "q_norm": P(),                       # [hd]
        "k_norm": P(),
        # dense mlp
        "w_gate": P(f, "model"),             # [D, F]
        "w_up": P(f, "model"),
        "w_gate_up": P(f, None, "model"),    # [D, 2, F] (fused)
        "w_down": P("model", f),             # [F, D]
        # moe
        "router": P(f, None),                # [D, E]
        "experts_w_gate": P(None, f, "model"),   # [E, D, Fe]
        "experts_w_up": P(None, f, "model"),
        "experts_w_gate_up": P(None, f, None, "model"),  # [E, D, 2, Fe]
        "experts_w_down": P(None, "model", f),   # [E, Fe, D]
        # mamba2 / ssd
        "in_proj": P(f, "model"),            # [D, proj]
        "out_proj": P("model", f),           # [di, D]
        "conv_w": P(None, "model"),          # [k, channels]
        "conv_b": P("model"),
        "A_log": P(),                        # [h]
        "D_skip": P(),                       # [h]
        "dt_bias": P(),
        "ssm_norm": P("model"),              # [di]
        # norms
        "scale": P(),
        "norm1": P(), "norm2": P(), "norm3": P(), "final_norm": P(),
    }


def param_spec(name: str, shape: tuple[int, ...],
               ctx: MeshContext | None = None) -> P:
    ctx = ctx or current_ctx()
    fsdp = ctx.fsdp_axes if ctx else None
    rules = _param_rules(fsdp)
    if name not in rules:
        return P()                           # replicate unknown small params
    spec = rules[name]
    ndim = len(shape)
    # stacked per-layer parameters have a leading layer dim
    if ndim == len(spec) + 1:
        spec = P(None, *spec)
    elif ndim != len(spec):
        # e.g. biases / scalars that share a rule name: replicate
        return P()
    if ctx is None:
        return spec
    # drop axes that don't divide the dim (e.g. vocab 50280 over 16)
    fixed = []
    for dim, axes in zip(shape, spec):
        if axes is None:
            fixed.append(None)
            continue
        ax = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax:
            size *= ctx.mesh.shape[a]
        fixed.append(axes if dim % size == 0 else None)
    return P(*fixed)


def param_specs(params: Any, ctx: MeshContext | None = None) -> Any:
    """Tree of PartitionSpec matching a parameter tree (by leaf key name)."""
    def walk(tree):
        if isinstance(tree, dict):
            return {k: (param_spec(k, v.shape, ctx)
                        if not isinstance(v, dict) else walk(v))
                    for k, v in tree.items()}
        return tree
    return walk(params)


def shardings_for(params: Any, mesh: Mesh, ctx: MeshContext | None = None):
    specs = param_specs(params, ctx)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------- activations

def activation_spec(kind: str, ctx: MeshContext | None = None) -> P:
    """Canonical activation shardings.

    kinds: tokens [B,S] | btd [B,S,D] | btf [B,S,F] | logits [B,S,V]
           | bhsd [B,H,S,hd] | bd [B,D]
    """
    ctx = ctx or current_ctx()
    dp = ctx.dp_axes if ctx else ("data",)
    return {
        "tokens": P(dp, None),
        # residual stream: sequence sharded over "model" between blocks
        # (Megatron-style sequence parallelism -- XLA inserts the
        # all-gather before qkv/mlp and the reduce-scatter after; cuts the
        # stored scan carries by the model-axis width)
        "btd": P(dp, "model", None),
        "btf": P(dp, None, "model"),
        "logits": P(dp, None, "model"),
        "bhsd": P(dp, "model", None, None),
        "bd": P(dp, None),
        # MoE expert buffers [E, G*C, *] (group-major): capacity over DP,
        # expert hidden over model
        "ecd": P(None, dp, None),
        "ecf": P(None, dp, "model"),
        # audio per-codebook logits [B, S, cb, V]
        "bscv": P(dp, None, None, "model"),
    }[kind]


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """with_sharding_constraint iff a mesh context is active (no-op in
    single-device smoke tests).  Mesh axes that don't divide the concrete
    dim are dropped (decode steps with S=1, batch=1 long-context, reduced
    smoke configs) -- the constraint degrades instead of erroring."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = activation_spec(kind, ctx)
    fixed = []
    for dim, axes in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if axes is None:
            fixed.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= ctx.mesh.shape[a]
        fixed.append(axes if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*fixed)))


def kv_cache_spec(n_kv_heads: int, head_dim: int,
                  ctx: MeshContext | None = None,
                  sequence_parallel: bool | None = None) -> P:
    """[B, Hkv, S, hd] cache sharding.

    Default: batch over DP, kv heads over model (falling back to head_dim
    when kv heads don't divide, e.g. MQA kv=1 with head_dim 256).
    Sequence-parallel decode (500k): sequence over "data", batch replicated.
    """
    ctx = ctx or current_ctx()
    if ctx is None:
        return P()
    model = ctx.mesh.shape.get("model", 1)
    heads_shardable = n_kv_heads % model == 0
    hd_shardable = head_dim % model == 0
    sp = (ctx.parallel.sequence_parallel_decode
          if sequence_parallel is None else sequence_parallel)
    if sp:
        if heads_shardable:
            return P(None, "model", "data", None)
        return P(None, None, "data", "model" if hd_shardable else None)
    dp = ctx.dp_axes
    if heads_shardable:
        return P(dp, "model", None, None)
    if hd_shardable:
        return P(dp, None, None, "model")
    return P(dp, None, None, None)
