"""Distribution substrate: mesh-aware sharding rules + collectives."""

from .sharding import (MeshContext, activation_spec, constrain, current_ctx,
                       kv_cache_spec, mesh_context, param_spec, param_specs)

__all__ = ["MeshContext", "activation_spec", "constrain", "current_ctx",
           "kv_cache_spec", "mesh_context", "param_spec", "param_specs"]
