"""Deterministic synthetic LM data pipeline.

Step-indexed and stateless: batch(step) is a pure function of (seed, step),
so a restarted/elastically-resized job resumes mid-stream with no data
skips or repeats -- the property the fault-tolerance tests assert.  Tokens
follow a Zipf-ish distribution with short-range structure (a Markov-y mix)
so losses actually decrease during the example runs.

Per-host sharding: each host materializes only its slice of the global
batch (process_index-based), matching multi-host TPU input pipelines.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..config import ModelConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def _tokens(self, step: int, extra: int = 0) -> np.ndarray:
        """[host_batch, seq_len + 1 + extra] int32 (shift -> inputs/labels)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s = self.host_batch, self.seq_len + 1 + extra
        v = self.cfg.vocab
        # Zipf base distribution
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        base = np.clip(base, 1, v - 1)
        # short-range structure: with p=0.35, copy the previous token + 1
        copy = rng.random((b, s)) < 0.35
        out = base.copy()
        for i in range(1, s):
            out[:, i] = np.where(copy[:, i], (out[:, i - 1] + 1) % v,
                                 out[:, i])
        return out.astype(np.int32)

    def batch(self, step: int) -> dict:
        m = self.cfg
        toks = self._tokens(step)
        if m.family == "audio":
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, self.host_id, 7]))
            t = rng.integers(0, m.vocab,
                             (self.host_batch, self.seq_len + 1,
                              m.n_codebooks)).astype(np.int32)
            return {"tokens": t[:, :-1], "labels": t[:, 1:]}
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if m.family == "vlm":
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, self.host_id, 11]))
            batch["patch_embeds"] = rng.normal(
                size=(self.host_batch, 8, m.d_model)).astype(np.float32)
        return batch


def make_batch_iterator(dataset: SyntheticLMDataset, start_step: int = 0):
    step = start_step
    while True:
        yield step, dataset.batch(step)
        step += 1
