"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
GeGLU, head_dim=256, tied embeddings. [arXiv:2403.08295; hf]"""

from ..config import ModelConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab=256000, head_dim=256,
        act="geglu", rope="standard", tie_embeddings=True,
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="gemma-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab=512, head_dim=32,
        act="geglu", tie_embeddings=True,
    ),
)
