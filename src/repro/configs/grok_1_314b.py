"""grok-1-314b [moe]: 64L d=6144 48H (GQA kv=8) expert d_ff=32768
vocab=131072, MoE 8 experts top-2. bf16 optimizer states (HBM budget --
see EXPERIMENTS.md roofline memory analysis). [hf:xai-org/grok-1; unverified]"""

from ..config import ModelConfig, MoEConfig, ParallelConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=0, vocab=131072, head_dim=128,
        act="gelu", rope="standard",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    ),
    parallel=ParallelConfig(opt_state_dtype="bfloat16"),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="grok-1-314b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=512, head_dim=16, act="gelu",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=4.0),
    ),
)
