"""zamba2-2.7b [hybrid]: 54L d=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  Mamba2 backbone + ONE shared attention+MLP block applied
every 6 layers (simplification of Zamba2's two alternating shared blocks;
DESIGN.md §Arch-applicability).  [arXiv:2411.15242; hf]"""

from ..config import HybridConfig, ModelConfig, RunConfig, SSMConfig

FULL = RunConfig(
    model=ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, head_dim=80,
        act="geglu", rope="standard",
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        hybrid=HybridConfig(attn_every=6),
        subquadratic=True, tie_embeddings=True,
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, head_dim=16,
        act="geglu",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=32),
        hybrid=HybridConfig(attn_every=2),
        subquadratic=True, tie_embeddings=True,
    ),
)
