"""nemotron-4-15b [dense]: 32L d=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""

from ..config import ModelConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab=256000, head_dim=128,
        act="relu2", rope="standard",
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="nemotron-4-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, head_dim=16, act="relu2",
    ),
)
