"""The paper's own configuration: the RASA matrix engine + Table I workloads.

This is the config the reproduction benchmarks run; the LM architectures in
this package consume the engine through ``RunConfig.engine`` instead.
"""

from ..core.designs import DESIGNS, EngineConfig, get_design
from ..core.tiling import ALG1_POLICY, LOW_REUSE_POLICY, MAX_REUSE_POLICY
from ..core.workloads import TABLE_I

#: evaluation setup of §V
ARRAY_ROWS = 32
ARRAY_COLS = 16
ENGINE_CLOCK_HZ = 500e6
CORE_CLOCK_HZ = 2e9

__all__ = ["DESIGNS", "EngineConfig", "get_design", "TABLE_I",
           "ALG1_POLICY", "LOW_REUSE_POLICY", "MAX_REUSE_POLICY",
           "ARRAY_ROWS", "ARRAY_COLS", "ENGINE_CLOCK_HZ", "CORE_CLOCK_HZ"]
