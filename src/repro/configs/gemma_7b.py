"""gemma-7b [dense]: 28L d=3072 16H (kv=16) d_ff=24576 vocab=256000.
GeGLU, head_dim=256, tied embeddings. [arXiv:2403.08295; hf]"""

from ..config import ModelConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
        d_ff=24576, vocab=256000, head_dim=256,
        act="geglu", rope="standard", tie_embeddings=True,
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="gemma-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=512, head_dim=32,
        act="geglu", tie_embeddings=True,
    ),
)
