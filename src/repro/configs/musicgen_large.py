"""musicgen-large [audio]: 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048.
Decoder-only over EnCodec tokens; 4 codebooks (stub frame-embedding
frontend sums the per-codebook embeddings; one lm head per codebook).
RoPE replaces the original sinusoidal positions -- noted in DESIGN.md.
[arXiv:2306.05284; hf]"""

from ..config import ModelConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64,
        act="gelu", rope="standard", n_codebooks=4, frontend="audio",
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="musicgen-large-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, head_dim=16,
        act="gelu", n_codebooks=4, frontend="audio",
    ),
)
