"""mamba2-130m [ssm]: 24L d=768 (attention-free) vocab=50280, ssm_state=128.
SSD (state-space duality), chunked. [arXiv:2405.21060; unverified]"""

from ..config import ModelConfig, RunConfig, SSMConfig

FULL = RunConfig(
    model=ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280, rope="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        subquadratic=True, tie_embeddings=True,
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="mamba2-130m-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=512, rope="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=32),
        subquadratic=True, tie_embeddings=True,
    ),
)
