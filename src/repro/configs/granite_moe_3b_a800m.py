"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) expert d_ff=512
vocab=49155, MoE 40 experts top-8.  (Pool prose says 32e; structured field
40e top-8 wins -- matches hf:ibm-granite/granite-3.0-3b-a800m-base.)
The tiny 512-wide expert GEMMs are exactly the register-limited small-tile
regime RASA targets -- see benchmarks/rasa_llm_projection.py.
[hf; verified]"""

from ..config import ModelConfig, MoEConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=0, vocab=49155, head_dim=64,
        act="swiglu", rope="standard",
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    ),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="granite-moe-3b-a800m-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=512, head_dim=16, act="swiglu",
        moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=32, capacity_factor=4.0),
    ),
)
