"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
M-RoPE, dynamic resolution (stub patch-embedding frontend).
[arXiv:2409.12191; hf]"""

from ..config import ModelConfig, ParallelConfig, RunConfig

FULL = RunConfig(
    model=ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        act="swiglu", rope="mrope", rope_theta=1e6,
        frontend="vision",
    ),
    parallel=ParallelConfig(opt_state_dtype="bfloat16"),
)

SMOKE = RunConfig(
    model=ModelConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16,
        act="swiglu", rope="mrope", frontend="vision",
    ),
)
