"""Architecture registry: ``--arch <id>`` -> RunConfig (FULL or SMOKE),
plus the (arch x shape) cell definitions used by the dry-run and roofline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import RunConfig, SHAPES
from . import (gemma_2b, gemma_7b, granite_moe_3b_a800m, grok_1_314b,
               mamba2_130m, musicgen_large, nemotron_4_15b, qwen2_vl_72b,
               qwen3_1_7b, zamba2_2_7b)

_MODULES = {
    "qwen2-vl-72b": qwen2_vl_72b,
    "nemotron-4-15b": nemotron_4_15b,
    "qwen3-1.7b": qwen3_1_7b,
    "gemma-2b": gemma_2b,
    "gemma-7b": gemma_7b,
    "musicgen-large": musicgen_large,
    "mamba2-130m": mamba2_130m,
    "grok-1-314b": grok_1_314b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "zamba2-2.7b": zamba2_2_7b,
}

ARCH_NAMES = list(_MODULES)

#: stub vision frontend: number of (precomputed) patch embeddings per sample
VLM_PATCHES = 256


def get_config(arch: str, smoke: bool = False) -> RunConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_NAMES}")
    return _MODULES[arch].SMOKE if smoke else _MODULES[arch].FULL


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Is (arch x shape) a valid cell?  Returns (ok, reason-if-not).

    long_500k requires sub-quadratic attention (DESIGN.md skip notes); all
    ten archs are decoder-style so decode/prefill shapes run everywhere.
    """
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.model.subquadratic:
        return False, ("full-attention arch: 512k dense-KV decode is "
                       "quadratic-cost; skipped per shape definition")
    return True, ""


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape, applicable, reason) for the 40 cells."""
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            ok, why = cell_applicable(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, why


def input_specs(cfg: RunConfig, shape: str,
                seq_len: int | None = None,
                global_batch: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell
    (weak-type-correct, shardable, no device allocation).

    train/prefill: token batches; decode: a single new token per sequence
    (the KV cache / SSM state specs come from ``decode_state_specs``).
    """
    s, b, kind = SHAPES[shape]
    s = seq_len or s
    b = global_batch or b
    m = cfg.model
    i32 = jnp.int32

    if kind == "train":
        if m.family == "audio":
            return {"tokens": jax.ShapeDtypeStruct((b, s, m.n_codebooks), i32),
                    "labels": jax.ShapeDtypeStruct((b, s, m.n_codebooks), i32)}
        if m.family == "vlm":
            st = s - VLM_PATCHES
            return {"tokens": jax.ShapeDtypeStruct((b, st), i32),
                    "labels": jax.ShapeDtypeStruct((b, st), i32),
                    "patch_embeds": jax.ShapeDtypeStruct(
                        (b, VLM_PATCHES, m.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}

    if kind == "prefill":
        if m.family == "audio":
            return {"tokens": jax.ShapeDtypeStruct((b, s, m.n_codebooks), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}

    # decode: one new token; cache length s
    if m.family == "audio":
        return {"token": jax.ShapeDtypeStruct((b, m.n_codebooks), i32)}
    return {"token": jax.ShapeDtypeStruct((b,), i32)}


__all__ = ["ARCH_NAMES", "VLM_PATCHES", "get_config", "cell_applicable",
           "all_cells", "input_specs", "SHAPES"]
