"""Int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick for bandwidth-bound DP: gradients are
quantized to int8 with a per-tensor scale before the data-parallel psum and
dequantized after; the quantization residual is kept locally and added back
the next step (error feedback keeps the scheme unbiased over time).

Implemented as a shard_map collective so it composes with the pjit train
step: ``compressed_psum`` is dropped in where a bf16/fp32 psum would be.
4x fewer bytes on the wire than fp32 (2x vs bf16).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _psum_one(g: jax.Array, residual: jax.Array, axis_names) -> tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32) + residual.astype(jnp.float32)
    q, scale = compress_int8(gf)
    new_residual = gf - decompress_int8(q, scale)
    # int8 summands would overflow int8; widen to int32 for the wire-level
    # reduction (XLA reduces in the widened type; bytes on the wire are the
    # int8 payload when the backend supports it -- semantics preserved here)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
    scale_sum = jax.lax.pmax(scale, axis_names)   # conservative shared scale
    return summed.astype(jnp.float32) * scale_sum, new_residual.astype(residual.dtype)


def compressed_psum(grads: Any, residuals: Any, mesh: Mesh,
                    axis_names: tuple[str, ...] = ("data",),
                    spec: P | None = None) -> tuple[Any, Any]:
    """psum `grads` over `axis_names` with int8 error feedback.

    grads/residuals: pytrees of per-device *local* gradient shards (i.e.
    call inside shard_map, or pass fully-replicated values).  Returns
    (summed grads fp32, new residuals).
    """
    def one(g, r):
        fn = shard_map(
            partial(_psum_one, axis_names=axis_names),
            mesh=mesh,
            in_specs=(spec or P(), spec or P()),
            out_specs=(spec or P(), spec or P()),
            check_rep=False)
        return fn(g, r)

    pairs = jax.tree.map(one, grads, residuals)
    summed = jax.tree.map(lambda t: t[0], pairs,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_res
