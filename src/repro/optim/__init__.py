"""Optimizers + schedules + gradient transforms (compression, clipping)."""

from .adamw import AdamWState, adamw_init, adamw_update
from .schedule import linear_warmup_cosine
from .compression import compress_int8, decompress_int8, compressed_psum

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "linear_warmup_cosine", "compress_int8", "decompress_int8",
           "compressed_psum"]
