"""AdamW with dtype-configurable moments (bf16 moments halve optimizer HBM
for the 314B config) and global-norm clipping.  Pure pytree functions --
the optimizer state shards exactly like the parameters (FSDP), giving
ZeRO-1/3 semantics for free under pjit.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: AdamWState, *,
                 lr: jax.Array | float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics).  Math in fp32; params and
    moments are cast back to their storage dtypes."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if grad_clip else jnp.float32(1.0)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        update = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
        pf = p.astype(jnp.float32) * (1.0 - lr * weight_decay) - lr * update
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
