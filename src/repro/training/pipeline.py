"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The layer stack is split into ``n_stages`` contiguous stages; stage s lives
on the mesh slice ``axis == s`` (stage-dim-sharded stacked params).
Microbatches stream through: at tick t, stage s computes microbatch
t - s (bubble at the ends -- the classic GPipe schedule), then activations
collective-permute to the next stage.

This composes with the other axes: on the (2,16,16) production mesh,
``axis="pod"`` gives 2 pipeline stages, each sharded FSDP x TP over
(data, model) within its pod -- inter-pod traffic becomes the activation
ppermute instead of FSDP all-gathers, which is the right trade when
inter-pod links are the slow tier (DCN).  See EXPERIMENTS.md §Perf.

API:
    y = pipeline_apply(stage_params, x, stage_fn, mesh,
                       axis="pod", n_microbatches=m)
where stage_params leaves are [n_stages, ...] and
``stage_fn(params_slice, x_mb) -> y_mb``.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_params: Any, x: jax.Array,
                   stage_fn: Callable[[Any, jax.Array], jax.Array],
                   mesh: Mesh, *, axis: str = "pod",
                   n_microbatches: int | None = None) -> jax.Array:
    """Run x [B, ...] through the staged computation; returns y [B, ...].

    Stage params: pytree with leading [n_stages] dim (sharded over `axis`).
    The batch is split into n_microbatches (default = n_stages) along dim 0.
    """
    n_stages = mesh.shape[axis]
    m = n_microbatches or n_stages
    b = x.shape[0]
    assert b % m == 0, (b, m)
    mb = b // m

    x_mb = x.reshape(m, mb, *x.shape[1:])

    def staged(params_local, x_local):
        # params_local: this stage's slice (leading dim 1); x_local: the
        # full microbatch stream (replicated over `axis`)
        params_s = jax.tree.map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        n_ticks = m + n_stages - 1
        size = jax.lax.psum(1, axis)  # == n_stages

        def tick(carry, t):
            buf = carry                     # [mb, ...] current activation
            # stage 0 injects microbatch t from the input stream
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = x_local[mb_idx]
            cur = jnp.where(stage_id == 0, inject, buf)
            out = stage_fn(params_s, cur)
            # pass to the next stage (ring; last stage's output wraps to 0
            # where it is ignored/collected)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(out, axis, perm)
            # the LAST stage's outputs are the pipeline outputs, valid for
            # ticks in [n_stages-1, n_ticks); collect them on every device
            # (cheap: one microbatch per tick)
            done = out  # stage-local; only last stage's is meaningful
            return nxt, done

        _, outs = jax.lax.scan(tick, jnp.zeros_like(x_local[0]),
                               jnp.arange(n_ticks))
        # outs: [n_ticks, mb, ...] per stage; select the last stage's ticks
        # [s-1 .. s-1+m) -- psum the masked stream so every stage returns
        # the same assembled output
        is_last = stage_id == (size - 1)
        valid = outs[n_stages - 1:n_stages - 1 + m]
        contrib = jnp.where(is_last, valid, jnp.zeros_like(valid))
        y = jax.lax.psum(contrib, axis)
        return y

    y_mb = shard_map(
        staged, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )(stage_params, x_mb)
    return y_mb.reshape(b, *y_mb.shape[2:])


def split_stages(params: Any, n_stages: int) -> Any:
    """Reshape stacked per-layer params [L, ...] -> [n_stages, L/n_stages, ...]."""
    def f(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree.map(f, params)
