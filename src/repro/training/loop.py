"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by the integration tests
via fault injection):

  * checkpoint/restart -- async CheckpointManager; on any step failure the
    loop restores the latest checkpoint and continues (bounded retries);
  * elastic restart    -- the step-indexed data pipeline + resharding
    restore let a resumed run continue on a *different* mesh;
  * straggler watch    -- EWMA of step wall-times; steps slower than
    ``straggler_factor`` x the running median are logged and counted, the
    hook a cluster scheduler uses to evict slow hosts;
  * preemption         -- SIGTERM triggers checkpoint-and-exit at the next
    step boundary (standard TPU preemption handling).
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable, Iterator

import jax

from ..checkpoint import CheckpointManager, latest_step
from .step import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    max_restarts: int = 3
    straggler_factor: float = 2.0
    log_every: int = 10
    handle_sigterm: bool = False


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 2.0
    window: int = 64
    times: list = dataclasses.field(default_factory=list)
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.factor * med:
                is_straggler = True
                self.flagged += 1
        self.times.append(dt)
        if len(self.times) > 4 * self.window:
            del self.times[:-self.window]
        return is_straggler


class TrainLoop:
    def __init__(self, step_fn: Callable, state: TrainState,
                 batch_fn: Callable[[int], Any], cfg: LoopConfig,
                 state_shardings: Any = None,
                 fault_hook: Callable[[int], None] | None = None,
                 log_fn: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.state = state
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook          # tests inject failures here
        self.log = log_fn
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      keep=cfg.keep_checkpoints)
        self.straggler = StragglerMonitor(cfg.straggler_factor)
        self.metrics_history: list[dict] = []
        self.restarts = 0
        self._preempted = False
        if cfg.handle_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, *_):
        self._preempted = True

    def _current_step(self) -> int:
        return int(jax.device_get(self.state.step))

    def _restore(self) -> None:
        """Restore the newest checkpoint (elastic: onto current shardings)."""
        self.state, step = self.ckpt.restore_latest(
            jax.tree.map(lambda x: x, self.state), self.state_shardings)
        self.log(f"[loop] restored checkpoint at step {step}")

    def run(self) -> TrainState:
        cfg = self.cfg
        step = self._current_step()
        if latest_step(cfg.checkpoint_dir) is not None and step == 0:
            self._restore()
            step = self._current_step()

        while step < cfg.total_steps:
            if self._preempted:
                self.log(f"[loop] SIGTERM: checkpointing at step {step} and exiting")
                self.ckpt.save_async(step, self.state)
                self.ckpt.wait()
                break
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.batch_fn(step)
                new_state, metrics = self.step_fn(self.state, batch)
                # materialize to surface async device errors inside the try
                loss = float(jax.device_get(metrics["loss"]))
            except Exception as e:  # noqa: BLE001 -- any step fault
                self.restarts += 1
                self.log(f"[loop] step {step} failed ({type(e).__name__}: {e}); "
                         f"restart {self.restarts}/{cfg.max_restarts}")
                if self.restarts > cfg.max_restarts:
                    raise
                if latest_step(cfg.checkpoint_dir) is not None:
                    self._restore()
                    step = self._current_step()
                continue

            self.state = new_state
            dt = time.perf_counter() - t0
            if self.straggler.observe(dt):
                self.log(f"[loop] straggler step {step}: {dt*1e3:.1f} ms "
                         f"(flagged {self.straggler.flagged} so far)")
            self.metrics_history.append(
                {"step": step, "loss": loss, "time_s": dt})
            if step % cfg.log_every == 0:
                self.log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            step += 1
            if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
                self.ckpt.save_async(step, self.state)

        self.ckpt.wait()
        return self.state
