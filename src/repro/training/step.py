"""The jitted train step: loss -> grad -> (optional microbatching,
compression) -> AdamW, with FSDP/TP shardings attached.

The step is built once per (model, mesh) and reused; donation of params +
optimizer state keeps peak HBM at ~1x state size.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..config import RunConfig
from ..distributed.sharding import (MeshContext, activation_spec,
                                    param_specs)
from ..models import ModelApi
from ..optim import adamw_init, adamw_update, linear_warmup_cosine
from ..optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(api: ModelApi, rng: jax.Array) -> TrainState:
    params = api.init(rng)
    opt = adamw_init(params, api.cfg.parallel.opt_state_dtype)
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))


def state_shardings(api: ModelApi, state: TrainState, ctx: MeshContext):
    pspecs = param_specs(state.params, ctx)
    to_shard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    params_sh = to_shard(pspecs)
    rep = NamedSharding(ctx.mesh, P())
    return TrainState(
        params=params_sh,
        opt=AdamWState(step=rep, m=params_sh, v=params_sh),
        step=rep)


def batch_shardings(api: ModelApi, batch_specs: dict, ctx: MeshContext):
    out = {}
    for k, v in batch_specs.items():
        kind = "tokens" if v.ndim == 2 else ("btd" if v.ndim == 3 else "btd")
        if k == "patch_embeds":
            kind = "btd"
        elif v.ndim == 3:   # audio [B, S, cb]
            kind = "btd"
        out[k] = NamedSharding(ctx.mesh, activation_spec(kind, ctx))
    return out


def build_train_step(api: ModelApi):
    """Returns step(state, batch) -> (state, metrics).  Pure function of
    explicit args -- jit/shard decisions happen at the call site
    (launcher/dryrun attach in_shardings + donation)."""
    cfg = api.cfg
    tr = cfg.train

    def lr_at(step):
        return linear_warmup_cosine(step, peak_lr=tr.lr,
                                    warmup_steps=tr.warmup_steps,
                                    total_steps=tr.total_steps)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss(p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def step_fn(state: TrainState, batch: dict):
        if tr.microbatches > 1:
            # gradient accumulation: split the batch along B and scan
            def slice_mb(i):
                return jax.tree.map(
                    lambda a: a.reshape(tr.microbatches,
                                        a.shape[0] // tr.microbatches,
                                        *a.shape[1:])[i], batch)

            def acc_body(carry, i):
                g_acc, loss_acc = carry
                loss, _, g = grads_of(state.params, slice_mb(i))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)),
                jnp.arange(tr.microbatches))
            grads = jax.tree.map(lambda g: g / tr.microbatches, grads)
            loss = loss / tr.microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(state.params, batch)

        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt,
            lr=lr_at(state.opt.step), b1=tr.b1, b2=tr.b2,
            weight_decay=tr.weight_decay, grad_clip=tr.grad_clip)
        metrics = {"loss": loss, **metrics, **opt_metrics,
                   "lr": lr_at(state.opt.step)}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step_fn


def jit_train_step(api: ModelApi, state_template: TrainState,
                   batch_specs: dict, ctx: MeshContext):
    """jit with explicit in/out shardings + state donation."""
    step_fn = build_train_step(api)
    st_sh = state_shardings(api, state_template, ctx)
    b_sh = batch_shardings(api, batch_specs, ctx)
    return jax.jit(step_fn,
                   in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,))
