"""Training substrate: step builder, fault-tolerant loop, straggler watch."""

from .step import TrainState, build_train_step, init_train_state
from .loop import TrainLoop, LoopConfig

__all__ = ["TrainState", "build_train_step", "init_train_state",
           "TrainLoop", "LoopConfig"]
