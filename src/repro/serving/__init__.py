"""Serving substrate: batched prefill/decode + sequence-parallel decode,
plus the simulated contention-aware batcher over the RASA chip model
(:mod:`repro.serving.simbatch` -- see ``docs/serving_sim.md``)."""

from .engine import (ServeSession, decode_state_shardings, jit_decode_step,
                     jit_prefill)
from .simbatch import (POLICIES, BatchReport, ServeRequest, model_trace,
                       run_batcher, skewed_trace, synthetic_trace)
from .sp_decode import sp_flash_decode

__all__ = ["ServeSession", "decode_state_shardings", "jit_decode_step",
           "jit_prefill", "sp_flash_decode",
           "POLICIES", "BatchReport", "ServeRequest", "run_batcher",
           "model_trace", "skewed_trace", "synthetic_trace"]
