"""Serving substrate: batched prefill/decode + sequence-parallel decode."""

from .engine import (ServeSession, decode_state_shardings, jit_decode_step,
                     jit_prefill)
from .sp_decode import sp_flash_decode

__all__ = ["ServeSession", "decode_state_shardings", "jit_decode_step",
           "jit_prefill", "sp_flash_decode"]
