"""Batched serving: jitted prefill + decode steps with cache shardings,
and a small session wrapper that serves batched requests (examples/serve_lm
drives it; tests check greedy decoding end-to-end).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.sharding import (MeshContext, activation_spec,
                                    kv_cache_spec, param_specs)
from ..models import ModelApi
from ..models.layers import KVCache
from ..models.ssm import SSMState


def decode_state_shardings(api: ModelApi, state: Any, ctx: MeshContext):
    """Shardings for a decode state pytree: KV caches via kv_cache_spec
    (with the leading stacking dim), SSM states batch-over-dp (or
    replicated when batch doesn't divide, e.g. long_500k batch=1),
    scalars replicated."""
    m = api.model
    mesh = ctx.mesh
    sp = ctx.parallel.sequence_parallel_decode
    dp = ctx.dp_axes
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def batch_axes(b):
        return dp if b % dp_size == 0 else None

    def spec_for(leaf):
        shape = leaf.shape
        if (leaf.ndim == 5 and m.n_kv_heads
                and shape[2] == m.n_kv_heads
                and shape[4] == m.resolved_head_dim):
            # stacked KV cache [L(or apps), B, Hkv, S, hd]
            base = kv_cache_spec(m.n_kv_heads, m.resolved_head_dim, ctx,
                                 sequence_parallel=sp)
            base = list(base) + [None] * (4 - len(base))
            if base[0] is not None and shape[1] % dp_size != 0:
                base[0] = None      # batch too small to shard
            return P(None, *base)
        if m.ssm is not None and leaf.ndim in (4, 5) and shape[0] == m.n_layers:
            # ssm state [L, B, H, P, N] / conv window [L, B, k-1, C]
            return P(None, batch_axes(shape[1]), *(None,) * (leaf.ndim - 2))
        return P()

    specs = jax.tree.map(spec_for, state)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def jit_prefill(api: ModelApi, ctx: MeshContext, state_template: Any):
    params_sh = _params_shardings(api, ctx)
    st_sh = decode_state_shardings(api, state_template, ctx)
    tok_sh = NamedSharding(ctx.mesh, activation_spec("tokens", ctx))
    return jax.jit(api.prefill,
                   in_shardings=(params_sh, tok_sh, st_sh),
                   out_shardings=(None, st_sh),
                   donate_argnums=(2,))


def jit_decode_step(api: ModelApi, ctx: MeshContext, state_template: Any):
    params_sh = _params_shardings(api, ctx)
    st_sh = decode_state_shardings(api, state_template, ctx)
    dp = ctx.dp_axes
    tok_sh = NamedSharding(
        ctx.mesh, P(dp) if api.model.family != "audio" else P(dp, None))
    return jax.jit(api.decode_step,
                   in_shardings=(params_sh, tok_sh, st_sh),
                   out_shardings=(None, st_sh),
                   donate_argnums=(2,))


def _params_shardings(api: ModelApi, ctx: MeshContext):
    # build from an eval_shape of init (no allocation)
    shapes = jax.eval_shape(api.init, jax.random.key(0))
    if api.cfg.parallel.serve_param_sharding == "tp":
        # inference layout: TP only -- no FSDP all-gathers per step
        import dataclasses as _dc
        ctx = MeshContext(mesh=ctx.mesh,
                          parallel=_dc.replace(ctx.parallel, fsdp=False))
    specs = param_specs(shapes, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class ServeSession:
    """Greedy batched decoding session (single-host friendly).

    Prefill and decode are staged through cached jitted step functions --
    one compilation per batch size, shared across every ``generate`` call
    of the session (the compiled-function cache is keyed on the batch
    size; ``max_seq`` is fixed per session).  Inside an active
    :func:`repro.distributed.sharding.mesh_context` the session uses the
    sharded :func:`jit_prefill` / :func:`jit_decode_step` wrappers
    (KV-cache shardings, donated state); outside one it falls back to
    plain ``jax.jit`` of the model api.
    """
    api: ModelApi
    params: Any
    max_seq: int = 128
    _compiled: dict = dataclasses.field(default_factory=dict, repr=False)

    def _fns(self, batch: int):
        """(prefill_fn, decode_fn) for this batch size, compiled once.

        Keyed on the active mesh context too: a session used both inside
        and outside ``mesh_context`` (or across different meshes) must not
        reuse functions compiled for the wrong sharding.
        """
        from ..distributed.sharding import current_ctx
        ctx = current_ctx()
        key = (batch, None) if ctx is None else \
            (batch, ctx.mesh, ctx.parallel)
        hit = self._compiled.get(key)
        if hit is not None:
            return hit
        if ctx is not None:
            template = jax.eval_shape(
                lambda: self.api.init_decode_state(batch, self.max_seq))
            fns = (jit_prefill(self.api, ctx, template),
                   jit_decode_step(self.api, ctx, template))
        else:
            fns = (jax.jit(self.api.prefill, donate_argnums=(2,)),
                   jax.jit(self.api.decode_step, donate_argnums=(2,)))
        self._compiled[key] = fns
        return fns

    def generate(self, prompts: jax.Array, steps: int) -> jax.Array:
        """prompts: [B, S] int32 -> generated tokens [B, steps]."""
        b = prompts.shape[0]
        prefill, decode = self._fns(b)
        state = self.api.init_decode_state(b, self.max_seq)
        logits, state = prefill(self.params, prompts, state)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(steps):
            outs.append(tok)
            logits, state = decode(self.params, tok, state)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.stack(outs, axis=1)
