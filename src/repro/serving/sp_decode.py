"""Sequence-parallel flash decode (shard_map) for 500k-context serving.

The KV cache is sharded along the *sequence* axis over "data"; each shard
computes local attention with a local logsumexp, and the shards are
combined with the numerically-exact flash-decoding reduction:

    out = sum_i exp(lse_i - lse) out_i,   lse = logsumexp_i(lse_i)

One psum of [B, H, D+2] per layer instead of gathering a 500k-long score
row (or worse, the cache) -- this is the collective-term optimization
recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _local_decode(q, k, v, start, lengths, scale):
    """q: [B,H,hd]; k/v: [B,H,Sl,hd] (local shard); start: scalar global
    offset of this shard; lengths: [B] valid global lengths.
    Returns (out [B,H,hd], lse [B,H])."""
    s_local = k.shape[2]
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    pos = start + jnp.arange(s_local)[None, None, :]
    mask = pos < lengths[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1)                          # [B,H]
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32))
    # locally-normalized output + logsumexp (guard fully-masked shards)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out, lse


def sp_flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    lengths: jax.Array, mesh: Mesh, *,
                    seq_axis: str = "data",
                    scale: float | None = None) -> jax.Array:
    """Decode attention over a sequence-sharded KV cache.

    q: [B, H, hd] (replicated over seq shards); caches [B, H, S, hd] sharded
    on S over `seq_axis`; lengths [B].  GQA expansion happens before the
    call.  Returns [B, H, hd].
    """
    b, h, hd = q.shape
    s = k_cache.shape[2]
    if scale is None:
        scale = hd ** -0.5
    n_shards = mesh.shape[seq_axis]
    s_local = s // n_shards

    def shard_fn(q_l, k_l, v_l, len_l):
        idx = jax.lax.axis_index(seq_axis)
        start = idx * s_local
        out, lse = _local_decode(q_l, k_l, v_l, start, len_l, scale)
        # flash-decoding combine across shards
        g_max = jax.lax.pmax(lse, seq_axis)
        g_max = jnp.where(jnp.isfinite(g_max), g_max, 0.0)
        w = jnp.exp(jnp.where(jnp.isfinite(lse), lse - g_max, -jnp.inf))
        num = jax.lax.psum(out * w[..., None], seq_axis)
        den = jax.lax.psum(w, seq_axis)
        return (num / jnp.maximum(den[..., None], 1e-30)).astype(q.dtype)

    # head sharding over model when divisible; sequence over `seq_axis`
    hm = "model" if ("model" in mesh.axis_names
                     and h % mesh.shape["model"] == 0) else None
    spec_q = P(None, hm, None)
    spec_kv = P(None, hm, seq_axis, None)
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(spec_q, spec_kv, spec_kv, P()),
                   out_specs=spec_q,
                   check_rep=False)
    return fn(q, k_cache, v_cache, lengths)
