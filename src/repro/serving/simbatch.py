"""Contention-aware serving batcher over the online chip model.

``repro.serving`` serves real tokens on real hardware; this module answers
the capacity-planning question next to it on the *simulated* RASA chip:
given a stream of serving requests -- each one prefill GEMM plus a chain of
decode micro-GEMMs, lowered through the same
:mod:`repro.core.tiling` register-aware compiler as everything else -- how
should requests be admitted into the chip so the shared memory system
sustains them?  Per-engine throughput is flat for batch 1..16 (paper
Fig. 7); at chip scale the binding resource is bandwidth, so batch
formation must see *chip* state, not a fixed batch knob.

Requests flow through :class:`repro.multicore.online.OnlineChip`: they
arrive at epoch boundaries, an **admission policy** decides at every
decision epoch (arrival or completion) which waiting requests enter the
chip and on which core, and admitted requests run to completion under the
epoch bandwidth arbiter.  Policies (:data:`POLICIES`):

``fixed``
    The classic static batcher and the baseline every aware policy must
    beat: admit requests in groups of ``batch_size`` the moment a full
    group is waiting (plus the final partial group once arrivals end),
    placed blind round-robin.  Sees neither occupancy nor bandwidth.
``bandwidth``
    Threshold admission: admit head-of-line requests only while the
    projected per-request bandwidth share ``budget / (n_active + k + 1)``
    stays at or above ``min_share``; placement on the soonest-free core
    (:func:`repro.multicore.scheduler.assign_incremental`).
``occupancy``
    Occupancy-aware: admit at most one request per *idle* core (never
    queues behind a busy engine), subject to the same bandwidth headroom
    check as ``bandwidth``.  This is the policy that sees both live chip
    signals.
``predicted``
    Predicted-occupancy: like ``occupancy``, but instead of reacting to
    cores that are idle *now* it forecasts departures from the online
    chip's settled share-schedule prefix -- a core whose settled work (and
    queued backlog estimate) drains within ``lookahead`` epochs counts as
    available, and the admitted request is queued so it starts at the
    exact boundary the core frees up, instead of waiting for the next
    decision epoch.  Never admits more than one request per predicted-free
    core, and subject to the same bandwidth headroom check.
``phase_aware``
    ``occupancy`` plus a cap of ``max_prefills`` concurrently *running*
    prefill-heavy requests (prefill >= half the request's MACs): decode
    work is latency-bound and cheap per epoch, prefill is a bandwidth
    storm -- letting every idle core start a prefill at once starves the
    decodes behind them.  Decode-heavy requests are admitted past waiting
    prefills (no head-of-line blocking across phases).
``degraded``
    Graceful degradation: ``occupancy`` while the chip is healthy; when
    measured headroom collapses (zero bandwidth headroom for another
    request, or a core is down under a fault plan) it sheds load by
    admitting only decode-heavy requests -- prefill-heavy work waits (and
    may time out and retry) instead of piling onto a saturated or
    shrunken chip and collapsing the queue for everyone.

Deadlines, retry and abandonment: a :class:`ServeRequest` may carry a
``deadline`` (cycles, per attempt, measured from the attempt's arrival).
A request still *waiting* when its deadline lapses is retried with
exponential backoff (re-arrival after ``backoff_epochs * 2**(attempt-1)``
epochs), up to ``max_attempts`` attempts, then **abandoned** (infinite
latency, excluded from the makespan).  An *admitted* request always runs
to completion; finishing past its deadline counts as a deadline miss.
:class:`BatchReport` reports ``deadline_miss_rate``, ``retries``,
``abandoned`` and ``goodput_macs_per_cycle`` (MACs of requests served
within their deadline, per makespan cycle) -- the metric the
fault-tolerance benchmark ranks policies by.

Work conservation: whenever the chip is completely idle and a
threshold policy (``bandwidth``/``occupancy``) declines every waiting
request, the head request is admitted anyway (a share floor must never
deadlock an idle chip); the forced request goes to the soonest-free core.
The ``fixed`` policy is exempt -- idling until a full group has arrived is
its defining behavior, and it cannot deadlock (the partial tail group is
flushed once arrivals end).

:func:`run_batcher` returns a :class:`BatchReport` with per-request
latencies (p50/p99), the makespan, and the admission timeline.  Results
are backend-independent (``reference``/``fast``/``numpy``); the parity
suite pins it.
"""

from __future__ import annotations

import dataclasses
import math
import random
from collections import deque
from typing import Sequence

import numpy as np

from ..core.fastsim import SNAP_STRIDE
from ..core.tiling import GemmSpec
from ..multicore.chip import ChipConfig
from ..multicore.online import OnlineChip
from ..multicore.scheduler import assign_incremental
from ..obs.config import OFF, TelemetryConfig

POLICIES = ("fixed", "bandwidth", "occupancy", "predicted", "phase_aware",
            "degraded")


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One serving request: a prefill phase plus its decode micro-GEMMs.

    ``arrival_epoch`` is the scheduling epoch at whose boundary the request
    enters the arrival queue.  Lowered onto one core as a single segment:
    decode steps of one request are sequentially dependent.  ``prefill``
    is one GEMM (the synthetic single-layer traces) or a tuple of GEMMs (a
    compiled model's per-layer prefill stream -- see :func:`model_trace`);
    ``decode`` likewise holds one GEMM per step, or the model's per-step
    GEMM chain flattened across steps.
    """

    name: str
    arrival_epoch: int
    prefill: GemmSpec | tuple[GemmSpec, ...]
    decode: tuple[GemmSpec, ...] = ()
    #: per-attempt service deadline in cycles, measured from the attempt's
    #: (re-)arrival; ``None`` -- the default -- means best-effort (never
    #: retried, never abandoned, never counted as a miss)
    deadline: float | None = None

    @property
    def specs(self) -> tuple[GemmSpec, ...]:
        pf = (self.prefill,) if isinstance(self.prefill, GemmSpec) \
            else tuple(self.prefill)
        return (*pf, *self.decode)

    @property
    def macs(self) -> int:
        return sum(s.macs for s in self.specs)

    @property
    def prefill_macs(self) -> int:
        pf = (self.prefill,) if isinstance(self.prefill, GemmSpec) \
            else tuple(self.prefill)
        return sum(s.macs for s in pf)

    @property
    def prefill_heavy(self) -> bool:
        """Prefill is at least half this request's MACs -- the phase the
        ``phase_aware`` cap and ``degraded`` shedding gate on."""
        return 2 * self.prefill_macs >= self.macs


def arrival_process(n_requests: int, seed: int, mean_gap: int,
                    prompt_lens: Sequence[int], decode_steps: Sequence[int]
                    ) -> tuple[tuple[int, int, int, int], ...]:
    """The shared ``(i, arrival_epoch, prompt, steps)`` draw sequence.

    One RNG arrival loop serves both :func:`synthetic_trace` and
    :func:`model_trace`: inter-arrival gaps uniform on ``[0, 2*mean_gap]``
    epochs (``mean_gap`` is the offered-load knob; smaller = heavier
    load), prompt lengths and decode-chain lengths drawn from the given
    menus.  A seed therefore produces the *same* arrival pattern in both
    trace builders -- only the per-request GEMM lowering differs.
    """
    rng = random.Random(seed)
    draws, epoch = [], 0
    for i in range(n_requests):
        if i:
            epoch += rng.randrange(0, 2 * mean_gap + 1)
        prompt = rng.choice(tuple(prompt_lens))
        steps = rng.choice(tuple(decode_steps))
        draws.append((i, epoch, prompt, steps))
    return tuple(draws)


def synthetic_trace(n_requests: int = 16, *, seed: int = 0,
                    mean_gap: int = 2, d_model: int = 512,
                    prompt_lens: Sequence[int] = (32, 64, 128),
                    decode_steps: Sequence[int] = (2, 4, 8),
                    decode_batch: int = 8) -> tuple[ServeRequest, ...]:
    """Deterministic synthetic request trace.

    Arrivals and shape draws come from :func:`arrival_process`.  Each
    request is ``prefill[M=prompt, K=N=d_model]`` followed by
    ``decode[M=decode_batch, K=N=d_model]`` per step -- the Fig. 7 shapes,
    one layer GEMM standing in for the model's layer stack.
    """
    reqs = []
    for i, epoch, prompt, steps in arrival_process(
            n_requests, seed, mean_gap, prompt_lens, decode_steps):
        prefill = GemmSpec(f"r{i}.prefill", M=prompt, K=d_model, N=d_model)
        decode = tuple(GemmSpec(f"r{i}.d{j}", M=decode_batch, K=d_model,
                                N=d_model) for j in range(steps))
        reqs.append(ServeRequest(f"r{i}", epoch, prefill, decode))
    return tuple(reqs)


def skewed_trace(d_model: int = 512, *, heavy_prompt: int = 512,
                 light_prompt: int = 32, n_heavy: int = 2,
                 n_light: int = 10,
                 decode_batch: int = 8) -> tuple[ServeRequest, ...]:
    """The canonical skewed 4-core trace (acceptance scenario).

    ``n_heavy`` prefill-heavy requests arrive first, then bursts of light
    decode-dominated requests.  Blind round-robin placement piles light
    requests behind the heavy ones while other cores drain dry;
    occupancy-aware admission routes them to idle engines.  The keyword
    knobs scale the trace down for oracle-backend (reference) test runs.
    """
    heavy = [ServeRequest(
        f"h{i}", 0,
        GemmSpec(f"h{i}.prefill", M=heavy_prompt, K=d_model, N=d_model),
        tuple(GemmSpec(f"h{i}.d{j}", M=decode_batch, K=d_model, N=d_model)
              for j in range(4))) for i in range(n_heavy)]
    light = [ServeRequest(
        f"l{i}", i // 2,
        GemmSpec(f"l{i}.prefill", M=light_prompt, K=d_model, N=d_model),
        tuple(GemmSpec(f"l{i}.d{j}", M=decode_batch, K=d_model, N=d_model)
              for j in range(2))) for i in range(n_light)]
    return tuple(heavy + light)


def model_trace(arch, n_requests: int = 16, *, seed: int = 0,
                mean_gap: int = 2, prompt_lens: Sequence[int] = (32, 64, 128),
                decode_steps: Sequence[int] = (2, 4, 8),
                decode_batch: int = 1,
                options=None) -> tuple[ServeRequest, ...]:
    """Request trace whose GEMMs come from a compiled model, not synthetic
    shapes.

    The real-model analogue of :func:`synthetic_trace`: same arrival
    process and menu knobs, but each request's prefill is the model's
    compiled per-layer prefill stream at its prompt length, and each decode
    step is the compiled decode stream at ``decode_batch`` (one compile per
    distinct ``(prompt, steps)`` point -- decode steps share specs by
    construction, so the trace compiler lowers each distinct shape once no
    matter the request count).  ``arch`` is a ``repro.configs`` name or a
    :class:`repro.config.ModelConfig`; ``options`` defaults to the capped
    two-layer projection lowering that keeps oracle-backend runs feasible.
    """
    from ..workload.compile import CompileOptions, compile_workload
    if options is None:
        options = CompileOptions(dim_cap=1024, max_layers=2)
    name = arch if isinstance(arch, str) else arch.name
    reqs = []
    for i, epoch, prompt, steps in arrival_process(
            n_requests, seed, mean_gap, prompt_lens, decode_steps):
        prefill = compile_workload(arch, batch=1, seq=prompt,
                                   phase="prefill", options=options).specs
        step = compile_workload(arch, batch=decode_batch, seq=prompt,
                                phase="decode", options=options).specs
        reqs.append(ServeRequest(f"{name}.r{i}", epoch, prefill,
                                 step * steps))
    return tuple(reqs)


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """Outcome of one batched-serving run (cf. ChipReport).

    Per-request arrays (``latencies``/``finish_times``/...) are in the
    caller's submission order, ``names[i]`` identifying request *i*.
    """

    policy: str
    design: str
    n_cores: int
    n_requests: int
    epoch_cycles: float
    makespan: float                     # cycles, first arrival to last retire
    names: tuple[str, ...]
    latencies: tuple[float, ...]        # finish - arrival, per request
    finish_times: tuple[float, ...]
    arrival_epochs: tuple[int, ...]
    admit_epochs: tuple[int, ...]       # when each request entered the chip
    macs: int
    #: (late-served + abandoned) / n_requests; 0.0 when no request carries
    #: a deadline
    deadline_miss_rate: float = 0.0
    #: waiting-timeout retries across all requests (each re-arrival after
    #: exponential backoff counts once)
    retries: int = 0
    #: requests that exhausted ``max_attempts`` without being admitted --
    #: their latency/finish is ``inf`` and they are excluded from the
    #: makespan
    abandoned: int = 0
    #: MACs of requests served within their deadline (all served MACs when
    #: no deadlines are set; abandoned requests never count)
    served_macs: int = 0
    #: :class:`repro.obs.timeline.ChipTelemetry` when the run was made with
    #: ``telemetry=TelemetryConfig(enabled=True)``; excluded from equality
    #: (reports with and without telemetry compare by the numbers above)
    telemetry: object | None = dataclasses.field(default=None, compare=False)
    #: why a ``backend="jax"`` run fell back to the incremental client
    #: (one of ``repro.multicore.jitarb.GATE_REASONS``) -- ``None`` when
    #: the run took the jitted whole-trace path or never tried it;
    #: diagnostic only, excluded from equality like ``telemetry``
    jit_gate: str | None = dataclasses.field(default=None, compare=False)

    @property
    def attribution(self):
        """Per-core stall attribution (None without telemetry)."""
        return self.telemetry.attribution if self.telemetry else None

    def latency_percentile(self, q: float) -> float:
        """Linear-interpolated percentile of the request latencies."""
        if not self.latencies:
            return 0.0
        return float(np.percentile(self.latencies, q))

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / len(self.latencies) \
            if self.latencies else 0.0

    @property
    def throughput_macs_per_cycle(self) -> float:
        return self.macs / self.makespan if self.makespan else 0.0

    @property
    def goodput_macs_per_cycle(self) -> float:
        """Within-deadline MACs per makespan cycle -- equals throughput on
        a deadline-free run, and the metric the fault-tolerance benchmark
        ranks admission policies by."""
        return self.served_macs / self.makespan if self.makespan else 0.0


class _Pending:
    """A logical request waiting for admission: its current attempt's
    (re-)arrival epoch and how many attempts it has made so far."""

    __slots__ = ("req", "arrival", "attempts")

    def __init__(self, req: ServeRequest, arrival: int,
                 attempts: int = 1):
        self.req = req
        self.arrival = arrival
        self.attempts = attempts


class _Batcher:
    """One admission-policy run over an arrival trace (driver state)."""

    def __init__(self, requests: Sequence[ServeRequest], chip: ChipConfig,
                 policy: str, batch_size: int, min_share: float,
                 snap_stride: int, lookahead: int = 1,
                 prefix_cache: bool = True,
                 telemetry: TelemetryConfig = OFF,
                 max_attempts: int = 3, backoff_epochs: int = 1,
                 max_prefills: int = 1):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"available: {POLICIES}")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if lookahead < 0:
            raise ValueError("lookahead must be >= 0")
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if backoff_epochs < 0:
            raise ValueError("backoff_epochs must be >= 0")
        if max_prefills < 1:
            raise ValueError("max_prefills must be >= 1")
        self.chip = chip
        self.policy = policy
        self.batch_size = batch_size
        self.min_share = min_share
        self.lookahead = lookahead
        self.telemetry = telemetry
        self.max_attempts = max_attempts
        self.backoff_epochs = backoff_epochs
        self.max_prefills = max_prefills
        self.submitted = list(requests)     # caller order, for the report
        self.requests = sorted(requests, key=lambda r: r.arrival_epoch)
        self.sim = OnlineChip(chip, snap_stride=snap_stride,
                              prefix_cache=prefix_cache,
                              telemetry=telemetry)
        self.waiting: deque[_Pending] = deque()
        self.next_arrival = 0               # index into self.requests
        self.segments: dict[str, object] = {}
        self.admit_epochs: dict[str, int] = {}
        self._rr = 0                        # fixed policy's blind pointer
        # -- deadline / retry state (all inert without deadlines) --
        self._deadlines = any(r.deadline is not None for r in requests)
        #: backoff re-arrivals not yet due, as (epoch, seq, record) --
        #: ``seq`` makes equal-epoch ordering deterministic
        self.retry: list[tuple[int, int, _Pending]] = []
        self._rseq = 0
        self.abandoned_names: set[str] = set()
        self.n_retries = 0
        #: (epoch, label) retry/abandon instants for the telemetry marks
        self.events: list[tuple[int, str]] = []
        #: arrival epoch of the attempt that was finally admitted (the
        #: point deadline misses of served requests are measured from)
        self.attempt_arrival: dict[str, int] = {}
        #: admitted prefill-heavy segments (phase_aware cap accounting)
        self._pf_segs: list = []

    # -- admission ---------------------------------------------------------
    def _headroom(self) -> int:
        """How many more requests fit before the projected per-request
        share drops below ``min_share`` (conservative: counts currently
        active segments plus the admissions of this decision epoch)."""
        if self.min_share <= 0:
            return len(self.waiting)
        n_act = self.sim.n_active()
        budget = self.chip.bw_bytes_per_cycle
        k = 0
        while (k < len(self.waiting)
               and budget / (n_act + k + 1) >= self.min_share):
            k += 1
        return k

    def _active_prefills(self) -> int:
        """Admitted prefill-heavy requests still running right now
        (following preemption-resume chains; queued resumes count as
        running)."""
        now = self.sim.epoch * self.chip.epoch_cycles
        alive = []
        for seg in self._pf_segs:
            seg = self.sim.final_instance(seg)
            if (seg.span is None or seg.result is None
                    or self.sim.finish_time(seg) > now):
                alive.append(seg)
        self._pf_segs = alive
        return len(alive)

    def _take_waiting(self, picks: Sequence[int],
                      free_cores: Sequence[int]
                      ) -> list[tuple[_Pending, int]]:
        """Remove the picked waiting records (by index) and place them on
        the free cores in order."""
        out = [(self.waiting[i], free_cores[j])
               for j, i in enumerate(picks)]
        for i in reversed(picks):
            del self.waiting[i]
        return out

    def _admit(self) -> list[tuple[_Pending, int]]:
        """The policy's admissions for the current epoch: (record, core)."""
        sim, waiting = self.sim, self.waiting
        n_cores = self.chip.n_cores
        if self.policy == "fixed":
            out = []
            drained = self.next_arrival >= len(self.requests)
            while (len(waiting) >= self.batch_size
                   or (drained and waiting)):
                for _ in range(min(self.batch_size, len(waiting))):
                    out.append((waiting.popleft(), self._rr % n_cores))
                    self._rr += 1
            return out
        take = min(len(waiting), self._headroom())
        if self.policy == "occupancy":
            free_cores = [c for c, busy in enumerate(sim.core_busy())
                          if not busy]
            take = min(take, len(free_cores))
            return [(waiting.popleft(), free_cores[i]) for i in range(take)]
        if self.policy == "phase_aware":
            free_cores = [c for c, busy in enumerate(sim.core_busy())
                          if not busy]
            limit = min(take, len(free_cores))
            pf_slots = self.max_prefills - self._active_prefills()
            picks: list[int] = []
            for i, rec in enumerate(waiting):
                if len(picks) >= limit:
                    break
                if rec.req.prefill_heavy:
                    if pf_slots <= 0:
                        continue    # decode work may pass the waiting prefill
                    pf_slots -= 1
                picks.append(i)
            return self._take_waiting(picks, free_cores)
        if self.policy == "degraded":
            free_cores = [c for c, busy in enumerate(sim.core_busy())
                          if not busy]
            shed = any(sim.down_cores) or self._headroom() == 0
            if not shed:
                take = min(take, len(free_cores))
                return [(waiting.popleft(), free_cores[i])
                        for i in range(take)]
            # headroom collapsed (or the chip shrank): decode-heavy only,
            # one per idle core, past the bandwidth floor -- decode traffic
            # is light and keeping it flowing is what preserves goodput
            picks = [i for i, rec in enumerate(waiting)
                     if not rec.req.prefill_heavy][:len(free_cores)]
            return self._take_waiting(picks, free_cores)
        if self.policy == "predicted":
            # forecast from the settled schedule: a core whose settled
            # work + queued backlog drains within the lookahead window is
            # available -- its admitted request starts at the exact
            # boundary it frees up, one decision epoch earlier than the
            # reactive occupancy policy can manage
            horizon = (sim.epoch + self.lookahead) * self.chip.epoch_cycles
            free_at = sim.free_at_estimate()
            soon = sorted((c for c in range(n_cores)
                           if free_at[c] <= horizon),
                          key=lambda c: free_at[c])
            take = min(take, len(soon))
            return [(waiting.popleft(), soon[i]) for i in range(take)]
        # bandwidth: headroom-gated, placed on the soonest-free core
        recs = [waiting.popleft() for _ in range(take)]
        return self._soonest_free(recs)

    def _soonest_free(self, recs: Sequence[_Pending]
                      ) -> list[tuple[_Pending, int]]:
        # one freshly-built list per request: items are distinct objects by
        # construction, so identity maps them back to their request even
        # when two requests have equal GEMM shapes
        items = [list(rec.req.specs) for rec in recs]
        by_item = {id(item): rec for item, rec in zip(items, recs)}
        placement = assign_incremental(items, self.chip,
                                       self.sim.free_at_estimate())
        out = []
        for core, placed in enumerate(placement):
            for item in placed:
                out.append((by_item[id(item)], core))
        return out

    # -- deadlines: waiting-expiry, backoff, abandonment -------------------
    def _expire(self, t: int) -> None:
        """Time out waiting attempts whose deadline lapsed: re-enqueue
        with exponential backoff, or abandon past ``max_attempts``."""
        E = self.chip.epoch_cycles
        kept: deque[_Pending] = deque()
        for rec in self.waiting:
            dl = rec.req.deadline
            if dl is None or (t - rec.arrival) * E <= dl:
                kept.append(rec)
            elif rec.attempts >= self.max_attempts:
                self.abandoned_names.add(rec.req.name)
                self.events.append((t, f"abandon {rec.req.name}"))
            else:
                delay = self.backoff_epochs * (2 ** (rec.attempts - 1))
                rec.attempts += 1
                rec.arrival = t + delay
                self.n_retries += 1
                self._rseq += 1
                self.retry.append((rec.arrival, self._rseq, rec))
                self.events.append((t, f"retry {rec.req.name}"))
        self.waiting = kept

    def _next_expiry(self) -> int | None:
        """First epoch at which some waiting attempt's deadline lapses
        (a decision-epoch candidate: expiry changes batcher state even
        when the chip does nothing)."""
        if not self._deadlines:
            return None
        E = self.chip.epoch_cycles
        out = None
        for rec in self.waiting:
            dl = rec.req.deadline
            if dl is None:
                continue
            e = math.floor((rec.arrival * E + dl) / E) + 1
            out = e if out is None else min(out, e)
        return out

    # -- driver ------------------------------------------------------------
    def run(self) -> BatchReport:
        sim = self.sim
        E = self.chip.epoch_cycles
        if self.requests:
            t = self.requests[0].arrival_epoch
            while (self.next_arrival < len(self.requests) or self.waiting
                   or self.retry):
                sim.advance_to(t)
                while (self.next_arrival < len(self.requests)
                       and self.requests[self.next_arrival].arrival_epoch
                       <= t):
                    r = self.requests[self.next_arrival]
                    self.waiting.append(_Pending(r, r.arrival_epoch))
                    self.next_arrival += 1
                if self.retry:
                    due = sorted(x for x in self.retry if x[0] <= t)
                    if due:
                        self.retry = [x for x in self.retry if x[0] > t]
                        for _, _, rec in due:
                            self.waiting.append(rec)
                if self._deadlines:
                    self._expire(t)
                admitted = self._admit()
                if (not admitted and self.waiting
                        and self.policy != "fixed"
                        and not any(sim.core_busy())):
                    # work conservation: a threshold policy must not
                    # starve a waiting request on an idle chip.  The
                    # fixed policy is exempt -- waiting for a full group
                    # is its defining (and deadlock-free) behavior.
                    admitted = self._soonest_free([self.waiting.popleft()])
                segs = sim.submit_batch([(core, rec.req.specs)
                                         for rec, core in admitted])
                for (rec, _), seg in zip(admitted, segs):
                    self.segments[rec.req.name] = seg
                    self.admit_epochs[rec.req.name] = t
                    self.attempt_arrival[rec.req.name] = rec.arrival
                    if (self.policy == "phase_aware"
                            and rec.req.prefill_heavy):
                        self._pf_segs.append(seg)
                cands = []
                if self.next_arrival < len(self.requests):
                    cands.append(
                        self.requests[self.next_arrival].arrival_epoch)
                if self.retry:
                    cands.append(min(x[0] for x in self.retry))
                if self.waiting:
                    nxt = sim.next_event()
                    if nxt is not None:
                        cands.append(nxt)
                    exp = self._next_expiry()
                    if exp is not None:
                        cands.append(exp)
                if not cands:
                    break
                t = min(cands)
            sim.drain()
        reqs = self.submitted
        finishes: list[float] = []
        latencies: list[float] = []
        missed = 0
        served_macs = 0
        for r in reqs:
            seg = self.segments.get(r.name)
            if seg is None:
                # abandoned without ever being admitted
                finishes.append(math.inf)
                latencies.append(math.inf)
                missed += 1
                continue
            f = sim.finish_time(sim.final_instance(seg))
            finishes.append(f)
            latencies.append(f - r.arrival_epoch * E)
            if (r.deadline is not None
                    and f - self.attempt_arrival[r.name] * E > r.deadline):
                missed += 1     # admitted, but retired past the deadline
            else:
                served_macs += r.macs
        first = min((r.arrival_epoch for r in reqs), default=0) * E
        finite = [f for f in finishes if not math.isinf(f)]
        tele = None
        if self.telemetry.enabled:
            from ..obs.timeline import build_online_telemetry
            names = {}
            for name, seg in self.segments.items():
                names[seg.sid] = name                # type: ignore[attr-defined]
                while seg.preempted_at is not None:  # type: ignore[attr-defined]
                    seg = sim.resume_of(seg)
                    names[seg.sid] = name
            marks = [(r.arrival_epoch * E, f"arrive {r.name}")
                     for r in reqs]
            marks += [(self.admit_epochs[r.name] * E, f"admit {r.name}")
                      for r in reqs if r.name in self.admit_epochs]
            marks += [(e * E, label) for e, label in self.events]
            tele = build_online_telemetry(sim, self.telemetry, names=names,
                                          marks=marks)
        return BatchReport(
            policy=self.policy,
            design=self.chip.design_name,
            n_cores=self.chip.n_cores,
            n_requests=len(reqs),
            epoch_cycles=E,
            makespan=max(finite, default=first) - first,
            names=tuple(r.name for r in reqs),
            latencies=tuple(latencies),
            finish_times=tuple(finishes),
            arrival_epochs=tuple(r.arrival_epoch for r in reqs),
            admit_epochs=tuple(self.admit_epochs.get(r.name, -1)
                               for r in reqs),
            macs=sum(r.macs for r in reqs),
            deadline_miss_rate=missed / len(reqs) if reqs else 0.0,
            retries=self.n_retries,
            abandoned=len(self.abandoned_names),
            served_macs=served_macs,
            telemetry=tele,
        )


def run_batcher(requests: Sequence[ServeRequest],
                chip: ChipConfig | None = None, *,
                policy: str = "occupancy", batch_size: int = 4,
                min_share: float | None = None,
                snap_stride: int = SNAP_STRIDE,
                lookahead: int = 1,
                prefix_cache: bool = True,
                telemetry: TelemetryConfig = OFF,
                max_attempts: int = 3,
                backoff_epochs: int = 1,
                max_prefills: int = 1,
                **chip_kwargs) -> BatchReport:
    """Serve an arrival trace through the online chip model.

    ``min_share`` (bytes/cycle) is the bandwidth-headroom floor of the
    threshold policies (``bandwidth``/``occupancy``/``predicted``); the
    default admits up to two concurrent requests per core before
    throttling admission.  ``lookahead`` (epochs) is the ``predicted``
    policy's departure-forecast window.  ``prefix_cache=False`` runs the
    online arbiter in its rebuild-from-epoch-0 baseline mode (identical
    results, linearly more work -- the ``benchmarks/online_scaling.py``
    comparison).  ``telemetry=TelemetryConfig(enabled=True)`` attaches a
    full :class:`repro.obs.timeline.ChipTelemetry` to the report (see
    ``docs/observability.md``).  ``max_attempts``/``backoff_epochs`` bound
    the deadline retry loop and ``max_prefills`` is the ``phase_aware``
    concurrent-prefill cap (all three inert without deadlines or that
    policy; see ``docs/resilience.md``).  Extra keyword arguments
    construct the :class:`ChipConfig` when none is given (cf.
    :func:`repro.multicore.simulate_chip`).
    """
    if chip is None:
        chip = ChipConfig(**chip_kwargs)
    elif chip_kwargs:
        raise TypeError(f"pass either a ChipConfig or config kwargs, not "
                        f"both: {sorted(chip_kwargs)}")
    if min_share is None:
        min_share = chip.bw_bytes_per_cycle / (2.0 * chip.n_cores)
    names = [r.name for r in requests]
    if len(set(names)) != len(names):
        raise ValueError("request names must be unique")
    jit_gate = None
    if (prefix_cache and not telemetry.enabled and chip.backend == "jax"
            and requests and all(r.deadline is None for r in requests)):
        # whole-trace fast lane: one jitted program replays the full
        # arbitration -- admission decisions included (see
        # repro.multicore.jitarb; bit-identical to the incremental
        # client, pinned by tests/test_online_jax.py).  plan_ex gates
        # and explains configurations the program cannot replay.
        from ..multicore import jitarb
        plan, jit_gate = jitarb.plan_ex(
            [(r.arrival_epoch, r.specs) for r in requests], chip,
            policy=policy, batch_size=batch_size, min_share=min_share,
            lookahead=lookahead)
        if plan is not None:
            fins, adm = jitarb.finish_admit_times(plan)
            return report_from_finishes(requests, chip, fins,
                                        policy=policy, admit_epochs=adm)
    report = _Batcher(requests, chip, policy, batch_size, min_share,
                      snap_stride, lookahead, prefix_cache, telemetry,
                      max_attempts, backoff_epochs, max_prefills).run()
    if jit_gate is not None:
        report = dataclasses.replace(report, jit_gate=jit_gate)
    return report


def report_from_finishes(requests: Sequence[ServeRequest],
                         chip: ChipConfig,
                         finishes: Sequence[float], *,
                         policy: str = "fixed",
                         admit_epochs: Sequence[float] | None = None
                         ) -> BatchReport:
    """Assemble a :class:`BatchReport` from absolute finish cycles in
    caller order -- the jitted whole-trace arbitration
    (:mod:`repro.multicore.jitarb`) returns finish cycles and admit
    epochs, and every other report field is a closed form of the inputs
    on its domain (no deadlines: every request is served within deadline
    by definition, and under ``fixed``@1 admission -- the default when
    ``admit_epochs`` is omitted -- each is admitted at its arrival)."""
    E = chip.epoch_cycles
    fins = tuple(float(f) for f in finishes)
    first = min((r.arrival_epoch for r in requests), default=0) * E
    macs = sum(r.macs for r in requests)
    if admit_epochs is None:
        admit_epochs = tuple(r.arrival_epoch for r in requests)
    else:
        admit_epochs = tuple(float(a) for a in admit_epochs)
    return BatchReport(
        policy=policy,
        design=chip.design_name,
        n_cores=chip.n_cores,
        n_requests=len(requests),
        epoch_cycles=E,
        makespan=max(fins, default=first) - first,
        names=tuple(r.name for r in requests),
        latencies=tuple(f - r.arrival_epoch * E
                        for r, f in zip(requests, fins)),
        finish_times=fins,
        arrival_epochs=tuple(r.arrival_epoch for r in requests),
        admit_epochs=tuple(admit_epochs),
        macs=macs,
        deadline_miss_rate=0.0,
        retries=0,
        abandoned=0,
        served_macs=macs,
        telemetry=None,
    )
