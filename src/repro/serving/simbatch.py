"""Contention-aware serving batcher over the online chip model.

``repro.serving`` serves real tokens on real hardware; this module answers
the capacity-planning question next to it on the *simulated* RASA chip:
given a stream of serving requests -- each one prefill GEMM plus a chain of
decode micro-GEMMs, lowered through the same
:mod:`repro.core.tiling` register-aware compiler as everything else -- how
should requests be admitted into the chip so the shared memory system
sustains them?  Per-engine throughput is flat for batch 1..16 (paper
Fig. 7); at chip scale the binding resource is bandwidth, so batch
formation must see *chip* state, not a fixed batch knob.

Requests flow through :class:`repro.multicore.online.OnlineChip`: they
arrive at epoch boundaries, an **admission policy** decides at every
decision epoch (arrival or completion) which waiting requests enter the
chip and on which core, and admitted requests run to completion under the
epoch bandwidth arbiter.  Policies (:data:`POLICIES`):

``fixed``
    The classic static batcher and the baseline every aware policy must
    beat: admit requests in groups of ``batch_size`` the moment a full
    group is waiting (plus the final partial group once arrivals end),
    placed blind round-robin.  Sees neither occupancy nor bandwidth.
``bandwidth``
    Threshold admission: admit head-of-line requests only while the
    projected per-request bandwidth share ``budget / (n_active + k + 1)``
    stays at or above ``min_share``; placement on the soonest-free core
    (:func:`repro.multicore.scheduler.assign_incremental`).
``occupancy``
    Occupancy-aware: admit at most one request per *idle* core (never
    queues behind a busy engine), subject to the same bandwidth headroom
    check as ``bandwidth``.  This is the policy that sees both live chip
    signals.
``predicted``
    Predicted-occupancy: like ``occupancy``, but instead of reacting to
    cores that are idle *now* it forecasts departures from the online
    chip's settled share-schedule prefix -- a core whose settled work (and
    queued backlog estimate) drains within ``lookahead`` epochs counts as
    available, and the admitted request is queued so it starts at the
    exact boundary the core frees up, instead of waiting for the next
    decision epoch.  Never admits more than one request per predicted-free
    core, and subject to the same bandwidth headroom check.

Work conservation: whenever the chip is completely idle and a
threshold policy (``bandwidth``/``occupancy``) declines every waiting
request, the head request is admitted anyway (a share floor must never
deadlock an idle chip); the forced request goes to the soonest-free core.
The ``fixed`` policy is exempt -- idling until a full group has arrived is
its defining behavior, and it cannot deadlock (the partial tail group is
flushed once arrivals end).

:func:`run_batcher` returns a :class:`BatchReport` with per-request
latencies (p50/p99), the makespan, and the admission timeline.  Results
are backend-independent (``reference``/``fast``/``numpy``); the parity
suite pins it.
"""

from __future__ import annotations

import dataclasses
import random
from collections import deque
from typing import Sequence

import numpy as np

from ..core.fastsim import SNAP_STRIDE
from ..core.tiling import GemmSpec
from ..multicore.chip import ChipConfig
from ..multicore.online import OnlineChip
from ..multicore.scheduler import assign_incremental
from ..obs.config import OFF, TelemetryConfig

POLICIES = ("fixed", "bandwidth", "occupancy", "predicted")


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One serving request: a prefill phase plus its decode micro-GEMMs.

    ``arrival_epoch`` is the scheduling epoch at whose boundary the request
    enters the arrival queue.  Lowered onto one core as a single segment:
    decode steps of one request are sequentially dependent.  ``prefill``
    is one GEMM (the synthetic single-layer traces) or a tuple of GEMMs (a
    compiled model's per-layer prefill stream -- see :func:`model_trace`);
    ``decode`` likewise holds one GEMM per step, or the model's per-step
    GEMM chain flattened across steps.
    """

    name: str
    arrival_epoch: int
    prefill: GemmSpec | tuple[GemmSpec, ...]
    decode: tuple[GemmSpec, ...] = ()

    @property
    def specs(self) -> tuple[GemmSpec, ...]:
        pf = (self.prefill,) if isinstance(self.prefill, GemmSpec) \
            else tuple(self.prefill)
        return (*pf, *self.decode)

    @property
    def macs(self) -> int:
        return sum(s.macs for s in self.specs)


def synthetic_trace(n_requests: int = 16, *, seed: int = 0,
                    mean_gap: int = 2, d_model: int = 512,
                    prompt_lens: Sequence[int] = (32, 64, 128),
                    decode_steps: Sequence[int] = (2, 4, 8),
                    decode_batch: int = 8) -> tuple[ServeRequest, ...]:
    """Deterministic synthetic request trace.

    Inter-arrival gaps are uniform on ``[0, 2 * mean_gap]`` epochs, so
    ``mean_gap`` is the offered-load knob (smaller = heavier load); prompt
    lengths and decode-chain lengths are drawn from the given menus.  Each
    request is ``prefill[M=prompt, K=N=d_model]`` followed by
    ``decode[M=decode_batch, K=N=d_model]`` per step -- the Fig. 7 shapes,
    one layer GEMM standing in for the model's layer stack.
    """
    rng = random.Random(seed)
    reqs, epoch = [], 0
    for i in range(n_requests):
        if i:
            epoch += rng.randrange(0, 2 * mean_gap + 1)
        prompt = rng.choice(tuple(prompt_lens))
        steps = rng.choice(tuple(decode_steps))
        prefill = GemmSpec(f"r{i}.prefill", M=prompt, K=d_model, N=d_model)
        decode = tuple(GemmSpec(f"r{i}.d{j}", M=decode_batch, K=d_model,
                                N=d_model) for j in range(steps))
        reqs.append(ServeRequest(f"r{i}", epoch, prefill, decode))
    return tuple(reqs)


def skewed_trace(d_model: int = 512, *, heavy_prompt: int = 512,
                 light_prompt: int = 32, n_heavy: int = 2,
                 n_light: int = 10,
                 decode_batch: int = 8) -> tuple[ServeRequest, ...]:
    """The canonical skewed 4-core trace (acceptance scenario).

    ``n_heavy`` prefill-heavy requests arrive first, then bursts of light
    decode-dominated requests.  Blind round-robin placement piles light
    requests behind the heavy ones while other cores drain dry;
    occupancy-aware admission routes them to idle engines.  The keyword
    knobs scale the trace down for oracle-backend (reference) test runs.
    """
    heavy = [ServeRequest(
        f"h{i}", 0,
        GemmSpec(f"h{i}.prefill", M=heavy_prompt, K=d_model, N=d_model),
        tuple(GemmSpec(f"h{i}.d{j}", M=decode_batch, K=d_model, N=d_model)
              for j in range(4))) for i in range(n_heavy)]
    light = [ServeRequest(
        f"l{i}", i // 2,
        GemmSpec(f"l{i}.prefill", M=light_prompt, K=d_model, N=d_model),
        tuple(GemmSpec(f"l{i}.d{j}", M=decode_batch, K=d_model, N=d_model)
              for j in range(2))) for i in range(n_light)]
    return tuple(heavy + light)


def model_trace(arch, n_requests: int = 16, *, seed: int = 0,
                mean_gap: int = 2, prompt_lens: Sequence[int] = (32, 64, 128),
                decode_steps: Sequence[int] = (2, 4, 8),
                decode_batch: int = 1,
                options=None) -> tuple[ServeRequest, ...]:
    """Request trace whose GEMMs come from a compiled model, not synthetic
    shapes.

    The real-model analogue of :func:`synthetic_trace`: same arrival
    process and menu knobs, but each request's prefill is the model's
    compiled per-layer prefill stream at its prompt length, and each decode
    step is the compiled decode stream at ``decode_batch`` (one compile per
    distinct ``(prompt, steps)`` point -- decode steps share specs by
    construction, so the trace compiler lowers each distinct shape once no
    matter the request count).  ``arch`` is a ``repro.configs`` name or a
    :class:`repro.config.ModelConfig`; ``options`` defaults to the capped
    two-layer projection lowering that keeps oracle-backend runs feasible.
    """
    from ..workload.compile import CompileOptions, compile_workload
    if options is None:
        options = CompileOptions(dim_cap=1024, max_layers=2)
    name = arch if isinstance(arch, str) else arch.name
    rng = random.Random(seed)
    reqs, epoch = [], 0
    for i in range(n_requests):
        if i:
            epoch += rng.randrange(0, 2 * mean_gap + 1)
        prompt = rng.choice(tuple(prompt_lens))
        steps = rng.choice(tuple(decode_steps))
        prefill = compile_workload(arch, batch=1, seq=prompt,
                                   phase="prefill", options=options).specs
        step = compile_workload(arch, batch=decode_batch, seq=prompt,
                                phase="decode", options=options).specs
        reqs.append(ServeRequest(f"{name}.r{i}", epoch, prefill,
                                 step * steps))
    return tuple(reqs)


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """Outcome of one batched-serving run (cf. ChipReport).

    Per-request arrays (``latencies``/``finish_times``/...) are in the
    caller's submission order, ``names[i]`` identifying request *i*.
    """

    policy: str
    design: str
    n_cores: int
    n_requests: int
    epoch_cycles: float
    makespan: float                     # cycles, first arrival to last retire
    names: tuple[str, ...]
    latencies: tuple[float, ...]        # finish - arrival, per request
    finish_times: tuple[float, ...]
    arrival_epochs: tuple[int, ...]
    admit_epochs: tuple[int, ...]       # when each request entered the chip
    macs: int
    #: :class:`repro.obs.timeline.ChipTelemetry` when the run was made with
    #: ``telemetry=TelemetryConfig(enabled=True)``; excluded from equality
    #: (reports with and without telemetry compare by the numbers above)
    telemetry: object | None = dataclasses.field(default=None, compare=False)

    @property
    def attribution(self):
        """Per-core stall attribution (None without telemetry)."""
        return self.telemetry.attribution if self.telemetry else None

    def latency_percentile(self, q: float) -> float:
        """Linear-interpolated percentile of the request latencies."""
        if not self.latencies:
            return 0.0
        return float(np.percentile(self.latencies, q))

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / len(self.latencies) \
            if self.latencies else 0.0

    @property
    def throughput_macs_per_cycle(self) -> float:
        return self.macs / self.makespan if self.makespan else 0.0


class _Batcher:
    """One admission-policy run over an arrival trace (driver state)."""

    def __init__(self, requests: Sequence[ServeRequest], chip: ChipConfig,
                 policy: str, batch_size: int, min_share: float,
                 snap_stride: int, lookahead: int = 1,
                 prefix_cache: bool = True,
                 telemetry: TelemetryConfig = OFF):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"available: {POLICIES}")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if lookahead < 0:
            raise ValueError("lookahead must be >= 0")
        self.chip = chip
        self.policy = policy
        self.batch_size = batch_size
        self.min_share = min_share
        self.lookahead = lookahead
        self.telemetry = telemetry
        self.submitted = list(requests)     # caller order, for the report
        self.requests = sorted(requests, key=lambda r: r.arrival_epoch)
        self.sim = OnlineChip(chip, snap_stride=snap_stride,
                              prefix_cache=prefix_cache,
                              telemetry=telemetry)
        self.waiting: deque[ServeRequest] = deque()
        self.next_arrival = 0               # index into self.requests
        self.segments: dict[str, object] = {}
        self.admit_epochs: dict[str, int] = {}
        self._rr = 0                        # fixed policy's blind pointer

    # -- admission ---------------------------------------------------------
    def _headroom(self) -> int:
        """How many more requests fit before the projected per-request
        share drops below ``min_share`` (conservative: counts currently
        active segments plus the admissions of this decision epoch)."""
        if self.min_share <= 0:
            return len(self.waiting)
        n_act = self.sim.n_active()
        budget = self.chip.bw_bytes_per_cycle
        k = 0
        while (k < len(self.waiting)
               and budget / (n_act + k + 1) >= self.min_share):
            k += 1
        return k

    def _admit(self) -> list[tuple[ServeRequest, int]]:
        """The policy's admissions for the current epoch: (request, core)."""
        sim, waiting = self.sim, self.waiting
        n_cores = self.chip.n_cores
        if self.policy == "fixed":
            out = []
            drained = self.next_arrival >= len(self.requests)
            while (len(waiting) >= self.batch_size
                   or (drained and waiting)):
                for _ in range(min(self.batch_size, len(waiting))):
                    out.append((waiting.popleft(), self._rr % n_cores))
                    self._rr += 1
            return out
        take = min(len(waiting), self._headroom())
        if self.policy == "occupancy":
            free_cores = [c for c, busy in enumerate(sim.core_busy())
                          if not busy]
            take = min(take, len(free_cores))
            return [(waiting.popleft(), free_cores[i]) for i in range(take)]
        if self.policy == "predicted":
            # forecast from the settled schedule: a core whose settled
            # work + queued backlog drains within the lookahead window is
            # available -- its admitted request starts at the exact
            # boundary it frees up, one decision epoch earlier than the
            # reactive occupancy policy can manage
            horizon = (sim.epoch + self.lookahead) * self.chip.epoch_cycles
            free_at = sim.free_at_estimate()
            soon = sorted((c for c in range(n_cores)
                           if free_at[c] <= horizon),
                          key=lambda c: free_at[c])
            take = min(take, len(soon))
            return [(waiting.popleft(), soon[i]) for i in range(take)]
        # bandwidth: headroom-gated, placed on the soonest-free core
        reqs = [waiting.popleft() for _ in range(take)]
        return self._soonest_free(reqs)

    def _soonest_free(self, reqs: Sequence[ServeRequest]
                      ) -> list[tuple[ServeRequest, int]]:
        # one freshly-built list per request: items are distinct objects by
        # construction, so identity maps them back to their request even
        # when two requests have equal GEMM shapes
        items = [list(r.specs) for r in reqs]
        by_item = {id(item): r for item, r in zip(items, reqs)}
        placement = assign_incremental(items, self.chip,
                                       self.sim.free_at_estimate())
        out = []
        for core, placed in enumerate(placement):
            for item in placed:
                out.append((by_item[id(item)], core))
        return out

    # -- driver ------------------------------------------------------------
    def run(self) -> BatchReport:
        sim = self.sim
        if self.requests:
            t = self.requests[0].arrival_epoch
            while self.next_arrival < len(self.requests) or self.waiting:
                sim.advance_to(t)
                while (self.next_arrival < len(self.requests)
                       and self.requests[self.next_arrival].arrival_epoch
                       <= t):
                    self.waiting.append(self.requests[self.next_arrival])
                    self.next_arrival += 1
                admitted = self._admit()
                if (not admitted and self.waiting
                        and self.policy != "fixed"
                        and not any(sim.core_busy())):
                    # work conservation: a threshold policy must not
                    # starve a waiting request on an idle chip.  The
                    # fixed policy is exempt -- waiting for a full group
                    # is its defining (and deadlock-free) behavior.
                    admitted = self._soonest_free([self.waiting.popleft()])
                segs = sim.submit_batch([(core, req.specs)
                                         for req, core in admitted])
                for (req, _), seg in zip(admitted, segs):
                    self.segments[req.name] = seg
                    self.admit_epochs[req.name] = t
                cands = []
                if self.next_arrival < len(self.requests):
                    cands.append(
                        self.requests[self.next_arrival].arrival_epoch)
                if self.waiting:
                    nxt = sim.next_event()
                    if nxt is not None:
                        cands.append(nxt)
                if not cands:
                    break
                t = min(cands)
            sim.drain()
        E = self.chip.epoch_cycles
        reqs = self.submitted
        finishes = [sim.finish_time(self.segments[r.name]) for r in reqs]
        latencies = [f - r.arrival_epoch * E
                     for f, r in zip(finishes, reqs)]
        first = min((r.arrival_epoch for r in reqs), default=0) * E
        tele = None
        if self.telemetry.enabled:
            from ..obs.timeline import build_online_telemetry
            names = {seg.sid: name                       # type: ignore[attr-defined]
                     for name, seg in self.segments.items()}
            marks = [(r.arrival_epoch * E, f"arrive {r.name}")
                     for r in reqs]
            marks += [(self.admit_epochs[r.name] * E, f"admit {r.name}")
                      for r in reqs]
            tele = build_online_telemetry(sim, self.telemetry, names=names,
                                          marks=marks)
        return BatchReport(
            policy=self.policy,
            design=self.chip.design_name,
            n_cores=self.chip.n_cores,
            n_requests=len(reqs),
            epoch_cycles=E,
            makespan=max(finishes, default=first) - first,
            names=tuple(r.name for r in reqs),
            latencies=tuple(latencies),
            finish_times=tuple(finishes),
            arrival_epochs=tuple(r.arrival_epoch for r in reqs),
            admit_epochs=tuple(self.admit_epochs[r.name] for r in reqs),
            macs=sum(r.macs for r in reqs),
            telemetry=tele,
        )


def run_batcher(requests: Sequence[ServeRequest],
                chip: ChipConfig | None = None, *,
                policy: str = "occupancy", batch_size: int = 4,
                min_share: float | None = None,
                snap_stride: int = SNAP_STRIDE,
                lookahead: int = 1,
                prefix_cache: bool = True,
                telemetry: TelemetryConfig = OFF,
                **chip_kwargs) -> BatchReport:
    """Serve an arrival trace through the online chip model.

    ``min_share`` (bytes/cycle) is the bandwidth-headroom floor of the
    threshold policies (``bandwidth``/``occupancy``/``predicted``); the
    default admits up to two concurrent requests per core before
    throttling admission.  ``lookahead`` (epochs) is the ``predicted``
    policy's departure-forecast window.  ``prefix_cache=False`` runs the
    online arbiter in its rebuild-from-epoch-0 baseline mode (identical
    results, linearly more work -- the ``benchmarks/online_scaling.py``
    comparison).  ``telemetry=TelemetryConfig(enabled=True)`` attaches a
    full :class:`repro.obs.timeline.ChipTelemetry` to the report (see
    ``docs/observability.md``).  Extra keyword arguments construct the
    :class:`ChipConfig` when none is given (cf.
    :func:`repro.multicore.simulate_chip`).
    """
    if chip is None:
        chip = ChipConfig(**chip_kwargs)
    elif chip_kwargs:
        raise TypeError(f"pass either a ChipConfig or config kwargs, not "
                        f"both: {sorted(chip_kwargs)}")
    if min_share is None:
        min_share = chip.bw_bytes_per_cycle / (2.0 * chip.n_cores)
    names = [r.name for r in requests]
    if len(set(names)) != len(names):
        raise ValueError("request names must be unique")
    return _Batcher(requests, chip, policy, batch_size, min_share,
                    snap_stride, lookahead, prefix_cache, telemetry).run()
