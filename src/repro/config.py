"""Config system: model / engine / parallelism / training, all dataclasses.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
the registry maps ``--arch <id>`` to (full config, reduced smoke config).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    #: auxiliary load-balancing loss weight (Switch-style)
    aux_loss_weight: float = 0.01
    #: independent dispatch groups (per-shard EP-style dispatch; keeps the
    #: sort/scatter batched over a DP-sharded dim -- see models/moe.py)
    dispatch_groups: int = 16


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone + one *shared* attention block applied
    every ``attn_every`` layers (same weights each application)."""
    attn_every: int = 6


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Which matrix engine executes model GEMMs (the paper's technique as a
    first-class feature)."""
    kind: str = "xla"              # "xla" | "pallas_rasa"
    schedule: str = "wls"          # RASA schedule for the Pallas engine
    block_m: int = 256
    block_k: int = 512
    block_n: int = 256
    #: flash-attention kernel for prefill when on TPU
    flash_attention: bool = False
    flash_block_q: int = 512
    flash_block_kv: int = 512
    #: XLA-path chunk sizes (memory/HLO-size trade; the roofline
    #: reduced-depth compiles set these to seq_len so cost_analysis counts
    #: every chunk -- scan bodies are counted once)
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 2048
    ce_chunk: int = 256
    #: unroll the SSD chunk scan (roofline d-compiles only)
    unroll_ssd: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0               # 0 for attention-free
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "swiglu"            # swiglu | geglu | relu2 | gelu
    qk_norm: bool = False
    rope: str = "standard"         # standard | mrope | none
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    #: normalization of attention logits for stability at depth
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    #: stub modality frontend: none | vision | audio (input_specs provides
    #: precomputed patch/frame embeddings -- see DESIGN.md §4)
    frontend: str = "none"
    #: audio: number of EnCodec codebooks (musicgen)
    n_codebooks: int = 1
    #: supports O(1)-state long-context decode (SSM/hybrid)
    subquadratic: bool = False
    #: fuse the gate+up projections into one GEMM (x read once, one weight
    #: load serves two outputs -- the WL-skip idea at model level; §Perf)
    fuse_gate_up: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        total = v * d                                  # embedding
        if not self.tie_embeddings:
            total += d * v * self.n_codebooks          # lm head(s)
        n_attn = self.n_layers
        if self.family == "ssm":
            n_attn = 0
        elif self.family == "hybrid":
            n_attn = 1                                 # one shared block
        # attention
        attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d) if self.n_heads else 0
        total += n_attn * attn
        # ffn / experts
        if self.moe:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert \
                + d * self.moe.n_experts
            total += self.n_layers * ff
        elif self.d_ff:
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            per_layer_ff = mult * d * self.d_ff
            n_ff = self.n_layers if self.family != "hybrid" else 1
            total += n_ff * per_layer_ff
        # ssm blocks
        if self.ssm is not None:
            di = self.ssm.expand * d
            h = di // self.ssm.head_dim
            g = self.ssm.n_groups
            per = (d * (2 * di + 2 * g * self.ssm.d_state + h)   # in_proj
                   + self.ssm.d_conv * (di + 2 * g * self.ssm.d_state)
                   + di * d                                      # out_proj
                   + 2 * h + di)                                 # A, D, norm
            total += self.n_layers * per
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        inactive = (self.moe.n_experts - self.moe.top_k) * 3 * d * self.moe.d_ff_expert
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    data: int = 16
    model: int = 16
    pods: int = 1
    #: FSDP: shard parameters (and optimizer state) over the data axes
    fsdp: bool = True
    #: sequence parallelism for long-context decode (shard KV cache on seq)
    sequence_parallel_decode: bool = False
    #: remat policy for the layer scan: "full" | "dots" | "none"
    remat: str = "full"
    #: scan over layers (True, production: O(1) HLO in depth) or unroll a
    #: python loop (False: used by the reduced-depth roofline compiles,
    #: where cost_analysis must count every layer)
    scan_layers: bool = True
    #: optimizer state dtype ("float32" | "bfloat16"); bf16 halves optimizer
    #: HBM for the largest configs (grok-1-314b)
    opt_state_dtype: str = "float32"
    #: parameter sharding at serving time: "fsdp" re-uses the training
    #: layout (per-step all-gathers), "tp" shards only over "model" --
    #: the right layout for inference (no optimizer state to co-shard);
    #: see EXPERIMENTS.md §Perf hillclimb (collective term)
    serve_param_sharding: str = "fsdp"

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return ("pod", "data") if self.pods > 1 else ("data",)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatches: int = 1
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    #: int8 error-feedback gradient compression over the DP axes
    grad_compression: bool = False
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    train: TrainConfig = TrainConfig()
    parallel: ParallelConfig = ParallelConfig()
    engine: EngineConfig = EngineConfig()


#: the four assigned input shapes (LM family): (seq_len, global_batch, kind)
SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}
