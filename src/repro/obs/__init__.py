"""Telemetry: per-core timelines, stall attribution, Perfetto export.

The subsystem is strictly *post-hoc*: nothing in here adds hooks to the
simulation loops.  Events are derived after the fact by replaying a
compiled trace against the exact stream-model parameters a run used
(:func:`repro.obs.record.replay_events`), so the fast backends pay zero
overhead when telemetry is off and the reference loop stays untouched.

Layers, bottom up:

- :mod:`repro.obs.config` -- the :class:`TelemetryConfig` opt-in knob.
- :mod:`repro.obs.record` -- per-instruction event replay (grant times,
  MM sub-stage windows) over a :class:`repro.core.trace.CompiledTrace`.
- :mod:`repro.obs.attribution` -- {compute, fill/drain, bandwidth-stall,
  queue-wait, idle} bucket decomposition with exact conservation.
- :mod:`repro.obs.timeline` -- chip-level assembly: one
  :class:`SegmentTimeline` per (core, segment) plus the share/occupancy
  traces, built from a finished closed-batch or online run.
- :mod:`repro.obs.perfetto` / :mod:`repro.obs.render` -- exporters:
  Chrome ``trace_event`` JSON (Perfetto-viewable) and a plain-text
  timeline for docs/tests.

See ``docs/observability.md`` for the event model and bucket definitions.
"""

from .attribution import (CoreAttribution, StallAttribution,
                          attribute_segments, simreport_attribution,
                          workload_compute_cycles)
from .config import OFF, TelemetryConfig
from .perfetto import to_trace_events, write_trace
from .record import StreamEvents, replay_events
from .render import render_timeline
from .timeline import (ChipTelemetry, SegmentTimeline, build_chip_telemetry,
                       build_online_telemetry)

__all__ = [
    "TelemetryConfig", "OFF",
    "StreamEvents", "replay_events",
    "CoreAttribution", "StallAttribution", "attribute_segments",
    "simreport_attribution", "workload_compute_cycles",
    "SegmentTimeline", "ChipTelemetry",
    "build_chip_telemetry", "build_online_telemetry",
    "to_trace_events", "write_trace", "render_timeline",
]
