"""Stall-cycle attribution: where did every core-cycle go?

Each core's share of the run window (``window`` cycles per core) is
decomposed into six disjoint buckets:

``compute``
    Cycles the systolic array was streaming useful feed rows: the sum of
    ``tm`` over the core's ``rasa_mm`` instructions.  FF windows of
    consecutive MMs never overlap (every design rule chains
    ``ff_start >= p_ff_end``), so this is a true cycle count.
``fill_drain``
    Pipeline overhead cycles: WL/FS/DR stages, load-latency and register
    dependency gaps -- everything a segment spends beyond compute that an
    *unthrottled* run would also spend.
``bw_stall``
    End-to-end cost of bandwidth contention: the segment's throttled
    makespan minus its unthrottled makespan (not the arbiter's raw grant
    delay, which the pipeline may absorb; see
    ``TimingResult.bw_stall_cycles``).
``fault_lost``
    Fault runs only: busy cycles whose progress a preemption discarded --
    the preempted instance's busy interval minus its kept prefix's
    compute credit (see :mod:`repro.multicore.faults`).  Zero on every
    fault-free run.
``queue_wait``
    Online runs only: cycles the core sat idle while work addressed to it
    was waiting in its queue (submitted but not yet started).
``idle``
    The remainder -- the core had nothing to do.

Conservation is exact by construction (``idle`` is the residual) and
non-negativity of ``fill_drain`` is guaranteed: a segment's busy cycles
minus its bandwidth stall equals its unthrottled makespan, which is at
least its total FF feed time (a preempted instance charges everything
past its compute credit to ``fault_lost`` instead).  ``tests/test_obs.py``
asserts both on all backends.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from ..core.tiling import GemmSpec, RegPolicy
from ..core.trace import OP_MM, compiled_trace


@dataclasses.dataclass(frozen=True)
class CoreAttribution:
    """One core's bucket decomposition; every field is engine cycles."""

    core: int
    compute: float
    fill_drain: float
    bw_stall: float
    queue_wait: float
    idle: float
    #: busy cycles discarded by fault preemption (0 on fault-free runs;
    #: defaulted last so fault-free construction sites stay unchanged)
    fault_lost: float = 0.0

    @property
    def busy(self) -> float:
        return (self.compute + self.fill_drain + self.bw_stall
                + self.fault_lost)

    @property
    def total(self) -> float:
        return self.busy + self.queue_wait + self.idle


#: bucket names in table/export order
BUCKETS = ("compute", "fill_drain", "bw_stall", "fault_lost",
           "queue_wait", "idle")


@dataclasses.dataclass(frozen=True)
class StallAttribution:
    """Chip-level rollup: per-core buckets over a shared window."""

    window: float
    cores: tuple[CoreAttribution, ...]

    def total(self, bucket: str) -> float:
        return sum(getattr(c, bucket) for c in self.cores)

    @property
    def occupied_cycles(self) -> float:
        """window x cores -- what the buckets must sum to."""
        return self.window * len(self.cores)

    def fractions(self) -> dict[str, float]:
        occ = self.occupied_cycles
        if occ <= 0:
            return {b: 0.0 for b in BUCKETS}
        return {b: self.total(b) / occ for b in BUCKETS}

    def table(self) -> str:
        """Plain-text summary table (one row per core + a chip total).

        The ``fault_lost`` column appears only when some core has a
        nonzero entry, keeping fault-free output byte-identical to the
        five-bucket format."""
        buckets = list(BUCKETS)
        if not any(c.fault_lost for c in self.cores):
            buckets.remove("fault_lost")
        labels = {"fill_drain": "fill/drain", "bw_stall": "bw-stall",
                  "queue_wait": "queue-wait", "fault_lost": "fault-lost"}
        head = f"{'core':>6} " + " ".join(
            f"{labels.get(b, b):>12}" for b in buckets)
        lines = [head, "-" * len(head)]
        for c in self.cores:
            lines.append(f"{c.core:>6} " + " ".join(
                f"{getattr(c, b):>12.0f}" for b in buckets))
        fr = self.fractions()
        lines.append(f"{'chip':>6} " + " ".join(
            f"{100 * fr[b]:>11.1f}%" for b in buckets))
        return "\n".join(lines)


def workload_compute_cycles(specs: Sequence[GemmSpec],
                            policy: RegPolicy) -> float:
    """Sum of FF feed cycles (``tm``) of the lowered workload."""
    tr = compiled_trace(tuple(specs), policy)
    return float(tr.tm[tr.opcode == OP_MM].sum())


def _merge(intervals: Iterable[tuple[float, float]]
           ) -> list[tuple[float, float]]:
    out: list[tuple[float, float]] = []
    for s, e in sorted(i for i in intervals if i[1] > i[0]):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _measure_minus(wait: list[tuple[float, float]],
                   busy: list[tuple[float, float]]) -> float:
    """Total length of (union of wait) minus (union of busy)."""
    total = 0.0
    busy = _merge(busy)
    for s, e in _merge(wait):
        cut = s
        for bs, be in busy:
            if be <= cut:
                continue
            if bs >= e:
                break
            if bs > cut:
                total += bs - cut
            cut = max(cut, be)
            if cut >= e:
                break
        if cut < e:
            total += e - cut
    return total


def attribute_segments(
        n_cores: int, window: float,
        segments: Sequence[tuple],
) -> StallAttribution:
    """Fold per-segment facts into per-core buckets.

    ``segments`` rows are ``(core, submit, start, finish, compute,
    bw_stall)`` with an optional seventh ``fault_lost`` element -- times
    on the shared chip clock, ``compute``/``bw_stall``/``fault_lost`` in
    cycles.  ``queue_wait`` is the measure of the union of each core's
    ``[submit, start)`` intervals minus its busy intervals, so overlapping
    waiters are not double counted and waiting behind a running segment
    counts as that segment's busy time, not queue-wait.
    """
    per: list[list[tuple]] = [[] for _ in range(n_cores)]
    for row in segments:
        per[row[0]].append(row)
    cores = []
    for core in range(n_cores):
        rows = per[core]
        busy = sum(r[3] - r[2] for r in rows)
        compute = sum(r[4] for r in rows)
        bw = sum(r[5] for r in rows)
        lost = sum(r[6] for r in rows if len(r) > 6)
        fill_drain = busy - compute - bw - lost
        busy_iv = [(r[2], r[3]) for r in rows]
        wait_iv = [(r[1], min(r[2], window)) for r in rows]
        queue_wait = _measure_minus(wait_iv, busy_iv)
        idle = window - busy - queue_wait
        cores.append(CoreAttribution(core, compute, fill_drain, bw,
                                     queue_wait, idle, fault_lost=lost))
    return StallAttribution(window=window, cores=tuple(cores))


def simreport_attribution(specs: Sequence[GemmSpec], policy: RegPolicy,
                          cycles: float, bw_stall: float = 0.0
                          ) -> StallAttribution:
    """Single-engine decomposition of one simulated workload.

    The window is the run's own makespan, so ``idle`` is zero and the
    split is {compute, fill_drain, bw_stall} -- the form the design-search
    harness prints per candidate.
    """
    compute = workload_compute_cycles(specs, policy)
    return StallAttribution(
        window=cycles,
        cores=(CoreAttribution(0, compute, cycles - compute - bw_stall,
                               bw_stall, 0.0, 0.0),))
